"""Shared benchmark plumbing: run tuner comparisons under the paper's
protocols and emit CSV rows.

Protocol notes (faithful to Sec. 5):
  * cost oracle = AnalyticalTPUCost with measurement noise (sigma=0.1)
    and n_repeats like the paper's "mean of 10 repeated trials"
    (n_repeats=3 here to keep CPU benchmark time sane; configurable);
  * per-trial search clock charges a TVM-like codegen+launch overhead
    (0.35 s) plus the measured kernel time — Fig. 7b's x-axis;
  * G-BFS rho=5, N-A2C T=3, s0 = untiled (paper Sec. 5).
"""

from __future__ import annotations

import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import AnalyticalTPUCost, Budget, GemmConfigSpace, MeasureEngine, workload_key
from repro.core.tuners import TUNERS

PAPER_TUNERS = ["g-bfs", "n-a2c", "xgboost-like", "rnn-controller"]
EXTRA_TUNERS = ["random", "genetic", "sim-anneal"]

TUNER_KW = {
    "g-bfs": {"rho": 5},
    "n-a2c": {"steps_per_episode": 3},
}


def make_cost(space: GemmConfigSpace, seed: int = 0, noise: float = 0.1,
              repeats: int = 3) -> AnalyticalTPUCost:
    return AnalyticalTPUCost(space, n_repeats=repeats, noise_sigma=noise, seed=seed)


def make_xla_cost(space: GemmConfigSpace, seed: int = 0, repeats: int = 2,
                  n_build_workers: int = 4, cache_dir=None):
    """Real timed XLA:CPU oracle with the persistent compiled-program
    cache — ``n_build_workers`` compiles candidate batches in parallel,
    ``cache_dir`` lets re-runs/workers skip compilation entirely."""
    from repro.core.cost.measured import XLATimedCost

    return XLATimedCost(space, n_repeats=repeats, seed=seed,
                        n_build_workers=n_build_workers, cache_dir=cache_dir)


def add_measure_args(ap) -> None:
    """The measurement-engine CLI block shared by the benchmark mains:
    lane count/executor (PR 2) plus compile parallelism and the
    persistent compiled-program cache directory (measured backends)."""
    from repro.core.executor import EXECUTORS

    ap.add_argument("--workers", type=int, default=1,
                    help="parallel measurement lanes per engine")
    ap.add_argument("--executor", default=None, choices=sorted(EXECUTORS),
                    help="how lanes run: simulated clock, threads, or "
                         "crash-isolated worker processes")
    ap.add_argument("--n-build-workers", type=int, default=4,
                    help="parallel XLA compile threads (measured backends)")
    ap.add_argument("--compile-cache-dir", default=None,
                    help="persistent compiled-program cache directory "
                         "(measured backends)")


def true_cost(space: GemmConfigSpace, state) -> float:
    """Noise-free cost of a configuration (for fair final scoring)."""
    return AnalyticalTPUCost(space, n_repeats=1, noise_sigma=0.0).cost(state)


def run_tuner(space, tuner_name: str, budget: Budget, seed: int = 0,
              noise: float = 0.1, n_workers: int = 1, journal=None,
              executor=None, analyze: str = "off", stats=None,
              learned_filter=None):
    """One tuning run under the paper protocol.  ``n_workers`` spreads
    each proposed candidate batch over parallel engine lanes (the trial
    sequence is unchanged; only the clock compresses); ``journal`` plugs
    in a persistent trial cache.  ``executor`` (a LaneExecutor or a
    ``sim``/``thread``/``process`` name) picks how lanes run — with a
    real executor the clock is *measured* lane wall time, so reported
    speedups are wall-clock parallelism, not simulated compression.
    ``analyze`` turns on the engine's static pre-filter (``warn`` or
    ``prune``, see ``repro.core.analysis``); ``stats`` plugs in a shared
    :class:`MeasureStats` so callers can read ``trials_avoided``;
    ``learned_filter`` plugs a :class:`repro.core.learn.ProposalFilter`
    into the engine (score each wave, really measure only the predicted
    best).  With everything at defaults the engine-free path is
    bit-identical to the historical protocol."""
    from repro.core.executor import make_executor

    cost = make_cost(space, seed=seed, noise=noise)
    owns_executor = isinstance(executor, str)
    if owns_executor:
        executor = make_executor(executor)
    engine = None
    if (journal is not None or n_workers > 1 or executor is not None
            or analyze != "off" or stats is not None
            or learned_filter is not None):
        engine = MeasureEngine(
            cost,
            n_workers=n_workers,
            journal=journal,
            workload_key=workload_key(space.m, space.k, space.n, "bfloat16", cost.name),
            executor=executor,
            analyze=analyze,
            stats=stats,
            learned_filter=learned_filter,
        )
    tuner = TUNERS[tuner_name](space, cost, seed=seed, **TUNER_KW.get(tuner_name, {}))
    try:
        if engine is not None:
            res = tuner.tune(budget, engine=engine)  # engine owns the clock model
        else:
            res = tuner.tune(budget, overhead_s=0.35, n_workers=n_workers)
    finally:
        if owns_executor:
            executor.close()
    final = (
        true_cost(space, res.best_state) if res.best_state is not None else math.inf
    )
    return res, final


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")

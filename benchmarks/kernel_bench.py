"""Kernel-level benchmark: tuned-vs-default GEMM cost under the
analytical TPU model, plus a real XLA:CPU wall-time comparison on a
small shape (an honest on-this-machine measurement)."""

from __future__ import annotations


from repro.core import AnalyticalTPUCost, Budget, GemmConfigSpace
from repro.core.config_space import TilingState
from repro.core.tuners import GBFSTuner


def model_costs() -> None:
    for size in (512, 1024, 2048, 4096):
        space = GemmConfigSpace(size, size, size)
        cost = AnalyticalTPUCost(space)
        s0 = space.initial_state()
        res = GBFSTuner(space, cost, seed=0).tune(Budget(max_fraction=0.001))
        c0 = cost.cost(s0)
        heur = _heuristic_state(space)
        ch = cost.cost(heur)
        print(
            f"kernel_model,{size},untiled_us={c0*1e6:.2f},"
            f"heuristic_us={ch*1e6:.2f},tuned_us={res.best_cost*1e6:.2f},"
            f"tuned_vs_heuristic={ch/res.best_cost:.2f}x"
        )


def _heuristic_state(space) -> TilingState:
    """The ops.default_config heuristic expressed as a tuner state."""
    m, k, n = space.m, space.k, space.n
    bm, bk, bn = min(m, 256), min(k, 512), min(n, 256)
    return TilingState(
        (m // bm, 1, bm // min(bm, 8), min(bm, 8)),
        (k // bk, bk),
        (n // bn, 1, bn // min(bn, 128), min(bn, 128)),
    )


def xla_walltime() -> None:
    """Real timing: tuned blocked matmul vs naive on XLA:CPU (256^3)."""
    from repro.core.cost.measured import XLATimedCost

    space = GemmConfigSpace(256, 256, 256)
    cost = XLATimedCost(space, n_repeats=3)
    res = GBFSTuner(space, cost, seed=0).tune(Budget(max_trials=25))
    c0 = cost.cost(space.initial_state())
    print(
        f"kernel_xla_cpu,256,untiled_us={c0*1e6:.1f},"
        f"tuned_us={res.best_cost*1e6:.1f},speedup={c0/res.best_cost:.2f}x,"
        f"trials={res.n_trials}"
    )


def main(quick: bool = False):
    model_costs()
    if not quick:
        xla_walltime()


if __name__ == "__main__":
    main()

"""Serving benchmark — the tune→serve loop as numbers
(``BENCH_serve.json``).

MaxText-style serving protocol over :class:`repro.launch.serve.ServeEngine`,
timing **prefill** and **autoregressive decode** separately:

  * **tune** — measure a handful of flash-attention schedules for the
    bench's prompt shape with :class:`PallasInterpretCost` (the actual
    Pallas kernel, interpret mode) and write the best into
    :class:`TuningRecords` — the same records file `launch/tune.py`
    produces;
  * **heuristic engine** — no records: ``attention_dispatch`` falls back
    to its built-in blocks.  Timed generate calls give tok/s and
    per-stage latency;
  * **tuned engine** — records installed: the trace picks up the tuned
    ``(block_q, block_kv)`` (asserted via the trace-time dispatch
    counters in the payload) and must serve at least as fast;
  * **warm restart** — a second engine over the same persistent
    executable cache directory must report **zero fresh compiles**
    (``warm_restart.zero_fresh_compiles``) — the AOT pre-warm replays
    prior work from disk.  Note its dispatch counters stay zero too:
    nothing is re-traced;
  * **stream** — an open-loop synthetic request stream (varied prompt
    lengths, exponential inter-arrivals) replayed through bucketed
    continuous batching; reports tokens/sec plus p50/p95/p99 latency per
    stage and per request.  This phase runs the default (pure-XLA)
    policy, so its tok/s is the stable metric the ``--diff`` regression
    gate tracks (kernel-interpret timings are too host-sensitive to
    gate on).

Usage::

  python -m benchmarks.serve_bench --quick     # CI smoke + artifact
  python -m benchmarks.run --only serve        # via the harness
"""

from __future__ import annotations

import json
import math
import os
import shutil
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.registry import get_arch
from repro.core.flash_space import FlashAttnConfigSpace
from repro.core.records import (
    TuningRecords,
    set_global_records,
    workload_key_for,
)
from repro.kernels.ops import (
    KernelPolicy,
    dispatch_stats,
    reset_dispatch_stats,
    set_kernel_policy,
)
from repro.launch.serve import ServeEngine
from repro.models.api import Model

#: records namespace for this bench — costs come from the interpret-mode
#: Pallas kernel, so label them as such (dispatch consults the namespace
#: named by KernelPolicy.cost_backend)
BACKEND = "pallas_interpret_timed"


def _percentiles(xs) -> dict:
    a = np.asarray(xs, float)
    return {
        "p50": round(float(np.percentile(a, 50)), 5),
        "p95": round(float(np.percentile(a, 95)), 5),
        "p99": round(float(np.percentile(a, 99)), 5),
    }


def _tune_flash(space: FlashAttnConfigSpace, records: TuningRecords,
                n_candidates: int, repeats: int, cache_dir: str) -> dict:
    """Measure ``n_candidates`` schedules with the real (interpret-mode)
    kernel and keep-best into ``records`` under this bench's namespace."""
    from repro.core.cost.measured import PallasInterpretCost

    cost = PallasInterpretCost(
        space, n_repeats=repeats, cache_dir=cache_dir
    )
    cands = [s for s in space.enumerate() if space.is_legitimate(s)]
    # deterministic spread across the enumeration order
    if len(cands) > n_candidates:
        step = len(cands) / n_candidates
        cands = [cands[int(i * step)] for i in range(n_candidates)]
    best_s, best_c = None, math.inf
    for s in cands:
        c = cost.cost(s)
        if c < best_c:
            best_s, best_c = s, c
    key = workload_key_for("flash", space.dims, "float32", BACKEND)
    records.update(key, best_s, best_c, tuner="serve-bench-sweep",
                   n_trials=len(cands))
    return {
        "op": "flash",
        "dims": list(space.dims),
        "n_candidates": len(cands),
        "best_blocks": [best_s.block_q, best_s.block_kv],
        "best_cost_s": round(best_c, 5),
        **{f"cache_{k}": v for k, v in cost.compile_stats().items()},
    }


def _timed_engine(engine: ServeEngine, prompts: np.ndarray, gen: int,
                  repeats: int) -> dict:
    """Warm up once, then ``repeats`` timed generates; medians of the
    per-stage stage timings (prefill is where tuned flash blocks act —
    decode re-attends a single query row and is schedule-independent)."""
    b, p = prompts.shape
    engine.generate(prompts, gen)  # warmup: executables + buffers live
    pre, dec = [], []
    for _ in range(repeats):
        engine.generate(prompts, gen)
        pre.append(engine.last_timing["prefill_s"])
        dec.append(engine.last_timing["decode_s"])
    pre_s, dec_s = float(np.median(pre)), float(np.median(dec))
    return {
        "prefill_s": round(pre_s, 5),
        "decode_s": round(dec_s, 5),
        "prefill_tok_s": round(b * p / pre_s, 2),
        "decode_tok_s": round(b * gen / dec_s, 2),
        "tok_s": round(b * (p + gen) / (pre_s + dec_s), 2),
    }


def _stream_requests(n: int, rate_rps: float, len_lo: int, len_hi: int,
                     seed: int) -> list[tuple[float, int]]:
    """Open-loop arrivals: (arrival_time_s, prompt_len) with exponential
    inter-arrivals at ``rate_rps`` and uniform prompt lengths."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, n)
    t = np.cumsum(gaps)
    lens = rng.integers(len_lo, len_hi + 1, n)
    return list(zip(t.tolist(), lens.tolist()))


def _replay_stream(engine: ServeEngine, arrivals, gen: int) -> dict:
    """Discrete-event replay of continuous batching at batch
    granularity: requests are served in arrival order, greedily batched
    while they map to the same prompt bucket (ragged rows ride along
    via ``prompt_lens``); service times are the engine's measured
    wall-clock stage timings."""
    from repro.launch.serve import _bucket_for

    i, sim_t = 0, 0.0
    pre_lat, dec_lat, req_lat = [], [], []
    n_batches = 0
    while i < len(arrivals):
        t0, l0 = arrivals[i]
        bucket = _bucket_for(l0, engine.prompt_buckets)
        batch = [arrivals[i]]
        i += 1
        while (
            i < len(arrivals)
            and len(batch) < engine.max_batch
            and _bucket_for(arrivals[i][1], engine.prompt_buckets) == bucket
        ):
            batch.append(arrivals[i])
            i += 1
        sim_t = max(sim_t, batch[-1][0])  # open loop: wait for arrivals
        lens = np.array([l for _, l in batch], np.int32)
        prompts = np.zeros((len(batch), int(lens.max())), np.int32)
        for r, (_, ln) in enumerate(batch):
            prompts[r, :ln] = (np.arange(ln) * 7 + r) % engine.cfg.vocab_size
        engine.generate(prompts, gen, prompt_lens=lens)
        pre_s = engine.last_timing["prefill_s"]
        dec_s = engine.last_timing["decode_s"]
        sim_t += pre_s + dec_s
        n_batches += 1
        pre_lat.append(pre_s)
        dec_lat.append(dec_s)
        req_lat.extend(sim_t - t for t, _ in batch)
    span = sim_t - arrivals[0][0]
    service_s = sum(pre_lat) + sum(dec_lat)
    total_tokens = len(arrivals) * gen
    return {
        "n_requests": len(arrivals),
        "n_batches": n_batches,
        # open-loop delivered rate (arrival-gap dominated at low rates)
        "tok_s": round(total_tokens / span, 2),
        # saturated engine throughput: tokens per second of *service*
        # time — the stable metric the --diff regression gate tracks
        "service_tok_s": round(total_tokens / service_s, 2),
        "latency_s": {
            "prefill": _percentiles(pre_lat),
            "decode": _percentiles(dec_lat),
            "request": _percentiles(req_lat),
        },
        "bucket_misses": engine.stats["bucket_misses"],
    }


def main(
    quick: bool = False,
    out: str = "BENCH_serve.json",
    arch: str = "yi-6b",
    seed: int = 0,
    cache_root: str | None = None,
) -> dict:
    import jax

    seq = 256 if quick else 512          # > reduced attn_chunk_threshold (64)
    gen = 4 if quick else 8
    batch = 2
    repeats = 2 if quick else 3
    n_candidates = 4 if quick else 8
    n_stream = 12 if quick else 32

    cfg = get_arch(arch).reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    hd = cfg.resolved_head_dim

    own_root = cache_root is None
    root = cache_root or tempfile.mkdtemp(prefix="serve-bench-")
    d_tune = os.path.join(root, "tune")
    d_heur = os.path.join(root, "engine-heur")
    d_tuned = os.path.join(root, "engine-tuned")
    d_stream = os.path.join(root, "engine-stream")

    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size, (batch, seq)).astype(np.int32)
    max_len = seq + gen

    result: dict = {
        "bench": "serve",
        "quick": quick,
        "arch": arch,
        "shape": {"batch": batch, "seq": seq, "gen": gen, "head_dim": hd},
        "host": {"cpus": os.cpu_count(), "jax": jax.__version__},
    }
    saved_policy = KernelPolicy()
    try:
        # ---- tune: measure flash schedules, keep-best into records ---------
        records = TuningRecords(os.path.join(root, "records.json"))
        space = FlashAttnConfigSpace(seq, seq, hd)
        result["tune"] = _tune_flash(
            space, records, n_candidates, repeats, d_tune
        )

        # flash-only Pallas policy: the bench isolates attention dispatch
        # (projection GEMMs stay on XLA either way)
        pol = KernelPolicy(
            use_pallas=True, interpret=True,
            cost_backend=BACKEND, pallas_ops=("flash",),
        )

        # ---- heuristic engine: no records ----------------------------------
        set_global_records(TuningRecords())
        set_kernel_policy(pol)
        reset_dispatch_stats()
        heur = ServeEngine(
            cfg, params, max_batch=batch, max_len=max_len,
            prompt_buckets=[seq], gen_buckets=[gen], cache_dir=d_heur,
        )
        heur_block = _timed_engine(heur, prompts, gen, repeats)
        heur_block["dispatch"] = dispatch_stats().get("flash", {})
        heur_block["cache"] = heur.cache_report()
        result.setdefault("engines", {})["heuristic"] = heur_block

        # ---- tuned engine: records drive the traced blocks -----------------
        set_global_records(records)
        set_kernel_policy(pol)  # also drops the dispatch memo
        reset_dispatch_stats()
        tuned = ServeEngine(
            cfg, params, max_batch=batch, max_len=max_len,
            prompt_buckets=[seq], gen_buckets=[gen], cache_dir=d_tuned,
        )
        tuned_block = _timed_engine(tuned, prompts, gen, repeats)
        tuned_block["dispatch"] = dispatch_stats().get("flash", {})
        tuned_block["cache"] = tuned.cache_report()
        result["engines"]["tuned"] = tuned_block
        result["tuned_record_dispatched"] = (
            tuned_block["dispatch"].get("records", 0) > 0
        )
        result["tuned_ge_heuristic_tok_s"] = (
            tuned_block["tok_s"] >= heur_block["tok_s"]
        )

        # ---- warm restart: same cache dir, zero fresh compiles -------------
        warm = ServeEngine(
            cfg, params, max_batch=batch, max_len=max_len,
            prompt_buckets=[seq], gen_buckets=[gen], cache_dir=d_tuned,
        )
        warm.generate(prompts, gen)
        wrep = warm.cache_report()
        result["warm_restart"] = {
            **wrep,
            "zero_fresh_compiles": wrep["compiles"] == 0,
        }

        # ---- open-loop stream under the default (pure-XLA) policy ----------
        set_kernel_policy(KernelPolicy())
        set_global_records(TuningRecords())
        stream_buckets = [16, 32, 64]
        stream = ServeEngine(
            cfg, params, max_batch=4, max_len=64 + gen,
            prompt_buckets=stream_buckets, gen_buckets=[gen],
            cache_dir=d_stream,
        )
        arrivals = _stream_requests(
            n_stream, rate_rps=4.0, len_lo=4, len_hi=64, seed=seed
        )
        # replay 1: latency percentiles (includes first-touch buffer
        # warmup, like a freshly restarted server); replays 2-4: median
        # service throughput over warm executables for the --diff gate
        result["stream"] = _replay_stream(stream, arrivals, gen)
        warm_tps = [
            _replay_stream(stream, arrivals, gen)["service_tok_s"]
            for _ in range(3)
        ]
        result["stream"]["service_tok_s"] = float(np.median(warm_tps))
        result["stream"]["buckets"] = stream_buckets
        result["stream"]["cache"] = stream.cache_report()
    finally:
        set_kernel_policy(saved_policy)
        set_global_records(TuningRecords())
        if own_root:
            shutil.rmtree(root, ignore_errors=True)

    with open(out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
    print(f"serve,tuned_blocks,{result['tune']['best_blocks']}")
    print(f"serve,heuristic_tok_s,{result['engines']['heuristic']['tok_s']}")
    print(f"serve,tuned_tok_s,{result['engines']['tuned']['tok_s']}")
    print(f"serve,tuned_record_dispatched,{result['tuned_record_dispatched']}")
    print(f"serve,warm_restart_compiles,{result['warm_restart']['compiles']}")
    print(f"serve,stream_tok_s,{result['stream']['tok_s']}")
    print(f"serve,stream_service_tok_s,{result['stream']['service_tok_s']}")
    print(f"serve,artifact,{out}")
    if not result["tuned_ge_heuristic_tok_s"]:
        print(
            "serve,WARNING,tuned engine slower than heuristic "
            f"({result['engines']['tuned']['tok_s']} < "
            f"{result['engines']['heuristic']['tok_s']} tok/s)",
            file=sys.stderr,
        )
    if not result["warm_restart"]["zero_fresh_compiles"]:
        print(
            "serve,WARNING,warm restart recompiled "
            f"{result['warm_restart']['compiles']} executables",
            file=sys.stderr,
        )
    return result


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced protocol")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache-root", default=None,
                    help="persist executable caches here (default: tmp)")
    a = ap.parse_args()
    main(quick=a.quick, out=a.out, arch=a.arch, seed=a.seed,
         cache_root=a.cache_root)

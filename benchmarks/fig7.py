"""Paper Fig. 7 — (1024,1024,1024) GEMM tuning.

7a: best discovered cost vs fraction of configuration space explored.
7b: best discovered cost vs (simulated) search wall time.

Output: CSV rows ``fig7a,<tuner>,<fraction>,<best_us>,<mean_us>`` and
``fig7b,<tuner>,<clock_s>,<true_us>,<best_us>``, plus one
``fig7engine,<tuner>,workers=<n>,cache_hit=<rate>,clock_s=<s>`` row per
tuner so clock speedups are attributable to engine lanes / cache hits;
the summary compares every tuner at the paper's 0.1%-explored operating
point.

``--workers N`` measures each tuner's candidate batches on N parallel
engine lanes: the trial sequence (and hence best cost) is identical to
serial, but the search clock pays each batch's critical path instead of
its sum — the batched-measurement win of the TVM line of work.

``--executor {sim,thread,process}`` picks how those lanes run.  With
``sim`` (default) the clock is *simulated* compression — the historical
bit-identical numbers.  With ``thread``/``process`` the lanes genuinely
run concurrently and the clock is measured lane wall time, so the
``fig7engine`` rows (which carry ``executor=…``) let readers separate
simulated-clock compression from real wall-clock parallelism.
"""

from __future__ import annotations


from repro.core import Budget, GemmConfigSpace
from repro.core.measure import MeasureStats

from .common import PAPER_TUNERS, EXTRA_TUNERS, run_tuner, true_cost


def main(seeds: int = 3, fractions=(0.0002, 0.0005, 0.001), quick: bool = False,
         n_workers: int = 1, executor: str | None = None,
         analyze: str = "off") -> dict:
    space = GemmConfigSpace(1024, 1024, 1024)
    tuners = PAPER_TUNERS + EXTRA_TUNERS
    if quick:
        tuners, seeds = PAPER_TUNERS, 1
    results: dict[str, dict] = {t: {} for t in tuners}
    for tuner in tuners:
        for frac in fractions:
            finals = []
            for seed in range(seeds):
                res, final = run_tuner(
                    space, tuner, Budget(max_fraction=frac), seed=seed,
                    n_workers=n_workers, executor=executor, analyze=analyze,
                )
                finals.append(final)
            best = min(finals)
            mean = sum(finals) / len(finals)
            results[tuner][frac] = (best, mean)
            print(f"fig7a,{tuner},{frac},{best*1e6:.3f},{mean*1e6:.3f}", flush=True)
        # time curve at the largest budget (one seed, the paper's style)
        stats = MeasureStats() if analyze != "off" else None
        res, _ = run_tuner(
            space, tuner, Budget(max_fraction=fractions[-1]), seed=0,
            n_workers=n_workers, executor=executor, analyze=analyze,
            stats=stats,
        )
        for t_s, c in res.best_time_curve()[:: max(1, res.n_trials // 20)]:
            print(f"fig7b,{tuner},{t_s:.1f},{true_cost(space, res.best_state)*1e6:.3f},{c*1e6:.3f}")
        avoided = f",trials_avoided={stats.trials_avoided}" if stats else ""
        print(
            f"fig7engine,{tuner},workers={res.n_workers},"
            f"executor={res.executor},"
            f"cache_hit={res.cache_hit_rate:.3f},clock_s={res.clock_s:.1f}"
            f"{avoided}",
            flush=True,
        )
    # headline: savings vs xgboost/rnn at 0.1% (paper: 24% / 40%)
    f = fractions[-1]
    if "xgboost-like" in results and "g-bfs" in results:
        g = results["g-bfs"][f][1]
        x = results["xgboost-like"][f][1]
        print(f"headline,gbfs_vs_xgboost_saving,{100*(1-g/x):.1f}%")
    if "rnn-controller" in results and "g-bfs" in results:
        r = results["rnn-controller"][f][1]
        g = results["g-bfs"][f][1]
        print(f"headline,gbfs_vs_rnn_saving,{100*(1-g/r):.1f}%")
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--executor", default=None,
                    choices=["sim", "thread", "process"],
                    help="lane executor; sim = simulated clock (default), "
                         "thread/process = measured wall-clock lanes")
    ap.add_argument("--analyze", default="off", choices=["off", "warn", "prune"],
                    help="static schedule pre-filter; prune rejects "
                         "provably-bad candidates before they occupy a lane "
                         "(the final best is unchanged — see "
                         "repro.core.analysis)")
    args = ap.parse_args()
    main(seeds=args.seeds, quick=args.quick, n_workers=args.workers,
         executor=args.executor, analyze=args.analyze)

"""Roofline table from the dry-run records (deliverable g).

Reads experiments/dryrun/<mesh>/*.json and prints the per-cell roofline
terms, dominant bottleneck, MODEL_FLOPS ratio, and HBM fit — the table
EXPERIMENTS.md §Roofline embeds."""

from __future__ import annotations

import glob
import json
import os


def load_cells(root: str = "experiments/dryrun", mesh: str = "single") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(root, mesh, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def table(mesh: str = "single", root: str = "experiments/dryrun") -> str:
    rows = []
    header = (
        f"{'arch':24s} {'shape':12s} {'status':8s} {'compute_s':>10s} "
        f"{'memory_s':>10s} {'coll_s':>10s} {'dominant':>10s} "
        f"{'useful':>7s} {'fits':>5s}"
    )
    rows.append(header)
    rows.append("-" * len(header))
    for rec in load_cells(root, mesh):
        if rec["status"] == "ok":
            r = rec["roofline"]
            rows.append(
                f"{rec['arch']:24s} {rec['shape']:12s} {'ok':8s} "
                f"{r['compute_s']:10.3e} {r['memory_s']:10.3e} "
                f"{r['collective_s']:10.3e} {r['dominant']:>10s} "
                f"{r['useful_ratio']:7.3f} {str(rec['fits_hbm']):>5s}"
            )
        else:
            reason = rec.get("reason", rec.get("error", ""))[:40]
            rows.append(
                f"{rec['arch']:24s} {rec['shape']:12s} {rec['status']:8s} {reason}"
            )
    return "\n".join(rows)


def main():
    for mesh in ("single", "multi"):
        if os.path.isdir(os.path.join("experiments/dryrun", mesh)):
            print(f"== mesh: {mesh} ==")
            print(table(mesh))
            print()


if __name__ == "__main__":
    main()

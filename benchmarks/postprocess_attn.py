"""One-shot postprocess: fold the analytic chunked-attention flops into
already-recorded dry-run JSONs (no recompilation — the stored
extrapolated flops/bytes/collectives are unchanged inputs)."""

import glob
import json
import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src")))

from repro.configs.registry import get_arch, get_shape
from repro.launch.dryrun import analytic_chunked_attn_flops
from repro.utils.roofline import model_flops, roofline_from_costs


def main(root="experiments/dryrun"):
    n = 0
    for path in glob.glob(os.path.join(root, "*", "*.json")):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok" or "attn_flops_analytic_per_device" in rec:
            continue
        cfg = get_arch(rec["arch"])
        shape = get_shape(rec["shape"])
        attn_fix = analytic_chunked_attn_flops(cfg, shape) / rec["chips"]
        ext = rec["cost_analysis_extrapolated"]
        terms = roofline_from_costs(
            ext["flops"] + attn_fix,
            ext["bytes accessed"],
            rec["collectives"],
            rec["chips"],
            model_flops(cfg, shape),
        )
        rec["attn_flops_analytic_per_device"] = attn_fix
        rec["roofline"] = terms.as_dict()
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    print(f"updated {n} records")


if __name__ == "__main__":
    main()

"""Measurement-throughput benchmark — the recorded perf trajectory of
the real-measurement hot path (``BENCH_measure.json``).

The paper's headline result is search *cost*; in this reproduction that
cost is dominated by XLA compilation whenever the oracle is
:class:`XLATimedCost`.  This benchmark records trials/sec through the
measurement engine so every PR's effect on the hot path is a number,
not a claim:

  * **cold** — fresh persistent cache, serial lanes: every trial pays a
    full ``jax.jit`` compile (the historical per-session behavior);
  * **warm** — a *new* backend over the same cache directory (i.e. a
    session restart): every executable is served by the persistent
    on-disk layer, zero compiles;
  * **journal replay** — a second engine over the populated
    :class:`TrialJournal`: trials served without touching the backend;
  * **thread** — thread lanes over one shared backend (compiles overlap
    where XLA drops the GIL; timed regions serialize on the gate);
  * **process** — the same states through crash-isolated
    :class:`ProcessExecutor` lanes (``XLATimedCost.worker_spec()``),
    with the compile-cache hit rate attributed across the process
    boundary by worker-shipped deltas.

  * **fault_injection** — the same states through process lanes with a
    seeded :class:`FaultPlan` crashing ~10% of the workers mid-trial
    (one fire per state) and a :class:`RetryPolicy` re-queuing the
    transients: the hardened path's throughput under realistic lane
    mortality, every cost finite.

  * **learned_filter** — a fig7-miniature quality check for the learned
    proposal filter (``repro.core.learn``): tune three training shapes
    unfiltered into a journal, then tune the fig7 target shape twice
    with identical tuner/seed/budget — once plain, once with a
    :class:`ProposalFilter` trained on the cross-shape corpus — and
    compare real measurements dispatched and final (noise-free) best
    cost.

  * **sharded_search** — two concurrent shard sessions (``0/2`` and
    ``1/2``) over one journal vs an unsharded reference at the same
    tuner/seed/budget: hash ownership must partition the measured
    candidates disjointly and the elect-and-merge step must reproduce
    the single-engine best exactly (analytical oracle, so the equality
    is bitwise, not approximate).

Acceptance: warm trials/sec >= 3x the cold serial baseline on the quick
shape (``meets_3x_warm_speedup`` in the JSON), faulted process-lane
trials/sec >= 2x the cold serial baseline (``meets_2x_fault_speedup``),
the filtered search dispatches >= 30% fewer real measurements
(``meets_30pct_fewer_measurements``) while landing a true best cost
within 5% of the unfiltered run (``best_within_5pct``), and the sharded
search keeps both partition invariants (``meets_shard_invariants``).

Usage::

  python -m benchmarks.measure_bench --quick           # CI smoke + artifact
  python -m benchmarks.measure_bench --executor sim    # skip process lanes
"""

from __future__ import annotations

import json
import math
import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (
    FaultInjectionCost,
    FaultPlan,
    GemmConfigSpace,
    MeasureEngine,
    ProcessExecutor,
    RetryPolicy,
    ThreadExecutor,
    TrialJournal,
    workload_key,
)
from repro.core.measure import MeasureStats

from .common import make_xla_cost


def _pick_states(space, backend, n):
    """First ``n`` enumerable states that are legitimate and fit the
    VMEM guard — deterministic, so runs are comparable across PRs."""
    out = []
    for s in space.enumerate():
        if space.is_legitimate(s) and backend._fits_vmem(s):
            out.append(s)
            if len(out) >= n:
                break
    return out


def _timed_serial(engine, states):
    t0 = time.perf_counter()
    for s in states:
        engine.measure_wave([s])
    return time.perf_counter() - t0


def _compile_block(stats: MeasureStats) -> dict:
    return {
        "n_compiles": stats.n_compiles,
        "n_mem_hits": stats.n_compile_mem_hits,
        "n_disk_hits": stats.n_compile_disk_hits,
        "n_evictions": stats.n_compile_evictions,
        "compile_s": round(stats.compile_s, 3),
        "compile_cache_hit_rate": round(stats.compile_cache_hit_rate(), 4),
    }


def _learned_filter_phase(quick: bool, workdir: str) -> dict:
    """Filtered vs unfiltered search on the fig7 shape, same budget.

    Everything runs on the analytical oracle (the fig7 protocol): the
    phase scores search *quality*, not compile throughput, and the
    analytical model makes the corpus, the trial sequence, and the
    final noise-free scoring deterministic across hosts.  The corpus
    comes from shapes the target was never tuned at, so the filter is
    exercised exactly as deployed: ranking a shape its model never saw.
    """
    from repro.core import Budget, TrialJournal
    from repro.core.learn import ProposalFilter
    from repro.core.measure import MeasureStats

    from .common import make_cost, run_tuner

    n_workers = 8
    tuner = "g-bfs"
    # the budget is NOT scaled down for --quick: greedy BFS needs room
    # to reconverge after the filter prunes a descent direction (at 160
    # trials the filtered search lands ~8x off; at 320 it matches the
    # unfiltered best), and the analytical oracle keeps 320 trials cheap
    train_budget = Budget(max_trials=120)
    target_budget = Budget(max_trials=320)
    train_shapes = [(512, 512, 512), (512, 1024, 512), (1024, 512, 1024)]
    target_shape = (1024, 1024, 1024)  # the fig7 protocol shape

    corpus = os.path.join(workdir, "learned-corpus.jsonl")
    with TrialJournal(corpus) as journal:
        for m, k, n in train_shapes:
            run_tuner(GemmConfigSpace(m, k, n), tuner, train_budget,
                      seed=0, n_workers=n_workers, journal=journal)

    target = GemmConfigSpace(*target_shape)
    fingerprint = make_cost(target, seed=0).measure_fingerprint()

    def target_run(tag: str, filtered: bool):
        # each run gets its own copy of the corpus: the two searches
        # must not serve each other's target-shape rows as cache hits
        jpath = os.path.join(workdir, f"learned-{tag}.jsonl")
        shutil.copyfile(corpus, jpath)
        stats = MeasureStats()
        with TrialJournal(jpath) as journal:
            flt = None
            if filtered:
                flt = ProposalFilter(
                    target, journal, dtype="bfloat16",
                    fingerprint=fingerprint, keep=0.5,
                    retrain_every=8, min_rows=64,
                )
            _res, final = run_tuner(
                target, tuner, target_budget, seed=0,
                n_workers=n_workers, journal=journal, stats=stats,
                learned_filter=flt,
            )
        return stats, final

    t0 = time.perf_counter()
    plain_stats, plain_best = target_run("plain", filtered=False)
    flt_stats, flt_best = target_run("filtered", filtered=True)
    elapsed = time.perf_counter() - t0

    reduction = 1.0 - flt_stats.n_dispatched / max(1, plain_stats.n_dispatched)
    within_5pct = flt_best <= plain_best * 1.05
    return {
        "tuner": tuner,
        "n_workers": n_workers,
        "keep_frac": 0.5,
        "train_shapes": [list(s) for s in train_shapes],
        "target_shape": list(target_shape),
        "budget_trials": target_budget.max_trials,
        "n_measured_unfiltered": plain_stats.n_dispatched,
        "n_measured_filtered": flt_stats.n_dispatched,
        "trials_avoided_learned": flt_stats.trials_avoided_learned,
        "measurement_reduction_frac": round(reduction, 4),
        "n_learned_retrains": flt_stats.n_learned_retrains,
        "learn_s": round(flt_stats.learn_s, 3),
        "best_cost_unfiltered": plain_best,
        "best_cost_filtered": flt_best,
        "best_cost_ratio": round(flt_best / plain_best, 4),
        "elapsed_s": round(elapsed, 3),
        "meets_30pct_fewer_measurements": reduction >= 0.30,
        "best_within_5pct": within_5pct,
    }


def _sharded_search_phase(quick: bool, workdir: str) -> dict:
    """Two concurrent shard sessions vs one unsharded reference.

    Both shards run the full tune loop — same tuner, seed, and budget —
    against ONE journal file; hash ownership decides who measures each
    candidate, a mid-run ``reload_every`` serves the sibling's rows as
    cache hits, and the elect-and-merge step reconciles the two local
    bests into one records entry.  The phase gates the two invariants
    the design promises: the measured sets are disjoint (every journal
    row is owned by the shard that wrote it, no candidate measured
    twice) and the merged best equals the single-engine best.

    The ``random`` tuner's proposal stream is cost-independent, so both
    shards enumerate the identical candidate sequence and the union of
    their measurements is exactly the unsharded run's set — that is
    what makes the equality check exact, not approximate.  Everything
    runs on the deterministic analytical oracle; the budget is not
    scaled for --quick (it is already cheap).
    """
    from threading import Thread

    from repro.core import (
        Budget,
        GemmWorkload,
        TuningRecords,
        TuningSession,
        elect_best,
        parse_shard,
        read_done_markers,
        shard_dir_for,
        shard_of,
    )

    wl = GemmWorkload(512, 512, 512)
    budget = Budget(max_trials=96)
    n_workers = 8
    seed = 0
    tuner = "random"

    # -- unsharded reference: the best the merge must reproduce ----------
    ref_dir = os.path.join(workdir, "shard-ref")
    os.makedirs(ref_dir, exist_ok=True)
    with TrialJournal(os.path.join(ref_dir, "trials.jsonl")) as journal:
        session = TuningSession(
            TuningRecords(), seed=seed, verbose=False, journal=journal
        )
        ref = session.tune_workload(wl, tuner, budget, n_workers=n_workers)

    # -- two concurrent shard sessions over one journal path -------------
    sh_dir = os.path.join(workdir, "shard-run")
    os.makedirs(sh_dir, exist_ok=True)
    jpath = os.path.join(sh_dir, "trials.jsonl")
    recs = [TuningRecords(), TuningRecords()]
    stats = [MeasureStats(), MeasureStats()]
    errs: list = [None, None]

    def run_shard(i: int) -> None:
        try:
            # each thread gets its own journal handle: appends are
            # single O_APPEND writes, so the shared file never tears
            with TrialJournal(jpath) as journal:
                session = TuningSession(
                    recs[i], seed=seed, verbose=False, journal=journal
                )
                session.tune_workload(
                    wl, tuner, budget, n_workers=n_workers,
                    stats=stats[i], reload_every=2,
                    shard=parse_shard(f"{i}/2"), shard_wait_s=60.0,
                )
        except Exception as e:  # surface in the artifact, don't wedge CI
            errs[i] = repr(e)

    t0 = time.perf_counter()
    threads = [Thread(target=run_shard, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    elapsed = time.perf_counter() - t0

    # -- audit the shared journal: ownership + disjointness ---------------
    owners: dict = {}
    per_shard = [0, 0]
    n_violations = 0
    with open(jpath) as f:
        for line in f:
            if not line.strip():
                continue
            row = json.loads(line)
            tag = row.get("shard")
            if tag is None:  # static/pred audit rows carry no shard tag
                continue
            si, sn = tag
            per_shard[si] += 1
            if shard_of(row["w"], row["k"], sn) != si:
                n_violations += 1
            if owners.setdefault((row["w"], row["k"]), si) != si:
                n_violations += 1  # same candidate measured by two shards
    disjoint = n_violations == 0 and len(owners) == sum(per_shard)

    # -- the merge: both records tables carry the elected single best -----
    wkey = wl.key("analytical_tpu_v5e")
    bests = [r.lookup(wkey) for r in recs]
    merged_ok = (
        all(b is not None for b in bests)
        and bests[0]["cost"] == bests[1]["cost"]  # both elected the same
        and bests[0]["cost"] == ref.best_cost  # noise-free oracle: exact
    )
    # the election is reproducible from the markers alone
    root = shard_dir_for(jpath)
    cost = session.cost_factory(wl.space())
    markers = read_done_markers(root, f"{wkey}?{cost.measure_fingerprint()}", 2)
    won = elect_best(markers)
    election_ok = (
        set(markers) == {0, 1}
        and won is not None
        and bests[0] is not None
        and won[2] == bests[0]["cost"]
    )

    ok = disjoint and merged_ok and election_ok and not any(errs)
    return {
        "tuner": tuner,
        "n_workers": n_workers,
        "seed": seed,
        "budget_trials": budget.max_trials,
        "shape": [512, 512, 512],
        "n_rows_per_shard": per_shard,
        "n_owned_candidates": len(owners),
        "n_ownership_violations": n_violations,
        "n_deferred_to_sibling": [s.n_deferred_to_sibling for s in stats],
        "n_served_by_sibling": [s.n_served_by_sibling for s in stats],
        "errors": [e for e in errs if e],
        "best_cost_single": ref.best_cost,
        "best_cost_merged": None if bests[0] is None else bests[0]["cost"],
        "elapsed_s": round(elapsed, 3),
        "shard_disjoint": disjoint,
        "merged_best_matches_single": merged_ok,
        "election_reproducible": election_ok,
        "meets_shard_invariants": ok,
    }


def main(
    quick: bool = False,
    out: str = "BENCH_measure.json",
    dim: int | None = None,
    n_states: int | None = None,
    repeats: int | None = None,
    workers: int = 2,
    n_build_workers: int = 4,
    compile_cache_dir: str | None = None,
    executor: str | None = None,
) -> dict:
    import jax

    dim = dim or (64 if quick else 128)
    n_states = n_states or (6 if quick else 12)
    repeats = repeats or (1 if quick else 2)
    space = GemmConfigSpace(dim, dim, dim)
    wkey = workload_key(dim, dim, dim, "float32", "xla_cpu_timed")

    own_cache = compile_cache_dir is None
    cache_dir = compile_cache_dir or tempfile.mkdtemp(prefix="measure-bench-xla-")
    tmp_journal = tempfile.mkdtemp(prefix="measure-bench-journal-")
    jpath = os.path.join(tmp_journal, "trials.jsonl")

    mk = lambda: make_xla_cost(  # noqa: E731 — one fresh "session" per phase
        space, repeats=repeats, n_build_workers=n_build_workers,
        cache_dir=cache_dir,
    )
    result: dict = {
        "bench": "measure",
        "quick": quick,
        "shape": [dim, dim, dim],
        "n_states": n_states,
        "n_repeats": repeats,
        "host": {"cpus": os.cpu_count(), "jax": jax.__version__},
        "executors": {},
    }
    try:
        # ---- cold serial baseline: every trial pays a compile --------------
        cold = mk()
        states = _pick_states(space, cold, n_states)
        eng = MeasureEngine(cold, n_workers=1)
        t_cold = _timed_serial(eng, states)
        cold_tps = len(states) / t_cold
        sim_block = {
            "cold": {
                "trials_per_s": round(cold_tps, 3),
                "elapsed_s": round(t_cold, 3),
                **_compile_block(eng.stats),
            }
        }

        # ---- warm restart: new backend, same persistent cache --------------
        warm = mk()
        eng = MeasureEngine(warm, n_workers=1)
        t_warm = _timed_serial(eng, states)
        warm_tps = len(states) / t_warm
        sim_block["warm"] = {
            "trials_per_s": round(warm_tps, 3),
            "elapsed_s": round(t_warm, 3),
            **_compile_block(eng.stats),
        }
        sim_block["warm_speedup"] = round(warm_tps / cold_tps, 2)

        # ---- journal replay: trials served without touching the backend ----
        with TrialJournal(jpath) as journal:
            eng = MeasureEngine(warm, n_workers=1, journal=journal,
                                workload_key=wkey)
            _timed_serial(eng, states)  # populate
        with TrialJournal(jpath) as journal:
            eng = MeasureEngine(mk(), n_workers=1, journal=journal,
                                workload_key=wkey)
            t_replay = _timed_serial(eng, states)
            sim_block["journal_hit_rate"] = round(eng.stats.cache_hit_rate(), 4)
            sim_block["journal_replay_trials_per_s"] = round(
                len(states) / t_replay, 1
            )

        # ---- static pre-filter: analyzer verdicts ahead of the lanes --------
        # prepend the untiled initial state (degenerate, hence prunable)
        # so trials_avoided is deterministically nonzero; the kept states
        # are served by the warm compile cache, so the delta measured
        # here is the filter itself, not compilation
        flt = mk()
        eng = MeasureEngine(flt, n_workers=1, analyze="prune")
        filter_states = [space.initial_state()] + states
        t_flt = _timed_serial(eng, filter_states)
        sim_block["static_filter"] = {
            "mode": "prune",
            "trials_avoided": eng.stats.trials_avoided,
            "n_static_flags": eng.stats.n_static_flags,
            "static_s": round(eng.stats.static_s, 6),
            "static_s_per_wave": round(
                eng.stats.static_s / max(1, eng.stats.n_waves), 9
            ),
            "elapsed_s": round(t_flt, 3),
            **_compile_block(eng.stats),
        }
        result["executors"]["sim"] = sim_block

        # ---- thread lanes: shared backend, gated timed regions -------------
        if executor in (None, "thread"):
            th = mk()
            with ThreadExecutor() as ex:
                eng = MeasureEngine(th, n_workers=workers, executor=ex)
                t0 = time.perf_counter()
                costs = []
                for i in range(0, len(states), workers):
                    wave = eng.measure_wave(states[i : i + workers])
                    costs.extend(o.cost for o in wave)
                t_th = time.perf_counter() - t0
            result["executors"]["thread"] = {
                "n_workers": workers,
                "trials_per_s": round(len(states) / t_th, 3),
                "elapsed_s": round(t_th, 3),
                "n_failures": eng.stats.n_failures,
                "all_finite": all(math.isfinite(c) for c in costs),
                **_compile_block(eng.stats),
            }

        # ---- process lanes: worker-side caches + shipped compile deltas ----
        if executor in (None, "process"):
            proc = mk()
            with ProcessExecutor() as ex:
                ex.warm_up(workers)
                eng = MeasureEngine(proc, n_workers=workers, executor=ex)
                t0 = time.perf_counter()
                costs = []
                for i in range(0, len(states), workers):
                    wave = eng.measure_wave(states[i : i + workers])
                    costs.extend(o.cost for o in wave)
                t_proc = time.perf_counter() - t0
            result["executors"]["process"] = {
                "n_workers": workers,
                "trials_per_s": round(len(states) / t_proc, 3),
                "elapsed_s": round(t_proc, 3),
                "n_failures": eng.stats.n_failures,
                "all_finite": all(math.isfinite(c) for c in costs),
                **_compile_block(eng.stats),
            }

        # ---- fault injection: ~10% of states kill their worker once, the
        # retry lanes recover them — throughput under lane mortality ---------
        # Trials carry a real per-measurement occupancy (delay_s) so lane
        # parallelism is what's being measured, not XLA:CPU's microsecond
        # matmuls; the comparator is a COLD SERIAL run of the same states
        # under the same occupancy (fresh compile cache, one lane, zero
        # faults) — the session a user without the hardened path runs.
        if executor in (None, "process"):
            f_workers = max(workers, 4)
            f_delay = 0.5
            f_states = _pick_states(space, cold, max(12, n_states))
            n_faults = max(1, round(0.10 * len(f_states)))
            # deterministic seed scan: land EXACTLY the 10% crash quota on
            # this state list, so the artifact is comparable across hosts
            for fseed in range(200):
                plan = FaultPlan(seed=fseed, p_crash=0.10, fires=1)
                if sum(
                    plan.fault_for(s.key()) == "crash" for s in f_states
                ) == n_faults:
                    break
            fault_dir = os.path.join(tmp_journal, "faults")
            base_cache = tempfile.mkdtemp(prefix="measure-bench-faultbase-")
            try:
                base = FaultInjectionCost(
                    make_xla_cost(
                        space, repeats=repeats,
                        n_build_workers=n_build_workers,
                        cache_dir=base_cache,
                    ),
                    FaultPlan(seed=plan.seed, p_crash=0.10, fires=0),
                    fault_dir=fault_dir, delay_s=f_delay,
                )
                eng0 = MeasureEngine(base, n_workers=1)
                t_base = _timed_serial(eng0, f_states)
            finally:
                shutil.rmtree(base_cache, ignore_errors=True)
            base_tps = len(f_states) / t_base

            faulty = FaultInjectionCost(
                mk(), plan, fault_dir=fault_dir, delay_s=f_delay
            )
            with ProcessExecutor() as ex:
                # +1 hot spare: a crashed lane adopts a warm worker instead
                # of paying a cold interpreter start-up mid-run; passing
                # the backend pre-builds it (jax import + cache open)
                # inside every worker before the clock starts
                ex.warm_up(f_workers + 1, backend=faulty)
                eng = MeasureEngine(
                    faulty, n_workers=f_workers, executor=ex,
                    retry=RetryPolicy(max_attempts=3, backoff_s=0.01, seed=0),
                )
                t0 = time.perf_counter()
                costs = []
                for i in range(0, len(f_states), f_workers):
                    wave = eng.measure_wave(f_states[i : i + f_workers])
                    costs.extend(o.cost for o in wave)
                t_fault = time.perf_counter() - t0
                adoptions = ex.fault_stats()["n_spare_adoptions"]
            fault_tps = len(f_states) / t_fault
            result["fault_injection"] = {
                "n_workers": f_workers,
                "n_states": len(f_states),
                "fault_rate": 0.10,
                "delay_s": f_delay,
                "plan_seed": plan.seed,
                "n_planned_crashes": n_faults,
                "cold_serial_trials_per_s": round(base_tps, 3),
                "trials_per_s": round(fault_tps, 3),
                "elapsed_s": round(t_fault, 3),
                "n_retries": eng.stats.n_retries,
                "n_transient_recovered": eng.stats.n_transient_recovered,
                "n_failed_transient": eng.stats.n_failed_transient,
                "n_respawns": eng.stats.n_respawns,
                "n_spare_adoptions": adoptions,
                "retry_backoff_s": round(eng.stats.retry_backoff_s, 3),
                "all_finite": all(math.isfinite(c) for c in costs),
                "fault_speedup_vs_cold": round(fault_tps / base_tps, 2),
                **_compile_block(eng.stats),
            }
            result["meets_2x_fault_speedup"] = fault_tps / base_tps >= 2.0

        # ---- learned proposal filter: fig7-miniature quality check ---------
        # analytical oracle, no XLA: filtered vs unfiltered search on the
        # fig7 shape with a cross-shape training corpus
        lf = _learned_filter_phase(quick, tmp_journal)
        result["learned_filter"] = lf
        result["meets_30pct_fewer_measurements"] = (
            lf["meets_30pct_fewer_measurements"]
        )
        result["best_within_5pct"] = lf["best_within_5pct"]

        # ---- sharded search: disjoint ownership + elect-and-merge ----------
        ss = _sharded_search_phase(quick, tmp_journal)
        result["sharded_search"] = ss
        result["meets_shard_invariants"] = ss["meets_shard_invariants"]

        result["meets_3x_warm_speedup"] = sim_block["warm_speedup"] >= 3.0
    finally:
        shutil.rmtree(tmp_journal, ignore_errors=True)
        if own_cache:
            shutil.rmtree(cache_dir, ignore_errors=True)

    with open(out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
    print(f"measure,cold_trials_per_s,{sim_block['cold']['trials_per_s']}")
    print(f"measure,warm_trials_per_s,{sim_block['warm']['trials_per_s']}")
    print(f"measure,warm_speedup,{sim_block['warm_speedup']}")
    if "process" in result["executors"]:
        p = result["executors"]["process"]
        print(
            f"measure,process_trials_per_s,{p['trials_per_s']}"
            f",compile_cache_hit={p['compile_cache_hit_rate']}"
        )
    if "fault_injection" in result:
        fi = result["fault_injection"]
        print(
            f"measure,fault_trials_per_s,{fi['trials_per_s']}"
            f",recovered={fi['n_transient_recovered']}"
            f",speedup_vs_cold={fi['fault_speedup_vs_cold']}"
        )
        if not result["meets_2x_fault_speedup"]:
            print(
                "measure,WARNING,faulted throughput "
                f"{fi['fault_speedup_vs_cold']}x below the 2x acceptance bar",
                file=sys.stderr,
            )
    if "learned_filter" in result:
        lf = result["learned_filter"]
        print(
            f"measure,learned_filter_measurements,"
            f"{lf['n_measured_filtered']}/{lf['n_measured_unfiltered']}"
            f",reduction={lf['measurement_reduction_frac']}"
            f",best_ratio={lf['best_cost_ratio']}"
        )
        if not lf["meets_30pct_fewer_measurements"]:
            print(
                "measure,WARNING,learned filter saved only "
                f"{lf['measurement_reduction_frac']:.0%} of real "
                "measurements (bar: 30%)",
                file=sys.stderr,
            )
        if not lf["best_within_5pct"]:
            print(
                "measure,WARNING,filtered best cost "
                f"{lf['best_cost_ratio']}x the unfiltered best "
                "(bar: within 5%)",
                file=sys.stderr,
            )
    if "sharded_search" in result:
        ss = result["sharded_search"]
        print(
            f"measure,sharded_search_rows,"
            f"{ss['n_rows_per_shard'][0]}+{ss['n_rows_per_shard'][1]}"
            f",disjoint={ss['shard_disjoint']}"
            f",merged_matches_single={ss['merged_best_matches_single']}"
        )
        if not ss["meets_shard_invariants"]:
            print(
                "measure,WARNING,sharded search broke an invariant: "
                f"disjoint={ss['shard_disjoint']} "
                f"merged_matches_single={ss['merged_best_matches_single']} "
                f"election_reproducible={ss['election_reproducible']} "
                f"errors={ss['errors']}",
                file=sys.stderr,
            )
    print(f"measure,artifact,{out}")
    if not result["meets_3x_warm_speedup"]:
        print(
            "measure,WARNING,warm speedup "
            f"{sim_block['warm_speedup']}x below the 3x acceptance bar",
            file=sys.stderr,
        )
    return result


if __name__ == "__main__":
    import argparse

    from .common import add_measure_args

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced protocol")
    ap.add_argument("--out", default="BENCH_measure.json")
    ap.add_argument("--dim", type=int, default=None, help="GEMM dim (cube)")
    ap.add_argument("--states", type=int, default=None, dest="n_states")
    ap.add_argument("--repeats", type=int, default=None)
    add_measure_args(ap)
    ap.set_defaults(workers=2)  # the process phase needs >=2 lanes to mean much
    a = ap.parse_args()
    main(
        quick=a.quick, out=a.out, dim=a.dim, n_states=a.n_states,
        repeats=a.repeats, workers=max(1, a.workers),
        n_build_workers=a.n_build_workers,
        compile_cache_dir=a.compile_cache_dir, executor=a.executor,
    )

"""Paper Fig. 8 — multi-size comparison + variance.

8a: best cost at 0.1% of the space explored, for (512,512,512),
    (1024,1024,1024), (2048,2048,2048).
8b: distribution (min/q1/median/mean/q3/max) of the best cost found
    within a fixed search-time budget (750 simulated seconds), 10 trials
    on (1024,1024,1024).
"""

from __future__ import annotations

import statistics

from repro.core import Budget, GemmConfigSpace

from .common import PAPER_TUNERS, run_tuner


def fig8a(tuners=None, seeds: int = 3) -> dict:
    tuners = tuners or PAPER_TUNERS
    out = {}
    for size in (512, 1024, 2048):
        space = GemmConfigSpace(size, size, size)
        for tuner in tuners:
            finals = [
                run_tuner(space, tuner, Budget(max_fraction=0.001), seed=s)[1]
                for s in range(seeds)
            ]
            mean = sum(finals) / len(finals)
            out[(size, tuner)] = mean
            print(f"fig8a,{size},{tuner},{mean*1e6:.3f}", flush=True)
    return out


def fig8b(tuners=None, trials: int = 10, time_budget_s: float = 750.0) -> dict:
    tuners = tuners or PAPER_TUNERS
    space = GemmConfigSpace(1024, 1024, 1024)
    out = {}
    for tuner in tuners:
        finals = []
        for seed in range(trials):
            _, final = run_tuner(
                space, tuner, Budget(max_time_s=time_budget_s), seed=seed
            )
            finals.append(final * 1e6)
        finals.sort()
        q = statistics.quantiles(finals, n=4)
        row = {
            "min": finals[0],
            "q1": q[0],
            "median": q[1],
            "mean": statistics.mean(finals),
            "q3": q[2],
            "max": finals[-1],
            "stdev": statistics.stdev(finals),
        }
        out[tuner] = row
        print(
            f"fig8b,{tuner},min={row['min']:.3f},q1={row['q1']:.3f},"
            f"median={row['median']:.3f},mean={row['mean']:.3f},"
            f"q3={row['q3']:.3f},max={row['max']:.3f},std={row['stdev']:.3f}",
            flush=True,
        )
    return out


def main(quick: bool = False):
    a = fig8a(seeds=1 if quick else 3)
    b = fig8b(trials=3 if quick else 10,
              time_budget_s=300.0 if quick else 750.0)
    return a, b


if __name__ == "__main__":
    main()

"""Paper Fig. 8 — multi-size comparison + variance.

8a: best cost at 0.1% of the space explored, for (512,512,512),
    (1024,1024,1024), (2048,2048,2048).
8b: distribution (min/q1/median/mean/q3/max) of the best cost found
    within a fixed search-time budget (750 simulated seconds), 10 trials
    on (1024,1024,1024).

Each fig8a row carries the engine's worker count, lane executor, and
cache-hit rate (``workers=…,executor=…,cache_hit=…``) so any clock
difference between runs is attributable — and so simulated-clock
compression (``sim``) is never confused with measured wall-clock
parallelism (``thread``/``process``); fig8b emits one ``fig8bengine``
row per tuner.  NOTE:
under a *time* budget (8b), ``--workers > 1`` genuinely changes the
search — the compressed clock lets every tuner afford more trials
before the budget expires.
"""

from __future__ import annotations

import statistics

from repro.core import Budget, GemmConfigSpace

from .common import PAPER_TUNERS, run_tuner


def fig8a(tuners=None, seeds: int = 3, n_workers: int = 1,
          executor: str | None = None) -> dict:
    tuners = tuners or PAPER_TUNERS
    out = {}
    for size in (512, 1024, 2048):
        space = GemmConfigSpace(size, size, size)
        for tuner in tuners:
            finals, hits, trials = [], 0, 0
            for s in range(seeds):
                res, final = run_tuner(
                    space, tuner, Budget(max_fraction=0.001), seed=s,
                    n_workers=n_workers, executor=executor,
                )
                finals.append(final)
                hits += res.n_cache_hits
                trials += res.n_trials
            mean = sum(finals) / len(finals)
            out[(size, tuner)] = mean
            print(
                f"fig8a,{size},{tuner},{mean*1e6:.3f},"
                f"workers={n_workers},executor={res.executor},"
                f"cache_hit={hits / max(1, trials):.3f}",
                flush=True,
            )
    return out


def fig8b(tuners=None, trials: int = 10, time_budget_s: float = 750.0,
          n_workers: int = 1, executor: str | None = None) -> dict:
    tuners = tuners or PAPER_TUNERS
    space = GemmConfigSpace(1024, 1024, 1024)
    out = {}
    for tuner in tuners:
        finals, hits, n_meas = [], 0, 0
        for seed in range(trials):
            res, final = run_tuner(
                space, tuner, Budget(max_time_s=time_budget_s), seed=seed,
                n_workers=n_workers, executor=executor,
            )
            finals.append(final * 1e6)
            hits += res.n_cache_hits
            n_meas += res.n_trials
        finals.sort()
        q = statistics.quantiles(finals, n=4)
        row = {
            "min": finals[0],
            "q1": q[0],
            "median": q[1],
            "mean": statistics.mean(finals),
            "q3": q[2],
            "max": finals[-1],
            "stdev": statistics.stdev(finals),
        }
        out[tuner] = row
        print(
            f"fig8b,{tuner},min={row['min']:.3f},q1={row['q1']:.3f},"
            f"median={row['median']:.3f},mean={row['mean']:.3f},"
            f"q3={row['q3']:.3f},max={row['max']:.3f},std={row['stdev']:.3f}",
            flush=True,
        )
        print(
            f"fig8bengine,{tuner},workers={n_workers},executor={res.executor},"
            f"cache_hit={hits / max(1, n_meas):.3f},mean_trials={n_meas / max(1, trials):.0f}",
            flush=True,
        )
    return out


def main(quick: bool = False, n_workers: int = 1, executor: str | None = None):
    a = fig8a(seeds=1 if quick else 3, n_workers=n_workers, executor=executor)
    b = fig8b(trials=3 if quick else 10,
              time_budget_s=300.0 if quick else 750.0,
              n_workers=n_workers, executor=executor)
    return a, b


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--executor", default=None,
                    choices=["sim", "thread", "process"],
                    help="lane executor; sim = simulated clock (default), "
                         "thread/process = measured wall-clock lanes")
    args = ap.parse_args()
    main(quick=args.quick, n_workers=args.workers, executor=args.executor)

"""Benchmark harness entry point — one function per paper table/figure,
plus the bench-history diff gate.

``python -m benchmarks.run [--quick]`` prints ``name,us_per_call,derived``
CSV per the repo contract, then the full figure protocols:

  fig7   — Fig. 7a/7b: cost-vs-fraction and cost-vs-time @ 1024^3
  fig8   — Fig. 8a/8b: multi-size @0.1% and variance boxplot
  kernel — tuned-vs-heuristic GEMM (analytical model + real XLA:CPU)
  measure — real-measurement hot-path throughput (BENCH_measure.json:
            cold vs warm-compile-cache trials/sec, journal replay,
            process lanes)
  roofline — dry-run roofline table (if dry-run records exist)

``python -m benchmarks.run --diff`` compares the working-tree
``BENCH_measure.json`` (the one the bench just wrote) against the
previously *committed* one (``git show HEAD:BENCH_measure.json``, or
``--diff-base <ref-or-file>``) and exits non-zero when warm trials/sec
regressed by more than ``--diff-threshold`` (default 20%) — the CI
smoke gate that turns the per-PR artifact into a tracked history.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

BENCH_MEASURE = "BENCH_measure.json"


def _load_baseline(base: str) -> dict:
    """Baseline BENCH_measure.json: a file path, or a git ref whose
    committed copy is read via ``git show``."""
    if os.path.exists(base) and not os.path.isdir(base):
        with open(base) as f:
            return json.load(f)
    blob = subprocess.run(
        ["git", "show", f"{base}:{BENCH_MEASURE}"],
        capture_output=True, text=True, check=True,
        cwd=os.path.dirname(os.path.abspath(__file__)),
    ).stdout
    return json.loads(blob)


def _warm_tps(bench: dict) -> float:
    return float(bench["executors"]["sim"]["warm"]["trials_per_s"])


def diff_measure(
    current: str = BENCH_MEASURE,
    base: str = "HEAD",
    threshold: float = 0.20,
) -> int:
    """Fail (return 1) when warm-cache trials/sec regressed more than
    ``threshold`` vs the committed baseline.  A missing baseline (first
    PR to record the bench, or a fresh clone) passes with a note —
    history has to start somewhere."""
    with open(current) as f:
        cur = json.load(f)
    try:
        prev = _load_baseline(base)
    except (subprocess.CalledProcessError, FileNotFoundError, json.JSONDecodeError):
        print(f"measure-diff,baseline_missing,{base}")
        return 0
    cur_tps, prev_tps = _warm_tps(cur), _warm_tps(prev)
    regression = 1.0 - cur_tps / prev_tps if prev_tps > 0 else 0.0
    print(f"measure-diff,baseline_warm_trials_per_s,{prev_tps}")
    print(f"measure-diff,current_warm_trials_per_s,{cur_tps}")
    print(f"measure-diff,regression_frac,{regression:+.3f}")
    if regression > threshold:
        print(
            f"measure-diff,FAIL,warm trials/sec regressed "
            f"{regression:.1%} > {threshold:.0%} "
            f"({prev_tps} -> {cur_tps})",
            file=sys.stderr,
        )
        return 1
    print(f"measure-diff,OK,within {threshold:.0%}")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced protocol")
    ap.add_argument(
        "--only", default=None,
        choices=["fig7", "fig8", "kernel", "measure", "roofline"],
    )
    ap.add_argument("--diff", action="store_true",
                    help="diff BENCH_measure.json against the committed "
                         "baseline and exit (no benchmarks are run)")
    ap.add_argument("--diff-base", default="HEAD",
                    help="baseline for --diff: a git ref (committed "
                         "BENCH_measure.json) or a JSON file path")
    ap.add_argument("--diff-threshold", type=float, default=0.20,
                    help="max tolerated warm trials/sec regression "
                         "fraction before --diff fails (default 0.20)")
    args = ap.parse_args()

    if args.diff:
        sys.exit(
            diff_measure(base=args.diff_base, threshold=args.diff_threshold)
        )

    from . import fig7, fig8, kernel_bench, measure_bench, roofline_report

    jobs = {
        "fig7": lambda: fig7.main(quick=args.quick),
        "fig8": lambda: fig8.main(quick=args.quick),
        "kernel": lambda: kernel_bench.main(quick=args.quick),
        "measure": lambda: measure_bench.main(quick=args.quick),
        "roofline": roofline_report.main,
    }
    if args.only:
        jobs = {args.only: jobs[args.only]}
    for name, fn in jobs.items():
        t0 = time.monotonic()
        print(f"==== {name} ====", flush=True)
        try:
            fn()
        except Exception as e:  # pragma: no cover
            print(f"{name},ERROR,{type(e).__name__}: {e}", file=sys.stderr)
            raise
        print(f"{name},elapsed_s,{time.monotonic() - t0:.1f}", flush=True)


if __name__ == "__main__":
    main()

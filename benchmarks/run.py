"""Benchmark harness entry point — one function per paper table/figure,
plus the bench-history diff gate.

``python -m benchmarks.run [--quick]`` prints ``name,us_per_call,derived``
CSV per the repo contract, then the full figure protocols:

  fig7   — Fig. 7a/7b: cost-vs-fraction and cost-vs-time @ 1024^3
  fig8   — Fig. 8a/8b: multi-size @0.1% and variance boxplot
  kernel — tuned-vs-heuristic GEMM (analytical model + real XLA:CPU)
  measure — real-measurement hot-path throughput (BENCH_measure.json:
            cold vs warm-compile-cache trials/sec, journal replay,
            process lanes)
  serve  — tune→serve loop (BENCH_serve.json: tuned-record vs heuristic
           flash dispatch tok/s, AOT warm-restart compile counters,
           open-loop bucketed serving latency percentiles)
  roofline — dry-run roofline table (if dry-run records exist)

``python -m benchmarks.run --diff`` compares the working-tree
``BENCH_measure.json`` (the one the bench just wrote) against the
previously *committed* one (``git show HEAD:BENCH_measure.json``, or
``--diff-base <ref-or-file>``) and exits non-zero when warm trials/sec
regressed by more than ``--diff-threshold`` (default 20%) — the CI
smoke gate that turns the per-PR artifact into a tracked history.
``--diff-serve`` is the same gate over ``BENCH_serve.json`` (stream
service tok/s plus the warm-restart zero-compile and tuned-dispatch
counter invariants).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

BENCH_MEASURE = "BENCH_measure.json"
BENCH_SERVE = "BENCH_serve.json"


def _load_baseline(base: str, name: str = BENCH_MEASURE) -> dict:
    """Baseline bench JSON: a file path, or a git ref whose committed
    copy is read via ``git show``."""
    if os.path.exists(base) and not os.path.isdir(base):
        with open(base) as f:
            return json.load(f)
    blob = subprocess.run(
        ["git", "show", f"{base}:{name}"],
        capture_output=True, text=True, check=True,
        cwd=os.path.dirname(os.path.abspath(__file__)),
    ).stdout
    return json.loads(blob)


def _warm_tps(bench: dict) -> float:
    return float(bench["executors"]["sim"]["warm"]["trials_per_s"])


def diff_measure(
    current: str = BENCH_MEASURE,
    base: str = "HEAD",
    threshold: float = 0.20,
) -> int:
    """Fail (return 1) when warm-cache trials/sec regressed more than
    ``threshold`` vs the committed baseline, or when the learned-filter
    quality block (present since the ``repro.core.learn`` PR) misses its
    acceptance bars in the *current* run — >=30% fewer real
    measurements at a true best cost within 5% of the unfiltered
    search — or when the sharded-search block (present since the
    ``repro.core.shard`` PR) breaks a partition invariant.  A missing
    baseline (first PR to record the bench, or a
    fresh clone) passes with a note — history has to start somewhere."""
    with open(current) as f:
        cur = json.load(f)
    rc = 0
    lf = cur.get("learned_filter")
    if lf is not None:
        # quality invariants hold run-by-run, no baseline needed (the
        # block is absent from pre-learn artifacts, which is fine)
        if not lf.get("meets_30pct_fewer_measurements", False):
            print(
                "measure-diff,FAIL,learned filter saved only "
                f"{lf.get('measurement_reduction_frac', '?')} of real "
                "measurements (bar: 0.30)",
                file=sys.stderr,
            )
            rc = 1
        if not lf.get("best_within_5pct", False):
            print(
                "measure-diff,FAIL,learned-filtered best cost "
                f"{lf.get('best_cost_ratio', '?')}x the unfiltered best "
                "(bar: 1.05)",
                file=sys.stderr,
            )
            rc = 1
    ss = cur.get("sharded_search")
    if ss is not None:
        # partition invariants hold run-by-run too (block absent from
        # pre-shard artifacts, which is fine): the measured sets must be
        # disjoint and the elect-and-merge must land the single-engine
        # best exactly
        if not ss.get("meets_shard_invariants", False):
            print(
                "measure-diff,FAIL,sharded search broke an invariant: "
                f"disjoint={ss.get('shard_disjoint')} "
                f"merged_matches_single={ss.get('merged_best_matches_single')} "
                f"election_reproducible={ss.get('election_reproducible')} "
                f"errors={ss.get('errors')}",
                file=sys.stderr,
            )
            rc = 1
    try:
        prev = _load_baseline(base)
    except (subprocess.CalledProcessError, FileNotFoundError, json.JSONDecodeError):
        print(f"measure-diff,baseline_missing,{base}")
        return rc
    cur_tps, prev_tps = _warm_tps(cur), _warm_tps(prev)
    regression = 1.0 - cur_tps / prev_tps if prev_tps > 0 else 0.0
    print(f"measure-diff,baseline_warm_trials_per_s,{prev_tps}")
    print(f"measure-diff,current_warm_trials_per_s,{cur_tps}")
    print(f"measure-diff,regression_frac,{regression:+.3f}")
    if regression > threshold:
        print(
            f"measure-diff,FAIL,warm trials/sec regressed "
            f"{regression:.1%} > {threshold:.0%} "
            f"({prev_tps} -> {cur_tps})",
            file=sys.stderr,
        )
        return 1
    if rc == 0:
        print(f"measure-diff,OK,within {threshold:.0%}")
    return rc


def diff_serve(
    current: str = BENCH_SERVE,
    base: str = "HEAD",
    threshold: float = 0.20,
) -> int:
    """Serving regression gate over ``BENCH_serve.json``:

    * stream ``service_tok_s`` (saturated engine throughput, pure-XLA
      policy — the stable timing) must not regress more than
      ``threshold`` vs the committed baseline;
    * two noise-free counter invariants must hold in the *current* run
      regardless of baseline: a warm-restart engine reports zero fresh
      compiles, and the tuned engine's trace actually consumed a tuning
      record (``tuned_record_dispatched``).
    """
    with open(current) as f:
        cur = json.load(f)
    rc = 0
    if not cur.get("warm_restart", {}).get("zero_fresh_compiles", False):
        print(
            "serve-diff,FAIL,warm restart recompiled "
            f"{cur.get('warm_restart', {}).get('compiles', '?')} executables",
            file=sys.stderr,
        )
        rc = 1
    if not cur.get("tuned_record_dispatched", False):
        print(
            "serve-diff,FAIL,tuned engine trace did not consume a "
            "tuning record",
            file=sys.stderr,
        )
        rc = 1
    try:
        prev = _load_baseline(base, BENCH_SERVE)
    except (subprocess.CalledProcessError, FileNotFoundError, json.JSONDecodeError):
        print(f"serve-diff,baseline_missing,{base}")
        return rc
    cur_tps = float(cur["stream"]["service_tok_s"])
    prev_tps = float(prev["stream"]["service_tok_s"])
    regression = 1.0 - cur_tps / prev_tps if prev_tps > 0 else 0.0
    print(f"serve-diff,baseline_service_tok_s,{prev_tps}")
    print(f"serve-diff,current_service_tok_s,{cur_tps}")
    print(f"serve-diff,regression_frac,{regression:+.3f}")
    if regression > threshold:
        print(
            f"serve-diff,FAIL,stream service tok/s regressed "
            f"{regression:.1%} > {threshold:.0%} "
            f"({prev_tps} -> {cur_tps})",
            file=sys.stderr,
        )
        rc = 1
    elif rc == 0:
        print(f"serve-diff,OK,within {threshold:.0%}")
    return rc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced protocol")
    ap.add_argument(
        "--only", default=None,
        choices=["fig7", "fig8", "kernel", "measure", "serve", "roofline"],
    )
    ap.add_argument("--diff", action="store_true",
                    help="diff BENCH_measure.json against the committed "
                         "baseline and exit (no benchmarks are run)")
    ap.add_argument("--diff-serve", action="store_true",
                    help="diff BENCH_serve.json against the committed "
                         "baseline and exit (no benchmarks are run)")
    ap.add_argument("--diff-base", default="HEAD",
                    help="baseline for --diff/--diff-serve: a git ref "
                         "(committed bench JSON) or a JSON file path")
    ap.add_argument("--diff-threshold", type=float, default=0.20,
                    help="max tolerated throughput regression fraction "
                         "before --diff/--diff-serve fails (default 0.20)")
    args = ap.parse_args()

    if args.diff or args.diff_serve:
        rc = 0
        if args.diff:
            rc |= diff_measure(
                base=args.diff_base, threshold=args.diff_threshold
            )
        if args.diff_serve:
            rc |= diff_serve(
                base=args.diff_base, threshold=args.diff_threshold
            )
        sys.exit(rc)

    from . import (
        fig7,
        fig8,
        kernel_bench,
        measure_bench,
        roofline_report,
        serve_bench,
    )

    jobs = {
        "fig7": lambda: fig7.main(quick=args.quick),
        "fig8": lambda: fig8.main(quick=args.quick),
        "kernel": lambda: kernel_bench.main(quick=args.quick),
        "measure": lambda: measure_bench.main(quick=args.quick),
        "serve": lambda: serve_bench.main(quick=args.quick),
        "roofline": roofline_report.main,
    }
    if args.only:
        jobs = {args.only: jobs[args.only]}
    for name, fn in jobs.items():
        t0 = time.monotonic()
        print(f"==== {name} ====", flush=True)
        try:
            fn()
        except Exception as e:  # pragma: no cover
            print(f"{name},ERROR,{type(e).__name__}: {e}", file=sys.stderr)
            raise
        print(f"{name},elapsed_s,{time.monotonic() - t0:.1f}", flush=True)


if __name__ == "__main__":
    main()

"""Benchmark harness entry point — one function per paper table/figure.

``python -m benchmarks.run [--quick]`` prints ``name,us_per_call,derived``
CSV per the repo contract, then the full figure protocols:

  fig7   — Fig. 7a/7b: cost-vs-fraction and cost-vs-time @ 1024^3
  fig8   — Fig. 8a/8b: multi-size @0.1% and variance boxplot
  kernel — tuned-vs-heuristic GEMM (analytical model + real XLA:CPU)
  measure — real-measurement hot-path throughput (BENCH_measure.json:
            cold vs warm-compile-cache trials/sec, journal replay,
            process lanes)
  roofline — dry-run roofline table (if dry-run records exist)
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced protocol")
    ap.add_argument(
        "--only", default=None,
        choices=["fig7", "fig8", "kernel", "measure", "roofline"],
    )
    args = ap.parse_args()

    from . import fig7, fig8, kernel_bench, measure_bench, roofline_report

    jobs = {
        "fig7": lambda: fig7.main(quick=args.quick),
        "fig8": lambda: fig8.main(quick=args.quick),
        "kernel": lambda: kernel_bench.main(quick=args.quick),
        "measure": lambda: measure_bench.main(quick=args.quick),
        "roofline": roofline_report.main,
    }
    if args.only:
        jobs = {args.only: jobs[args.only]}
    for name, fn in jobs.items():
        t0 = time.monotonic()
        print(f"==== {name} ====", flush=True)
        try:
            fn()
        except Exception as e:  # pragma: no cover
            print(f"{name},ERROR,{type(e).__name__}: {e}", file=sys.stderr)
            raise
        print(f"{name},elapsed_s,{time.monotonic() - t0:.1f}", flush=True)


if __name__ == "__main__":
    main()

import os
import sys

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
if _SRC not in [os.path.abspath(p) for p in sys.path]:
    sys.path.insert(0, _SRC)

"""§Perf hillclimb driver: re-run a dry-run cell under named variants
(config / sharding-rule overrides) and tabulate the three roofline terms
per variant, so each hypothesis → change → measure iteration is one
command:

  PYTHONPATH=src python -m benchmarks.hillclimb --cell qwen2-72b:train_4k \
      --variants baseline,ga2,flash2k,remat_dots

Variant records land in experiments/dryrun/single-<variant>/ so nothing
overwrites the baseline table.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src")))


def variant_space(cfg, rules):
    """Named variants: (cfg_override, rules_override) builders."""

    def no_sp(r):
        return dataclasses.replace(r, sp=None)

    def no_fsdp(r):
        return dataclasses.replace(r, fsdp=False)

    return {
        "baseline": (cfg, rules),
        # microbatching: 2 gradient-accumulation steps (halves live batch)
        "ga2": (dataclasses.replace(cfg, dryrun_grad_accum=2), rules),
        "ga4": (dataclasses.replace(cfg, dryrun_grad_accum=4), rules),
        # flash-chunked attention already at 4k (threshold below seq)
        "flash2k": (dataclasses.replace(cfg, attn_chunk_threshold=2048), rules),
        # remat policy comparison
        "remat_dots": (dataclasses.replace(cfg, remat="dots"), rules),
        "remat_none": (dataclasses.replace(cfg, remat="none"), rules),
        # sharding ablations
        "no_sp": (cfg, no_sp(rules)),
        "no_fsdp": (cfg, no_fsdp(rules)),
        # MoE strategy flips
        "moe_tp": (dataclasses.replace(cfg, moe_shard="tp"), rules),
        "moe_ep": (dataclasses.replace(cfg, moe_shard="ep"), rules),
        "cap1": (dataclasses.replace(cfg, moe_capacity_factor=1.0), rules),
        # explicit shard_map all-to-all expert dispatch (beyond-GSPMD)
        "moe_a2a": (dataclasses.replace(cfg, moe_impl="a2a"), rules),
        "moe_a2a_flash2k": (
            dataclasses.replace(cfg, moe_impl="a2a", attn_chunk_threshold=2048),
            rules,
        ),
        # combos
        "ga2_flash2k": (
            dataclasses.replace(cfg, dryrun_grad_accum=2, attn_chunk_threshold=2048),
            rules,
        ),
        "ga4_flash2k": (
            dataclasses.replace(cfg, dryrun_grad_accum=4, attn_chunk_threshold=2048),
            rules,
        ),
        "cap1_flash2k": (
            dataclasses.replace(cfg, moe_capacity_factor=1.0, attn_chunk_threshold=2048),
            rules,
        ),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="<arch>:<shape>")
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    arch, shape = args.cell.split(":")

    from repro.configs.registry import get_arch
    from repro.launch.dryrun import run_cell
    from repro.launch.mesh import make_production_mesh, rules_for_mesh

    base_cfg = get_arch(arch)
    base_rules = rules_for_mesh(make_production_mesh(multi_pod=args.mesh == "multi"))
    table = variant_space(base_cfg, base_rules)

    rows = []
    for name in args.variants.split(","):
        if name not in table:
            print(f"unknown variant {name}; have {sorted(table)}")
            continue
        cfg_v, rules_v = table[name]
        rec = run_cell(
            arch, shape, args.mesh,
            rules_override=rules_v,
            cfg_override=cfg_v,
            tag=name if name != "baseline" else "",
        )
        if rec["status"] == "ok":
            r = rec["roofline"]
            rows.append(
                (name, r["compute_s"], r["memory_s"], r["collective_s"],
                 r["dominant"], r["useful_ratio"], rec["fits_hbm"],
                 rec["memory_analysis"].get("temp_size_in_bytes", 0) / 1e9)
            )
        else:
            rows.append((name, None, rec.get("error", rec.get("reason", ""))))
    print(f"\n== hillclimb {args.cell} ({args.mesh}) ==")
    print(f"{'variant':12s} {'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
          f"{'dominant':>10s} {'useful':>7s} {'fits':>5s} {'tempGB':>7s}")
    for row in rows:
        if row[1] is None:
            print(f"{row[0]:12s} ERROR {row[2][:80]}")
        else:
            n, c, m, co, dom, u, fits, temp = row
            print(f"{n:12s} {c:10.3e} {m:10.3e} {co:10.3e} {dom:>10s} "
                  f"{u:7.3f} {str(fits):>5s} {temp:7.1f}")


if __name__ == "__main__":
    main()

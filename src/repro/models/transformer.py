"""Transformer model zoo: dense GQA decoders, MoE decoders, VLM backbones
(stub frontend), and encoder–decoder (whisper family).

Functional style: ``init_params(cfg, key)`` builds a pytree of arrays
(layers stacked on a leading axis so the forward pass can
``lax.scan`` over them — this keeps the lowered HLO size independent of
depth, which is what makes 80–95-layer dry-runs compile fast);
``loss_fn`` / ``prefill`` / ``decode_step`` are pure functions of
(cfg, params, batch).  Sharding is injected via
``repro.dist.api.constrain`` (no-op outside a mesh context).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.api import constrain, logical
from repro.models import common as cm

__all__ = [
    "init_params",
    "loss_fn",
    "lm_loss_from_logits",
    "forward_logits",
    "prefill",
    "decode_step",
    "init_cache",
]


def _dt(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


# =============================================================================
# per-block params
# =============================================================================


def init_attn(key, cfg: ArchConfig, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    dt = _dt(cfg)
    ks = jax.random.split(key, 4)
    return {
        "wq": cm.init_dense(ks[0], d, h * hd, dt, bias=cfg.qkv_bias),
        "wk": cm.init_dense(ks[1], d, kv * hd, dt, bias=cfg.qkv_bias),
        "wv": cm.init_dense(ks[2], d, kv * hd, dt, bias=cfg.qkv_bias),
        "wo": cm.init_dense(ks[3], h * hd, d, dt),
    }


def init_mlp(key, cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    dt = _dt(cfg)
    ks = jax.random.split(key, 3)
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {
            "wi": cm.init_dense(ks[0], d, f, dt),
            "wg": cm.init_dense(ks[1], d, f, dt),
            "wo": cm.init_dense(ks[2], f, d, dt),
        }
    return {
        "wi": cm.init_dense(ks[0], d, f, dt),
        "wo": cm.init_dense(ks[2], f, d, dt),
    }


def init_moe(key, cfg: ArchConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = _dt(cfg)
    ks = jax.random.split(key, 4)
    gated = cfg.mlp_kind in ("swiglu", "geglu")
    p = {
        "router": {"w": cm.trunc_normal(ks[0], (d, e), 1.0 / math.sqrt(d), jnp.float32)},
        "wi": cm.trunc_normal(ks[1], (e, d, f), 1.0 / math.sqrt(d), dt),
        "wo": cm.trunc_normal(ks[3], (e, f, d), 1.0 / math.sqrt(f), dt),
    }
    if gated:
        p["wg"] = cm.trunc_normal(ks[2], (e, d, f), 1.0 / math.sqrt(d), dt)
    return p


def init_block(key, cfg: ArchConfig, moe: bool, cross: bool = False) -> dict:
    ks = jax.random.split(key, 5)
    d = cfg.d_model
    dt = _dt(cfg)
    p = {
        "ln1": cm.init_norm(d, cfg.norm, dt),
        "attn": init_attn(ks[0], cfg),
        "ln2": cm.init_norm(d, cfg.norm, dt),
        "mlp": init_moe(ks[1], cfg) if moe else init_mlp(ks[1], cfg),
    }
    if cross:
        p["ln_cross"] = cm.init_norm(d, cfg.norm, dt)
        p["cross"] = init_attn(ks[2], cfg, cross=True)
    return p


# =============================================================================
# block application
# =============================================================================


def attn_apply(
    cfg: ArchConfig,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    cross: bool = False,
    kv_cache: Optional[dict] = None,
    cache_len: Optional[jax.Array] = None,
    xkv: Optional[jax.Array] = None,
    valid_len: Optional[jax.Array] = None,
    prefix_len: Optional[jax.Array] = None,
):
    """Self- or cross-attention.  Returns (out, new_kv | None).

    self, no cache:   keys/values from x (train / prefill)
    self, cache:      decode — append (B,1) K/V at cache_len, attend prefix
    cross, no cache:  keys/values from xkv = encoder output
    cross, cache:     decode — attend precomputed encoder K/V in cache"""
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = cm.dense(p["wq"], x).reshape(b, s, h, hd)
    if cross and kv_cache is not None:
        if cfg.pos_embed == "rope":
            pass  # no rope on cross-attention queries (whisper family)
        out = cm.cross_attention(q, kv_cache["k"], kv_cache["v"], softcap=cfg.attn_softcap)
        return cm.dense(p["wo"], out.reshape(b, s, h * hd)), None

    src = x if xkv is None else xkv
    k = cm.dense(p["wk"], src).reshape(b, src.shape[1], kvh, hd)
    v = cm.dense(p["wv"], src).reshape(b, src.shape[1], kvh, hd)
    if cfg.pos_embed == "rope" and not cross:
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
    # NOTE: no manual q/k constraints — over-constraining forced
    # replicated-K layouts whose backward all-reduced (T, d) f32 grads
    # every layer; GSPMD propagates head sharding from the weights.

    new_kv = None
    if cross:
        out = cm.cross_attention(q, k, v, softcap=cfg.attn_softcap)
    elif kv_cache is not None:  # self-attention decode: append to cache
        kc = jax.lax.dynamic_update_slice_in_dim(kv_cache["k"], k, cache_len, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(kv_cache["v"], v, cache_len, axis=1)
        kc = constrain(kc, logical(None, "kv_seq", None, None) if b == 1 else logical("dp", None, None, None))
        vc = constrain(vc, logical(None, "kv_seq", None, None) if b == 1 else logical("dp", None, None, None))
        new_kv = {"k": kc, "v": vc}
        out = cm.decode_attention(q, kc, vc, cache_len + s, softcap=cfg.attn_softcap,
                                  valid_len=valid_len, prefix_len=prefix_len)
    else:
        if not causal:
            out = cm.cross_attention(q, k, v, softcap=cfg.attn_softcap)
        else:
            out = cm.attention_dispatch(
                q, k, v, softcap=cfg.attn_softcap,
                chunk_threshold=cfg.attn_chunk_threshold,
            )
        # the cached copies are sequence-sharded like the prefill cache
        new_kv = {
            "k": constrain(k, logical("dp", "sp", None, None)),
            "v": constrain(v, logical("dp", "sp", None, None)),
        }
    return cm.dense(p["wo"], out.reshape(b, s, h * hd)), new_kv


def mlp_apply(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.mlp_kind in ("swiglu", "geglu"):
        hidden = cm.mlp_act(cfg.mlp_kind, cm.dense(p["wi"], x), cm.dense(p["wg"], x))
    else:
        hidden = cm.mlp_act(cfg.mlp_kind, cm.dense(p["wi"], x))
    return cm.dense(p["wo"], hidden)


def _moe_route(cfg: ArchConfig, p: dict, xf: jax.Array):
    """Router: top-k experts + weights + aux losses (global, tiny)."""
    e, k = cfg.n_experts, cfg.experts_per_token
    router_logits = jnp.einsum(
        "td,de->te", xf.astype(jnp.float32), p["router"]["w"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)
    if cfg.router_norm_topk:
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32), axis=0)
    density_prob = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(density * density_prob)
    zloss = jnp.mean(jax.nn.logsumexp(router_logits, axis=-1) ** 2)
    return top_e, top_w, 0.01 * aux + 1e-3 * zloss


def _sorted_capacity_buffers(t: int, e: int, cap: int, k: int, top_e, top_w):
    """Sorted-dispatch bookkeeping shared by both MoE impls.  Returns
    (buf_tok (e,cap), buf_valid (e,cap), inv (t,k) slot-or--1)."""
    flat_e = top_e.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t * k) - starts[sorted_e]
    keep = pos_in_e < cap
    slot = sorted_e * cap + jnp.where(keep, pos_in_e, 0)
    buf_tok = jnp.zeros((e * cap,), jnp.int32).at[slot].set(
        jnp.where(keep, sorted_tok, 0)
    )
    buf_valid = jnp.zeros((e * cap,), bool).at[slot].max(keep)
    inv = jnp.full((t * k,), -1, jnp.int32).at[order].set(jnp.where(keep, slot, -1))
    return buf_tok.reshape(e, cap), buf_valid.reshape(e, cap), inv.reshape(t, k)


def _expert_ffn(cfg: ArchConfig, p_or_weights, xe):
    wi = p_or_weights["wi"]
    wo = p_or_weights["wo"]
    if "wg" in p_or_weights:
        hid = cm.mlp_act(
            cfg.mlp_kind,
            jnp.einsum("ecd,edf->ecf", xe, wi),
            jnp.einsum("ecd,edf->ecf", xe, p_or_weights["wg"]),
        )
    else:
        hid = cm.mlp_act(cfg.mlp_kind, jnp.einsum("ecd,edf->ecf", xe, wi))
    return hid, wo


def moe_apply_a2a(cfg: ArchConfig, p: dict, x: jax.Array, mesh, rules):
    """Expert dispatch/combine with EXPLICIT all-to-all under shard_map.

    Pure-GSPMD dispatch gathers index across shards, which the partitioner
    lowers by REPLICATING the (T_global, d) token buffer (17 GB/device on
    qwen3 — measured, §Perf cell 2).  Here every device routes its LOCAL
    tokens into per-expert send buffers, one all-to-all over the model
    axis delivers them to the expert owners, the expert FFN runs with
    FSDP-gathered weights, and the reverse all-to-all brings results home.
    shard_map collectives are differentiable (all_to_all^T = all_to_all,
    all_gather^T = psum_scatter), so the same code serves training."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.experts_per_token
    xf = x.reshape(t, d)
    top_e, top_w, aux_total = _moe_route(cfg, p, xf)

    dp_ax = rules.get("dp")
    dp_axes = dp_ax if isinstance(dp_ax, tuple) else (dp_ax,)
    dp_axes = tuple(a for a in dp_axes if a in mesh.shape)
    tok_axes = dp_axes + ("model",)
    n_tok_shards = 1
    for a in tok_axes:
        n_tok_shards *= mesh.shape[a]
    m_size = mesh.shape["model"]
    t_dev = t // n_tok_shards
    gated = "wg" in p
    # few-expert case (grok: 8 experts < 16-way model axis): r model
    # shards co-own each expert; capacity splits across the replicas
    r = 1 if e % m_size == 0 else m_size // e
    cap_dev = max(r, int(k * t_dev * cfg.moe_capacity_factor / e))
    cap_dev = ((cap_dev + r - 1) // r) * r  # divisible by the replica count

    def local(xf_l, te_l, tw_l, wi_l, wg_l, wo_l):
        # xf_l: (t_dev, d); te/tw: (t_dev, k)
        buf_tok, buf_valid, inv = _sorted_capacity_buffers(
            t_dev, e, cap_dev, k, te_l, tw_l
        )
        send = xf_l[buf_tok] * buf_valid[..., None].astype(xf_l.dtype)  # (e,cap,d)
        if r > 1:
            send = send.reshape(e * r, cap_dev // r, d)
        recv = jax.lax.all_to_all(
            send, "model", split_axis=0, concat_axis=1, tiled=True
        )  # e>=m: (e/m, cap_dev*m, d);  e<m: (1, (cap_dev//r)*m, d)

        if r > 1:
            # this device owns expert (model_index // r): slice, then
            # FSDP-gather only that expert's weights over dp
            e_idx = jax.lax.axis_index("model") // r
            def slice_gather(w):  # (e, d/dp, f) -> (d, f)
                we = jax.lax.dynamic_index_in_dim(w, e_idx, 0, keepdims=False)
                return jax.lax.all_gather(we, dp_axes, axis=0, tiled=True)
            wi_f, wo_f = slice_gather(wi_l), slice_gather(wo_l)
            tok = recv.reshape(-1, d)
            hid_in = tok @ wi_f
            if gated:
                hid = cm.mlp_act(cfg.mlp_kind, hid_in, tok @ slice_gather(wg_l))
            else:
                hid = cm.mlp_act(cfg.mlp_kind, hid_in)
            ye = (hid @ wo_f).reshape(*recv.shape[:-1], d)
        else:
            wi_f = jax.lax.all_gather(wi_l, dp_axes, axis=1, tiled=True)
            wo_f = jax.lax.all_gather(wo_l, dp_axes, axis=1, tiled=True)
            weights = {"wi": wi_f, "wo": wo_f}
            if gated:  # static: ungated models never gather wg_l
                weights["wg"] = jax.lax.all_gather(wg_l, dp_axes, axis=1, tiled=True)
            hid, wo_full = _expert_ffn(cfg, weights, recv)
            ye = jnp.einsum("ecf,efd->ecd", hid, wo_full)

        back = jax.lax.all_to_all(
            ye, "model", split_axis=1, concat_axis=0, tiled=True
        )
        flat = back.reshape(e * cap_dev, d)
        gathered = flat[inv.clip(0)] * (inv >= 0)[..., None].astype(flat.dtype)
        return jnp.einsum("tkd,tk->td", gathered, tw_l.astype(flat.dtype))

    tok_spec = P(tok_axes, None)
    # e >= m: experts sharded on model; e < m: experts replicated on model
    w_spec = P("model", dp_axes, None) if r == 1 else P(None, dp_axes, None)
    in_specs = [tok_spec, P(tok_axes, None), P(tok_axes, None), w_spec, w_spec, w_spec]
    wg = p.get("wg")
    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=tok_spec,
        check_rep=False,
    )
    out = fn(xf, top_e, top_w, p["wi"], wg if wg is not None else p["wi"], p["wo"])
    # (when ungated, wg input is a dummy alias; `local` ignores it)
    return out.reshape(b, s, d), aux_total


def moe_apply(cfg: ArchConfig, p: dict, x: jax.Array):
    """Top-k routed MoE with capacity buffers (GShard/Switch-style sorted
    dispatch — O(T·k) memory, expert-parallel friendly).

    Returns (out, aux_loss)."""
    from repro.dist.api import current_mesh, current_rules

    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.experts_per_token
    mesh = current_mesh()
    if (
        cfg.moe_impl == "a2a"
        and mesh is not None
        and "model" in mesh.shape
        and (e % mesh.shape["model"] == 0 or mesh.shape["model"] % e == 0)
        and t % mesh.devices.size == 0
    ):
        return moe_apply_a2a(cfg, p, x, mesh, current_rules())
    xf = x.reshape(t, d)

    router_logits = jnp.einsum(
        "td,de->te", xf.astype(jnp.float32), p["router"]["w"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)  # (t, k)
    if cfg.router_norm_topk:
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # -- load-balance aux (Switch) + router z-loss ---------------------------
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32), axis=0)
    density_prob = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(density * density_prob)
    zloss = jnp.mean(jax.nn.logsumexp(router_logits, axis=-1) ** 2)
    aux_total = 0.01 * aux + 1e-3 * zloss

    # -- sorted capacity dispatch --------------------------------------------
    cap = max(1, int(k * t * cfg.moe_capacity_factor / e))
    flat_e = top_e.reshape(-1)  # (t*k,)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]
    # position of each entry within its expert group
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t * k) - starts[sorted_e]
    keep = pos_in_e < cap
    slot = sorted_e * cap + jnp.where(keep, pos_in_e, 0)

    # gather tokens into (e, cap, d) buffers; the INDEX buffers are
    # sharded (expert, capacity) FIRST so the gather executes shard-local
    # (the all-to-all of token rows is the dispatch collective) instead of
    # materializing a replicated (e, cap, d) — which cost 32 GB/device
    buf_tok = jnp.full((e * cap,), 0, jnp.int32)
    buf_valid = jnp.zeros((e * cap,), bool)
    buf_tok = buf_tok.at[slot].set(jnp.where(keep, sorted_tok, 0))
    buf_valid = buf_valid.at[slot].max(keep)
    buf_tok2 = constrain(buf_tok.reshape(e, cap), logical("expert", "expert_cap"))
    buf_valid2 = constrain(buf_valid.reshape(e, cap), logical("expert", "expert_cap"))
    xe = xf[buf_tok2] * buf_valid2[..., None].astype(xf.dtype)
    xe = constrain(xe, logical("expert", "expert_cap", None))

    # expert FFN (batched einsum over the expert dim)
    if "wg" in p:
        hid = cm.mlp_act(
            cfg.mlp_kind,
            jnp.einsum("ecd,edf->ecf", xe, p["wi"]),
            jnp.einsum("ecd,edf->ecf", xe, p["wg"]),
        )
    else:
        hid = cm.mlp_act(cfg.mlp_kind, jnp.einsum("ecd,edf->ecf", xe, p["wi"]))
    hid = constrain(hid, logical("expert", "expert_cap", "expert_ffn"))
    ye = constrain(
        jnp.einsum("ecf,efd->ecd", hid, p["wo"]),
        logical("expert", "expert_cap", None),
    )

    # combine back as a token-sharded GATHER (a scatter-add here makes
    # GSPMD replicate the full (t, d) accumulator — 25 GB/dev on grok);
    # inv[t, j] = slot of (token t, choice j), -1 if dropped
    inv = jnp.full((t * k,), -1, jnp.int32)
    inv = inv.at[order].set(jnp.where(keep, slot, -1))
    inv2 = constrain(inv.reshape(t, k), logical("dp", None))
    w2 = constrain(top_w.astype(ye.dtype), logical("dp", None))
    gathered = ye.reshape(e * cap, d)[inv2.clip(0)]  # (t, k, d)
    gathered = gathered * (inv2 >= 0)[..., None].astype(ye.dtype) * w2[..., None]
    out = gathered.sum(axis=1)
    return out.reshape(b, s, d), aux_total


def block_apply(
    cfg: ArchConfig,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    moe: bool,
    causal: bool = True,
    kv_cache: Optional[dict] = None,
    cache_len=None,
    cross_kv: Optional[dict] = None,
    enc_out: Optional[jax.Array] = None,
    valid_len=None,
    prefix_len=None,
):
    """One transformer block.  Returns (x, new_kv, aux)."""
    h = constrain(cm.norm_apply(p["ln1"], x, cfg.norm, cfg.norm_eps),
                  logical("dp", "sp", None))
    a, new_kv = attn_apply(
        cfg, p["attn"], h, positions, causal=causal, kv_cache=kv_cache,
        cache_len=cache_len, valid_len=valid_len, prefix_len=prefix_len,
    )
    a = constrain(a, logical("dp", "sp", None))  # reduce-scatter into seq shards
    x = x + a
    if "cross" in p:
        h = cm.norm_apply(p["ln_cross"], x, cfg.norm, cfg.norm_eps)
        c, _ = attn_apply(
            cfg, p["cross"], h, positions, cross=True,
            kv_cache=cross_kv, cache_len=cache_len, xkv=enc_out,
        )
        x = x + c
    h = constrain(cm.norm_apply(p["ln2"], x, cfg.norm, cfg.norm_eps),
                  logical("dp", "sp", None))
    aux = jnp.zeros((), jnp.float32)
    if moe:
        m, aux = moe_apply(cfg, p["mlp"], h)
    else:
        m = mlp_apply(cfg, p["mlp"], h)
    m = constrain(m, logical("dp", "sp", None))
    x = x + m
    x = constrain(x, logical("dp", "sp", None))
    return x, new_kv, aux


# =============================================================================
# full models
# =============================================================================


def init_params(cfg: ArchConfig, key) -> dict:
    dt = _dt(cfg)
    ks = jax.random.split(key, 8)
    v, d = cfg.padded_vocab, cfg.d_model
    moe = cfg.family == "moe"
    p: dict = {
        "embed": {"table": cm.trunc_normal(ks[0], (v, d), d ** -0.5, dt)},
        "ln_f": cm.init_norm(d, cfg.norm, dt),
    }
    if not cfg.tie_embeddings:
        p["head"] = {"w": cm.trunc_normal(ks[1], (d, v), 1.0 / math.sqrt(d), dt)}

    cross = cfg.family == "encdec"
    layer_keys = jax.random.split(ks[2], cfg.n_layers)
    p["layers"] = jax.vmap(lambda k: init_block(k, cfg, moe=moe, cross=cross))(layer_keys)

    if cfg.family == "encdec":
        enc_keys = jax.random.split(ks[3], cfg.n_encoder_layers)
        p["encoder"] = {
            "layers": jax.vmap(lambda k: init_block(k, cfg, moe=False))(enc_keys),
            "ln_f": cm.init_norm(d, cfg.norm, dt),
        }
    if cfg.pos_embed == "learned":
        p["pos_table"] = cm.trunc_normal(ks[4], (32768, d), 0.02, dt)
    return p


def _remat(cfg: ArchConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def _scan_blocks(cfg: ArchConfig, layers, x, positions, *, moe, causal=True,
                 enc_out=None, collect_kv=False):
    """lax.scan over the stacked layer params."""

    def body(carry, layer_p):
        x, aux = carry
        x2, kv, a = block_apply(
            cfg, layer_p, x, positions, moe=moe, causal=causal, enc_out=enc_out
        )
        ys = kv if collect_kv else None
        return (x2, aux + a), ys

    body = _remat(cfg, body)
    (x, aux), kvs = cm.scan_or_unroll(
        cfg.scan_layers, body, (x, jnp.zeros((), jnp.float32)), layers
    )
    return x, aux, kvs


def embed_tokens(cfg: ArchConfig, params: dict, tokens: jax.Array) -> jax.Array:
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    return constrain(x, logical("dp", "sp", None))


def lm_logits(cfg: ArchConfig, params: dict, x: jax.Array) -> jax.Array:
    x = cm.norm_apply(params["ln_f"], x, cfg.norm, cfg.norm_eps)
    if cfg.tie_embeddings:
        w = params["embed"]["table"].T
    else:
        w = params["head"]["w"]
    from repro.kernels.ops import gemm

    logits = gemm(x, w).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    return constrain(logits, logical("dp", None, "tp"))


def _encode(cfg: ArchConfig, params: dict, frames: jax.Array):
    """Whisper-family encoder over precomputed frame embeddings (conv
    frontend is a stub per the assignment)."""
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x = x + cm.sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    pos = jnp.arange(x.shape[1])[None, :]
    x, _, _ = _scan_blocks(cfg, params["encoder"]["layers"], x, pos, moe=False, causal=False)
    return cm.norm_apply(params["encoder"]["ln_f"], x, cfg.norm, cfg.norm_eps)


def forward_hidden(cfg: ArchConfig, params: dict, batch: dict):
    """Training/prefill forward to the FINAL HIDDEN states (pre ln_f).
    batch:
      tokens (B, S_text) int32
      [frontend_embeds (B, S_front, d)]   vlm patch / audio frame stub
      [enc_frames (B, S_enc, d)]          encdec encoder input
    Returns (x (B, S, d), aux_loss)."""
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    if cfg.frontend != "none" and "frontend_embeds" in batch:
        fe = batch["frontend_embeds"].astype(x.dtype)
        x = jnp.concatenate([fe, x], axis=1)
    positions = jnp.arange(x.shape[1])[None, :]
    if cfg.pos_embed == "learned":
        x = x + jnp.take(params["pos_table"], positions[0] % params["pos_table"].shape[0], axis=0)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(cfg, params, batch["enc_frames"])
    moe = cfg.family == "moe"
    x, aux, _ = _scan_blocks(
        cfg, params["layers"], x, positions, moe=moe, enc_out=enc_out
    )
    return x, aux


def forward_logits(cfg: ArchConfig, params: dict, batch: dict):
    x, aux = forward_hidden(cfg, params, batch)
    return lm_logits(cfg, params, x), aux


def lm_loss_from_logits(cfg: ArchConfig, logits: jax.Array, aux: jax.Array,
                        labels: jax.Array):
    """Cross-entropy (+ MoE aux, + z-loss).  labels -1 = masked.  Shared
    across all families (dense/moe/ssm/hybrid/encdec/vlm)."""
    if logits.shape[1] != labels.shape[1]:  # vlm frontend positions are unsupervised
        pad = logits.shape[1] - labels.shape[1]
        labels = jnp.concatenate(
            [jnp.full((labels.shape[0], pad), -1, labels.dtype), labels], axis=1
        )
    valid = labels >= 0
    lab = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(valid.sum(), 1)
    ce = -jnp.sum(jnp.where(valid, ll, 0.0)) / denom
    zloss = 1e-4 * jnp.sum(jnp.where(valid, jax.nn.logsumexp(logits, -1) ** 2, 0.0)) / denom
    loss = ce + zloss + aux
    metrics = {
        "loss": loss,
        "ce": ce,
        "aux": aux,
        "tokens": valid.sum(),
        "accuracy": jnp.sum(jnp.where(valid, (jnp.argmax(logits, -1) == lab), 0)) / denom,
    }
    return loss, metrics


def streaming_lm_loss(cfg: ArchConfig, params: dict, x: jax.Array,
                      labels: jax.Array, aux: jax.Array,
                      chunk: int = 512):
    """CE + z-loss WITHOUT materializing (B, S, V) logits: scan over
    sequence chunks, each chunk computing its own logits -> per-token
    loss pieces.  Cuts the dominant train-step temp buffer (the f32
    logits were ~10 GB/device at 4k x 256 x 150k-vocab) to
    (B, chunk, V) with the chunk body rematerialized in backward."""
    x = cm.norm_apply(params["ln_f"], x, cfg.norm, cfg.norm_eps)
    w = params["embed"]["table"].T if cfg.tie_embeddings else params["head"]["w"]
    b, s, d = x.shape
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s  # fallback: odd lengths take the unchunked path
    n_chunks = s // chunk
    xc = x.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_body(carry, inp):
        ce_sum, z_sum, acc_sum, n_valid = carry
        xi, li = inp  # (b, chunk, d), (b, chunk)
        from repro.kernels.ops import gemm

        logits = gemm(xi, w).astype(jnp.float32)
        if cfg.padded_vocab != cfg.vocab_size:
            pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
            logits = jnp.where(pad_mask, -1e30, logits)
        logits = constrain(logits, logical("dp", None, "tp"))
        valid = li >= 0
        lab = jnp.where(valid, li, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        ce = jnp.sum(jnp.where(valid, lse - picked, 0.0))
        zl = jnp.sum(jnp.where(valid, lse**2, 0.0))
        acc = jnp.sum(jnp.where(valid, jnp.argmax(logits, -1) == lab, 0))
        return (ce_sum + ce, z_sum + zl, acc_sum + acc, n_valid + valid.sum()), None

    init = (jnp.zeros(()), jnp.zeros(()), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32))
    (ce_sum, z_sum, acc_sum, n_valid), _ = jax.lax.scan(chunk_body, init, (xc, lc))
    denom = jnp.maximum(n_valid, 1)
    ce = ce_sum / denom
    zloss = 1e-4 * z_sum / denom
    loss = ce + zloss + aux
    metrics = {
        "loss": loss,
        "ce": ce,
        "aux": aux,
        "tokens": n_valid,
        "accuracy": acc_sum / denom,
    }
    return loss, metrics


def loss_fn(cfg: ArchConfig, params: dict, batch: dict):
    logits, aux = forward_logits(cfg, params, batch)
    return lm_loss_from_logits(cfg, logits, aux, batch["labels"])


# =============================================================================
# serving: prefill + decode
# =============================================================================


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> dict:
    dt = dtype or jnp.dtype(cfg.compute_dtype)
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_len, kvh, hd)
    cache = {
        "k": jnp.zeros(shape, dt),
        "v": jnp.zeros(shape, dt),
        "len": jnp.zeros((), jnp.int32),
    }
    if cfg.family == "encdec":
        eshape = (cfg.n_layers, batch, cfg.encoder_len, kvh, hd)
        cache["cross_k"] = jnp.zeros(eshape, dt)
        cache["cross_v"] = jnp.zeros(eshape, dt)
    return cache


def prefill(cfg: ArchConfig, params: dict, batch: dict, max_len: int,
            last_idx: Optional[jax.Array] = None):
    """Run the prompt, return (last_logits, cache).

    ``last_idx`` (B,) int32, optional: per-sequence index of the last
    *real* token along the final sequence axis.  The serving engine
    right-pads prompts into fixed buckets, so the logits that seed
    decoding must come from each sequence's own last real position, not
    the bucket's final column.  None keeps the legacy behavior (all
    sequences end at the last column)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    if cfg.frontend != "none" and "frontend_embeds" in batch:
        x = jnp.concatenate([batch["frontend_embeds"].astype(x.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1])[None, :]
    if cfg.pos_embed == "learned":
        x = x + jnp.take(params["pos_table"], positions[0] % params["pos_table"].shape[0], axis=0)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(cfg, params, batch["enc_frames"])
    moe = cfg.family == "moe"
    x, _, kvs = _scan_blocks(
        cfg, params["layers"], x, positions, moe=moe, enc_out=enc_out, collect_kv=True
    )
    if last_idx is None:
        x_last = x[:, -1:, :]
    else:
        idx = last_idx.astype(jnp.int32)[:, None, None]  # (B,1,1) -> bcast over d
        x_last = jnp.take_along_axis(x, idx, axis=1)
    logits = lm_logits(cfg, params, x_last)
    # build the fixed-size cache from collected per-layer K/V
    cache = init_cache(cfg, b, max_len)
    seq = x.shape[1]
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], kvs["k"], 0, axis=2)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], kvs["v"], 0, axis=2)
    cache["len"] = jnp.asarray(seq, jnp.int32)
    if cfg.family == "encdec":
        # precompute cross K/V per layer from encoder output
        def cross_kv(layer_p):
            kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
            k = cm.dense(layer_p["cross"]["wk"], enc_out)
            v = cm.dense(layer_p["cross"]["wv"], enc_out)
            bsz, es = enc_out.shape[:2]
            return k.reshape(bsz, es, kvh, hd), v.reshape(bsz, es, kvh, hd)

        ck, cv = jax.lax.map(cross_kv, params["layers"])
        cache["cross_k"], cache["cross_v"] = ck, cv
    return logits, cache


def decode_step(cfg: ArchConfig, params: dict, cache: dict, tokens: jax.Array):
    """One token for every sequence.  tokens: (B, 1).  Returns
    (logits (B,1,V), new_cache).

    The stacked (L, ...) KV cache rides in the scan CARRY and each layer
    updates its slice in place (dynamic_update_index) — XLA's while-loop
    state aliasing then keeps ONE cache buffer live instead of the
    xs+ys pair a scan-over-cache would hold (2x cache = 10.7 GB/device
    on qwen2-72b decode_32k)."""
    b = tokens.shape[0]
    x = embed_tokens(cfg, params, tokens)
    pos = cache["len"]
    # bucket-padded serving: the engine stashes per-sequence real prompt
    # lengths (+ the bucket width) in the cache so pad K/V rows are
    # masked out of every decode step (see cm.decode_attention) and each
    # sequence's rope/learned position continues from its OWN last real
    # token, not the bucket boundary — decoded tokens are then
    # bit-identical to an unpadded run (K/V just live at shifted slots).
    valid_len = cache.get("valid_len")
    prefix_len = cache.get("prefill_len")
    if valid_len is not None:
        positions = (valid_len[:, None] + (pos - prefix_len)).astype(jnp.int32)
    else:
        positions = jnp.full((b, 1), pos, jnp.int32)
    if cfg.pos_embed == "learned":
        x = x + jnp.take(params["pos_table"], positions[:, 0] % params["pos_table"].shape[0], axis=0)[:, None]
    moe = cfg.family == "moe"
    has_cross = cfg.family == "encdec"

    def body(carry, scanned):
        x, k_all, v_all, li = carry
        layer_p = scanned["p"]
        kv = {
            "k": jax.lax.dynamic_index_in_dim(k_all, li, 0, keepdims=False),
            "v": jax.lax.dynamic_index_in_dim(v_all, li, 0, keepdims=False),
        }
        cross_kv = (
            {"k": scanned["cross_k"], "v": scanned["cross_v"]} if has_cross else None
        )
        x2, new_kv, _ = block_apply(
            cfg, layer_p, x, positions, moe=moe, kv_cache=kv, cache_len=pos,
            cross_kv=cross_kv, enc_out=None,
            valid_len=valid_len, prefix_len=prefix_len,
        )
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, new_kv["k"], li, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, new_kv["v"], li, 0)
        return (x2, k_all, v_all, li + 1), None

    scanned = {"p": params["layers"]}
    if has_cross:
        scanned["cross_k"], scanned["cross_v"] = cache["cross_k"], cache["cross_v"]
    (x, new_k, new_v, _), _ = cm.scan_or_unroll(
        cfg.scan_layers, body,
        (x, cache["k"], cache["v"], jnp.zeros((), jnp.int32)), scanned,
    )
    logits = lm_logits(cfg, params, x)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = new_k, new_v
    new_cache["len"] = cache["len"] + 1
    return logits, new_cache

"""Zamba2-style hybrid: Mamba2 backbone with ONE shared attention+MLP
block applied every ``hybrid_attn_interval`` mamba layers
(arXiv:2411.15242, simplified: the shared block reuses the same params at
every application, which is the architecture's parameter-sharing trick).

Layout for L mamba layers and interval I:
  [mamba x I, shared_attn] x (L // I)  then  [mamba x (L % I)]
Mamba groups are scanned (params stacked per group position), the shared
block is closed over — so HLO stays compact and the shared params appear
once.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models import mamba2 as mb
from repro.models import transformer as tf

__all__ = [
    "init_hybrid_params",
    "hybrid_forward",
    "hybrid_hidden",
    "hybrid_prefill",
    "hybrid_init_cache",
    "hybrid_decode_step",
]


def _split(cfg: ArchConfig):
    i = cfg.hybrid_attn_interval
    n_groups = cfg.n_layers // i if i else 0
    tail = cfg.n_layers - n_groups * i if i else cfg.n_layers
    return i, n_groups, tail


def init_hybrid_params(cfg: ArchConfig, key) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    i, n_groups, tail = _split(cfg)
    ks = jax.random.split(key, 6)
    v, d = cfg.padded_vocab, cfg.d_model
    p: dict = {
        "embed": {"table": cm.trunc_normal(ks[0], (v, d), d ** -0.5, dt)},
        "ln_f": cm.init_norm(d, cfg.norm, dt),
        "head": {"w": cm.trunc_normal(ks[1], (d, v), 1.0 / (d**0.5), dt)},
        "shared_attn": tf.init_block(ks[2], cfg, moe=False),
    }
    if n_groups:
        gk = jax.random.split(ks[3], n_groups * i).reshape(n_groups, i, 2)
        p["groups"] = jax.vmap(
            lambda kk: jax.vmap(lambda k2: mb.init_mamba_block(k2, cfg))(kk)
        )(gk)
    if tail:
        tk = jax.random.split(ks[4], tail)
        p["tail"] = jax.vmap(lambda k2: mb.init_mamba_block(k2, cfg))(tk)
    return p


def _run_group_stack(cfg, stacked, x, inner_scan_len):
    def body(xc, layer_p):
        return mb.mamba_block_apply(cfg, layer_p, xc), None

    x, _ = cm.scan_or_unroll(cfg.scan_layers, body, x, stacked)
    return x


def hybrid_hidden(cfg: ArchConfig, params: dict, batch: dict):
    """Returns (final hidden, aux=0)."""
    i, n_groups, tail = _split(cfg)
    tokens = batch["tokens"]
    x = tf.embed_tokens(cfg, params, tokens)
    positions = jnp.arange(x.shape[1])[None, :]

    if n_groups:
        def group_body(xc, group_params):
            xc = _run_group_stack(cfg, group_params, xc, i)
            xc, _, _ = tf.block_apply(
                cfg, params["shared_attn"], xc, positions, moe=False
            )
            return xc, None

        if cfg.remat != "none":
            group_body = jax.checkpoint(
                group_body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                if cfg.remat == "dots"
                else None,
            )
        x, _ = cm.scan_or_unroll(cfg.scan_layers, group_body, x, params["groups"])
    if tail:
        x = _run_group_stack(cfg, params["tail"], x, tail)
    return x, jnp.zeros((), jnp.float32)


def hybrid_forward(cfg: ArchConfig, params: dict, batch: dict):
    """Returns (logits, aux=0)."""
    x, aux = hybrid_hidden(cfg, params, batch)
    return tf.lm_logits(cfg, params, x), aux


def hybrid_prefill(cfg: ArchConfig, params: dict, batch: dict, max_len: int):
    """Real prefill: run the prompt, collecting the mamba recurrent state
    per layer and the shared-attention KV per application."""
    i, n_groups, tail = _split(cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = tf.embed_tokens(cfg, params, tokens)
    positions = jnp.arange(s)[None, :]
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.compute_dtype)

    def pad_kv(kv):  # (B, S, KV, hd) -> (B, max_len, KV, hd)
        buf = jnp.zeros((b, max_len, kvh, hd), dt)
        return jax.lax.dynamic_update_slice_in_dim(buf, kv.astype(dt), 0, axis=1)

    new_cache: dict = {"len": jnp.asarray(s, jnp.int32)}
    if n_groups:
        def group_body(xc, group_params):
            def inner(xc2, layer_p):
                xc2, st = mb.mamba_block_prefill(cfg, layer_p, xc2)
                return xc2, st

            xc, states = cm.scan_or_unroll(cfg.scan_layers, inner, xc, group_params)
            xc, kv, _ = tf.block_apply(
                cfg, params["shared_attn"], xc, positions, moe=False
            )
            return xc, (states, pad_kv(kv["k"]), pad_kv(kv["v"]))

        x, (m_states, ks, vs) = cm.scan_or_unroll(
            cfg.scan_layers, group_body, x, params["groups"]
        )
        new_cache["mamba"] = m_states
        new_cache["attn_k"], new_cache["attn_v"] = ks, vs
    if tail:
        def tail_inner(xc2, layer_p):
            xc2, st = mb.mamba_block_prefill(cfg, layer_p, xc2)
            return xc2, st

        x, tail_states = cm.scan_or_unroll(cfg.scan_layers, tail_inner, x, params["tail"])
        new_cache["tail"] = tail_states
    logits = tf.lm_logits(cfg, params, x[:, -1:, :])
    return logits, new_cache


def hybrid_init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    i, n_groups, tail = _split(cfg)
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    state = jax.vmap(lambda _: mb.init_mamba_state(cfg, batch))(jnp.arange(max(n_groups * i, 1)))
    cache = {
        "mamba": jax.tree_util.tree_map(
            lambda a: a.reshape(n_groups, i, *a.shape[1:]) if n_groups else a, state
        )
        if n_groups
        else None,
        "attn_k": jnp.zeros((max(n_groups, 1), batch, max_len, kvh, hd), jnp.dtype(cfg.compute_dtype)),
        "attn_v": jnp.zeros((max(n_groups, 1), batch, max_len, kvh, hd), jnp.dtype(cfg.compute_dtype)),
        "len": jnp.zeros((), jnp.int32),
    }
    if tail:
        cache["tail"] = jax.vmap(lambda _: mb.init_mamba_state(cfg, batch))(jnp.arange(tail))
    return cache


def hybrid_decode_step(cfg: ArchConfig, params: dict, cache: dict, tokens: jax.Array):
    i, n_groups, tail = _split(cfg)
    b = tokens.shape[0]
    x = tf.embed_tokens(cfg, params, tokens)
    pos = cache["len"]
    positions = jnp.full((b, 1), pos, jnp.int32)
    new_cache = dict(cache)

    if n_groups:
        def group_body(xc, scanned):
            group_params, m_state, k_c, v_c = scanned

            def inner(xc2, inp):
                layer_p, st = inp
                xc2, new_st = mb.mamba_block_decode(cfg, layer_p, st, xc2)
                return xc2, new_st

            xc, new_m = cm.scan_or_unroll(cfg.scan_layers, inner, xc, (group_params, m_state))
            xc, new_kv, _ = tf.block_apply(
                cfg, params["shared_attn"], xc, positions, moe=False,
                kv_cache={"k": k_c, "v": v_c}, cache_len=pos,
            )
            return xc, (new_m, new_kv["k"], new_kv["v"])

        x, (new_m, new_k, new_v) = cm.scan_or_unroll(
            cfg.scan_layers, group_body, x,
            (params["groups"], cache["mamba"], cache["attn_k"], cache["attn_v"]),
        )
        new_cache["mamba"], new_cache["attn_k"], new_cache["attn_v"] = new_m, new_k, new_v
    if tail:
        def inner(xc2, inp):
            layer_p, st = inp
            xc2, new_st = mb.mamba_block_decode(cfg, layer_p, st, xc2)
            return xc2, new_st

        x, new_tail = cm.scan_or_unroll(cfg.scan_layers, inner, x, (params["tail"], cache["tail"]))
        new_cache["tail"] = new_tail
    logits = tf.lm_logits(cfg, params, x)
    new_cache["len"] = cache["len"] + 1
    return logits, new_cache

"""Shared model building blocks (pure-functional, no flax).

Params are nested dicts of jnp arrays.  Every dense projection funnels
through ``repro.kernels.ops.gemm`` so tuned Pallas GEMM configs apply to
the whole model zoo.  Norms/softmax run in f32; matmul inputs are cast to
the configured compute dtype.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ops import gemm

__all__ = [
    "dense",
    "init_dense",
    "rmsnorm",
    "layernorm",
    "init_norm",
    "rope_freqs",
    "apply_rope",
    "sinusoidal_positions",
    "causal_attention",
    "chunked_causal_attention",
    "cross_attention",
    "decode_attention",
    "mlp_act",
    "trunc_normal",
]


def scan_or_unroll(use_scan: bool, body, carry, xs):
    """lax.scan when use_scan else a python loop over the leading axis.

    The unrolled path exists for the dry-run depth probes: XLA's
    cost_analysis counts a scan body once regardless of trip count, so
    probe configs unroll to make per-layer costs visible."""
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    leaves = jax.tree_util.tree_leaves(xs)
    length = leaves[0].shape[0]
    ys = []
    for i in range(length):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = None
    return carry, stacked


def trunc_normal(key, shape, scale: float, dtype) -> jax.Array:
    x = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
    return (x * scale).astype(dtype)


def init_dense(key, d_in: int, d_out: int, dtype, bias: bool = False) -> dict:
    p = {"w": trunc_normal(key, (d_in, d_out), 1.0 / math.sqrt(d_in), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: dict, x: jax.Array) -> jax.Array:
    y = gemm(x, p["w"])
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def init_norm(d: int, kind: str, dtype) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    # statistics in f32, but cast back to the compute dtype BEFORE the
    # scale multiply: under sequence parallelism the norm output is what
    # crosses the all-gather, and keeping that tensor bf16 halves the
    # collective bytes (measured on yi-6b; see EXPERIMENTS.md §Perf)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    return y * p["scale"].astype(x.dtype)


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    y = y * p["scale"].astype(x.dtype)
    if "bias" in p:
        y = y + p["bias"].astype(x.dtype)
    return y


def norm_apply(p: dict, x: jax.Array, kind: str, eps: float) -> jax.Array:
    return layernorm(p, x, eps) if kind == "layernorm" else rmsnorm(p, x, eps)


# -- positions ----------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, d: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# -- attention ----------------------------------------------------------------


def _softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return logits
    return cap * jnp.tanh(logits / cap)


def _group_q(q: jax.Array, kv: int) -> jax.Array:
    """(B,S,H,hd) -> (B,S,KV,G,hd): GQA queries grouped by KV head so
    attention contracts against the ORIGINAL K/V — no materialized
    jnp.repeat of the KV tensors (8x memory for kv=8->64 heads)."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, kv, h // kv, hd)


def attention_dispatch(q, k, v, softcap: float = 0.0, chunk_threshold: int = 2048):
    """Policy-aware attention entry point: on a Pallas-enabled deployment
    (kernels/ops.KernelPolicy.use_pallas) long sequences run the Pallas
    flash-attention kernel — with the **tuned** ``(block_q, block_kv)``
    schedule when `launch/tune.py` has recorded one for this
    ``(seq_q, seq_kv, head_dim, dtype)`` workload (see
    ``kernels/ops.flash_schedule``), the built-in heuristic blocks when
    not.  Otherwise the pure-JAX paths below (which are also the
    kernel's correctness oracle)."""
    from repro.kernels.ops import flash_schedule, kernel_policy, note_dispatch

    b, s, h, hd = q.shape
    sk = k.shape[1]
    pol = kernel_policy()
    if (
        pol.use_pallas
        and "flash" in pol.pallas_ops
        and softcap == 0.0
        and s > chunk_threshold
    ):
        from repro.kernels.flash_attention import flash_attention

        tuned = flash_schedule(s, sk, hd, str(q.dtype))
        if tuned is not None:
            note_dispatch("flash", "records")
            return flash_attention(q, k, v, block_q=tuned[0], block_k=tuned[1],
                                   interpret=pol.interpret)
        if s % 256 == 0 and sk % 512 == 0:
            note_dispatch("flash", "heuristic")
            return flash_attention(q, k, v, block_q=256, block_k=512,
                                   interpret=pol.interpret)
        note_dispatch("flash", "xla")
    if s > chunk_threshold:
        return chunked_causal_attention(q, k, v, softcap=softcap)
    return causal_attention(q, k, v, softcap=softcap)


def causal_attention(q, k, v, softcap: float = 0.0, causal: bool = True):
    """Attention without KV materialized repeat.  q: (B,S,H,hd)
    k/v: (B,Sk,KV,hd)."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    qg = _group_q(q, kv)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    logits = _softcap(logits * (1.0 / math.sqrt(hd)), softcap)
    if causal:
        sk = k.shape[1]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, h, hd)


def chunked_causal_attention(q, k, v, chunk_q: int = 512, chunk_k: int = 1024,
                             softcap: float = 0.0):
    """Flash-style online-softmax attention with O(S·chunk) memory.

    Used automatically for long sequences (prefill_32k) where the full
    (S×S) score tensor would not fit HBM.  lax.scan over KV chunks keeps
    the lowered HLO compact; per-chunk compute is MXU-shaped."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    sk = k.shape[1]
    chunk_q = min(chunk_q, sq)
    chunk_k = min(chunk_k, sk)
    nq, nk = sq // chunk_q, sk // chunk_k
    scale = 1.0 / math.sqrt(hd)

    qc = q.reshape(b, nq, chunk_q, kv, g, hd)
    kc = k.reshape(b, nk, chunk_k, kv, hd)
    vc = v.reshape(b, nk, chunk_k, kv, hd)

    def q_block(iq, q_i):
        # online softmax across kv chunks; q_i: (b, cq, kv, g, hd)
        def kv_step(carry, ik):
            acc, m, l = carry
            k_j = jax.lax.dynamic_index_in_dim(kc, ik, axis=1, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vc, ik, axis=1, keepdims=False)
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", q_i, k_j).astype(jnp.float32)
            logits = _softcap(logits * scale, softcap)
            q_pos = iq * chunk_q + jnp.arange(chunk_q)
            k_pos = ik * chunk_k + jnp.arange(chunk_k)
            mask = q_pos[:, None] >= k_pos[None, :]
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(q.dtype), v_j
            ).astype(jnp.float32)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, kv, g, chunk_q, hd), jnp.float32)
        m0 = jnp.full((b, kv, g, chunk_q), -1e30, jnp.float32)
        l0 = jnp.zeros((b, kv, g, chunk_q), jnp.float32)
        # only kv chunks that intersect the causal triangle
        last = jnp.minimum(nk - 1, ((iq + 1) * chunk_q - 1) // chunk_k)
        (acc, m, l), _ = jax.lax.scan(
            lambda c, ik: jax.lax.cond(
                ik <= last, lambda: kv_step(c, ik), lambda: (c, None)
            ),
            (acc0, m0, l0),
            jnp.arange(nk),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (b, kv, g, cq, hd) -> (b, cq, kv, g, hd)
        return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)

    outs = jax.lax.map(lambda i: q_block(i, qc[:, i]), jnp.arange(nq))
    # (nq, b, cq, kv, g, hd) -> (b, S, h, hd)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, hd)


def cross_attention(q, k, v, softcap: float = 0.0):
    return causal_attention(q, k, v, softcap=softcap, causal=False)


def decode_attention(q, k_cache, v_cache, length, softcap: float = 0.0,
                     valid_len=None, prefix_len=None):
    """Single-position attention over a cache (no KV repeat).

    q: (B,1,H,hd); k/v_cache: (B,S_max,KV,hd); length: valid prefix len.

    ``valid_len``/``prefix_len`` support bucket-padded prefill (the
    serving engine right-pads prompts to a fixed bucket of length
    ``prefix_len``): cache positions in ``[valid_len[b], prefix_len)``
    hold pad-token K/V and are masked out per sequence; positions at or
    beyond ``prefix_len`` are decode appends and stay governed by
    ``length`` alone."""
    b, sq, h, hd = q.shape
    kv = k_cache.shape[2]
    qg = _group_q(q, kv)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache).astype(jnp.float32)
    logits = _softcap(logits * (1.0 / math.sqrt(hd)), softcap)
    pos = jnp.arange(k_cache.shape[1])
    mask = pos[None, None, None, None, :] < length
    if valid_len is not None:
        real = (pos[None, :] < valid_len[:, None]) | (pos[None, :] >= prefix_len)
        mask = mask & real[:, None, None, None, :]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v_cache)
    return out.reshape(b, sq, h, hd)


# -- MLP activations -------------------------------------------------------------


def mlp_act(kind: str, x: jax.Array, gate: Optional[jax.Array] = None) -> jax.Array:
    if kind == "swiglu":
        assert gate is not None
        return jax.nn.silu(gate) * x
    if kind == "geglu":
        assert gate is not None
        return jax.nn.gelu(gate) * x
    if kind == "squared_relu":
        r = jax.nn.relu(x)
        return r * r
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(f"unknown activation {kind}")

"""Unified model API — one dispatch surface over all families.

``Model(cfg)`` gives init/loss/prefill/decode for any assigned arch;
``batch_specs`` produces the ShapeDtypeStruct stand-ins the dry-run
lowers against (the modality frontends are stubs per the assignment:
``frontend_embeds`` / ``enc_frames`` arrive as precomputed embeddings).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import hybrid as hy
from repro.models import mamba2 as mb
from repro.models import transformer as tf

__all__ = ["Model"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # -- params ---------------------------------------------------------------
    def init_params(self, key) -> dict:
        c = self.cfg
        if c.family in ("dense", "vlm", "moe", "encdec"):
            return tf.init_params(c, key)
        if c.family == "ssm":
            return mb.init_mamba_lm(c, key)
        if c.family == "hybrid":
            return hy.init_hybrid_params(c, key)
        raise ValueError(f"unknown family {c.family}")

    def abstract_params(self) -> dict:
        return jax.eval_shape(self.init_params, jax.random.PRNGKey(0))

    # -- training -------------------------------------------------------------
    def logits(self, params, batch):
        c = self.cfg
        if c.family in ("dense", "vlm", "moe", "encdec"):
            return tf.forward_logits(c, params, batch)
        if c.family == "ssm":
            return mb.mamba_lm_forward(c, params, batch)
        if c.family == "hybrid":
            return hy.hybrid_forward(c, params, batch)
        raise ValueError(c.family)

    def hidden(self, params, batch):
        c = self.cfg
        if c.family in ("dense", "vlm", "moe", "encdec"):
            return tf.forward_hidden(c, params, batch)
        if c.family == "ssm":
            return mb.mamba_lm_hidden(c, params, batch)
        if c.family == "hybrid":
            return hy.hybrid_hidden(c, params, batch)
        raise ValueError(c.family)

    def loss(self, params, batch):
        """Streaming (sequence-chunked) CE — never materializes the full
        (B, S, V) logits tensor (see transformer.streaming_lm_loss)."""
        x, aux = self.hidden(params, batch)
        labels = batch["labels"]
        if x.shape[1] != labels.shape[1]:  # vlm frontend positions unsupervised
            pad = x.shape[1] - labels.shape[1]
            labels = jnp.concatenate(
                [jnp.full((labels.shape[0], pad), -1, labels.dtype), labels],
                axis=1,
            )
        return tf.streaming_lm_loss(self.cfg, params, x, labels, aux)

    # -- serving ----------------------------------------------------------------
    def prefill(self, params, batch, max_len: int, last_idx=None):
        """``last_idx`` (B,) selects each sequence's last real position
        for the seed logits (bucket-padded serving); attention families
        only — SSM/hybrid state would be polluted by pad tokens, so the
        engine never pads those."""
        c = self.cfg
        if c.family in ("dense", "vlm", "moe", "encdec"):
            return tf.prefill(c, params, batch, max_len, last_idx=last_idx)
        if last_idx is not None:
            raise ValueError(f"family {c.family} does not support padded prefill")
        if c.family == "ssm":
            return mb.mamba_lm_prefill(c, params, batch, max_len)
        if c.family == "hybrid":
            return hy.hybrid_prefill(c, params, batch, max_len)
        raise ValueError(c.family)

    def init_cache(self, batch_size: int, max_len: int):
        c = self.cfg
        if c.family in ("dense", "vlm", "moe", "encdec"):
            return tf.init_cache(c, batch_size, max_len)
        if c.family == "ssm":
            return mb.mamba_lm_init_cache(c, batch_size, max_len)
        if c.family == "hybrid":
            return hy.hybrid_init_cache(c, batch_size, max_len)
        raise ValueError(c.family)

    def abstract_cache(self, batch_size: int, max_len: int):
        return jax.eval_shape(lambda: self.init_cache(batch_size, max_len))

    def decode_step(self, params, cache, tokens):
        c = self.cfg
        if c.family in ("dense", "vlm", "moe", "encdec"):
            return tf.decode_step(c, params, cache, tokens)
        if c.family == "ssm":
            return mb.mamba_lm_decode_step(c, params, cache, tokens)
        if c.family == "hybrid":
            return hy.hybrid_decode_step(c, params, cache, tokens)
        raise ValueError(c.family)

    # -- dry-run input specs --------------------------------------------------
    def batch_specs(self, shape: ShapeSpec) -> dict:
        """ShapeDtypeStruct stand-ins for one step's data inputs."""
        c = self.cfg
        b, s = shape.global_batch, shape.seq_len
        tok = jnp.int32
        emb_dt = jnp.dtype(c.compute_dtype)
        specs: dict = {}
        if shape.kind in ("train", "prefill"):
            n_front = c.n_frontend_tokens if c.frontend != "none" else 0
            s_text = s - n_front
            specs["tokens"] = jax.ShapeDtypeStruct((b, s_text), tok)
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((b, s_text), tok)
            if n_front:
                specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                    (b, n_front, c.d_model), emb_dt
                )
            if c.family == "encdec":
                specs["enc_frames"] = jax.ShapeDtypeStruct(
                    (b, c.encoder_len, c.d_model), emb_dt
                )
        else:  # decode: one new token against a seq_len-deep cache
            specs["tokens"] = jax.ShapeDtypeStruct((b, 1), tok)
        return specs

    def supports_shape(self, shape: ShapeSpec) -> tuple[bool, str]:
        c = self.cfg
        if shape.name == "long_500k" and c.family not in ("ssm", "hybrid"):
            return False, "full quadratic attention: 512k KV cache skipped per assignment"
        return True, ""

"""Mamba2 — State Space Duality (SSD) blocks (arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm: quadratic
attention-like computation inside chunks (MXU-friendly matmuls) plus a
linear recurrence across chunk boundaries (lax.scan / associative_scan).
Decode keeps an O(1)-in-sequence recurrent state per layer — this is why
the long_500k cell runs for the SSM-family archs while full-attention
archs skip it (DESIGN.md §4).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.api import constrain, logical
from repro.models import common as cm

__all__ = [
    "init_mamba_block",
    "mamba_block_apply",
    "mamba_block_prefill",
    "mamba_block_decode",
    "init_mamba_state",
    "ssd_chunked",
    "ssd_reference",
    "init_mamba_lm",
    "mamba_lm_forward",
    "mamba_lm_prefill",
    "mamba_lm_init_cache",
    "mamba_lm_decode_step",
]


# =============================================================================
# SSD core
# =============================================================================


def ssd_reference(x, dt, A, B, C):
    """Naive O(L) recurrence — the oracle the chunked path is tested
    against.  x: (b,l,h,p); dt: (b,l,h); A: (h,); B,C: (b,l,h,n)."""
    b, l, h, p = x.shape
    n = B.shape[-1]

    def step(state, inp):
        x_t, dt_t, B_t, C_t = inp  # (b,h,p), (b,h), (b,h,n), (b,h,n)
        dA = jnp.exp(dt_t * A)  # (b,h)
        dBx = jnp.einsum("bhn,bhp,bh->bhpn", B_t, x_t, dt_t)
        state = state * dA[..., None, None] + dBx
        y = jnp.einsum("bhpn,bhn->bhp", state, C_t)
        return state, y

    state0 = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (
        x.transpose(1, 0, 2, 3).astype(jnp.float32),
        dt.transpose(1, 0, 2).astype(jnp.float32),
        B.transpose(1, 0, 2, 3).astype(jnp.float32),
        C.transpose(1, 0, 2, 3).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3)  # (b,l,h,p)


def ssd_chunked(x, dt, A, B, C, chunk: int, return_state: bool = False):
    """Chunked SSD (Mamba2 Listing 1, adapted to TPU-friendly einsums).

    All SSD math runs in f32 for stability; inputs may be bf16.
    x: (b,l,h,p); dt: (b,l,h); A: (h,) (negative); B,C: (b,l,h,n)."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, l)
    assert l % q == 0, f"seq {l} not divisible by chunk {q}"
    c = l // q
    f32 = jnp.float32
    xc = x.reshape(b, c, q, h, p).astype(f32)
    dtc = dt.reshape(b, c, q, h).astype(f32)
    Bc = B.reshape(b, c, q, h, n).astype(f32)
    Cc = C.reshape(b, c, q, h, n).astype(f32)

    dA = dtc * A  # (b,c,q,h), negative
    dA_cs = jnp.cumsum(dA, axis=2)  # within-chunk cumulative

    # -- intra-chunk (diagonal blocks): attention-like quadratic form -------
    # decay matrix L[i,j] = exp(dA_cs[i] - dA_cs[j]) for i >= j
    seg = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]  # (b,c,qi,qj,h)
    ii = jnp.arange(q)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cc, Bc) * L
    y_diag = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", scores, dtc, xc)

    # -- chunk summary states -------------------------------------------------
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (b,c,q,h)
    S = jnp.einsum("bcqhn,bcqh,bcqh,bcqhp->bchpn", Bc, dtc, decay_to_end, xc)

    # -- inter-chunk recurrence: carry states across chunks -------------------
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])  # (b,c,h)

    def carry_fn(prev, inp):
        S_c, g_c = inp  # (b,h,p,n), (b,h)
        new = prev * g_c[..., None, None] + S_c
        return new, prev  # emit the state ENTERING this chunk

    S_t = S.transpose(1, 0, 2, 3, 4)  # (c,b,h,p,n)
    g_t = chunk_decay.transpose(1, 0, 2)  # (c,b,h)
    init = jnp.zeros((b, h, p, n), f32)
    final_state, entering = jax.lax.scan(carry_fn, init, (S_t, g_t))
    entering = entering.transpose(1, 0, 2, 3, 4)  # (b,c,h,p,n)

    # -- off-diagonal contribution from carried state -------------------------
    state_decay = jnp.exp(dA_cs)  # decay from chunk start to position i
    y_off = jnp.einsum("bcihn,bchpn,bcih->bcihp", Cc, entering, state_decay)

    y = (y_diag + y_off).reshape(b, l, h, p).astype(x.dtype)
    if return_state:
        return y, final_state
    return y


# =============================================================================
# Mamba2 block
# =============================================================================


def _shapes(cfg: ArchConfig):
    di = cfg.d_inner
    g, n = cfg.ssm_n_groups, cfg.ssm_state
    h = cfg.ssm_heads
    conv_ch = di + 2 * g * n
    return di, g, n, h, conv_ch


def init_mamba_block(key, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    di, g, n, h, conv_ch = _shapes(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    proj_out = 2 * di + 2 * g * n + h  # z, x, B, C, dt
    return {
        "ln": cm.init_norm(d, cfg.norm, dt),
        "in_proj": cm.init_dense(ks[0], d, proj_out, dt),
        "conv_w": cm.trunc_normal(ks[1], (cfg.ssm_conv_width, conv_ch), 0.5 / math.sqrt(cfg.ssm_conv_width), dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 1e-2))).astype(jnp.float32) * 0
        + jnp.asarray(
            jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(ks[2], (h,), minval=math.log(1e-3), maxval=math.log(1e-1))))),
            jnp.float32,
        ),
        "norm": {"scale": jnp.ones((di,), dt)},
        "out_proj": cm.init_dense(ks[3], di, d, dt),
    }


def _causal_conv(xBC, conv_w, conv_b):
    """Depthwise causal conv over sequence.  xBC: (b, l, ch)."""
    w = conv_w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC)
    for i in range(w):  # width is tiny (4): unrolled taps, XLA fuses these
        out = out + pad[:, i : i + xBC.shape[1], :] * conv_w[i][None, None, :]
    return out + conv_b[None, None, :]


def _split_proj(cfg, zxbcdt):
    di, g, n, h, conv_ch = _shapes(cfg)
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : di + conv_ch]
    dt_raw = zxbcdt[..., di + conv_ch :]
    return z, xBC, dt_raw


def _ssm_inputs(cfg, xBC, dt_raw, p):
    di, g, n, h, conv_ch = _shapes(cfg)
    b, l = xBC.shape[:2]
    xs = xBC[..., :di].reshape(b, l, h, cfg.ssm_head_dim)
    Bm = xBC[..., di : di + g * n].reshape(b, l, g, n)
    Cm = xBC[..., di + g * n :].reshape(b, l, g, n)
    rep = h // g
    Bm = jnp.repeat(Bm, rep, axis=2)
    Cm = jnp.repeat(Cm, rep, axis=2)
    dt_f = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    return xs, Bm, Cm, dt_f, A


def mamba_block_apply(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    """Full-sequence forward (train / prefill)."""
    res = x
    x = cm.norm_apply(p["ln"], x, cfg.norm, cfg.norm_eps)
    zxbcdt = cm.dense(p["in_proj"], x)
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
    xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
    xs, Bm, Cm, dt_f, A = _ssm_inputs(cfg, xBC, dt_raw, p)
    xs = constrain(xs, logical("dp", None, "tp", None))
    y = ssd_chunked(xs, dt_f, A, Bm, Cm, cfg.ssm_chunk)
    y = y + p["D"][None, None, :, None].astype(y.dtype) * xs
    di = cfg.d_inner
    y = y.reshape(*y.shape[:2], di)
    y = cm.rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), cfg.norm_eps)
    out = cm.dense(p["out_proj"], y)
    return constrain(res + out, logical("dp", "sp", None))


def init_mamba_state(cfg: ArchConfig, batch: int) -> dict:
    di, g, n, h, conv_ch = _shapes(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), jnp.dtype(cfg.compute_dtype)),
        "ssm": jnp.zeros((batch, h, cfg.ssm_head_dim, n), jnp.float32),
    }


def mamba_block_prefill(cfg: ArchConfig, p: dict, x: jax.Array):
    """Full-sequence forward that ALSO returns the recurrent state after
    the last position (for prefill -> decode handoff)."""
    res = x
    xn = cm.norm_apply(p["ln"], x, cfg.norm, cfg.norm_eps)
    zxbcdt = cm.dense(p["in_proj"], xn)
    z, xBC_raw, dt_raw = _split_proj(cfg, zxbcdt)
    xBC = jax.nn.silu(_causal_conv(xBC_raw, p["conv_w"], p["conv_b"]))
    xs, Bm, Cm, dt_f, A = _ssm_inputs(cfg, xBC, dt_raw, p)
    y, final_state = ssd_chunked(xs, dt_f, A, Bm, Cm, cfg.ssm_chunk, return_state=True)
    y = y + p["D"][None, None, :, None].astype(y.dtype) * xs
    di = cfg.d_inner
    y = y.reshape(*y.shape[:2], di)
    y = cm.rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), cfg.norm_eps)
    out = cm.dense(p["out_proj"], y)
    w = cfg.ssm_conv_width
    conv_state = xBC_raw[:, -(w - 1):, :].astype(jnp.dtype(cfg.compute_dtype))
    x_out = constrain(res + out, logical("dp", "sp", None))
    return x_out, {"conv": conv_state, "ssm": final_state}


def mamba_block_decode(cfg: ArchConfig, p: dict, state: dict, x: jax.Array):
    """One-token step.  x: (b, 1, d).  Returns (out, new_state)."""
    res = x
    x = cm.norm_apply(p["ln"], x, cfg.norm, cfg.norm_eps)
    zxbcdt = cm.dense(p["in_proj"], x)
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
    # conv over the rolling window
    window = jnp.concatenate([state["conv"], xBC], axis=1)  # (b, w, ch)
    conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    xBC = jax.nn.silu(conv_out)[:, None, :]
    new_conv = window[:, 1:, :]
    xs, Bm, Cm, dt_f, A = _ssm_inputs(cfg, xBC, dt_raw, p)
    # single recurrent update
    x_t = xs[:, 0].astype(jnp.float32)  # (b,h,p)
    dt_t = dt_f[:, 0]  # (b,h)
    B_t = Bm[:, 0].astype(jnp.float32)
    C_t = Cm[:, 0].astype(jnp.float32)
    dA = jnp.exp(dt_t * A)
    new_ssm = state["ssm"] * dA[..., None, None] + jnp.einsum(
        "bhn,bhp,bh->bhpn", B_t, x_t, dt_t
    )
    y = jnp.einsum("bhpn,bhn->bhp", new_ssm, C_t).astype(x.dtype)
    y = y + p["D"][None, :, None].astype(y.dtype) * xs[:, 0]
    di = cfg.d_inner
    y = y.reshape(y.shape[0], 1, di)
    y = cm.rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), cfg.norm_eps)
    out = cm.dense(p["out_proj"], y)
    return res + out, {"conv": new_conv.astype(state["conv"].dtype), "ssm": new_ssm}


# =============================================================================
# Mamba2 language model (attention-free)
# =============================================================================


def init_mamba_lm(cfg: ArchConfig, key) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    v, d = cfg.padded_vocab, cfg.d_model
    layer_keys = jax.random.split(ks[2], cfg.n_layers)
    return {
        "embed": {"table": cm.trunc_normal(ks[0], (v, d), d ** -0.5, dt)},
        "ln_f": cm.init_norm(d, cfg.norm, dt),
        "head": {"w": cm.trunc_normal(ks[1], (d, v), 1.0 / math.sqrt(d), dt)},
        "layers": jax.vmap(lambda k: init_mamba_block(k, cfg))(layer_keys),
    }


def _remat_wrap(cfg, fn):
    if cfg.remat == "none":
        return fn
    policy = (
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        if cfg.remat == "dots"
        else None
    )
    return jax.checkpoint(fn, policy=policy)


def mamba_lm_hidden(cfg: ArchConfig, params: dict, batch: dict):
    from repro.models import transformer as tf

    x = tf.embed_tokens(cfg, params, batch["tokens"])

    def body(xc, layer_p):
        return mamba_block_apply(cfg, layer_p, xc), None

    body = _remat_wrap(cfg, body)
    x, _ = cm.scan_or_unroll(cfg.scan_layers, body, x, params["layers"])
    return x, jnp.zeros((), jnp.float32)


def mamba_lm_forward(cfg: ArchConfig, params: dict, batch: dict):
    from repro.models import transformer as tf

    x, aux = mamba_lm_hidden(cfg, params, batch)
    return tf.lm_logits(cfg, params, x), aux


def mamba_lm_init_cache(cfg: ArchConfig, batch: int, max_len: int = 0) -> dict:
    state = init_mamba_state(cfg, batch)
    stacked = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), state
    )
    return {"layers": stacked, "len": jnp.zeros((), jnp.int32)}


def mamba_lm_prefill(cfg: ArchConfig, params: dict, batch: dict, max_len: int = 0):
    from repro.models import transformer as tf

    x = tf.embed_tokens(cfg, params, batch["tokens"])

    def body(xc, layer_p):
        xc, st = mamba_block_prefill(cfg, layer_p, xc)
        return xc, st

    x, states = cm.scan_or_unroll(cfg.scan_layers, body, x, params["layers"])
    logits = tf.lm_logits(cfg, params, x[:, -1:, :])
    cache = {"layers": states, "len": jnp.asarray(x.shape[1], jnp.int32)}
    return logits, cache


def mamba_lm_decode_step(cfg: ArchConfig, params: dict, cache: dict, tokens: jax.Array):
    from repro.models import transformer as tf

    x = tf.embed_tokens(cfg, params, tokens)

    def body(xc, scanned):
        layer_p, st = scanned
        xc, new_st = mamba_block_decode(cfg, layer_p, st, xc)
        return xc, new_st

    x, new_states = cm.scan_or_unroll(
        cfg.scan_layers, body, x, (params["layers"], cache["layers"])
    )
    logits = tf.lm_logits(cfg, params, x)
    return logits, {"layers": new_states, "len": cache["len"] + 1}

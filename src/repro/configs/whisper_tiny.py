"""whisper-tiny — encoder-decoder; conv audio frontend is a stub
(``enc_frames`` arrive as precomputed frame embeddings).
[arXiv:2212.04356]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    n_encoder_layers=4,
    encoder_len=1500,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    mlp_kind="gelu",
    norm="layernorm",
    pos_embed="learned",
    frontend="audio_frames",
    optimizer="adamw",
)

"""deepseek-67b — llama-architecture dense GQA decoder (deep: 95L).
[arXiv:2401.02954]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102400,
    mlp_kind="swiglu",
    norm="rmsnorm",
    rope_theta=1e4,
    optimizer="adamw",
)

"""qwen3-moe-235b-a22b — 128-expert top-8 MoE decoder, GQA kv=4.
Expert-parallel sharding (8 experts per model-axis device on the 16-way
production mesh).  [hf:Qwen/Qwen3 family]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,  # per-expert FFN width
    vocab_size=151936,
    n_experts=128,
    experts_per_token=8,
    router_norm_topk=True,
    moe_shard="ep",
    moe_impl="a2a",  # shard_map all-to-all dispatch (§Perf: 9.6-10.1x less wire)
    mlp_kind="swiglu",
    norm="rmsnorm",
    rope_theta=1e6,
    optimizer="adafactor",  # factored states keep per-chip optimizer bytes flat
)

"""Architecture registry: ``--arch <id>`` resolution for every launcher."""

from __future__ import annotations

from repro.configs.base import SHAPES, ArchConfig, ShapeSpec

from repro.configs.llava_next_34b import CONFIG as _llava
from repro.configs.qwen2_72b import CONFIG as _qwen2
from repro.configs.nemotron_4_15b import CONFIG as _nemotron
from repro.configs.yi_6b import CONFIG as _yi
from repro.configs.deepseek_67b import CONFIG as _deepseek
from repro.configs.whisper_tiny import CONFIG as _whisper
from repro.configs.qwen3_moe_235b_a22b import CONFIG as _qwen3moe
from repro.configs.grok_1_314b import CONFIG as _grok
from repro.configs.mamba2_130m import CONFIG as _mamba2
from repro.configs.zamba2_1p2b import CONFIG as _zamba2

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in [
        _llava,
        _qwen2,
        _nemotron,
        _yi,
        _deepseek,
        _whisper,
        _qwen3moe,
        _grok,
        _mamba2,
        _zamba2,
    ]
}

__all__ = ["ARCHS", "SHAPES", "get_arch", "get_shape", "all_cells"]


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeSpec:
    if name not in SHAPES:
        raise KeyError(f"unknown shape '{name}'; available: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells() -> list[tuple[ArchConfig, ShapeSpec]]:
    """Every assigned (architecture x input-shape) pair — 40 cells."""
    return [(a, s) for a in ARCHS.values() for s in SHAPES.values()]

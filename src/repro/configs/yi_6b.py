"""yi-6b — llama-architecture dense GQA decoder.  [arXiv:2403.04652]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    mlp_kind="swiglu",
    norm="rmsnorm",
    rope_theta=5e6,
    optimizer="adamw",
)

"""mamba2-130m — attention-free SSD (state-space duality) LM.
Runs long_500k (O(1)-in-sequence recurrent state).  [arXiv:2405.21060]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=1,  # attention-free; unused
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
    ssm_n_groups=1,
    tie_embeddings=True,
    norm="rmsnorm",
    optimizer="adamw",
)

"""qwen2-72b — dense GQA decoder with QKV bias.  [arXiv:2407.10671]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    mlp_kind="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    rope_theta=1e6,
    optimizer="adamw",
)

"""zamba2-1.2b — hybrid: Mamba2 backbone + ONE shared attention block
applied every 6 mamba layers (parameter sharing).  Runs long_500k.
[arXiv:2411.15242]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,  # shared block is MHA
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
    ssm_n_groups=1,
    hybrid_attn_interval=6,
    mlp_kind="swiglu",
    norm="rmsnorm",
    rope_theta=1e4,
    optimizer="adamw",
)

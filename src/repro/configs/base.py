"""ArchConfig — one declarative record per architecture.

Every assigned architecture is a concrete instance of this dataclass in
`repro/configs/<id>.py`; smoke tests shrink the same record via
``reduced()``.  The config also exposes the distinct GEMM workloads the
arch executes (``gemm_workloads``) — the hook the paper's tuner uses to
autotune a whole model.
"""

from __future__ import annotations

import dataclasses

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # block details
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    mlp_kind: str = "swiglu"  # swiglu | squared_relu | gelu
    qkv_bias: bool = False
    attn_softcap: float = 0.0
    pos_embed: str = "rope"  # rope | learned | sinusoidal
    rope_theta: float = 1e6
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    router_norm_topk: bool = True
    moe_capacity_factor: float = 1.25

    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    ssm_n_groups: int = 1

    # hybrid (zamba2): one shared attention block applied every N mamba layers
    hybrid_attn_interval: int = 0

    # enc-dec (whisper)
    n_encoder_layers: int = 0
    encoder_len: int = 1500

    # modality frontend (stubbed per assignment: precomputed embeddings)
    frontend: str = "none"  # none | vision_patches | audio_frames
    n_frontend_tokens: int = 0  # e.g. anyres patch embeddings per sample

    # numerics / runtime
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    optimizer: str = "adamw"  # adamw | adafactor
    remat: str = "full"  # none | dots | full  (full: save only block inputs)
    attn_chunk_threshold: int = 2048  # flash-chunked attention above this (−24% HBM traffic at 4k; §Perf cell 1)
    vocab_pad_multiple: int = 2048
    scan_layers: bool = True  # False: unroll (dry-run probes use this so
    #                            cost_analysis counts every layer)
    dryrun_grad_accum: int = 1  # microbatching in the dry-run train step

    # MoE sharding strategy: "ep" (experts on model axis) or "tp"
    moe_shard: str = "ep"
    # MoE dispatch implementation: "gspmd" (pure jit; GSPMD replicates the
    # token buffer for the dispatch gathers) or "a2a" (explicit shard_map
    # all-to-all — see transformer.moe_apply_a2a; §Perf cell 2)
    moe_impl: str = "gspmd"

    # ----------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def n_params(self) -> int:
        """Total parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "vlm", "moe", "encdec"):
            qkv = d * (self.n_heads + 2 * self.n_kv_heads) * hd
            o = self.n_heads * hd * d
            per_layer = qkv + o + 2 * d  # + norms
            if self.family == "moe":
                gated = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
                per_layer += self.n_experts * gated * d * self.d_ff + d * self.n_experts
            else:
                gated = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
                per_layer += gated * d * self.d_ff
        total = emb + self.n_layers * per_layer
        if self.family == "encdec":
            # encoder layers + cross attention in decoder
            qkv = d * (self.n_heads + 2 * self.n_kv_heads) * hd
            o = self.n_heads * hd * d
            mlp = 2 * d * self.d_ff
            total += self.n_encoder_layers * (qkv + o + mlp + 2 * d)
            total += self.n_layers * (qkv + o + d)  # cross-attn in decoder
        if self.family in ("ssm", "hybrid"):
            di, g, ns = self.d_inner, self.ssm_n_groups, self.ssm_state
            h = self.ssm_heads
            in_proj = d * (2 * di + 2 * g * ns + h)
            out_proj = di * d
            per = in_proj + out_proj + self.ssm_conv_width * (di + 2 * g * ns) + 3 * h + 2 * d
            total = emb + self.n_layers * per
            if self.family == "hybrid" and self.hybrid_attn_interval:
                qkv = d * (self.n_heads + 2 * self.n_kv_heads) * hd
                o = self.n_heads * hd * d
                mlp = 3 * d * self.d_ff
                total += qkv + o + mlp + 2 * d  # ONE shared block
        return int(total)

    def n_active_params(self) -> int:
        """Params touched per token (MoE: routed experts only)."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        gated = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        all_experts = self.n_layers * self.n_experts * gated * d * self.d_ff
        active = self.n_layers * self.experts_per_token * gated * d * self.d_ff
        return self.n_params() - all_experts + active

    # ----------------------------------------------------------------------
    def reduced(self, **overrides) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        small = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            vocab_pad_multiple=64,
            param_dtype="float32",
            compute_dtype="float32",
            attn_chunk_threshold=64,
        )
        if self.family == "moe":
            small.update(n_experts=4, experts_per_token=2)
        if self.family in ("ssm", "hybrid"):
            small.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
        if self.family == "hybrid":
            small.update(hybrid_attn_interval=2)
        if self.family == "encdec":
            small.update(n_encoder_layers=2, encoder_len=32)
        if self.frontend != "none":
            small.update(n_frontend_tokens=8)
        small.update(overrides)
        return dataclasses.replace(self, **small)

    # ----------------------------------------------------------------------
    def gemm_workloads(self, batch: int, seq: int) -> list[tuple[int, int, int, str]]:
        """Distinct (M, K, N) GEMMs one block executes — the tuner's
        per-arch workload list (M = batch*seq tokens)."""
        t = batch * seq
        d, hd = self.d_model, self.resolved_head_dim
        out: list[tuple[int, int, int, str]] = []
        if self.family in ("dense", "vlm", "moe", "encdec"):
            out.append((t, d, (self.n_heads + 2 * self.n_kv_heads) * hd, "qkv"))
            out.append((t, self.n_heads * hd, d, "attn_out"))
            if self.family == "moe":
                cap = int(t * self.experts_per_token * self.moe_capacity_factor / self.n_experts)
                out.append((cap, d, self.d_ff, "expert_in"))
                out.append((cap, self.d_ff, d, "expert_out"))
                out.append((t, d, self.n_experts, "router"))
            else:
                out.append((t, d, self.d_ff, "ffn_in"))
                out.append((t, self.d_ff, d, "ffn_out"))
        else:  # ssm / hybrid
            di, g, ns = self.d_inner, self.ssm_n_groups, self.ssm_state
            out.append((t, d, 2 * di + 2 * g * ns + self.ssm_heads, "ssm_in"))
            out.append((t, di, d, "ssm_out"))
        out.append((t, d, self.padded_vocab, "lm_head"))
        return out

"""nemotron-4-15b — dense GQA decoder with squared-ReLU MLP and
LayerNorm.  [arXiv:2402.16819]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    mlp_kind="squared_relu",
    norm="layernorm",
    rope_theta=1e4,
    optimizer="adamw",
)

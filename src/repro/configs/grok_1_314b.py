"""grok-1-314b — 8-expert top-2 MoE decoder with attention-logit
softcapping.  Experts are TP-sharded (8 experts < 16-way model axis, so
each expert's FFN is split instead).  [hf:xai-org/grok-1]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,  # per-expert FFN width
    vocab_size=131072,
    n_experts=8,
    experts_per_token=2,
    router_norm_topk=True,
    moe_shard="tp",
    moe_impl="a2a",  # shard_map all-to-all dispatch (§Perf: 9.6-10.1x less wire)
    attn_softcap=30.0,
    mlp_kind="geglu",  # gated: matches the published 314B total
    norm="rmsnorm",
    rope_theta=1e4,
    optimizer="adafactor",  # 314B params: factored second moment
)

"""llava-next-34b — VLM: anyres-tiled vision frontend (stub) + dense GQA
LM backbone.  [hf:llava-hf/llava-v1.6; backbone sizes per assignment]

The frontend is a stub per the assignment: ``input_specs`` feeds
precomputed patch embeddings (anyres base grid 24x24 = 576 tokens); the
backbone below is the graded article.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    mlp_kind="swiglu",
    norm="rmsnorm",
    rope_theta=5e6,
    frontend="vision_patches",
    n_frontend_tokens=576,
    optimizer="adamw",
)

"""Distributed-execution primitives.

Only the *logical sharding annotation* layer (:mod:`repro.dist.api`)
ships today: it is what the model zoo (`repro.models.*`) and the serving
stack consume — ``constrain``/``logical`` no-op outside a mesh context,
so the same model code runs single-host (tests, serving benches, this
CPU container) and under a production mesh.  The heavier subsystems the
trainer references (``sharding`` — full param/opt-state spec derivation,
``fault`` — failure injection/restarts, ``compress`` — gradient
compression) are still to come; their tests skip on the specific
missing submodule.
"""

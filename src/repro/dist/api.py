"""Logical sharding annotations — the model-facing slice of `repro.dist`.

Models never name mesh axes directly; they annotate tensors with
*logical* dimension names (``constrain(x, logical("dp", "sp", None))``)
and :class:`MeshRules` maps each logical name to zero or more physical
mesh axes.  Outside a :func:`mesh_context` every annotation is a no-op,
which is what lets one model implementation serve tests, the CPU
serving engine, and a production mesh unchanged.

Resolution (:func:`resolve_spec`) drops any mapping the concrete
(mesh, shape) pair cannot honor — a logical axis whose physical axes are
absent from the mesh, or whose combined device count does not divide the
tensor dimension — so partial meshes degrade to replication instead of
erroring.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from typing import Optional, Sequence, Union

import jax

__all__ = [
    "MeshRules",
    "mesh_context",
    "current_mesh",
    "current_rules",
    "logical",
    "resolve_spec",
    "constrain",
]

#: a logical entry: a name, or None for "replicated along this dim"
LogicalName = Optional[str]
#: a physical mapping: one axis name, a tuple of axis names, or None
Physical = Union[str, tuple, None]


def _default_rules() -> dict:
    return {
        "dp": ("data",),  # batch / token parallel
        "sp": "seq",      # sequence parallel (activations)
        "kv_seq": "seq",  # decode KV cache sequence sharding
        "tp": "model",    # tensor parallel (vocab/ffn output dims)
        "expert": "model",  # MoE expert dim rides the model axis
        "expert_cap": None,
        "expert_ffn": None,
    }


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """logical name -> physical mesh axes.  ``get`` returns the mapping
    (str | tuple | None); unknown names resolve to None (replicate)."""

    overrides: Optional[dict] = None

    def get(self, name: Optional[str]) -> Physical:
        if name is None:
            return None
        table = _default_rules()
        if self.overrides:
            table.update(self.overrides)
        return table.get(name)


class _MeshCtx:
    """Process-global (mesh, rules) stack.  Annotations are trace-time
    constructs, and traces for one jit happen on one thread, but sibling
    engines may trace concurrently — guard the stack itself."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._stack: list[tuple] = []

    def push(self, mesh, rules) -> None:
        with self._lock:
            self._stack.append((mesh, rules))

    def pop(self) -> None:
        with self._lock:
            self._stack.pop()

    def top(self) -> tuple:
        with self._lock:
            return self._stack[-1] if self._stack else (None, MeshRules())


_CTX = _MeshCtx()


@contextlib.contextmanager
def mesh_context(mesh, rules: Optional[MeshRules] = None):
    """Activate (mesh, rules) for every ``constrain`` traced inside."""
    _CTX.push(mesh, rules or MeshRules())
    try:
        yield mesh
    finally:
        _CTX.pop()


def current_mesh():
    return _CTX.top()[0]


def current_rules() -> MeshRules:
    return _CTX.top()[1]


def logical(*names: LogicalName) -> tuple:
    """Package per-dim logical names (cosmetic, but keeps call sites
    greppable and leaves room for validation later)."""
    return names


def resolve_spec(
    names: Sequence[LogicalName],
    shape: Sequence[int],
    mesh,
    rules: MeshRules,
) -> jax.sharding.PartitionSpec:
    """Map logical names to a PartitionSpec for a concrete (mesh, shape).

    Per dimension: look up the physical axes, keep only axes present in
    the mesh, and drop the whole entry when none survive or when the
    combined axis size does not divide the tensor dim.  Trailing
    replicated entries are trimmed so specs compare clean."""
    entries: list[Physical] = []
    for size, name in zip(shape, names):
        phys = rules.get(name)
        if phys is None:
            entries.append(None)
            continue
        axes = phys if isinstance(phys, tuple) else (phys,)
        axes = tuple(a for a in axes if a in mesh.shape)
        k = math.prod(mesh.shape[a] for a in axes) if axes else 0
        if not axes or size % k != 0:
            entries.append(None)
        elif isinstance(phys, tuple):
            entries.append(axes)
        else:
            entries.append(axes[0])
    while entries and entries[-1] is None:
        entries.pop()
    return jax.sharding.PartitionSpec(*entries)


def constrain(x: jax.Array, names: Sequence[LogicalName]) -> jax.Array:
    """Sharding annotation: with_sharding_constraint under the active
    mesh context, identity outside one."""
    mesh, rules = _CTX.top()
    if mesh is None:
        return x
    spec = resolve_spec(names, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec)
    )

"""Deterministic synthetic LM data pipeline.

Real deployments stream tokenized corpora; this container is offline, so
the pipeline synthesizes a *learnable* token stream (noisy modular
arithmetic progressions — a model that learns reduces loss well below
uniform entropy, which the integration tests assert).  Everything is
deterministic in (seed, step, host), host-sharded by process, and
prefetched on a background thread — the structure a real pipeline needs
for elastic restart: ``state_dict()/load_state_dict()`` checkpoint the
cursor so restarts resume mid-epoch without replaying data.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

__all__ = ["SyntheticLM", "DataPipeline"]


class SyntheticLM:
    """tokens[t+1] = (tokens[t] + stride) % vocab with occasional noise —
    next-token prediction is learnable from (token, stride-class)."""

    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0,
                 n_strides: int = 8, noise: float = 0.05):
        self.vocab = max(vocab_size, 16)
        self.seq_len = seq_len
        self.seed = seed
        self.n_strides = n_strides
        self.noise = noise

    def sample(self, index: int) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng((self.seed * 1_000_003 + index) & 0xFFFFFFFF)
        stride = 1 + int(rng.integers(self.n_strides))
        start = int(rng.integers(self.vocab))
        toks = (start + stride * np.arange(self.seq_len + 1)) % self.vocab
        flips = rng.random(self.seq_len + 1) < self.noise
        toks = np.where(flips, rng.integers(0, self.vocab, self.seq_len + 1), toks)
        return toks[:-1].astype(np.int32), toks[1:].astype(np.int32)


class DataPipeline:
    def __init__(
        self,
        dataset: SyntheticLM,
        global_batch: int,
        process_index: int = 0,
        process_count: int = 1,
        prefetch: int = 2,
        start_step: int = 0,
    ):
        assert global_batch % process_count == 0
        self.ds = dataset
        self.global_batch = global_batch
        self.local_batch = global_batch // process_count
        self.process_index = process_index
        self.process_count = process_count
        self.step = start_step
        self._prefetch = prefetch
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- deterministic batch construction ----------------------------------
    def build_batch(self, step: int) -> dict:
        base = step * self.global_batch + self.process_index * self.local_batch
        toks = np.empty((self.local_batch, self.ds.seq_len), np.int32)
        labs = np.empty_like(toks)
        for i in range(self.local_batch):
            toks[i], labs[i] = self.ds.sample(base + i)
        return {"tokens": toks, "labels": labs}

    # -- iteration with background prefetch ---------------------------------
    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.build_batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[dict]:
        self._q = queue.Queue(maxsize=self._prefetch)
        self._stop.clear()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        try:
            while True:
                step, batch = self._q.get()
                self.step = step + 1
                yield batch
        finally:
            self.stop()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=1.0)
            self._thread = None

    # -- elastic restart ------------------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.ds.seed}

    def load_state_dict(self, state: dict) -> None:
        self.step = int(state["step"])

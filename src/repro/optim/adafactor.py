"""Adafactor (Shazeer & Stern 2018) — factored second moments.

Used for the two MoE giants (grok-1-314b, qwen3-moe-235b) where full
AdamW state (12 bytes/param) would not fit the per-chip HBM budget at
the assigned mesh; factored states are O(rows + cols) per matrix.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["Adafactor"]


def _rms(x):
    return jnp.sqrt(jnp.mean(jnp.square(x)) + 1e-30)


@dataclasses.dataclass(frozen=True)
class Adafactor:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    decay: float = 0.8  # beta2 exponent schedule: 1 - t^-decay
    eps1: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr, jnp.float32)

    def init(self, params) -> dict:
        def leaf(p):
            if p.ndim >= 2:
                # factor over the two largest trailing dims
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "step": jnp.zeros((), jnp.int32),
            "factored": jax.tree_util.tree_map(leaf, params),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-self.decay)
        lr = self._lr(step)

        def upd(g, st, p):
            g = g.astype(jnp.float32)
            g2 = g * g + self.eps1
            if p.ndim >= 2:
                vr = beta2 * st["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * st["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                vr_norm = vr / jnp.maximum(
                    jnp.mean(vr, axis=-1, keepdims=True), 1e-30
                )
                u = g * jax.lax.rsqrt(vr_norm)[..., None] * jax.lax.rsqrt(vc)[..., None, :]
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta2 * st["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(v)
                new_st = {"v": v}
            u = u / jnp.maximum(1.0, _rms(u) / self.clip_threshold)
            base = p.astype(jnp.float32)
            if self.weight_decay and p.ndim >= 2:
                u = u + self.weight_decay * base
            return (base - lr * u).astype(p.dtype), new_st

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["factored"])
        out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        return (
            treedef.unflatten([o[0] for o in out]),
            {"step": step, "factored": treedef.unflatten([o[1] for o in out])},
        )

"""LR schedules (pure functions of the step counter)."""

from __future__ import annotations

import math

import jax.numpy as jnp

__all__ = ["warmup_cosine", "constant", "warmup_linear"]


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(1, warmup_steps)
        prog = jnp.clip((s - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(math.pi * prog)))
        return jnp.where(s < warmup_steps, warm, cos)

    return fn


def warmup_linear(peak_lr: float, warmup_steps: int, total_steps: int):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(1, warmup_steps)
        lin = peak_lr * jnp.clip(
            1.0 - (s - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0
        )
        return jnp.where(s < warmup_steps, warm, lin)

    return fn

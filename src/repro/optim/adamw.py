"""AdamW with mixed-precision master weights (pure JAX, no optax).

When params are bf16 the optimizer keeps f32 master copies and casts
back after each update (standard large-model recipe); m/v are f32.
ZeRO-1 sharding of the state is applied by the trainer via sharding
constraints (see dist/sharding.py::zero1_state_specs).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "clip_by_global_norm", "global_norm"]


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr, jnp.float32)

    def init(self, params) -> dict:
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        state = {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree_util.tree_map(f32, params),
            "v": jax.tree_util.tree_map(f32, params),
        }
        # master weights only needed for low-precision params
        if any(p.dtype != jnp.float32 for p in jax.tree_util.tree_leaves(params)):
            state["master"] = jax.tree_util.tree_map(
                lambda p: p.astype(jnp.float32), params
            )
        return state

    def update(self, grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        lr = self._lr(step)
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1**t
        bc2 = 1.0 - b2**t

        def upd(g, m, v, p, master):
            g = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            mhat = m2 / bc1
            vhat = v2 / bc2
            base = master if master is not None else p.astype(jnp.float32)
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # decay matrices only (norms/biases exempt)
                delta = delta + self.weight_decay * base
            new_master = base - lr * delta
            return new_master.astype(p.dtype), m2, v2, new_master

        masters = state.get("master")
        if masters is None:
            masters = jax.tree_util.tree_map(lambda p: None, params)
        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_mst = treedef.flatten_up_to(masters) if state.get("master") else [None] * len(flat_p)
        out = [upd(g, m, v, p, mst) for g, m, v, p, mst in zip(flat_g, flat_m, flat_v, flat_p, flat_mst)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_state = {
            "step": step,
            "m": treedef.unflatten([o[1] for o in out]),
            "v": treedef.unflatten([o[2] for o in out]),
        }
        if state.get("master") is not None:
            new_state["master"] = treedef.unflatten([o[3] for o in out])
        return new_params, new_state

from .adamw import AdamW, clip_by_global_norm, global_norm
from .adafactor import Adafactor
from .schedules import constant, warmup_cosine, warmup_linear


def make_optimizer(name: str, lr, **kw):
    if name == "adamw":
        return AdamW(lr=lr, **kw)
    if name == "adafactor":
        return Adafactor(lr=lr, **kw)
    raise ValueError(f"unknown optimizer {name}")


__all__ = [
    "AdamW",
    "Adafactor",
    "clip_by_global_norm",
    "global_norm",
    "constant",
    "warmup_cosine",
    "warmup_linear",
    "make_optimizer",
]

"""Train / serve step factories.

``make_train_step`` builds the jit-able step: value_and_grad, optional
microbatched gradient accumulation (lax.scan over microbatches — the
accumulation structure also lets XLA overlap the cross-pod gradient
reduction of microbatch i with the compute of i+1), global-norm clip,
optimizer update.  Sharding enters via jit in/out shardings built in
launch/dryrun.py / launch/train.py, plus the model's internal
constraints.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.optim import clip_by_global_norm

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step"]


def make_train_step(model: Model, optimizer, grad_accum: int = 1,
                    clip_norm: float = 1.0):
    def single_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch
        )
        return grads, metrics

    def train_step(params, opt_state, batch):
        if grad_accum <= 1:
            grads, metrics = single_grads(params, batch)
        else:
            # split the batch into microbatches along dim 0 and scan
            def micro(carry, mb):
                acc = carry
                g, m = single_grads(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g
                )
                return acc, m

            micro_batches = jax.tree_util.tree_map(
                lambda a: a.reshape(grad_accum, a.shape[0] // grad_accum, *a.shape[1:]),
                batch,
            )
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            grads, ms = jax.lax.scan(micro, zeros, micro_batches)
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
            metrics = jax.tree_util.tree_map(lambda m: m.mean(axis=0), ms)

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_state = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(model: Model, max_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return decode_step

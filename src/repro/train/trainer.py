"""Training loop: jit + sharding wiring, checkpoint/resume, straggler
watchdog, failure injection, metrics logging (JSONL).

The same Trainer drives single-device CPU integration tests and the
512-way dry-run meshes — only the mesh/rules differ.
"""

from __future__ import annotations

import json
import time
from typing import Optional

import jax

from repro.checkpoint.checkpointer import Checkpointer, latest_step
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataPipeline
from repro.dist import sharding as shd
from repro.dist.api import MeshRules, mesh_context
from repro.dist.fault import FailureInjector, StragglerWatchdog
from repro.models.api import Model
from repro.optim import make_optimizer, warmup_cosine
from repro.train.step import make_train_step

__all__ = ["Trainer"]


class Trainer:
    def __init__(
        self,
        cfg: ArchConfig,
        pipeline: DataPipeline,
        ckpt_dir: str,
        mesh=None,
        rules: Optional[MeshRules] = None,
        lr: float = 3e-4,
        warmup_steps: int = 20,
        total_steps: int = 1000,
        grad_accum: int = 1,
        clip_norm: float = 1.0,
        ckpt_every: int = 50,
        log_path: Optional[str] = None,
        watchdog: Optional[StragglerWatchdog] = None,
        injector: Optional[FailureInjector] = None,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.model = Model(cfg)
        self.pipeline = pipeline
        self.mesh = mesh
        self.rules = rules or MeshRules()
        self.ckpt = Checkpointer(ckpt_dir)
        self.ckpt_every = ckpt_every
        self.log_path = log_path
        self.watchdog = watchdog
        self.injector = injector
        self.seed = seed
        self.optimizer = make_optimizer(
            cfg.optimizer, warmup_cosine(lr, warmup_steps, total_steps)
        )
        self.train_step_fn = make_train_step(
            self.model, self.optimizer, grad_accum=grad_accum, clip_norm=clip_norm
        )
        self._compiled = None
        self.step = 0
        self.params = None
        self.opt_state = None
        self.metrics_log: list[dict] = []

    # ------------------------------------------------------------------
    def _shardings(self):
        if self.mesh is None:
            return None, None
        abs_params = self.model.abstract_params()
        pspecs = shd.param_specs(self.cfg, abs_params, self.mesh, self.rules)
        psh = jax.tree_util.tree_map(
            lambda s: jax.sharding.NamedSharding(self.mesh, s), pspecs
        )
        abs_state = jax.eval_shape(self.optimizer.init, abs_params)
        osh = shd.opt_state_shardings(
            self.cfg.optimizer, abs_state, pspecs, self.mesh, self.rules
        )
        return psh, osh

    def initialize(self, resume: bool = True) -> None:
        psh, osh = self._shardings()
        if resume and latest_step(self.ckpt.directory) is not None:
            abs_params = self.model.abstract_params()
            abs_state = jax.eval_shape(self.optimizer.init, abs_params)
            tree, meta = self.ckpt.restore(
                {"params": abs_params, "opt": abs_state},
                shardings={"params": psh, "opt": osh} if psh is not None else None,
            )
            self.params, self.opt_state = tree["params"], tree["opt"]
            self.step = int(meta["step"])
            self.pipeline.load_state_dict(meta["pipeline"])
            return
        key = jax.random.PRNGKey(self.seed)
        if self.mesh is not None:
            init = jax.jit(self.model.init_params, out_shardings=psh)
            self.params = init(key)
            self.opt_state = jax.jit(self.optimizer.init, out_shardings=osh)(self.params)
        else:
            self.params = self.model.init_params(key)
            self.opt_state = self.optimizer.init(self.params)
        self.step = 0

    def _get_step_fn(self):
        if self._compiled is None:
            psh, osh = self._shardings()
            if self.mesh is not None:
                self._compiled = jax.jit(
                    self.train_step_fn,
                    in_shardings=(psh, osh, None),
                    out_shardings=(psh, osh, None),
                    donate_argnums=(0, 1),
                )
            else:
                self._compiled = jax.jit(self.train_step_fn, donate_argnums=(0, 1))
        return self._compiled

    def _save(self):
        self.ckpt.save(
            self.step,
            {"params": self.params, "opt": self.opt_state},
            metadata={"step": self.step, "pipeline": self.pipeline.state_dict()},
        )

    def _log(self, record: dict):
        self.metrics_log.append(record)
        if self.log_path:
            with open(self.log_path, "a") as f:
                f.write(json.dumps(record) + "\n")

    # ------------------------------------------------------------------
    def train(self, num_steps: int, resume: bool = True):
        if self.params is None:
            self.initialize(resume=resume)
        step_fn = self._get_step_fn()
        ctx = mesh_context(self.mesh, self.rules) if self.mesh is not None else None
        if ctx is not None:
            ctx.__enter__()
        try:
            it = iter(self.pipeline)
            while self.step < num_steps:
                batch = next(it)
                if self.injector is not None:
                    self.injector.maybe_fail(self.step)
                t0 = time.monotonic()
                self.params, self.opt_state, metrics = step_fn(
                    self.params, self.opt_state, batch
                )
                jax.block_until_ready(metrics["loss"])
                dur = time.monotonic() - t0
                self.step += 1
                if self.watchdog is not None:
                    self.watchdog.observe(self.step, dur)
                rec = {
                    "step": self.step,
                    "loss": float(metrics["loss"]),
                    "ce": float(metrics.get("ce", metrics["loss"])),
                    "grad_norm": float(metrics["grad_norm"]),
                    "step_time_s": dur,
                }
                self._log(rec)
                if self.step % self.ckpt_every == 0 or self.step == num_steps:
                    self._save()
            self.ckpt.wait()
            return self.metrics_log
        finally:
            self.pipeline.stop()
            if ctx is not None:
                ctx.__exit__(None, None, None)

"""Sharded, atomic, async checkpointing with elastic-resharding restore.

Layout:
    <dir>/step_<N>.tmp-<pid>/   (staging)
        manifest.json           tree structure, shapes, dtypes, metadata
        arrays.npz              leaf arrays keyed by flattened path
    <dir>/step_<N>/             (atomic rename publish)
        ... + COMMIT            marker written after rename

Restore never assumes the saving mesh: arrays are loaded whole and
``jax.device_put`` re-shards them onto whatever shardings the *current*
mesh wants — that is the elastic path (save on 8 devices, restore on 2,
or vice versa), exercised by tests/test_checkpoint.py.

Async mode snapshots to host (device_get) synchronously — consistent
with the step — then writes on a worker thread so training resumes
immediately (the ~checkpoint-write is off the critical path).
"""

from __future__ import annotations

import json
import os
import shutil
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["Checkpointer", "latest_step"]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            full = os.path.join(directory, name)
            if os.path.exists(os.path.join(full, "COMMIT")):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
    return max(steps) if steps else None


class Checkpointer:
    def __init__(self, directory: str, keep_n: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep_n = keep_n
        self.async_save = async_save
        self._pool = ThreadPoolExecutor(max_workers=1) if async_save else None
        self._pending: Optional[Future] = None
        os.makedirs(directory, exist_ok=True)

    # -- save --------------------------------------------------------------
    def save(self, step: int, tree: Any, metadata: Optional[dict] = None) -> None:
        self.wait()
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        # snapshot to host NOW (consistency), write later (async)
        arrays = {
            _path_str(path): np.asarray(jax.device_get(leaf)) for path, leaf in flat
        }
        manifest = {
            "step": step,
            "time": time.time(),
            "keys": list(arrays.keys()),
            "metadata": metadata or {},
        }
        if self.async_save:
            self._pending = self._pool.submit(self._write, step, arrays, manifest)
        else:
            self._write(step, arrays, manifest)

    def _write(self, step: int, arrays: dict, manifest: dict) -> None:
        final = os.path.join(self.directory, f"step_{step:08d}")
        staging = f"{final}.tmp-{os.getpid()}"
        os.makedirs(staging, exist_ok=True)
        np.savez(os.path.join(staging, "arrays.npz"), **arrays)
        with open(os.path.join(staging, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(staging, final)  # atomic publish
        with open(os.path.join(final, "COMMIT"), "w") as f:
            f.write(str(manifest["time"]))
        self._gc()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and "tmp" not in n
        )
        for s in steps[: -self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def restore(
        self,
        abstract_tree: Any,
        step: Optional[int] = None,
        shardings: Any = None,
    ) -> tuple[Any, dict]:
        """Returns (tree, metadata).  ``shardings`` (a matching pytree of
        NamedSharding / None) re-shards onto the current mesh — elastic."""
        self.wait()
        if step is None:
            step = latest_step(self.directory)
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            arrays = {k: z[k] for k in z.files}

        flat_abs, treedef = jax.tree_util.tree_flatten_with_path(abstract_tree)
        flat_sh = (
            treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(flat_abs)
        )
        leaves = []
        for (path, aval), sh in zip(flat_abs, flat_sh):
            key = _path_str(path)
            if key not in arrays:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = arrays[key]
            if tuple(arr.shape) != tuple(aval.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != expected {aval.shape}"
                )
            arr = arr.astype(aval.dtype)
            leaves.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest["metadata"]

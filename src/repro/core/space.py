"""Operator-agnostic search-space protocol — the tuner stack's view of
*any* tunable kernel schedule.

The paper closes with "the proposed approaches have potential to be
applied to other operator-level optimizations"; the TVM line of work
(Learning to Optimize Tensor Programs) shows the win comes from a
*generic* schedule-space abstraction.  This module is that abstraction
for this repo: every tuner, cost backend, journal and session programs
against :class:`SearchSpace` and the opaque :class:`State` protocol, so
opening a new workload (flash attention, a reduction, a conv) means
writing one space + one cost model and registering them in
``repro.core.ops`` — never touching the tuners.

Two layers live here:

* :class:`SearchSpace` — the protocol every tuner consumes:
  ``initial_state / actions / step / neighbors / is_legitimate / size /
  enumerate / random_state / transplant / features / n_features`` plus
  state (de)serialization hooks (``state_from_lists``) used by the
  records/journal layer and the process-executor boundary.
* :class:`FactoredSearchSpace` — the shared implementation for spaces
  whose state is a list of ordered factor rows with exact products (the
  paper's Eqn. 5/6 MDP, generalized from the GEMM's three ``m/k/n``
  rows to any number of dimension rows).  ``GemmConfigSpace`` is the
  canonical instance; ``FlashAttnConfigSpace`` is the first non-GEMM
  one.

States are op-specific frozen dataclasses; the module-level *state-type
registry* maps an op name to its state class so persisted rows (records
files, trial journals) can be deserialized without knowing every op up
front.
"""

from __future__ import annotations

import abc
import dataclasses
import itertools
import math
import random as _random
from typing import Callable, Iterator, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

__all__ = [
    "State",
    "Action",
    "SearchSpace",
    "FactoredSearchSpace",
    "compositions_pow2",
    "count_compositions_pow2",
    "register_state_type",
    "state_type_for",
    "state_from_lists",
]


@runtime_checkable
class State(Protocol):
    """What the tuner stack needs from a schedule point: a stable cache
    key, the dimension products it schedules, and a JSON-serializable
    row form (``as_lists``, inverted by the owning space's
    ``state_from_lists``)."""

    def key(self) -> str: ...

    def dims(self) -> tuple[int, ...]: ...

    def as_lists(self) -> list[list[int]]: ...


@dataclasses.dataclass(frozen=True)
class Action:
    """Double ``row[dim][i]``, halve ``row[dim][j]`` (paper Eqn. 6) —
    the product-preserving move shared by every factored space."""

    dim: int  # dimension-row index
    i: int
    j: int

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"(d{self.dim}: x2@{self.i}, /2@{self.j})"


# -- state-type registry ------------------------------------------------------
# op name -> state dataclass, so persisted rows (TuningRecords, the
# TrialJournal) deserialize without hard-coding every op.  Spaces
# register their state class at import time; repro.core.ops imports
# every bundled space, so importing repro.core (or any submodule) makes
# the bundled ops resolvable.
_STATE_TYPES: dict[str, type] = {}


def register_state_type(op: str, cls: type) -> None:
    _STATE_TYPES[op] = cls


def state_type_for(op: str) -> type:
    try:
        return _STATE_TYPES[op]
    except KeyError:
        raise KeyError(
            f"no state type registered for op {op!r} "
            f"(registered: {sorted(_STATE_TYPES)})"
        ) from None


def state_from_lists(op: str, lists: Sequence[Sequence[int]]) -> State:
    """Deserialize a persisted state row for ``op`` (see ``as_lists``)."""
    return state_type_for(op).from_lists(lists)


class SearchSpace(abc.ABC):
    """The operator-agnostic search-space protocol.

    A space owns one workload instance of one op (a GEMM shape, an
    attention shape, ...) and exposes the MDP the tuners walk plus the
    featurization the learned tuners train on.  Everything the tuner
    stack touches goes through this surface; nothing downstream may
    assume GEMM."""

    #: op name this space schedules (must have a registered state type)
    op: str = "base"
    #: optional extra legitimacy predicate (hardware constraint closure)
    extra_constraint: Optional[Callable] = None

    # -- identity ------------------------------------------------------------
    @property
    @abc.abstractmethod
    def dims(self) -> tuple[int, ...]:
        """Dimension sizes this space schedules (workload identity)."""

    @property
    @abc.abstractmethod
    def depths(self) -> tuple[int, ...]:
        """Nesting depth of each dimension row."""

    def dim_specs(self) -> list[tuple[int, int]]:
        """``(value, depth)`` per *factored* dimension row — what
        sequence-decision tuners (the RNN controller) need to emit a
        configuration.  ``dims`` may carry additional non-factored
        workload dims (e.g. flash's head_dim); those never appear
        here."""
        return list(zip(self.dims, self.depths))

    @property
    def n_fixed_dims(self) -> int:
        """How many trailing entries of ``dims`` are workload identity
        only (never factored).  Warm-start donors must match them
        exactly — e.g. a flash schedule tuned for head_dim 64 must never
        seed a head_dim 128 search."""
        return len(self.dims) - len(self.depths)

    def spec_kwargs(self) -> Optional[dict]:
        """Extra constructor kwargs (beyond dims/depths) needed to
        rebuild an equivalent space via the op registry's
        ``make_space``, or ``None`` when the space cannot be rebuilt
        from a picklable description (e.g. it carries a constraint
        closure) — process-shippable backends refuse to ship then."""
        return None if self.extra_constraint is not None else {}

    # -- states --------------------------------------------------------------
    @abc.abstractmethod
    def state_from_rows(self, rows: Sequence[Sequence[int]]) -> State:
        """Build this op's state from dimension factor rows."""

    def state_from_lists(self, lists: Sequence[Sequence[int]]) -> State:
        """Inverse of ``State.as_lists`` (the journal/executor format)."""
        return self.state_from_rows(lists)

    @abc.abstractmethod
    def initial_state(self) -> State: ...

    # -- MDP -----------------------------------------------------------------
    @property
    @abc.abstractmethod
    def actions(self) -> list[Action]: ...

    @property
    def n_actions(self) -> int:
        return len(self.actions)

    @abc.abstractmethod
    def step(self, s: State, a: Action) -> Optional[State]: ...

    @abc.abstractmethod
    def neighbors(self, s: State) -> list[State]: ...

    @abc.abstractmethod
    def is_legitimate(self, s: State) -> bool: ...

    def structural_error(self, s: State) -> Optional[tuple[str, str]]:
        """``(reason, detail)`` when the state is structurally invalid
        for this space — the machine-readable form of
        ``is_legitimate`` consumed by the static analyzer
        (``repro.core.analysis``).  ``None`` means structurally sound.
        Subclasses with richer structure override this with specific
        reasons; the default wraps ``is_legitimate``."""
        try:
            if self.is_legitimate(s):
                return None
        except Exception as e:
            return ("malformed", f"{type(e).__name__}: {e}")
        return ("illegitimate", "state fails the space's legitimacy check")

    # -- enumeration / sampling ----------------------------------------------
    @abc.abstractmethod
    def size(self) -> int: ...

    @abc.abstractmethod
    def enumerate(self) -> Iterator[State]: ...

    @abc.abstractmethod
    def random_state(self, rng: _random.Random) -> State: ...

    @abc.abstractmethod
    def transplant(self, s: State) -> Optional[State]:
        """Map a state tuned for *another* workload of the same op into
        this space (warm-start translation); None when impossible."""

    # -- featurization -------------------------------------------------------
    @abc.abstractmethod
    def features(self, s: State) -> np.ndarray: ...

    @property
    @abc.abstractmethod
    def n_features(self) -> int: ...

    # -- hardware footprint --------------------------------------------------
    @abc.abstractmethod
    def working_set_bytes(self, s: State, in_bytes: int = 2) -> int:
        """On-chip (VMEM) working set of the schedule — the shared
        legitimacy cliff every cost backend guards with."""


def count_compositions_pow2(value: int, parts: int) -> int:
    """Number of ordered factorizations of ``value`` into ``parts`` factors
    reachable under the doubling/halving moves (= power-of-two compositions
    times the fixed placement of the odd part, which rides along factor
    moves two-at-a-time).  For ``value = odd * 2^e`` this is the number of
    ways to distribute ``e`` twos into ``parts`` ordered slots, times the
    number of slots the odd part can occupy — except the odd part is only
    movable in factors of 2, i.e. it cannot move at all; it stays where the
    initial state put it.  Hence ``C(e + parts - 1, parts - 1)``.
    """
    e = (value & -value).bit_length() - 1  # exponent of 2 in value
    return math.comb(e + parts - 1, parts - 1)


def compositions_pow2(value: int, parts: int) -> Iterator[tuple[int, ...]]:
    """Enumerate ordered factor tuples ``(f_0..f_{parts-1})`` with
    ``prod == value`` where all variation is in powers of two and the odd
    part of ``value`` stays on factor 0 (the reachable set from the
    paper's initial state ``[value, 1, .., 1]``)."""
    odd = value
    e = 0
    while odd % 2 == 0:
        odd //= 2
        e += 1
    # distribute e twos into `parts` slots
    for cut in itertools.combinations(range(e + parts - 1), parts - 1):
        prev = -1
        exps = []
        for c in cut:
            exps.append(c - prev - 1)
            prev = c
        exps.append(e + parts - 2 - prev)
        factors = [2**x for x in exps]
        factors[0] *= odd
        yield tuple(factors)


class FactoredSearchSpace(SearchSpace):
    """Shared machinery for spaces whose state is ``N`` ordered factor
    rows with exact products — the paper's MDP generalized to any row
    count.  Subclasses fix the op name, the state dataclass
    (``state_from_rows``), the featurization, and the working-set model;
    everything else (actions, stepping, enumeration, sampling,
    transplanting) is row-generic and statement-for-statement the
    historical GEMM implementation, so ``GemmConfigSpace`` stays
    bit-identical."""

    def __init__(
        self,
        values: Sequence[int],
        depths: Sequence[int],
        extra_constraint: Optional[Callable[[State], bool]] = None,
    ):
        values = tuple(int(v) for v in values)
        depths = tuple(int(d) for d in depths)
        if len(values) != len(depths):
            raise ValueError(f"values/depths mismatch: {values} vs {depths}")
        if not values or min(values) < 1 or min(depths) < 1:
            raise ValueError(f"bad {self.op} dims {values} depths {depths}")
        self._values = values
        self._depths = depths
        self.extra_constraint = extra_constraint
        self._actions = self._build_actions()

    # -- identity ------------------------------------------------------------
    @property
    def dims(self) -> tuple[int, ...]:
        return self._values

    @property
    def depths(self) -> tuple[int, ...]:
        return self._depths

    def dim_specs(self) -> list[tuple[int, int]]:
        # from the factored rows directly: ``dims`` may be overridden to
        # append non-factored workload dims (flash's head_dim), which
        # must never leak into the decision sequence
        return list(zip(self._values, self._depths))

    # -- basic protocol ------------------------------------------------------
    def initial_state(self) -> State:
        """Paper Sec. 5: ``s0 = [[v, 1, ..], ...]`` (no tiling)."""
        return self.state_from_rows(
            [(v,) + (1,) * (d - 1) for v, d in zip(self._values, self._depths)]
        )

    def _build_actions(self) -> list[Action]:
        acts = []
        for dim, d in enumerate(self._depths):
            for i in range(d):
                for j in range(d):
                    if i != j:
                        acts.append(Action(dim, i, j))
        return acts

    @property
    def actions(self) -> list[Action]:
        return self._actions

    @property
    def n_actions(self) -> int:
        return len(self._actions)

    def step(self, s: State, a: Action) -> Optional[State]:
        """Apply Eqn. 6/7; returns None when the move is illegitimate
        (halving an odd factor)."""
        lists = s.as_lists()
        row = lists[a.dim]
        if row[a.j] % 2 != 0:
            return None
        row[a.i] *= 2
        row[a.j] //= 2
        s2 = self.state_from_rows(lists)
        if not self.is_legitimate(s2):
            return None
        return s2

    def neighbors(self, s: State) -> list[State]:
        """g(s) of Eqn. 9 — all legitimate one-action successors."""
        out = []
        for a in self._actions:
            s2 = self.step(s, a)
            if s2 is not None:
                out.append(s2)
        return out

    def is_legitimate(self, s: State) -> bool:
        """J of Eqn. 5: exact products, positive integers, row depths,
        plus the optional hardware-constraint closure and the
        subclass's :meth:`extra_legitimate` hook.  Defined as "no
        structural error", so the boolean check and the analyzer's
        reasons can never drift apart."""
        return self.structural_error(s) is None

    def structural_error(self, s: State) -> Optional[tuple[str, str]]:
        """Fine-grained structural verdict for factored-row states (see
        ``SearchSpace.structural_error``).  Detail strings are only
        built on the failure path — the passing path stays as cheap as
        the historical boolean check (this runs per neighbor step)."""
        try:
            rows = s.as_lists()
        except Exception as e:
            return ("malformed", f"{type(e).__name__}: {e}")
        if len(rows) != len(self._values):
            return (
                "row_count",
                f"{len(rows)} factor rows, space has {len(self._values)} dims",
            )
        for i, (row, v, d) in enumerate(zip(rows, self._values, self._depths)):
            if len(row) != d:
                return (
                    "row_depth",
                    f"dim {i}: {len(row)} factors, nesting depth is {d}",
                )
            if any(f < 1 for f in row):
                return (
                    "factor_nonpositive",
                    f"dim {i}: factors {list(row)} include a zero/negative "
                    f"grid or block extent",
                )
            if math.prod(row) != v:
                return (
                    "product_mismatch",
                    f"dim {i}: prod({list(row)}) != {v} (block larger than "
                    f"the dim, or a stale record for another shape)",
                )
        if self.extra_constraint is not None and not self.extra_constraint(s):
            return (
                "extra_constraint",
                "the space's hardware-constraint closure rejected the state",
            )
        if not self.extra_legitimate(s):
            return (
                "op_constraint",
                f"{self.op} op-specific legitimacy rejected the state",
            )
        return None

    def extra_legitimate(self, s: State) -> bool:
        """Op-specific legitimacy beyond exact products (default: none)."""
        return True

    # -- enumeration / sampling ----------------------------------------------
    def size(self) -> int:
        return math.prod(
            count_compositions_pow2(v, d)
            for v, d in zip(self._values, self._depths)
        )

    def enumerate(self) -> Iterator[State]:
        rows_iter = itertools.product(
            *(
                compositions_pow2(v, d)
                for v, d in zip(self._values, self._depths)
            )
        )
        for rows in rows_iter:
            s = self.state_from_rows(rows)
            if self.extra_constraint is not None and not self.extra_constraint(s):
                continue
            if self.extra_legitimate(s):  # keep enumerate == is_legitimate
                yield s

    def random_state(self, rng: _random.Random) -> State:
        def rand_comp(value: int, parts: int) -> tuple[int, ...]:
            odd = value
            e = 0
            while odd % 2 == 0:
                odd //= 2
                e += 1
            exps = [0] * parts
            for _ in range(e):
                exps[rng.randrange(parts)] += 1
            factors = [2**x for x in exps]
            factors[0] *= odd
            return tuple(factors)

        for _ in range(64):
            s = self.state_from_rows(
                [rand_comp(v, d) for v, d in zip(self._values, self._depths)]
            )
            if self.is_legitimate(s):
                return s
        return self.initial_state()

    def transplant(self, s: State) -> Optional[State]:
        """Map a state tuned for *another* workload of this op into this
        space — the warm-start translation.

        Tiling quality is carried by the inner factors (VMEM block, MXU
        sub-tile, register granularity), which transfer across shapes;
        the grid factor merely covers whatever dimension is left.  So:
        keep the donor's inner factors (resized to this space's nesting
        depth, register factor kept innermost), shrink them until their
        product divides the new dimension, and absorb the remainder —
        including the dimension's odd part, which keeps the state inside
        the reachable set — into the grid factor.  Returns None when no
        legitimate translation exists.
        """
        src_rows = s.as_lists()
        if len(src_rows) != len(self._values):
            return None
        rows = []
        for row, dim, d in zip(src_rows, self._values, self._depths):
            inner = list(row[1:])
            if len(inner) > d - 1:  # merge overflow into the outermost inner slot
                keep = len(inner) - (d - 1)
                inner = [math.prod(inner[: keep + 1])] + inner[keep + 1:]
            while len(inner) < d - 1:  # pad outermost, keep register innermost
                inner.insert(0, 1)
            for _ in range(64):
                p = math.prod(inner) if inner else 1
                if p >= 1 and dim % p == 0:
                    break
                big = max(range(len(inner)), key=lambda i: inner[i])
                inner[big] = inner[big] // 2 if inner[big] % 2 == 0 else 1
            p = math.prod(inner) if inner else 1
            if dim % p != 0:
                inner, p = [1] * (d - 1), 1
            rows.append([dim // p] + inner)
        s2 = self.state_from_rows(rows)
        return s2 if self.is_legitimate(s2) else None

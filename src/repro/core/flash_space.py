"""Flash-attention schedule space — the first non-GEMM
:class:`~repro.core.space.SearchSpace` instance.

The tunable schedule of `repro.kernels.flash_attention` is its
``(block_q, block_kv)`` pair: the q-sequence is split into
``seq_q // block_q`` parallel grid cells and each cell streams the kv
sequence ``block_kv`` rows at a time through the online-softmax inner
loop.  That is exactly the paper's factored MDP with two dimension rows
instead of three:

    s = [s_q, s_kv]      s_q = [q0, q1, ..],  prod == seq_q
                         s_kv = [kv0, kv1, ..], prod == seq_kv

with ``block_q = prod(s_q[1:])`` (grid cells ``q0``) and
``block_kv = prod(s_kv[1:])`` (inner iterations per visit ``kv0``).
``head_dim`` is a workload dimension — it shapes the working set, the
MXU calls and the cache keys — but is not factored: the kernel keeps
full heads resident.

All MDP machinery (product-preserving double/halve actions, neighbors,
enumeration, sampling, transplant warm starts) is inherited from
:class:`~repro.core.space.FactoredSearchSpace`; this module fixes the
state dataclass, the attention featurization, and the VMEM working-set
model that mirrors the kernel's scratch layout (K/V resident per grid
cell, f32 accumulator + running max/sum per q block).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import numpy as np

from .analysis import flash_working_set_bytes
from .space import FactoredSearchSpace, register_state_type

__all__ = ["FlashScheduleState", "FlashAttnConfigSpace"]


@dataclasses.dataclass(frozen=True)
class FlashScheduleState:
    """One flash-attention schedule ``s = [s_q, s_kv]``."""

    q: tuple[int, ...]
    kv: tuple[int, ...]

    # -- kernel mapping ------------------------------------------------------
    @property
    def n_q_blocks(self) -> int:
        """Parallel grid cells along the q sequence."""
        return self.q[0]

    @property
    def n_kv_blocks(self) -> int:
        """Inner-loop iterations per full kv sweep."""
        return self.kv[0]

    @property
    def block_q(self) -> int:
        return math.prod(self.q[1:]) if len(self.q) > 1 else 1

    @property
    def block_kv(self) -> int:
        return math.prod(self.kv[1:]) if len(self.kv) > 1 else 1

    def dims(self) -> tuple[int, int]:
        return (math.prod(self.q), math.prod(self.kv))

    def as_lists(self) -> list[list[int]]:
        return [list(self.q), list(self.kv)]

    @staticmethod
    def from_lists(lists: Sequence[Sequence[int]]) -> "FlashScheduleState":
        q, kv = lists
        return FlashScheduleState(tuple(q), tuple(kv))

    def key(self) -> str:
        return ",".join(map(str, self.q)) + "|" + ",".join(map(str, self.kv))

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"[q{list(self.q)} x kv{list(self.kv)}]"


class FlashAttnConfigSpace(FactoredSearchSpace):
    """Search space for one attention workload
    ``(seq_q, seq_kv, head_dim)`` with nesting depths ``(d_q, d_kv)``
    (default 2: one grid factor + one block factor per sequence, the
    kernel's actual degrees of freedom)."""

    op = "flash"

    def __init__(
        self,
        seq_q: int,
        seq_kv: int,
        head_dim: int,
        d_q: int = 2,
        d_kv: int = 2,
        causal: bool = True,
        extra_constraint: Optional[Callable[[FlashScheduleState], bool]] = None,
    ):
        if min(seq_q, seq_kv, head_dim) < 1:
            raise ValueError(
                f"bad attention dims ({seq_q},{seq_kv},{head_dim})"
            )
        self.seq_q, self.seq_kv, self.head_dim = seq_q, seq_kv, head_dim
        self.d_q, self.d_kv = d_q, d_kv
        self.causal = causal
        super().__init__((seq_q, seq_kv), (d_q, d_kv), extra_constraint)

    # -- identity ------------------------------------------------------------
    @property
    def dims(self) -> tuple[int, int, int]:
        # head_dim is part of the workload identity (cache keys, warm
        # starts must never cross head sizes) even though it is not a
        # factored row
        return (self.seq_q, self.seq_kv, self.head_dim)

    def spec_kwargs(self) -> Optional[dict]:
        kw = super().spec_kwargs()
        if kw is None:
            return None
        return {**kw, "causal": self.causal}

    def state_from_rows(self, rows: Sequence[Sequence[int]]) -> FlashScheduleState:
        return FlashScheduleState.from_lists(rows)

    # -- hardware footprint ---------------------------------------------------
    def working_set_bytes(self, s: FlashScheduleState, in_bytes: int = 2) -> int:
        """Mirror of the kernel's VMEM layout: the q block and the fully
        resident K/V (its BlockSpec streams whole sequences per grid
        cell), the f32 accumulator + logits tile, and running max/sum.
        The arithmetic lives in ``repro.core.analysis`` (the analyzer's
        single budget function) so filter and oracle can never
        disagree."""
        return flash_working_set_bytes(
            s.block_q, s.block_kv, self.seq_kv, self.head_dim, in_bytes
        )

    # -- featurization --------------------------------------------------------
    def features(self, s: FlashScheduleState) -> np.ndarray:
        """log2 of every factor plus derived schedule descriptors — the
        flash analogue of the GEMM tile features the learned tuners
        consume."""
        lg = lambda v: math.log2(max(v, 1))
        raw = [lg(f) for f in (s.q + s.kv)]
        bq, bkv = s.block_q, s.block_kv
        derived = [
            lg(bq),
            lg(bkv),
            lg(s.n_q_blocks),
            lg(s.n_kv_blocks),
            float(bq % 8 == 0),  # sublane-aligned q block
            float(bkv % 128 == 0),  # lane-aligned kv block
            lg(bq * bkv),  # logits tile (elements)
            lg(self.working_set_bytes(s)),
        ]
        return np.asarray(raw + derived, dtype=np.float32)

    @property
    def n_features(self) -> int:
        return self.d_q + self.d_kv + 8

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"FlashAttnConfigSpace(({self.seq_q},{self.seq_kv},"
            f"{self.head_dim}), d=({self.d_q},{self.d_kv}), "
            f"causal={self.causal}, size={self.size()})"
        )


register_state_type("flash", FlashScheduleState)

"""Batched measurement engine — the concurrency/caching substrate under
every tuner.

The paper's search-time axis (Figs. 7b/8b) is dominated by per-trial
measurement overhead; TVM-style systems win wall-clock by dispatching
*batches* of candidate configurations to parallel measurement workers
and by never re-measuring a configuration they have already seen.
:class:`MeasureEngine` packages both:

  * **lanes** — up to ``n_workers`` states are measured concurrently;
    a wave's duration is the *max* of its lane times, not the sum, which
    is what makes ``n_workers=8`` roughly 8x cheaper on the search clock
    for batch-proposing tuners.  *How* a lane runs is delegated to a
    pluggable :class:`~repro.core.executor.LaneExecutor`: the default
    :class:`~repro.core.executor.SimulatedExecutor` keeps the historical
    in-thread semantics (and the ``n_workers=1`` bit-identical parity
    guarantee), while ``ThreadExecutor`` / ``ProcessExecutor`` measure
    waves with real thread/process concurrency, per-lane timeouts, and
    crash isolation — a dead worker is an ``inf``-cost outcome, not a
    dead session;
  * **trial cache** — an optional :class:`~repro.core.records.TrialJournal`
    is consulted before dispatch, so states measured by *any previous
    session* for the same workload are served in ~zero lane time
    (a cache hit still counts as a search trial, it is just free on the
    clock);
  * **auto-reload** — with ``reload_every=N``, every N waves the engine
    merges journal rows appended by *sibling* engines/processes sharing
    the journal file, so concurrent searches serve each other's fresh
    measurements mid-search instead of re-measuring;
  * **shard ownership** — with an enabled
    :class:`~repro.core.shard.ShardSpec`, cache misses this engine does
    not own (stable hash of the journal key + state key mod the shard
    count) are *deferred* to the sibling shard after one journal reload,
    instead of occupying a lane — two hosts splitting one candidate
    stream never measure the same configuration;
  * **stats** — dispatch/hit counters plus build-cache counters
    (compiles vs LRU/disk hits, see ``CostBackend.compile_stats``),
    shareable across engines via :class:`MeasureStats`, so benchmarks
    can attribute speedups.

``TuningContext.measure_many`` slices candidate batches into waves,
charges the budget per trial and the clock per wave, and keeps the
incumbent — the engine itself is policy-free.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional, Sequence

from .analysis import ScheduleAnalyzer, analyzer_for_backend, should_prune
from .space import State
from .cost.base import CostBackend
from .executor import LaneExecutor, LaneResult, SimulatedExecutor
from .fault import RetryPolicy, TRANSIENT_KINDS, classify_error
from .learn.filter import ProposalFilter
from .records import TrialJournal
from .shard import ShardSpec

__all__ = ["MeasureEngine", "MeasureOutcome", "MeasureStats"]


@dataclasses.dataclass
class MeasureOutcome:
    """One measured (or cache-served) state."""

    state: State
    cost: float
    cache_hit: bool
    lane_s: float  # lane occupancy: simulated model or measured wall
    error: Optional[str] = None  # lane failure note (crash/timeout)
    static: Optional[str] = None  # analyzer verdict reason if pruned pre-dispatch
    kind: Optional[str] = None  # failure taxonomy (see repro.core.fault)
    attempts: int = 1  # measurement attempts spent (retries included)
    #: retries exhausted on transient failures — the ``inf`` says "the
    #: lanes kept dying", NOT "this schedule is infeasible"
    failed_transient: bool = False
    #: learned-filter skip: the model's rank score (lower = predicted
    #: better).  The ``inf`` cost means "not measured this run", NOT
    #: "infeasible" — the journal row is provenance, never a cache entry
    predicted: Optional[float] = None
    #: sharded search: this candidate belongs to a sibling shard and was
    #: not in the journal yet — the ``inf`` cost means "the sibling owns
    #: it", never "infeasible"; nothing is journaled for it here
    deferred: bool = False


@dataclasses.dataclass
class MeasureStats:
    """Dispatch counters; share one instance across engines to aggregate
    a whole arch-tuning run (see ``TuningSession.tune_arch``)."""

    n_dispatched: int = 0
    n_cache_hits: int = 0
    n_waves: int = 0
    lane_busy_s: float = 0.0  # sum of per-lane occupancy
    span_s: float = 0.0  # sum of wave critical paths (what the clock pays)
    n_failures: int = 0  # lanes that crashed / timed out / raised
    # -- build-cache counters (backends with a compile step, see
    # CostBackend.compile_stats; zero for analytical backends) ---------------
    n_compiles: int = 0  # fresh XLA compiles paid
    n_compile_mem_hits: int = 0  # served by the in-memory LRU
    n_compile_disk_hits: int = 0  # served by the persistent on-disk layer
    n_compile_evictions: int = 0  # LRU evictions (memory bound working)
    compile_s: float = 0.0  # wall seconds spent compiling
    # -- journal auto-reload (mid-search sibling merging) --------------------
    n_journal_reloads: int = 0
    n_journal_rows_merged: int = 0  # sibling rows ingested mid-search
    # -- static pre-filter (see repro.core.analysis; zero with analyze=off) --
    trials_avoided: int = 0  # candidates rejected without occupying a lane
    n_static_flags: int = 0  # advisory verdicts (warn mode, or non-pruned WASTEFUL)
    static_s: float = 0.0  # wall seconds spent in the analyzer
    # -- learned proposal filter (see repro.core.learn; zero without one) ----
    trials_avoided_learned: int = 0  # candidates skipped on a model's say-so
    n_learned_retrains: int = 0  # mid-search refits from fresh journal rows
    learn_s: float = 0.0  # wall seconds spent scoring + retraining
    # -- sharded search (see repro.core.shard; zero without a ShardSpec) -----
    n_deferred_to_sibling: int = 0  # non-owned misses left to a sibling shard
    n_served_by_sibling: int = 0  # non-owned candidates served from the journal
    # -- fault tolerance (see repro.core.fault; zero without a RetryPolicy) --
    n_retries: int = 0  # transient-failure re-dispatches
    retry_backoff_s: float = 0.0  # backoff charged to the clock by retries
    n_transient_recovered: int = 0  # candidates that succeeded on a retry
    #: candidates whose retries were exhausted on transient failures —
    #: distinct from infeasible: the lanes kept dying, the schedule was
    #: never actually judged (these are counted inside ``n_failures`` too)
    n_failed_transient: int = 0
    n_stragglers: int = 0  # lanes ≥ straggler_factor × wave median wall
    n_respawns: int = 0  # worker processes respawned after a death
    n_spare_adoptions: int = 0  # deaths absorbed by a pre-warmed spare worker
    n_degraded_lanes: int = 0  # lanes that fell back to in-thread measurement

    @property
    def n_measured(self) -> int:
        return self.n_dispatched + self.n_cache_hits

    def cache_hit_rate(self) -> float:
        return self.n_cache_hits / max(1, self.n_measured)

    def compile_cache_hit_rate(self) -> float:
        """Fraction of executable lookups served without a fresh compile
        (in-memory LRU or the persistent disk layer)."""
        hits = self.n_compile_mem_hits + self.n_compile_disk_hits
        return hits / max(1, hits + self.n_compiles)

    def add_compile_delta(self, delta: dict) -> None:
        """Fold one ``compile_stats`` increment (engine-side snapshot
        diff, or a worker-shipped per-job delta) into the totals."""
        self.n_compiles += int(delta.get("compiles", 0))
        self.n_compile_mem_hits += int(delta.get("mem_hits", 0))
        self.n_compile_disk_hits += int(delta.get("disk_hits", 0))
        self.n_compile_evictions += int(delta.get("evictions", 0))
        self.compile_s += float(delta.get("compile_s", 0.0))


class MeasureEngine:
    """Measures batches of schedule states on a cost backend with
    ``n_workers`` parallel lanes and an optional persistent trial cache.
    Journal traffic is scoped to the backend's op, so engines for
    different operators can share one journal file safely."""

    def __init__(
        self,
        backend: CostBackend,
        n_workers: int = 1,
        journal: Optional[TrialJournal] = None,
        workload_key: Optional[str] = None,
        overhead_s: float = 0.35,
        timeout_s: float = 4.0,
        stats: Optional[MeasureStats] = None,
        executor: Optional[LaneExecutor] = None,
        reload_every: int = 0,
        analyze: str = "off",
        analyzer: Optional[ScheduleAnalyzer] = None,
        retry: Optional[RetryPolicy] = None,
        straggler_factor: float = 8.0,
        learned_filter: Optional[ProposalFilter] = None,
        shard: Optional[ShardSpec] = None,
    ):
        if analyze not in ("off", "warn", "prune"):
            raise ValueError(
                f"analyze must be 'off', 'warn' or 'prune', got {analyze!r}"
            )
        self.backend = backend
        self.n_workers = max(1, int(n_workers))
        # how a lane runs: simulated (default, bit-identical to the
        # historical path) or real threads/processes; the engine never
        # closes it — lifetime belongs to whoever built it
        self.executor = executor if executor is not None else SimulatedExecutor()
        self.journal = journal
        self.workload_key = workload_key
        # Journal entries are keyed by workload AND measurement settings:
        # a cost measured under different noise/repeats must never be
        # served as this backend's measurement.
        self.journal_key = (
            None
            if workload_key is None
            else f"{workload_key}?{backend.measure_fingerprint()}"
        )
        # TVM-style per-trial codegen/upload/launch charge and the
        # AutoTVM measurement timeout (a pathological config charges at
        # most ``timeout_s`` of lane time, see TuningContext)
        self.overhead_s = overhead_s
        self.timeout_s = timeout_s
        self.stats = stats or MeasureStats()
        # auto-reload cadence: every ``reload_every`` waves the journal
        # merges rows appended by sibling engines/processes, so
        # concurrent searches serve each other's fresh measurements
        # mid-search instead of re-measuring (0 disables)
        self.reload_every = max(0, int(reload_every))
        self._waves_since_reload = 0
        # static pre-filter mode: "off" never consults the analyzer (the
        # historical bit-identical path), "warn" classifies misses and
        # counts advisory flags, "prune" rejects provably-bad candidates
        # before they occupy a lane (journaled as audit rows, counted in
        # trials_avoided; the trial is still charged by TuningContext)
        self.analyze = analyze
        self._analyzer = analyzer
        # fault tolerance: with a RetryPolicy, transient lane failures
        # (crash/timeout/spawn/corrupt — see repro.core.fault) are
        # re-dispatched with backoff instead of surfacing inf to the
        # tuner; None keeps the historical fail-fast semantics exactly
        self.retry = retry if (retry is not None and retry.enabled) else None
        # a successful lane whose wall exceeds straggler_factor × the
        # wave median is counted in stats.n_stragglers (real executors
        # with ≥3 lanes only — detection, not re-measurement)
        self.straggler_factor = straggler_factor
        # learned proposal filter: with a ProposalFilter, each wave's
        # cache-missing candidates are scored by the journal-trained
        # rank model and only the predicted-best fraction is really
        # measured (skips journal as {"c": null, "pred": score}
        # provenance rows); None keeps the historical path bit-identical
        self.learned_filter = learned_filter
        # sharded search: with an enabled ShardSpec, cache misses this
        # engine does not own (see repro.core.shard.shard_of) become
        # deferred outcomes served later by the sibling's journal rows
        # instead of occupying a lane.  A 1-shard spec normalizes to
        # None so the default path stays bit-identical.
        if shard is not None and not shard.enabled:
            shard = None
        if shard is not None and (journal is None or self.journal_key is None):
            raise ValueError(
                "sharded measurement needs a shared journal and a "
                "workload key (deferred candidates are served by the "
                "sibling's journal rows)"
            )
        self.shard = shard

    @property
    def analyzer(self) -> ScheduleAnalyzer:
        """The static analyzer for this backend's space/spec (built lazily
        so ``analyze='off'`` engines never pay for one)."""
        if self._analyzer is None:
            self._analyzer = analyzer_for_backend(self.backend)
        return self._analyzer

    # -- clock model ---------------------------------------------------------
    def lane_time(self, cost: float) -> float:
        """Per-lane occupancy of one measurement: fixed overhead plus the
        timeout-capped kernel runtime (failed builds charge overhead only)."""
        return self.overhead_s + (
            0.0 if math.isinf(cost) else min(cost, self.timeout_s)
        )

    # -- sharding ------------------------------------------------------------
    def _shard_tag(self) -> Optional[tuple[int, int]]:
        """Journal provenance for measured rows: ``(index, count)`` when
        sharding is active, None otherwise (rows stay byte-identical to
        the unsharded format)."""
        if self.shard is None:
            return None
        return (self.shard.index, self.shard.count)

    # -- fault handling ------------------------------------------------------
    def _lane_kind(self, lane: LaneResult) -> Optional[str]:
        """Classify one lane result.  ``None`` means the backend actually
        judged the schedule (including a failed build, which reports as
        ``inf`` cost with no error).  A lane that hands back a value no
        real measurement can produce (NaN / negative / non-numeric) is a
        ``corrupt`` transient — journaling it would poison the cache."""
        if lane.error is not None:
            return lane.kind or classify_error(lane.error)
        try:
            c = float(lane.cost)
        except (TypeError, ValueError):
            return "corrupt"
        if math.isnan(c) or c < 0:
            return "corrupt"
        return None

    def _finalize(
        self, s: State, lane: LaneResult, kind: Optional[str],
        n_attempts: int, lane_s: float,
    ) -> MeasureOutcome:
        """Book one candidate's final verdict after any retries."""
        if kind is None:
            cost = float(lane.cost)
            if n_attempts > 1:
                self.stats.n_transient_recovered += 1
            if self.journal is not None and self.journal_key is not None:
                self.journal.record(
                    self.journal_key, s, cost, op=self.backend.op,
                    attempts=n_attempts, shard=self._shard_tag(),
                )
            return MeasureOutcome(
                s, cost, False, lane_s, None,
                kind=None if math.isfinite(cost) else "build",
                attempts=n_attempts,
            )
        # executor-level failure (crash/timeout/spawn/raise/corrupt)
        self.stats.n_failures += 1
        failed_transient = kind in TRANSIENT_KINDS
        if failed_transient:
            self.stats.n_failed_transient += 1
        if (
            self.retry is not None
            and self.journal is not None
            and self.journal_key is not None
        ):
            # failure provenance: permanent kinds are cacheable inf rows;
            # transient kinds are audit-only rows that never enter the
            # cost table — a worker death must not be cached as "this
            # config is infeasible".  Without a RetryPolicy the
            # historical contract holds: executor failures are counted
            # but never journaled.
            self.journal.record_failure(
                self.journal_key, s, kind, attempts=n_attempts,
                op=self.backend.op, shard=self._shard_tag(),
            )
        return MeasureOutcome(
            s, math.inf, False, lane_s, lane.error, kind=kind,
            attempts=n_attempts, failed_transient=failed_transient,
        )

    def _fold_compile(
        self, lanes: Sequence[LaneResult], compile_before: Optional[dict]
    ) -> None:
        """Attribute one sub-wave's build-cache increments."""
        lane_deltas = [l.compile for l in lanes if l.compile]
        if lane_deltas:
            # process lanes: each job shipped its worker-side delta
            for d in lane_deltas:
                self.stats.add_compile_delta(d)
        elif compile_before is not None:
            # in-process executors share this backend object: the
            # wave's increment is the snapshot difference
            after = self.backend.compile_stats()
            self.stats.add_compile_delta(
                {k: after[k] - compile_before.get(k, 0) for k in after}
            )

    def _note_stragglers(self, lanes: Sequence[LaneResult]) -> None:
        """Count successful lanes whose measured wall dwarfs the wave
        median (preempted host, contended device).  Detection only — the
        value is kept; re-measuring belongs to a noise model, not here."""
        if not self.executor.real_time or len(lanes) < 3:
            return
        walls = sorted(l.wall_s for l in lanes if l.error is None)
        if len(walls) < 3:
            return
        # true median: even-length waves average the two middle walls —
        # taking the upper element alone biased the threshold high and
        # misclassified borderline lanes on 4-lane waves
        n = len(walls)
        if n % 2:
            med = walls[n // 2]
        else:
            med = 0.5 * (walls[n // 2 - 1] + walls[n // 2])
        if med <= 0.0:
            return
        for l in lanes:
            if (
                l.error is None
                and l.wall_s > self.straggler_factor * med
                and l.wall_s > 0.05
            ):
                self.stats.n_stragglers += 1

    # -- dispatch ------------------------------------------------------------
    def measure_wave(self, states: Sequence[State]) -> list[MeasureOutcome]:
        """Measure up to ``n_workers`` states as one concurrent wave.

        Journal hits are served without touching the backend and occupy a
        lane for zero time; misses go to the backend — via its batched API
        when the wave has more than one miss — and are journaled so future
        sessions (or other workloads sharing the journal) hit the cache.
        """
        assert len(states) <= self.n_workers, "wave larger than lane count"
        if self.journal is not None and self.reload_every:
            self._waves_since_reload += 1
            if self._waves_since_reload >= self.reload_every:
                # merge rows appended by sibling engines/processes since
                # the last read, *before* the cache lookup below — a
                # sibling's fresh measurement serves this wave for free
                self._waves_since_reload = 0
                self.stats.n_journal_reloads += 1
                self.stats.n_journal_rows_merged += self.journal.reload()
        outcomes: list[Optional[MeasureOutcome]] = [None] * len(states)
        miss_idx: list[int] = []
        n_hits = 0
        for i, s in enumerate(states):
            cached = None
            if self.journal is not None and self.journal_key is not None:
                cached = self.journal.get(
                    self.journal_key, s.key(), op=self.backend.op
                )
            if cached is not None:
                outcomes[i] = MeasureOutcome(s, cached, True, 0.0)
                n_hits += 1
                if self.shard is not None and not self.shard.owns(
                    self.journal_key, s.key()
                ):
                    # a hit on a candidate we don't own: the sibling's
                    # measurement (merged by an earlier reload) served it
                    self.stats.n_served_by_sibling += 1
            else:
                miss_idx.append(i)
        if miss_idx and self.analyze != "off":
            # static pre-filter: classify every miss before it occupies a
            # lane; provably-bad candidates (ILLEGAL, or degenerate
            # WASTEFUL) are rejected compile-free in prune mode and
            # journaled as audit rows, anything else merely flagged
            t0 = time.perf_counter()
            kept: list[int] = []
            for i in miss_idx:
                s = states[i]
                res = self.analyzer.analyze(s)
                if self.analyze == "prune" and should_prune(res):
                    outcomes[i] = MeasureOutcome(
                        s, math.inf, False, 0.0, static=res.reason
                    )
                    self.stats.trials_avoided += 1
                    if self.journal is not None and self.journal_key is not None:
                        self.journal.record_static(
                            self.journal_key, s, res.reason, op=self.backend.op
                        )
                else:
                    if not res.ok:
                        self.stats.n_static_flags += 1
                    kept.append(i)
            miss_idx = kept
            self.stats.static_s += time.perf_counter() - t0
        if self.learned_filter is not None and len(miss_idx) >= 2:
            # learned proposal filter: retrain at its cadence from the
            # journal rows accumulated so far (this very search's rows
            # included), then measure only the wave's predicted-best
            # fraction.  A skip is an inf outcome carrying the score and
            # a {"c": null, "pred": score} provenance row — never a
            # cost-table entry, so nothing downstream can ever serve the
            # guess as a measurement.  The trial is still charged by
            # TuningContext, exactly like a static prune.  Waves that
            # cannot skip anything (fully cache-served, or a single
            # miss) never reach this block, so they neither advance the
            # retrain cadence nor pay a build_dataset re-parse with
            # nothing to filter.
            flt = self.learned_filter
            learn_before = flt.learn_s
            retrains_before = flt.n_retrains
            flt.maybe_retrain()
            kept_rel, skipped_rel = flt.select([states[i] for i in miss_idx])
            for rel, score in skipped_rel:
                i = miss_idx[rel]
                s = states[i]
                outcomes[i] = MeasureOutcome(
                    s, math.inf, False, 0.0, predicted=score
                )
                self.stats.trials_avoided_learned += 1
                if self.journal is not None and self.journal_key is not None:
                    self.journal.record_predicted(
                        self.journal_key, s, score, op=self.backend.op
                    )
            miss_idx = [miss_idx[rel] for rel in kept_rel]
            self.stats.learn_s += flt.learn_s - learn_before
            self.stats.n_learned_retrains += flt.n_retrains - retrains_before
        if self.shard is not None and miss_idx:
            # shard ownership — the last funnel stage before the lanes:
            # misses this engine does not own are the sibling's to
            # measure.  One journal reload gives the sibling's fresh
            # rows a chance to serve them as free hits; whatever is
            # still missing defers (an inf outcome with zero lane time,
            # never journaled — the sibling will write the real row, and
            # the elect-and-merge step reconciles the bests at the end).
            owned = [
                i for i in miss_idx
                if self.shard.owns(self.journal_key, states[i].key())
            ]
            foreign = [i for i in miss_idx if i not in set(owned)]
            if foreign:
                self.stats.n_journal_reloads += 1
                self.stats.n_journal_rows_merged += self.journal.reload()
                for i in foreign:
                    s = states[i]
                    cached = self.journal.get(
                        self.journal_key, s.key(), op=self.backend.op
                    )
                    if cached is not None:
                        outcomes[i] = MeasureOutcome(s, cached, True, 0.0)
                        n_hits += 1
                        self.stats.n_served_by_sibling += 1
                    else:
                        outcomes[i] = MeasureOutcome(
                            s, math.inf, False, 0.0, deferred=True
                        )
                        self.stats.n_deferred_to_sibling += 1
            miss_idx = owned
        if miss_idx:
            # NOTE: self.timeout_s is the *simulated charging cap* (a slow
            # config charges at most that much search clock); the real
            # executors own their kill timeout separately — conflating the
            # two would kill legitimately slow measurements (XLA compiles)
            fault_fn = getattr(self.executor, "fault_stats", None)
            fault_before = fault_fn() if callable(fault_fn) else None
            attempts = dict.fromkeys(miss_idx, 0)
            acc_lane_s = dict.fromkeys(miss_idx, 0.0)
            pending = list(miss_idx)
            while pending:
                sub = [states[i] for i in pending]
                compile_before = self.backend.compile_stats()
                lanes = self.executor.run_wave(self.backend, sub)
                self._fold_compile(lanes, compile_before)
                self._note_stragglers(lanes)
                nxt: list[int] = []
                backoffs: list[float] = []
                for i, lane in zip(pending, lanes):
                    s = states[i]
                    attempts[i] += 1
                    kind = self._lane_kind(lane)
                    acc_lane_s[i] += (
                        lane.wall_s
                        if self.executor.real_time
                        else self.lane_time(lane.cost if kind is None else math.inf)
                    )
                    if (
                        self.retry is not None
                        and kind in TRANSIENT_KINDS
                        and attempts[i] < self.retry.max_attempts
                    ):
                        # transient: the lane died, the schedule was never
                        # judged — re-queue into a follow-up wave with
                        # deterministic backoff instead of surfacing inf
                        delay = self.retry.delay_s(s.key(), attempts[i])
                        self.stats.n_retries += 1
                        self.stats.retry_backoff_s += delay
                        acc_lane_s[i] += delay
                        backoffs.append(delay)
                        nxt.append(i)
                        continue
                    outcomes[i] = self._finalize(
                        s, lane, kind, attempts[i], acc_lane_s[i]
                    )
                if nxt and backoffs and self.executor.real_time:
                    # the retried lanes redispatch as one wave: sleep the
                    # longest backoff for real; simulated lanes only
                    # charged it to the clock above
                    time.sleep(max(backoffs))
                pending = nxt
            if fault_before is not None:
                after = fault_fn()
                for key, attr in (
                    ("n_respawns", "n_respawns"),
                    ("n_spare_adoptions", "n_spare_adoptions"),
                    ("n_degraded_lanes", "n_degraded_lanes"),
                ):
                    setattr(
                        self.stats, attr,
                        getattr(self.stats, attr)
                        + after.get(key, 0) - fault_before.get(key, 0),
                    )
        done = [o for o in outcomes if o is not None]
        self.stats.n_dispatched += len(miss_idx)
        self.stats.n_cache_hits += n_hits
        self.stats.n_waves += 1
        span = max((o.lane_s for o in done), default=0.0)
        self.stats.lane_busy_s += sum(o.lane_s for o in done)
        self.stats.span_s += span
        return done

    def cache_hit_rate(self) -> float:
        return self.stats.cache_hit_rate()

"""Static schedule analysis — compile-free legality/feasibility verdicts
over :class:`~repro.core.space.SearchSpace` schedule states.

Every candidate a tuner proposes normally burns a measurement lane (or a
full XLA compile) even when it is *statically* doomed.  TVM bakes these
legality constraints into its schedule templates; here they live in one
analyzer that every layer shares, so the oracle, the measurement
engine's pre-filter, trace-time dispatch, and the audit CLI can never
disagree about what "cannot work" means.

Verdict lattice (``AnalysisResult.verdict``):

``ILLEGAL`` — provably cannot compile or fit.  Two reason families:

  * *structural* (``SearchSpace.structural_error``): wrong row count or
    nesting depth, a factor < 1 (a zero grid dim), a row product that
    does not equal its dimension (which also covers block > dim), or a
    constraint-hook rejection.  Every oracle already scores these
    ``inf`` via ``is_legitimate``.
  * ``vmem_overflow``: the double-buffered working set (including the
    f32 scratch, via the op's single budget function below) exceeds the
    ``TpuSpec`` VMEM budget.  Both analytical cost models delegate their
    feasibility cliff here, and ``XLATimedCost``'s guard uses the same
    ``working_set_bytes``, so ILLEGAL states measure ``inf`` under every
    backend.

``WASTEFUL`` — legal but dominated.  Reasons:

  * ``degenerate``: the padding ratio (padded MXU/VPU FLOPs over useful
    FLOPs) sits at the space's worst-case corner — no tiling at all on
    any aligned axis (for GEMM: ``sub_m == block_k == sub_n == 1``).
  * ``padding``: padding ratio at or above an advisory threshold
    (default 16x) — e.g. a lane-misaligned ``sub_n``.  Misalignment is
    WASTEFUL, *not* ILLEGAL: Pallas pads and compiles such blocks fine,
    it just wastes systolic cycles.
  * ``under_buffer``: working set below the double-buffer floor (two
    double-buffered operand tiles of minimal aligned shape) — the DMA
    engine cannot overlap anything useful.

``OK`` — no static objection.

Pruning policy (:func:`should_prune`, what ``MeasureEngine``'s
``analyze="prune"`` rejects): ILLEGAL plus *only* the ``degenerate``
WASTEFUL subclass.  ILLEGAL pruning is sequence-preserving by
construction (the oracle returns ``inf`` for exactly those states).
Degenerate states are the provable plateau maximum of the padding model
and can never be a returned best; empirically, pruning them leaves the
G-BFS final best bit-identical on the paper's 1024^3 protocol at every
fraction/seed while still avoiding trials.  Pruning the *broader*
WASTEFUL classes is NOT search-neutral — replacing their finite-bad
costs with ``inf`` flattens the cost gradient greedy search descends —
so ``padding``/``under_buffer`` only ever warn.

This module deliberately imports nothing from the rest of ``repro.core``
at module level (``TpuSpec`` is resolved lazily); the spaces and cost
models import *it*.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

__all__ = [
    "ILLEGAL",
    "WASTEFUL",
    "OK",
    "AnalysisResult",
    "ScheduleAnalyzer",
    "analyzer_for_backend",
    "should_prune",
    "register_padding_model",
    "gemm_working_set_bytes",
    "flash_working_set_bytes",
    "dtype_in_bytes",
]

ILLEGAL = "ILLEGAL"
WASTEFUL = "WASTEFUL"
OK = "OK"

#: dtype name -> element bytes (for analyzers built from a backend's
#: dtype string rather than an explicit in_bytes)
_DTYPE_BYTES = {
    "float64": 8, "f64": 8,
    "float32": 4, "f32": 4,
    "bfloat16": 2, "bf16": 2,
    "float16": 2, "f16": 2,
    "int8": 1, "uint8": 1,
}


def dtype_in_bytes(dtype: Optional[str], default: int = 2) -> int:
    """Element size of a dtype name; unknown/None falls back to bf16."""
    if dtype is None:
        return default
    return _DTYPE_BYTES.get(str(dtype), default)


# -- single-source VMEM budget functions --------------------------------------
# THE working-set arithmetic.  GemmConfigSpace/FlashAttnConfigSpace
# delegate their ``working_set_bytes`` here and the cost models' batch
# paths call these directly, so the double-buffer multiplier and scratch
# accounting exist exactly once.  Exact integer arithmetic — callers
# rely on bit-identical values.


def gemm_working_set_bytes(block_m: int, block_k: int, block_n: int,
                           in_bytes: int = 2) -> int:
    """Double-buffered A/B blocks plus the f32 accumulator."""
    return 2 * (block_m * block_k + block_k * block_n) * in_bytes \
        + block_m * block_n * 4


def flash_working_set_bytes(block_q: int, block_kv: int, seq_kv: int,
                            head_dim: int, in_bytes: int = 2) -> int:
    """Q block + fully resident K/V (the kernel's BlockSpec streams whole
    sequences per grid cell) + f32 accumulator, logits tile, and running
    max/sum."""
    return (
        (block_q * head_dim + 2 * seq_kv * head_dim) * in_bytes
        + block_q * head_dim * 4  # f32 accumulator
        + block_q * block_kv * 4  # logits/probability tile
        + 2 * block_q * 4  # running max + sum
    )


def _pad(x: int, g: int) -> int:
    return ((x + g - 1) // g) * g


# -- per-op padding models ----------------------------------------------------
# (tiles, ratio) per op: ``tiles(space, state)`` extracts the tunable
# MXU-facing tile values; ``ratio(space, tiles, spec, sub_gran)`` is
# padded FLOPs over useful FLOPs for those tiles.  The all-ones tile
# tuple is the space's worst corner — the "degenerate" class.


def _gemm_padding_tiles(space, s) -> tuple[int, ...]:
    return (s.sub_m, s.block_k, s.sub_n)


def _gemm_padding_ratio(space, tiles, spec, sub_gran: int) -> float:
    sub_m, bk, sub_n = tiles
    padded = _pad(sub_m, sub_gran) * _pad(bk, spec.mxu_k) * _pad(sub_n, spec.lane)
    return padded / (sub_m * bk * sub_n)


def _flash_padding_tiles(space, s) -> tuple[int, ...]:
    return (s.block_q, s.block_kv)


def _flash_padding_ratio(space, tiles, spec, sub_gran: int) -> float:
    bq, bkv = tiles
    hd = space.head_dim
    # the kernel's two MXU calls per kv visit: q @ k^T and p @ v
    padded = _pad(bq, sub_gran) * (
        _pad(hd, spec.mxu_k) * _pad(bkv, spec.lane)
        + _pad(bkv, spec.mxu_k) * _pad(hd, spec.lane)
    )
    return padded / (bq * 2 * hd * bkv)


_PADDING_MODELS: dict[str, tuple[Callable, Callable]] = {
    "gemm": (_gemm_padding_tiles, _gemm_padding_ratio),
    "flash": (_flash_padding_tiles, _flash_padding_ratio),
}


def register_padding_model(op: str, tiles: Callable, ratio: Callable) -> None:
    """Plug a padding model in for a new op (same shapes as the built-in
    gemm/flash entries); ops without one skip the WASTEFUL padding
    checks but still get structural + VMEM legality."""
    _PADDING_MODELS[op] = (tiles, ratio)


# -- results ------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AnalysisResult:
    """One verdict: ``(verdict, reason, detail)``.  ``reason`` is the
    stable machine-readable tag (what journal ``static`` rows and tests
    key on); ``detail`` is the human-readable explanation."""

    verdict: str
    reason: str = ""
    detail: str = ""

    @property
    def ok(self) -> bool:
        return self.verdict == OK

    @property
    def illegal(self) -> bool:
        return self.verdict == ILLEGAL

    @property
    def wasteful(self) -> bool:
        return self.verdict == WASTEFUL


_OK_RESULT = AnalysisResult(OK)


def should_prune(result: AnalysisResult) -> bool:
    """The engine's search-neutral prune policy: ILLEGAL (the oracle
    scores those ``inf`` anyway) plus the ``degenerate`` WASTEFUL
    subclass only (see module docstring for why the broader WASTEFUL
    classes must keep measuring)."""
    return result.illegal or (result.wasteful and result.reason == "degenerate")


class ScheduleAnalyzer:
    """Classifies schedule states of one space without compiling or
    running anything.  Verdicts are pure functions of
    ``(state, space identity, spec, in_bytes, thresholds)`` — memoized
    per state key, and two analyzers built with equal parameters agree
    on every state.

    ``spec`` is duck-typed (needs ``vmem_bytes``, ``sublane``, ``lane``,
    ``mxu_k``); default is the shared :class:`TpuSpec`, imported lazily
    so this module stays import-light.  ``vmem_budget_bytes`` overrides
    the spec's budget (e.g. ``XLATimedCost.vmem_guard_bytes``)."""

    def __init__(
        self,
        space,
        spec=None,
        in_bytes: int = 2,
        wasteful_padding_ratio: float = 16.0,
        vmem_budget_bytes: Optional[int] = None,
    ):
        if spec is None:
            from .cost.analytical import TpuSpec  # lazy: keep imports one-way

            spec = TpuSpec()
        self.space = space
        self.spec = spec
        self.in_bytes = int(in_bytes)
        self.wasteful_padding_ratio = float(wasteful_padding_ratio)
        self.vmem_budget_bytes = (
            int(vmem_budget_bytes)
            if vmem_budget_bytes is not None
            else int(spec.vmem_bytes)
        )
        self._sub_gran = spec.sublane.get(self.in_bytes, 8)
        # two double-buffered operand tiles of minimal aligned shape —
        # below this the DMA engine has nothing to overlap
        self.buffer_floor_bytes = 2 * 2 * self._sub_gran * spec.lane * self.in_bytes
        self._model = _PADDING_MODELS.get(getattr(space, "op", None))
        self._worst_ratio: Optional[float] = None
        self._cache: dict[str, AnalysisResult] = {}

    # -- components ----------------------------------------------------------
    def vmem_bytes(self, s) -> int:
        """The schedule's working set under this analyzer's dtype — the
        single budget source (the space delegates to the functions
        above)."""
        return self.space.working_set_bytes(s, self.in_bytes)

    def exceeds_vmem(self, s) -> bool:
        """The feasibility cliff both analytical cost models delegate
        to.  Kept allocation-free: this sits on the oracle hot path."""
        return self.space.working_set_bytes(s, self.in_bytes) > self.vmem_budget_bytes

    def padding_ratio(self, s) -> Optional[float]:
        """Padded-over-useful FLOPs for the state's MXU tiles, or None
        when the op has no registered padding model."""
        if self._model is None:
            return None
        tiles, ratio = self._model
        return ratio(self.space, tiles(self.space, s), self.spec, self._sub_gran)

    def worst_padding_ratio(self) -> Optional[float]:
        """The space's worst padding corner — every tunable tile at 1
        (for GEMM that is the untiled ``sub_m = block_k = sub_n = 1``
        class).  States *at* this ratio are the ``degenerate`` class."""
        if self._model is None:
            return None
        if self._worst_ratio is None:
            tiles, ratio = self._model
            n = len(tiles(self.space, self.space.initial_state()))
            self._worst_ratio = ratio(
                self.space, (1,) * n, self.spec, self._sub_gran
            )
        return self._worst_ratio

    # -- classification ------------------------------------------------------
    def analyze(self, s) -> AnalysisResult:
        try:
            key = s.key()
        except Exception:
            return self._classify(s)
        cached = self._cache.get(key)
        if cached is None:
            cached = self._cache[key] = self._classify(s)
        return cached

    def _classify(self, s) -> AnalysisResult:
        err = self._structural(s)
        if err is not None:
            return AnalysisResult(ILLEGAL, err[0], err[1])
        ws = self.vmem_bytes(s)
        if ws > self.vmem_budget_bytes:
            return AnalysisResult(
                ILLEGAL,
                "vmem_overflow",
                f"working set {ws} B exceeds the {self.vmem_budget_bytes} B "
                f"VMEM budget (in_bytes={self.in_bytes})",
            )
        ratio = self.padding_ratio(s)
        if ratio is not None:
            worst = self.worst_padding_ratio()
            if worst is not None and ratio >= worst:
                return AnalysisResult(
                    WASTEFUL,
                    "degenerate",
                    f"padding ratio {ratio:.0f}x is the space's worst corner "
                    f"(no tiling on any MXU/VPU-aligned axis)",
                )
            if ratio >= self.wasteful_padding_ratio:
                return AnalysisResult(
                    WASTEFUL,
                    "padding",
                    f"padding ratio {ratio:.1f}x >= "
                    f"{self.wasteful_padding_ratio:g}x: misaligned tiles "
                    f"waste most systolic cycles",
                )
        if ws < self.buffer_floor_bytes:
            return AnalysisResult(
                WASTEFUL,
                "under_buffer",
                f"working set {ws} B is below the {self.buffer_floor_bytes} B "
                f"double-buffer floor",
            )
        return _OK_RESULT

    def _structural(self, s) -> Optional[tuple[str, str]]:
        structural_error = getattr(self.space, "structural_error", None)
        try:
            if structural_error is not None:
                return structural_error(s)
            if self.space.is_legitimate(s):
                return None
            return ("illegitimate", "state fails the space's legitimacy check")
        except Exception as e:  # malformed rows: wrong types, bad arity
            return ("malformed", f"{type(e).__name__}: {e}")


def analyzer_for_backend(backend) -> ScheduleAnalyzer:
    """Build the analyzer matching a cost backend's measurement settings:
    its space, its element width (``in_bytes`` attribute or dtype), its
    chip spec when it carries one, and its VMEM guard when it overrides
    the spec budget (``XLATimedCost.vmem_guard_bytes``)."""
    in_bytes = getattr(backend, "in_bytes", None)
    if in_bytes is None:
        in_bytes = dtype_in_bytes(getattr(backend, "dtype", None))
    return ScheduleAnalyzer(
        backend.space,
        spec=getattr(backend, "spec", None),
        in_bytes=in_bytes,
        vmem_budget_bytes=getattr(backend, "vmem_guard_bytes", None),
    )

"""Crash-safe tuning-session snapshots — the search-side counterpart of
``repro.checkpoint.checkpointer``.

A tuning session holds state the journal cannot reconstruct: the G-BFS
frontier, a genetic population, N-A2C network weights, every tuner's RNG
stream, the search clock, and the budget already spent.  Losing a
session to SIGTERM used to mean losing all of it (only the journal's
measurements survived).  :class:`TuneCheckpointer` snapshots that state
at tuner *round boundaries* — each tuner calls
``TuningContext.checkpoint(self)`` at the top of its proposal loop —
using the same atomic publish protocol as the training checkpointer
(staging dir → ``os.replace`` → ``COMMIT`` marker → GC), one snapshot
directory per ``(workload, tuner)``.

The division of labor on resume: **the journal replays measurements,
the snapshot restores the search.**  Rounds executed after the last
snapshot but before the kill re-run deterministically because their
measurements are journal cache hits (same costs) and the tuner RNG was
restored to the same cut — so an interrupted-and-resumed run reaches
the bit-identical best state an uninterrupted run finds.

SIGTERM/SIGINT handling is cooperative: the handler only sets a flag;
the next ``checkpoint()`` call flushes a final snapshot and raises
:class:`TuneInterrupted`, which ``launch/tune.py`` turns into exit code
130.  A second signal falls back to ``KeyboardInterrupt`` so a stuck
session can still be killed interactively.

Everything here is JSON (no jax import at module scope):
:func:`tree_to_jsonable` / :func:`tree_from_jsonable` round-trip nested
dict/list/tuple trees of numpy-or-jax array leaves exactly (float32
values survive the float repr round-trip bit-identically).
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import signal
from typing import Any, Callable, Optional

__all__ = [
    "TuneCheckpointer",
    "TuneInterrupted",
    "tree_to_jsonable",
    "tree_from_jsonable",
]


class TuneInterrupted(Exception):
    """A SIGTERM/SIGINT was honoured at a round boundary; the final
    snapshot is already on disk.  Carries the workload key."""


# -- pytree <-> JSON ----------------------------------------------------------

def tree_to_jsonable(tree: Any) -> Any:
    """Encode a nested dict/list/tuple tree with array leaves (numpy or
    jax) as plain JSON-serializable data."""
    import numpy as np

    if isinstance(tree, dict):
        return {"t": "d", "v": {k: tree_to_jsonable(v) for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        return {
            "t": "l" if isinstance(tree, list) else "u",
            "v": [tree_to_jsonable(x) for x in tree],
        }
    a = np.asarray(tree)
    return {
        "t": "a",
        "dtype": str(a.dtype),
        "shape": list(a.shape),
        "v": a.ravel().tolist(),
    }


def tree_from_jsonable(data: Any, leaf: Optional[Callable] = None) -> Any:
    """Inverse of :func:`tree_to_jsonable`.  ``leaf`` converts each
    reconstructed numpy array (e.g. ``jnp.asarray`` for jax trees)."""
    import numpy as np

    t = data["t"]
    if t == "d":
        return {k: tree_from_jsonable(v, leaf) for k, v in data["v"].items()}
    if t in ("l", "u"):
        out = [tree_from_jsonable(x, leaf) for x in data["v"]]
        return out if t == "l" else tuple(out)
    a = np.asarray(data["v"], dtype=data["dtype"]).reshape(data["shape"])
    return a if leaf is None else leaf(a)


# -- the snapshot store -------------------------------------------------------

class TuneCheckpointer:
    """Atomic per-``(workload, tuner)`` snapshot store with cooperative
    interrupt handling.

    ``every_rounds`` is the periodic cadence (snapshot when
    ``round % every_rounds == 0``); an interrupt request always flushes
    regardless of cadence.  ``keep_n`` committed snapshots are retained
    per workload (older ones GC'd) — the ``done`` snapshot written on
    workload completion is always the latest."""

    def __init__(self, directory: str, every_rounds: int = 1, keep_n: int = 2):
        self.directory = directory
        self.every_rounds = max(1, int(every_rounds))
        self.keep_n = max(1, int(keep_n))
        self._interrupted = False

    # -- interrupts ----------------------------------------------------------
    @property
    def interrupted(self) -> bool:
        return self._interrupted

    def request_interrupt(self) -> None:
        """Signal-safe: flag only; honoured at the next round boundary."""
        self._interrupted = True

    def install_signal_handlers(self) -> None:
        def handler(signum, frame):
            if self._interrupted:
                # second signal: the user means it — stop cooperating
                raise KeyboardInterrupt
            self.request_interrupt()

        for sig in (signal.SIGTERM, signal.SIGINT):
            signal.signal(sig, handler)

    # -- layout --------------------------------------------------------------
    def _wdir(self, workload_key: str, tuner_name: str) -> str:
        ident = f"{workload_key}__{tuner_name}"
        slug = re.sub(r"[^A-Za-z0-9._=-]+", "_", ident)[:80]
        h = hashlib.blake2b(ident.encode("utf-8"), digest_size=6).hexdigest()
        return os.path.join(self.directory, f"{slug}-{h}")

    def clear(self, workload_key: str, tuner_name: str) -> None:
        """Drop all snapshots for one ``(workload, tuner)`` — a fresh
        (non-resume) run must not leave a stale ``done`` marker behind
        for a later ``--resume`` to trip over."""
        shutil.rmtree(self._wdir(workload_key, tuner_name), ignore_errors=True)

    # -- save ----------------------------------------------------------------
    def save(
        self, workload_key: str, tuner_name: str, payload: dict, step: int
    ) -> str:
        """Publish one snapshot atomically; returns the committed path."""
        d = self._wdir(workload_key, tuner_name)
        final = os.path.join(d, f"step_{step:08d}")
        staging = f"{final}.tmp-{os.getpid()}"
        os.makedirs(staging, exist_ok=True)
        with open(os.path.join(staging, "state.json"), "w") as f:
            json.dump(payload, f, separators=(",", ":"))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(staging, final)  # atomic publish
        with open(os.path.join(final, "COMMIT"), "w") as f:
            f.write("ok\n")
        self._gc(d)
        return final

    def _gc(self, d: str) -> None:
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(d)
            if n.startswith("step_") and "tmp" not in n
            and os.path.exists(os.path.join(d, n, "COMMIT"))
        )
        for s in steps[: -self.keep_n]:
            shutil.rmtree(os.path.join(d, f"step_{s:08d}"), ignore_errors=True)

    # -- restore -------------------------------------------------------------
    def latest_step(self, workload_key: str, tuner_name: str) -> Optional[int]:
        d = self._wdir(workload_key, tuner_name)
        if not os.path.isdir(d):
            return None
        steps = []
        for name in os.listdir(d):
            if name.startswith("step_") and "tmp" not in name:
                if os.path.exists(os.path.join(d, name, "COMMIT")):
                    try:
                        steps.append(int(name.split("_")[1]))
                    except ValueError:
                        continue
        return max(steps) if steps else None

    def load(self, workload_key: str, tuner_name: str) -> Optional[dict]:
        """The latest committed snapshot payload, or None (no snapshot:
        resume degenerates to a fresh run, which the journal makes
        equivalent anyway)."""
        step = self.latest_step(workload_key, tuner_name)
        if step is None:
            return None
        d = self._wdir(workload_key, tuner_name)
        with open(os.path.join(d, f"step_{step:08d}", "state.json")) as f:
            return json.load(f)

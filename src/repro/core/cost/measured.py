"""Measured cost backends — real wall-clock oracles.

The paper measures candidate configurations on real hardware (Titan Xp).
These backends do the honest equivalent available in this container:

* :class:`XLATimedCost` — realizes the *tiled loop structure* of a
  configuration as an XLA:CPU program (fori_loop over the macro-grid with
  dynamic-sliced blocks, k innermost with VMEM-style accumulation) and
  times it.  Different tilings genuinely run at different speeds on the
  CPU cache hierarchy, so the search problem is real, just on a different
  memory system than the TPU target.  ``batch_cost`` compiles a batch's
  candidates concurrently on a thread pool (XLA compilation releases the
  GIL) and then times them serially — timing in parallel would contend
  for cores and corrupt the measurements.

* :class:`PallasInterpretCost` — times the actual Pallas kernel
  (`repro.kernels.gemm`) in ``interpret=True`` mode.  Functionally
  faithful to the TPU kernel; timing reflects the interpreter, so this
  backend is for correctness-coupled search demos on small shapes.

Both are deliberately interchangeable with :class:`AnalyticalTPUCost`
behind the same :class:`CostBackend` protocol (DESIGN.md §2).
"""

from __future__ import annotations

import math
import time
from functools import partial

import numpy as np

from ..config_space import GemmConfigSpace, TilingState
from .base import CostBackend

__all__ = ["XLATimedCost", "PallasInterpretCost"]


class XLATimedCost(CostBackend):
    name = "xla_cpu_timed"

    def __init__(
        self,
        space: GemmConfigSpace,
        n_repeats: int = 3,
        dtype: str = "float32",
        vmem_guard_bytes: int = 16 * 1024 * 1024,
        seed: int = 0,
        n_build_workers: int = 4,
    ):
        super().__init__(space, n_repeats)
        import jax
        import jax.numpy as jnp

        self._jax, self._jnp = jax, jnp
        self.dtype = dtype
        self.vmem_guard_bytes = vmem_guard_bytes
        self.n_build_workers = max(1, n_build_workers)
        rng = np.random.default_rng(seed)
        self._A = jnp.asarray(
            rng.standard_normal((space.m, space.k)), dtype=dtype
        )
        self._B = jnp.asarray(
            rng.standard_normal((space.k, space.n)), dtype=dtype
        )
        self._cache: dict[str, object] = {}

    def _build(self, s: TilingState):
        jax, jnp = self._jax, self._jnp
        lax = jax.lax
        gm, gk, gn = s.grid
        bm, bk, bn = s.block_m, s.block_k, s.block_n
        M, N = self.space.m, self.space.n

        def fn(A, B):
            C = jnp.zeros((M, N), dtype=self.dtype)

            def body(idx, C):
                ik = idx % gk
                rest = idx // gk
                i_n = rest % gn
                i_m = rest // gn
                a = lax.dynamic_slice(A, (i_m * bm, ik * bk), (bm, bk))
                b = lax.dynamic_slice(B, (ik * bk, i_n * bn), (bk, bn))
                c = jnp.dot(a, b)
                old = lax.dynamic_slice(C, (i_m * bm, i_n * bn), (bm, bn))
                return lax.dynamic_update_slice(C, old + c, (i_m * bm, i_n * bn))

            return lax.fori_loop(0, gm * gk * gn, body, C)

        return jax.jit(fn)

    def _fits_vmem(self, s: TilingState) -> bool:
        # Honor the TPU VMEM legitimacy constraint so the searched space
        # matches what the Pallas kernel would accept on hardware.
        itemsize = self._jnp.dtype(self.dtype).itemsize
        bm, bk, bn = s.block_m, s.block_k, s.block_n
        return (
            2 * (bm * bk + bk * bn) * itemsize + bm * bn * 4
            <= self.vmem_guard_bytes
        )

    def _build_and_warm(self, s: TilingState):
        fn = self._build(s)
        fn(self._A, self._B).block_until_ready()  # compile + warmup
        return fn

    def cost_once(self, s: TilingState, repeat_idx: int) -> float:
        if not self._fits_vmem(s):
            return math.inf
        key = s.key()
        fn = self._cache.get(key)
        if fn is None:
            fn = self._build_and_warm(s)
            self._cache[key] = fn
        t0 = time.perf_counter()
        fn(self._A, self._B).block_until_ready()
        return time.perf_counter() - t0

    def batch_cost(self, states) -> list[float]:
        """Compile the batch's unbuilt candidates on a thread pool, then
        time each serially (parallel timing would contend for cores)."""
        from concurrent.futures import ThreadPoolExecutor

        states = list(states)
        todo, seen = [], set()
        for s in states:
            key = s.key()
            if (
                key not in self._cache
                and key not in seen
                and self.space.is_legitimate(s)
                and self._fits_vmem(s)
            ):
                todo.append(s)
                seen.add(key)
        if len(todo) > 1:
            workers = min(self.n_build_workers, len(todo))
            with ThreadPoolExecutor(max_workers=workers) as ex:
                futures = [(s.key(), ex.submit(self._build_and_warm, s)) for s in todo]
                for key, fut in futures:
                    self._cache[key] = fut.result()
        return [self.cost(s) for s in states]


class PallasInterpretCost(CostBackend):
    name = "pallas_interpret_timed"

    def __init__(self, space: GemmConfigSpace, n_repeats: int = 1, seed: int = 0):
        super().__init__(space, n_repeats)
        import jax.numpy as jnp

        rng = np.random.default_rng(seed)
        self._A = jnp.asarray(
            rng.standard_normal((space.m, space.k)), dtype=jnp.float32
        )
        self._B = jnp.asarray(
            rng.standard_normal((space.k, space.n)), dtype=jnp.float32
        )

    def cost_once(self, s: TilingState, repeat_idx: int) -> float:
        from repro.kernels.gemm import gemm_pallas, kernel_config_from_state

        try:
            cfg = kernel_config_from_state(s)
        except ValueError:
            return math.inf
        t0 = time.perf_counter()
        out = gemm_pallas(self._A, self._B, cfg, interpret=True)
        out.block_until_ready()
        return time.perf_counter() - t0

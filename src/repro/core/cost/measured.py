"""Measured cost backends — real wall-clock oracles.

The paper measures candidate configurations on real hardware (Titan Xp).
These backends do the honest equivalent available in this container:

* :class:`XLATimedCost` — realizes the *blocked loop structure* of a
  schedule as an XLA:CPU program and times it.  The per-op build recipe
  comes from the op registry (``repro.core.ops``): a tiled macro-grid
  matmul for ``gemm``, the blocked online-softmax loop for ``flash``.
  Different schedules genuinely run at different speeds on the CPU cache
  hierarchy, so the search problem is real, just on a different memory
  system than the TPU target.

  Compilation — not timing, not search logic — dominates the trial cost
  of this backend, so it is engineered out of the hot path at every
  layer (the TVM line of work treats build/measure throughput as a
  first-class axis; see "Learning to Optimize Tensor Programs"):

  - an :class:`ExecutableCache` holds compiled programs behind an
    LRU-bounded in-memory layer and an optional **persistent on-disk
    layer** (JAX's AOT ``serialize_executable`` facility), content-keyed
    by ``(op, workload dims, dtype, state.key(), jax/jaxlib version)`` —
    a re-run, a sibling engine, or a worker process on the same host
    skips straight past compilation;
  - ``batch_cost`` compiles a batch's *unique* unbuilt candidates
    concurrently on a thread pool (XLA compilation releases the GIL) and
    times each unique configuration exactly once, fanning the result out
    to duplicates;
  - the backend is **process-shippable** (``worker_spec()``): process
    lanes rebuild it from a picklable recipe, each worker keeps its own
    warm executable cache across jobs, and the warmup+timed region is
    serialized across lanes by a :class:`_TimingGate` (thread lock
    in-process, ``flock`` across processes) so parallel lanes never
    contend for cores *while a measurement is being timed*.  Compiles
    still overlap — they are two orders of magnitude longer than the
    timed region, and serializing them would erase the parallel win.

* :class:`PallasInterpretCost` — times the op's actual Pallas kernel
  (via the registry's ``pallas_run`` binding) in ``interpret=True``
  mode.  Functionally faithful to the TPU kernel; timing reflects the
  interpreter, so this backend is for correctness-coupled search demos
  on small shapes.  Process-shippable via ``worker_spec()`` like the
  other backends.

Both are deliberately interchangeable with :class:`AnalyticalTPUCost`
behind the same :class:`CostBackend` protocol (DESIGN.md §2).
"""

from __future__ import annotations

import hashlib
import math
import os
import pickle
import tempfile
import threading
import time
from collections import OrderedDict
from typing import Optional


from ..space import SearchSpace, State
from .base import CostBackend

__all__ = ["XLATimedCost", "PallasInterpretCost", "ExecutableCache"]


class _TimingGate:
    """Serializes the warmup+timed region of a measurement: a thread lock
    covers lanes sharing one backend object (ThreadExecutor), an
    exclusive ``flock`` on ``lock_path`` covers sibling worker processes
    (ProcessExecutor).  Held only around execution — compilation stays
    parallel."""

    def __init__(self, lock_path: Optional[str] = None):
        self.lock_path = lock_path
        self._tlock = threading.Lock()
        self._fd: Optional[int] = None

    def _flock(self, exclusive: bool) -> None:
        try:
            import fcntl
        except ImportError:  # non-POSIX: thread lock only
            return
        try:
            if self._fd is None:
                d = os.path.dirname(os.path.abspath(self.lock_path))
                os.makedirs(d, exist_ok=True)
                self._fd = os.open(self.lock_path, os.O_CREAT | os.O_RDWR, 0o644)
            fcntl.flock(self._fd, fcntl.LOCK_EX if exclusive else fcntl.LOCK_UN)
        except OSError:
            pass  # lock file unusable: measure anyway, just unserialized

    def __enter__(self) -> "_TimingGate":
        self._tlock.acquire()
        if self.lock_path is not None:
            self._flock(exclusive=True)
        return self

    def __exit__(self, *exc) -> None:
        try:
            if self.lock_path is not None and self._fd is not None:
                self._flock(exclusive=False)
        finally:
            self._tlock.release()


class ExecutableCache:
    """Two-layer compiled-program cache for :class:`XLATimedCost`.

    * **memory** — an LRU of loaded executables, bounded by ``capacity``
      so a long ``tune_arch`` run over many shapes cannot grow without
      limit;
    * **disk** (optional) — serialized executables under ``cache_dir``
      via JAX's AOT ``serialize_executable`` facility, content-keyed so
      one directory safely serves every shape/dtype/version.  Writes are
      atomic (tmp + rename), so sibling processes can share the
      directory; a corrupt or version-mismatched entry silently falls
      back to a fresh compile.

    Counters (``stats()``) feed ``MeasureStats``/``BENCH_measure.json``:
    ``compiles``, ``mem_hits``, ``disk_hits``, ``evictions``,
    ``compile_s`` (seconds spent compiling), ``n_timed`` (maintained by
    the backend: how many timed executions actually ran).
    """

    def __init__(self, capacity: int = 512, cache_dir: Optional[str] = None):
        self.capacity = max(1, int(capacity))
        self.cache_dir = cache_dir
        self._mem: OrderedDict[str, object] = OrderedDict()
        self._lock = threading.Lock()
        self.counters = {
            "compiles": 0,
            "mem_hits": 0,
            "disk_hits": 0,
            "evictions": 0,
            "compile_s": 0.0,
            "n_timed": 0,
        }

    # -- key/paths -----------------------------------------------------------
    @staticmethod
    def content_key(
        space: SearchSpace, dtype: str, state: State, flavor: str = ""
    ) -> str:
        """Content key: the compiled program is fully determined by the
        op, its workload dims, dtype, schedule state, and the jax/jaxlib
        (XLA) version that produced it.  The op field keeps one shared
        cache directory safe across operators; ``flavor`` separates
        program families that would otherwise collide on the same
        (op, dims, state) — e.g. the interpret-mode Pallas program and
        the plain-XLA timed program of the same schedule.  The default
        "" adds nothing, so pre-flavor XLATimedCost disk caches
        survive."""
        import jax
        import jaxlib

        op = getattr(space, "op", "gemm")
        dims = "x".join(map(str, space.dims))
        # non-default space construction kwargs (e.g. flash's causal
        # flag) change the compiled program: fold them into the key.
        # Empty kwargs add nothing, so pre-registry GEMM keys survive.
        kw = getattr(space, "spec_kwargs", dict)() or {}
        extra = "".join(f"/{k}={v!r}" for k, v in sorted(kw.items()))
        fl = f"/{flavor}" if flavor else ""
        raw = (
            f"{op}/{dims}/{dtype}/{state.key()}{extra}{fl}"
            f"/jax{jax.__version__}/jaxlib{jaxlib.__version__}"
        )
        return hashlib.sha256(raw.encode()).hexdigest()[:40]

    def _path(self, ckey: str) -> str:
        return os.path.join(self.cache_dir, f"{ckey}.xlaexec")

    # -- layers --------------------------------------------------------------
    def peek(self, ckey: str) -> bool:
        """Uncounted membership probe of the memory layer (used to skip
        already-built states without charging a hit event)."""
        with self._lock:
            return ckey in self._mem

    def get_mem(self, ckey: str, count: bool = True):
        with self._lock:
            fn = self._mem.get(ckey)
            if fn is not None:
                self._mem.move_to_end(ckey)
                if count:
                    self.counters["mem_hits"] += 1
            return fn

    def count_mem_hit(self) -> None:
        with self._lock:
            self.counters["mem_hits"] += 1

    def put_mem(self, ckey: str, fn) -> None:
        with self._lock:
            self._mem[ckey] = fn
            self._mem.move_to_end(ckey)
            while len(self._mem) > self.capacity:
                self._mem.popitem(last=False)
                self.counters["evictions"] += 1

    def get_disk(self, ckey: str):
        """Deserialize a previously-persisted executable, or None."""
        if self.cache_dir is None:
            return None
        path = self._path(ckey)
        if not os.path.exists(path):
            return None
        try:
            from jax.experimental import serialize_executable

            with open(path, "rb") as f:
                payload, in_tree, out_tree = pickle.load(f)
            fn = serialize_executable.deserialize_and_load(payload, in_tree, out_tree)
        except Exception:  # corrupt / version drift: recompile instead
            return None
        with self._lock:
            self.counters["disk_hits"] += 1
        return fn

    def put_disk(self, ckey: str, compiled) -> None:
        if self.cache_dir is None:
            return
        try:
            from jax.experimental import serialize_executable

            payload, in_tree, out_tree = serialize_executable.serialize(compiled)
            os.makedirs(self.cache_dir, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=self.cache_dir, suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as f:
                    pickle.dump((payload, in_tree, out_tree), f)
                os.replace(tmp, self._path(ckey))  # atomic publish
            finally:
                if os.path.exists(tmp):
                    os.unlink(tmp)
        except Exception:
            pass  # persistence is an optimization, never a failure mode

    def count_compile(self, seconds: float) -> None:
        with self._lock:
            self.counters["compiles"] += 1
            self.counters["compile_s"] += seconds

    def count_timed(self) -> None:
        with self._lock:
            self.counters["n_timed"] += 1

    def stats(self) -> dict:
        with self._lock:
            return dict(self.counters)

    def __len__(self) -> int:
        return len(self._mem)


def _xla_timed_from_spec(
    op: str, dims: list, depths: list, space_kwargs: dict,
    n_repeats: int, dtype: str, vmem_guard_bytes: int, seed: int,
    n_build_workers: int, cache_dir: Optional[str],
    cache_capacity: int, timing_lock_path: Optional[str],
) -> "XLATimedCost":
    """Worker-process factory (see ``CostBackend.worker_spec``)."""
    from ..ops import get_op

    return XLATimedCost(
        get_op(op).make_space(tuple(dims), tuple(depths), **space_kwargs),
        n_repeats=n_repeats,
        dtype=dtype,
        vmem_guard_bytes=vmem_guard_bytes,
        seed=seed,
        n_build_workers=n_build_workers,
        cache_dir=cache_dir,
        cache_capacity=cache_capacity,
        timing_lock_path=timing_lock_path,
    )


class XLATimedCost(CostBackend):
    name = "xla_cpu_timed"

    def __init__(
        self,
        space: SearchSpace,
        n_repeats: int = 3,
        dtype: str = "float32",
        vmem_guard_bytes: int = 16 * 1024 * 1024,
        seed: int = 0,
        n_build_workers: int = 4,
        cache_dir: Optional[str] = None,
        cache_capacity: int = 512,
        timing_lock_path: Optional[str] = None,
    ):
        super().__init__(space, n_repeats)
        import jax
        import jax.numpy as jnp

        from ..ops import get_op  # lazy: the registry imports cost modules

        self._jax, self._jnp = jax, jnp
        self.dtype = dtype
        self.vmem_guard_bytes = vmem_guard_bytes
        self.seed = seed
        self.n_build_workers = max(1, n_build_workers)
        # the op binding supplies the operands and the per-state timed
        # program -- this backend is build-recipe-agnostic
        self._opspec = get_op(self.op)
        self._args = self._opspec.timed_operands(space, dtype, seed)
        self.cache = ExecutableCache(capacity=cache_capacity, cache_dir=cache_dir)
        if timing_lock_path is None and cache_dir is not None:
            timing_lock_path = os.path.join(cache_dir, ".timing.lock")
        self.timing_lock_path = timing_lock_path
        self._gate = _TimingGate(timing_lock_path)

    # -- build ---------------------------------------------------------------
    def _build(self, s: State):
        """Lower + AOT-compile the op's timed program for ``s`` (cold
        path) -- the traceable realization of the schedule comes from the
        op registry's ``timed_fn`` binding."""
        fn = self._opspec.timed_fn(self.space, s, self.dtype)
        t0 = time.perf_counter()
        compiled = self._jax.jit(fn).lower(*self._args).compile()
        self.cache.count_compile(time.perf_counter() - t0)
        return compiled

    def _fits_vmem(self, s: State) -> bool:
        # Honor the TPU VMEM legitimacy constraint so the searched space
        # matches what the Pallas kernel would accept on hardware.
        itemsize = self._jnp.dtype(self.dtype).itemsize
        return (
            self.space.working_set_bytes(s, itemsize) <= self.vmem_guard_bytes
        )

    def _ensure(self, s: State, count_mem_hit: bool = True):
        """Resolve the executable for ``s``: in-memory LRU, then the
        persistent disk layer, then a fresh compile (persisted for the
        next session/worker).  Disk loads and compiles are warmed with
        one untimed call before entering the memory layer.

        ``count_mem_hit=False`` suppresses the memory-layer hit counter
        for resolves whose trial already charged its cache event (the
        batch path counts exactly one event per unique trial)."""
        ckey = ExecutableCache.content_key(self.space, self.dtype, s)
        fn = self.cache.get_mem(ckey, count=count_mem_hit)
        if fn is not None:
            return fn
        fn = self.cache.get_disk(ckey)
        if fn is None:
            fn = self._build(s)
            self.cache.put_disk(ckey, fn)
        # warmup: never timed, but gated — a warm run on the cores would
        # contend with a sibling lane's in-flight timed region
        with self._gate:
            fn(*self._args).block_until_ready()
        self.cache.put_mem(ckey, fn)
        return fn

    def _timed_mean(self, fn) -> float:
        """``n_repeats`` gated timed runs of a resolved executable; the
        gate keeps sibling lanes (threads sharing this backend, worker
        processes sharing the lock file) off the cores while a
        measurement is on the clock."""
        total = 0.0
        for _ in range(self.n_repeats):
            with self._gate:
                t0 = time.perf_counter()
                fn(*self._args).block_until_ready()
                total += time.perf_counter() - t0
            self.cache.count_timed()
        return total / self.n_repeats

    def cost(self, s: State) -> float:
        # resolve once per *trial* (not per repeat): the cache counters
        # feed compile_cache_hit_rate, which must mean "fraction of
        # trials served without a fresh compile"
        if not self.space.is_legitimate(s) or not self._fits_vmem(s):
            return math.inf
        return self._timed_mean(self._ensure(s))

    def cost_once(self, s: State, repeat_idx: int) -> float:
        # kept for the CostBackend protocol; cost() bypasses it so the
        # executable resolve (and its counters) happen once per trial
        if not self._fits_vmem(s):
            return math.inf
        fn = self._ensure(s)
        with self._gate:
            t0 = time.perf_counter()
            fn(*self._args).block_until_ready()
            dt = time.perf_counter() - t0
        self.cache.count_timed()
        return dt

    def batch_cost(self, states) -> list[float]:
        """Compile the batch's *unique* unbuilt candidates on a thread
        pool (XLA compilation releases the GIL), then time each unique
        configuration once — serially, so timing never contends for
        cores — and fan results out to duplicates.  Exactly one cache
        event is counted per unique measurable state: a mem hit for
        already-built ones, a disk hit or compile for the rest (charged
        inside the prefetch)."""
        from concurrent.futures import ThreadPoolExecutor

        states = list(states)
        todo, seen = [], set()
        for s in states:
            key = s.key()
            if (
                key not in seen
                and self.space.is_legitimate(s)
                and self._fits_vmem(s)
            ):
                seen.add(key)
                ckey = ExecutableCache.content_key(self.space, self.dtype, s)
                if self.cache.peek(ckey):
                    self.cache.count_mem_hit()  # warm trial: one event
                else:
                    todo.append(s)
        if len(todo) > 1:
            workers = min(self.n_build_workers, len(todo))
            with ThreadPoolExecutor(max_workers=workers) as ex:
                # the prefetch charges the trial's disk-hit/compile event
                for fut in [ex.submit(self._ensure, s, False) for s in todo]:
                    fut.result()
            todo = []
        by_key: dict[str, float] = {}
        out: list[float] = []
        single = {s.key() for s in todo}  # <2 misses: cost() charges it
        for s in states:
            key = s.key()
            if key not in by_key:
                if not self.space.is_legitimate(s) or not self._fits_vmem(s):
                    by_key[key] = math.inf
                elif key in single:
                    by_key[key] = self.cost(s)
                else:
                    by_key[key] = self._timed_mean(
                        self._ensure(s, count_mem_hit=False)
                    )
            out.append(by_key[key])
        return out

    # -- CostBackend protocol ------------------------------------------------
    def measure_fingerprint(self) -> str:
        # seed fixes the operand contents; dtype changes the program
        return (
            f"r{self.n_repeats}|{self.dtype}|seed{self.seed}"
            + self.space_fingerprint()
        )

    def compile_stats(self) -> Optional[dict]:
        return self.cache.stats()

    def worker_spec(self):
        space_kwargs = self.space.spec_kwargs()
        if space_kwargs is None:
            # arbitrary closures don't survive the spec round-trip;
            # refuse to ship rather than search a subtly different space
            return None
        dims = self.space.dims
        lock = self.timing_lock_path
        if lock is None:
            # all workers rebuilt from this spec must share one gate so
            # their timed regions serialize; derive a stable path from
            # the measurement identity
            digest = hashlib.sha256(
                f"{self.op}/{'x'.join(map(str, dims))}"
                f"/{self.dtype}/s{self.seed}/{os.getpid()}".encode()
            ).hexdigest()[:16]
            lock = os.path.join(
                tempfile.gettempdir(), f"repro-xla-timing-{digest}.lock"
            )
        return (
            "repro.core.cost.measured:_xla_timed_from_spec",
            {
                "op": self.op, "dims": list(dims),
                "depths": list(self.space.depths),
                "space_kwargs": space_kwargs,
                "n_repeats": self.n_repeats,
                "dtype": self.dtype,
                "vmem_guard_bytes": self.vmem_guard_bytes,
                "seed": self.seed,
                "n_build_workers": self.n_build_workers,
                "cache_dir": self.cache.cache_dir,
                "cache_capacity": self.cache.capacity,
                "timing_lock_path": lock,
            },
        )


def _pallas_interpret_from_spec(
    op: str, dims: list, depths: list, space_kwargs: dict,
    n_repeats: int, seed: int,
    cache_dir: Optional[str] = None, cache_capacity: int = 128,
) -> "PallasInterpretCost":
    """Worker-process factory (see ``CostBackend.worker_spec``)."""
    from ..ops import get_op

    return PallasInterpretCost(
        get_op(op).make_space(tuple(dims), tuple(depths), **space_kwargs),
        n_repeats=n_repeats,
        seed=seed,
        cache_dir=cache_dir,
        cache_capacity=cache_capacity,
    )


class PallasInterpretCost(CostBackend):
    """Times the op's *actual Pallas kernel* in ``interpret=True`` mode,
    via the op registry's ``pallas_run`` binding (``repro.kernels.gemm``
    for GEMM, ``repro.kernels.flash_attention`` for flash).  Process-
    shippable like the other backends: ``worker_spec()`` ships the op
    name + dims, and the worker rebuilds space and operands from the
    registry.

    Each candidate program is AOT-compiled once and resolved through the
    same two-layer :class:`ExecutableCache` that backs
    :class:`XLATimedCost` — repeats time a pre-compiled executable (one
    uncounted warm run first), so trace/lower overhead never pollutes
    the measurement and a ``cache_dir`` lets interpret-mode lanes and
    later sessions replay prior compiles from disk.  Cache entries carry
    a ``"pallas_interpret"`` flavor so they can share a directory with
    XLATimedCost programs of the same schedule without collision."""

    name = "pallas_interpret_timed"
    _FLAVOR = "pallas_interpret"

    def __init__(
        self,
        space: SearchSpace,
        n_repeats: int = 1,
        seed: int = 0,
        cache_dir: Optional[str] = None,
        cache_capacity: int = 128,
    ):
        super().__init__(space, n_repeats)
        import jax

        from ..ops import get_op  # lazy: the registry imports cost modules

        self._jax = jax
        self.seed = seed
        self._opspec = get_op(self.op)
        if self._opspec.pallas_run is None:
            raise ValueError(f"op {self.op!r} has no Pallas kernel binding")
        self._args = self._opspec.timed_operands(space, "float32", seed)
        self.cache = ExecutableCache(capacity=cache_capacity, cache_dir=cache_dir)
        self._bad: set[str] = set()  # schedules the kernel refused at trace

    def _ensure(self, s: State):
        """Resolve the interpret-mode executable for ``s``: memory LRU,
        then the persistent disk layer, then a fresh AOT compile.  Fresh
        loads get one uncounted warm run before entering the memory
        layer.  Raises ValueError when the kernel refuses the
        schedule."""
        ckey = ExecutableCache.content_key(
            self.space, "float32", s, flavor=self._FLAVOR
        )
        fn = self.cache.get_mem(ckey)
        if fn is not None:
            return fn
        fn = self.cache.get_disk(ckey)
        if fn is None:
            t0 = time.perf_counter()
            traced = lambda *ops: self._opspec.pallas_run(
                self.space, s, ops, interpret=True
            )
            fn = self._jax.jit(traced).lower(*self._args).compile()
            self.cache.count_compile(time.perf_counter() - t0)
            self.cache.put_disk(ckey, fn)
        fn(*self._args).block_until_ready()  # warm: never timed
        self.cache.put_mem(ckey, fn)
        return fn

    def cost_once(self, s: State, repeat_idx: int) -> float:
        skey = s.key()
        if skey in self._bad:
            return math.inf
        try:
            fn = self._ensure(s)
        except ValueError:  # schedule the kernel refuses (bad blocks)
            self._bad.add(skey)
            return math.inf
        t0 = time.perf_counter()
        fn(*self._args).block_until_ready()
        dt = time.perf_counter() - t0
        self.cache.count_timed()
        return dt

    def measure_fingerprint(self) -> str:
        # "aot1": repeats time a pre-compiled executable (trace/lower
        # excluded) — values are incommensurable with pre-AOT journal
        # entries, so the fingerprint must not match them.  seed fixes
        # the operand contents.
        return (
            f"r{self.n_repeats}|aot1|seed{self.seed}"
            + self.space_fingerprint()
        )

    def compile_stats(self) -> Optional[dict]:
        return self.cache.stats()

    def worker_spec(self):
        space_kwargs = self.space.spec_kwargs()
        if space_kwargs is None:
            # constraint closures don't survive the spec round-trip
            return None
        return (
            "repro.core.cost.measured:_pallas_interpret_from_spec",
            {
                "op": self.op, "dims": list(self.space.dims),
                "depths": list(self.space.depths),
                "space_kwargs": space_kwargs,
                "n_repeats": self.n_repeats,
                "seed": self.seed,
                "cache_dir": self.cache.cache_dir,
                "cache_capacity": self.cache.capacity,
            },
        )

from .base import CostBackend, CountingCost, SleepingCost, backend_from_spec
from .analytical import AnalyticalTPUCost, TpuSpec
from .flash_analytical import FlashAnalyticalCost
from .measured import XLATimedCost, PallasInterpretCost

__all__ = [
    "CostBackend",
    "CountingCost",
    "SleepingCost",
    "backend_from_spec",
    "AnalyticalTPUCost",
    "FlashAnalyticalCost",
    "TpuSpec",
    "XLATimedCost",
    "PallasInterpretCost",
]

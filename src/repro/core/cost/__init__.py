from .base import CostBackend, CountingCost
from .analytical import AnalyticalTPUCost, TpuSpec
from .measured import XLATimedCost, PallasInterpretCost

__all__ = [
    "CostBackend",
    "CountingCost",
    "AnalyticalTPUCost",
    "TpuSpec",
    "XLATimedCost",
    "PallasInterpretCost",
]

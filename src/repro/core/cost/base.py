"""Cost-backend protocol — the paper's "run the configuration on target
hardware" abstraction (TVM measure).  A backend times one op's schedule
states (``backend.op``, derived from its space) and returns seconds per
kernel invocation;
``math.inf`` marks a configuration that fails to build/run (illegitimate
on the hardware), matching how TVM reports failed measurements.

Backends expose two entry points:

* ``cost(s)`` — one state, the historical serial path;
* ``batch_cost(states)`` — a *batch* of states for the measurement
  engine's parallel lanes.  The base implementation is a serial loop
  (always correct); concrete backends override it with something
  genuinely concurrent: :class:`AnalyticalTPUCost` vectorizes the model
  with numpy, :class:`XLATimedCost` compiles candidates on a thread
  pool, and :class:`CountingCost` advances its simulated clock by the
  per-wave *maximum* lane time so ``n_workers`` parallel lanes are
  modeled honestly.

Whatever the override, ``batch_cost(states)[i]`` must equal
``cost(states[i])`` for a fresh backend — batching changes time
accounting, never values.

For *process-backed* measurement lanes
(:class:`~repro.core.executor.ProcessExecutor`), a backend additionally
advertises a **worker spec** — a picklable ``("module:callable",
kwargs)`` recipe that worker processes use to rebuild an equivalent
backend on their side of the process boundary (the backend object
itself is never pickled; JAX arrays and compiled-function caches don't
survive a pickle round-trip).  ``worker_spec()`` returns ``None`` for
backends that cannot be shipped.
"""

from __future__ import annotations

import abc
import importlib
import math
import operator
import os
import time
from typing import Optional, Sequence

from ..space import SearchSpace, State

__all__ = ["CostBackend", "CountingCost", "SleepingCost", "backend_from_spec"]


def backend_from_spec(spec: tuple[str, dict]) -> "CostBackend":
    """Rebuild a backend from a :meth:`CostBackend.worker_spec` recipe —
    the worker-process side of the executor boundary."""
    entry, kwargs = spec
    mod_name, _, attr = entry.partition(":")
    fn = operator.attrgetter(attr)(importlib.import_module(mod_name))
    return fn(**kwargs)


class CostBackend(abc.ABC):
    """Measures ``cost(s; m, k, n, d_m, d_k, d_n)`` (paper Sec. 3.3)."""

    name: str = "base"

    def __init__(self, space: SearchSpace, n_repeats: int = 1):
        self.space = space
        # paper: "arithmetic mean for 10 repeated trials"
        self.n_repeats = n_repeats

    @property
    def op(self) -> str:
        """Which operator this backend times (journal/cache scoping)."""
        return getattr(self.space, "op", "gemm")

    @abc.abstractmethod
    def cost_once(self, s: State, repeat_idx: int) -> float:
        ...

    def cost(self, s: State) -> float:
        if not self.space.is_legitimate(s):
            return math.inf
        total = 0.0
        for r in range(self.n_repeats):
            c = self.cost_once(s, r)
            if not math.isfinite(c):
                return math.inf
            total += c
        return total / self.n_repeats

    def batch_cost(self, states: Sequence[State]) -> list[float]:
        """Measure a batch; value-equivalent to ``[cost(s) for s in states]``."""
        return [self.cost(s) for s in states]

    def measure_fingerprint(self) -> str:
        """Identifies the backend's *measurement settings* (not just its
        name), so persistent caches never serve a cost measured under
        different settings — e.g. a different noise model or repeat
        count — as if it were this backend's measurement."""
        return f"r{self.n_repeats}" + self.space_fingerprint()

    def space_fingerprint(self) -> str:
        """Fingerprint component for non-default space construction
        kwargs (``SearchSpace.spec_kwargs``) — e.g. flash's ``causal``
        flag changes every measured value, so journals must scope on it.
        Empty kwargs contribute nothing, keeping pre-registry GEMM
        fingerprints (and their journals) valid."""
        kw = getattr(self.space, "spec_kwargs", dict)() or {}
        if not kw:
            return ""
        return "|" + ",".join(f"{k}={v!r}" for k, v in sorted(kw.items()))

    def worker_spec(self) -> Optional[tuple[str, dict]]:
        """Picklable ``("module:callable", kwargs)`` recipe that rebuilds
        an equivalent backend inside a measurement worker process, or
        ``None`` when this backend cannot cross a process boundary (see
        :func:`backend_from_spec`).  The rebuilt backend must produce the
        same costs as this one."""
        return None

    def compile_stats(self) -> Optional[dict]:
        """Cumulative build-cache counters for backends that compile
        programs (``compiles``/``mem_hits``/``disk_hits``/``evictions``/
        ``compile_s``/``n_timed``), or ``None`` for backends with no
        build step.  The measurement engine folds per-wave deltas into
        :class:`~repro.core.measure.MeasureStats` — across a process
        boundary the worker ships the delta back with each job result."""
        return None


class CountingCost(CostBackend):
    """Wraps another backend, counting measurements and charging a
    simulated (or real) wall-clock per trial — used by the benchmark
    harness to reproduce the paper's cost-vs-time plots without real
    hardware time.

    ``n_workers`` models parallel measurement lanes: a batched call is
    split into waves of ``n_workers`` states and each wave advances the
    simulated clock by its *maximum* lane time, so the clock of a
    parallel harness agrees with what ``TuningContext`` charges.  Each
    lane's charge is capped at ``timeout_s`` (AutoTVM-style measurement
    timeout), matching ``TuningContext.measure_timeout_s`` — without the
    cap, a pathological config (e.g. the untiled s0) charges minutes of
    simulated time here while the context charges 4 s, and the two
    clocks diverge.
    """

    def __init__(
        self,
        inner: CostBackend,
        simulated_overhead_s: float = 0.35,
        timeout_s: float = 4.0,
        n_workers: int = 1,
    ):
        super().__init__(inner.space, n_repeats=1)
        self.inner = inner
        self.name = f"counting({inner.name})"
        self.n_measured = 0
        self.simulated_clock_s = 0.0
        self.wall_started = time.monotonic()
        # TVM-style per-trial overhead: codegen + upload + launch. The
        # paper's Fig 7b horizontal axis is dominated by this, not by the
        # GEMM itself.
        self.simulated_overhead_s = simulated_overhead_s
        self.timeout_s = timeout_s
        self.n_workers = max(1, n_workers)

    def cost_once(self, s: State, repeat_idx: int) -> float:  # pragma: no cover
        raise RuntimeError("CountingCost delegates via cost()")

    def _lane_s(self, c: float) -> float:
        t = self.simulated_overhead_s
        if math.isfinite(c):
            t += min(c * self.inner.n_repeats, self.timeout_s)
        return t

    def cost(self, s: State) -> float:
        c = self.inner.cost(s)
        self.n_measured += 1
        self.simulated_clock_s += self._lane_s(c)
        return c

    def batch_cost(self, states: Sequence[State]) -> list[float]:
        out: list[float] = []
        for i in range(0, len(states), self.n_workers):
            wave = states[i : i + self.n_workers]
            costs = self.inner.batch_cost(wave)
            self.n_measured += len(wave)
            self.simulated_clock_s += max(self._lane_s(c) for c in costs)
            out.extend(costs)
        return out

    def compile_stats(self) -> Optional[dict]:
        return self.inner.compile_stats()

    def fraction_explored(self) -> float:
        return self.n_measured / max(1, self.space.size())


def _sleeping_from_spec(
    inner: tuple[str, dict],
    delay_s: float,
    hang_s: float,
    raise_keys: list,
    exit_keys: list,
    hang_keys: list,
) -> "SleepingCost":
    return SleepingCost(
        backend_from_spec(inner),
        delay_s=delay_s,
        hang_s=hang_s,
        raise_keys=raise_keys,
        exit_keys=exit_keys,
        hang_keys=hang_keys,
    )


class SleepingCost(CostBackend):
    """Hardware-in-the-loop stand-in: returns the inner backend's costs
    but *occupies real wall-clock* — ``delay_s`` of sleep per measurement,
    the way a device occupies a measurement lane.  This is what the
    executor layer is exercised and benchmarked against in a container
    with no accelerator: real lanes (threads/processes) overlap the
    sleeps, the simulated lane cannot.

    Failure injection (for executor crash/timeout isolation tests):
    states whose ``key()`` is in ``raise_keys`` raise, ``exit_keys``
    hard-kill the measuring process via ``os._exit`` (only meaningful
    under a :class:`~repro.core.executor.ProcessExecutor` — in-process it
    kills the session, which is exactly the failure mode process lanes
    exist to contain), and ``hang_keys`` sleep ``hang_s`` to trip the
    per-lane timeout.
    """

    def __init__(
        self,
        inner: CostBackend,
        delay_s: float = 0.05,
        hang_s: float = 3600.0,
        raise_keys: Sequence[str] = (),
        exit_keys: Sequence[str] = (),
        hang_keys: Sequence[str] = (),
    ):
        super().__init__(inner.space, n_repeats=1)
        self.inner = inner
        self.name = f"sleeping({inner.name})"
        self.delay_s = delay_s
        self.hang_s = hang_s
        self.raise_keys = frozenset(raise_keys)
        self.exit_keys = frozenset(exit_keys)
        self.hang_keys = frozenset(hang_keys)

    def cost_once(self, s: State, repeat_idx: int) -> float:  # pragma: no cover
        raise RuntimeError("SleepingCost delegates via cost()")

    def cost(self, s: State) -> float:
        key = s.key()
        if key in self.exit_keys:
            os._exit(13)  # simulated segfault: no exception, no cleanup
        if key in self.raise_keys:
            raise RuntimeError(f"injected measurement failure for {key}")
        time.sleep(self.hang_s if key in self.hang_keys else self.delay_s)
        return self.inner.cost(s)

    def measure_fingerprint(self) -> str:
        # sleeping changes lane occupancy, never the measured value
        return self.inner.measure_fingerprint()

    def compile_stats(self) -> Optional[dict]:
        return self.inner.compile_stats()

    def worker_spec(self) -> Optional[tuple[str, dict]]:
        inner_spec = self.inner.worker_spec()
        if inner_spec is None:
            return None
        return (
            "repro.core.cost.base:_sleeping_from_spec",
            {
                "inner": inner_spec,
                "delay_s": self.delay_s,
                "hang_s": self.hang_s,
                "raise_keys": sorted(self.raise_keys),
                "exit_keys": sorted(self.exit_keys),
                "hang_keys": sorted(self.hang_keys),
            },
        )

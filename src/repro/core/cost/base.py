"""Cost-backend protocol — the paper's "run the configuration on target
hardware" abstraction (TVM measure).  Backends return seconds-per-GEMM;
``math.inf`` marks a configuration that fails to build/run (illegitimate
on the hardware), matching how TVM reports failed measurements.
"""

from __future__ import annotations

import abc
import math
import time
from typing import Sequence

from ..config_space import GemmConfigSpace, TilingState

__all__ = ["CostBackend", "CountingCost"]


class CostBackend(abc.ABC):
    """Measures ``cost(s; m, k, n, d_m, d_k, d_n)`` (paper Sec. 3.3)."""

    name: str = "base"

    def __init__(self, space: GemmConfigSpace, n_repeats: int = 1):
        self.space = space
        # paper: "arithmetic mean for 10 repeated trials"
        self.n_repeats = n_repeats

    @abc.abstractmethod
    def cost_once(self, s: TilingState, repeat_idx: int) -> float:
        ...

    def cost(self, s: TilingState) -> float:
        if not self.space.is_legitimate(s):
            return math.inf
        total = 0.0
        for r in range(self.n_repeats):
            c = self.cost_once(s, r)
            if not math.isfinite(c):
                return math.inf
            total += c
        return total / self.n_repeats

    def batch_cost(self, states: Sequence[TilingState]) -> list[float]:
        return [self.cost(s) for s in states]


class CountingCost(CostBackend):
    """Wraps another backend, counting measurements and charging a
    simulated (or real) wall-clock per trial — used by the benchmark
    harness to reproduce the paper's cost-vs-time plots without real
    hardware time."""

    def __init__(self, inner: CostBackend, simulated_overhead_s: float = 0.35):
        super().__init__(inner.space, n_repeats=1)
        self.inner = inner
        self.name = f"counting({inner.name})"
        self.n_measured = 0
        self.simulated_clock_s = 0.0
        self.wall_started = time.monotonic()
        # TVM-style per-trial overhead: codegen + upload + launch. The
        # paper's Fig 7b horizontal axis is dominated by this, not by the
        # GEMM itself.
        self.simulated_overhead_s = simulated_overhead_s

    def cost_once(self, s: TilingState, repeat_idx: int) -> float:  # pragma: no cover
        raise RuntimeError("CountingCost delegates via cost()")

    def cost(self, s: TilingState) -> float:
        c = self.inner.cost(s)
        self.n_measured += 1
        self.simulated_clock_s += self.simulated_overhead_s
        if math.isfinite(c):
            self.simulated_clock_s += c * self.inner.n_repeats
        return c

    def fraction_explored(self) -> float:
        return self.n_measured / max(1, self.space.size())

"""Analytical TPU-v5e cost model for the blocked flash-attention
schedule — the flash op's default oracle, mirroring
:class:`~repro.core.cost.analytical.AnalyticalTPUCost` for GEMM.

Model of one ``(block_q, block_kv)`` schedule of the Pallas kernel
(`repro.kernels.flash_attention`), per batch/kv-head slice:

  grid      = n_q_blocks parallel cells; each streams kv blocks through
              the online-softmax inner loop (causal cells stop at the
              diagonal, so coarser blocks waste masked work)
  VMEM use  = q block + resident K/V + f32 accumulator + logits tile
              -> inf ("fails to build") above the budget
  compute   = per-visit MXU calls (q@k^T and p@v), padded to
              sublane/lane/MXU granularity -> misaligned blocks waste
              systolic cycles; plus the VPU softmax (exp/max/sum) over
              the logits tile
  memory    = HBM traffic: Q read once, K/V read once (the kernel keeps
              them resident across q cells), O written once
  overhead  = per-grid-cell dispatch + per-kv-visit slice/issue cost

  cost      = max(compute, memory) + overheads   [+ lognormal noise]

The causal visit count is exact (the kernel's ``last`` bound), so the
model rewards fine kv blocks near the diagonal and punishes the
per-visit overhead of making them *too* fine — a real optimum interior
to the space.
"""

from __future__ import annotations

import math
import zlib

import numpy as np

from ..analysis import ScheduleAnalyzer
from ..flash_space import FlashAttnConfigSpace, FlashScheduleState
from .analytical import TpuSpec, _pad
from .base import CostBackend

__all__ = ["FlashAnalyticalCost"]


def _flash_analytical_from_spec(
    seq_q: int, seq_kv: int, head_dim: int, d_q: int, d_kv: int,
    causal: bool, n_repeats: int, in_bytes: int, out_bytes: int,
    noise_sigma: float, seed: int,
) -> "FlashAnalyticalCost":
    """Worker-process factory (see ``CostBackend.worker_spec``)."""
    return FlashAnalyticalCost(
        FlashAttnConfigSpace(seq_q, seq_kv, head_dim, d_q, d_kv, causal=causal),
        n_repeats=n_repeats,
        in_bytes=in_bytes,
        out_bytes=out_bytes,
        noise_sigma=noise_sigma,
        seed=seed,
    )


class FlashAnalyticalCost(CostBackend):
    name = "analytical_tpu_v5e"

    def __init__(
        self,
        space: FlashAttnConfigSpace,
        n_repeats: int = 1,
        in_bytes: int = 2,  # bf16 activations
        out_bytes: int = 2,
        noise_sigma: float = 0.0,
        seed: int = 0,
        spec: TpuSpec | None = None,
    ):
        super().__init__(space, n_repeats)
        self.in_bytes = in_bytes
        self.out_bytes = out_bytes
        self.noise_sigma = noise_sigma
        self.seed = seed
        self.spec = spec or TpuSpec()
        # the shared static analyzer owns the feasibility cliff, so this
        # oracle and the engine's pre-filter can never disagree
        self.analyzer = ScheduleAnalyzer(
            self.space, spec=self.spec, in_bytes=self.in_bytes
        )
        # visits depend only on the block schedule; compute_time and
        # overhead_time both ask per repeat, so memoize per (bq, bkv)
        self._visits_cache: dict[tuple[int, int], int] = {}

    # -- components -----------------------------------------------------------
    def vmem_bytes(self, s: FlashScheduleState) -> int:
        return self.analyzer.vmem_bytes(s)

    def kv_visits(self, s: FlashScheduleState) -> int:
        """Total kv-block visits across the q grid — exact, matching the
        kernel's causal early-exit bound ``last``."""
        bq, bkv = s.block_q, s.block_kv
        n_q, n_kv = s.n_q_blocks, s.n_kv_blocks
        if not self.space.causal:
            return n_q * n_kv
        cached = self._visits_cache.get((bq, bkv))
        if cached is None:
            ends = (np.arange(1, n_q + 1, dtype=np.int64) * bq + bkv - 1) // bkv
            cached = int(np.minimum(ends, n_kv).sum())
            self._visits_cache[(bq, bkv)] = cached
        return cached

    def compute_time(self, s: FlashScheduleState) -> float:
        sp = self.spec
        bq, bkv = s.block_q, s.block_kv
        hd = self.space.head_dim
        sub_gran = sp.sublane.get(self.in_bytes, 8)
        visits = self.kv_visits(s)
        # two MXU calls per visit: logits = q @ k^T, out += p @ v
        call_flops = 2.0 * _pad(bq, sub_gran) * (
            _pad(hd, sp.mxu_k) * _pad(bkv, sp.lane)  # q @ k^T
            + _pad(bkv, sp.mxu_k) * _pad(hd, sp.lane)  # p @ v
        )
        mxu = visits * call_flops / sp.peak_flops
        # online softmax on the VPU: ~8 elementwise ops per logit
        vpu = visits * 8.0 * _pad(bq, sub_gran) * _pad(bkv, sp.lane) / sp.vpu_flops
        return mxu + vpu + visits * 2 * sp.mxu_call_overhead_s

    def memory_time(self, s: FlashScheduleState) -> float:
        sp = self.spec
        sq, skv, hd = self.space.seq_q, self.space.seq_kv, self.space.head_dim
        traffic = (
            sq * hd * self.in_bytes  # Q read once
            + 2 * skv * hd * self.in_bytes  # K and V, resident across cells
            + sq * hd * self.out_bytes  # O written once
        )
        return traffic / sp.hbm_bw

    def overhead_time(self, s: FlashScheduleState) -> float:
        sp = self.spec
        # grid dispatch per q cell + dynamic-slice issue per kv visit
        return (
            s.n_q_blocks * sp.grid_step_overhead_s
            + self.kv_visits(s) * 0.5 * sp.grid_step_overhead_s
        )

    def breakdown(self, s: FlashScheduleState) -> dict:
        return {
            "vmem_bytes": self.vmem_bytes(s),
            "kv_visits": self.kv_visits(s),
            "compute_s": self.compute_time(s),
            "memory_s": self.memory_time(s),
            "overhead_s": self.overhead_time(s),
        }

    # -- CostBackend ------------------------------------------------------------
    def measure_fingerprint(self) -> str:
        return (
            f"r{self.n_repeats}|noise{self.noise_sigma:g}|seed{self.seed}"
            f"|io{self.in_bytes}.{self.out_bytes}"
            + self.space_fingerprint()
        )

    def worker_spec(self):
        # constraint closures and subclassed chip specs don't survive the
        # spec round-trip; refuse to ship rather than rebuild a subtly
        # different model (same policy as AnalyticalTPUCost)
        if self.space.extra_constraint is not None or type(self.spec) is not TpuSpec:
            return None
        sp = self.space
        return (
            "repro.core.cost.flash_analytical:_flash_analytical_from_spec",
            {
                "seq_q": sp.seq_q, "seq_kv": sp.seq_kv, "head_dim": sp.head_dim,
                "d_q": sp.d_q, "d_kv": sp.d_kv, "causal": sp.causal,
                "n_repeats": self.n_repeats,
                "in_bytes": self.in_bytes, "out_bytes": self.out_bytes,
                "noise_sigma": self.noise_sigma, "seed": self.seed,
            },
        )

    def _noise_factor(self, s: FlashScheduleState, repeat_idx: int) -> float:
        # deterministic per-(state, repeat) jitter, stable across processes
        h = zlib.crc32(f"{self.seed}|{s.key()}|{repeat_idx}".encode()) & 0xFFFFFFFF
        rng = np.random.default_rng(h)
        return rng.lognormal(0.0, self.noise_sigma)

    def cost_once(self, s: FlashScheduleState, repeat_idx: int) -> float:
        if self.analyzer.exceeds_vmem(s):
            return math.inf  # does not fit VMEM: measurement failure
        base = max(self.compute_time(s), self.memory_time(s)) + self.overhead_time(s)
        if self.noise_sigma <= 0.0:
            return base
        return float(base * self._noise_factor(s, repeat_idx))

    def optimum(self, max_states: int = 2_000_000) -> tuple[FlashScheduleState, float]:
        """Brute-force the space (only for small spaces / tests)."""
        if self.space.size() > max_states:
            raise ValueError("space too large to brute force")
        best_s, best_c = None, math.inf
        for s in self.space.enumerate():
            c = self.cost(s)
            if c < best_c:
                best_s, best_c = s, c
        assert best_s is not None
        return best_s, best_c

"""Analytical TPU-v5e cost model for tiled GEMM.

This is the default cost oracle in this container (which has no TPU and
is CPU-only): a physically-grounded roofline model of one chip executing
the Pallas kernel produced by a :class:`TilingState`.  It plays the role
the Titan Xp played in the paper — the thing the tuners query — while
being deterministic (optionally noisy) and cheap, which also lets tests
brute-force small spaces and check the tuners actually find the optimum.

Model (see DESIGN.md §2 for the state->kernel mapping):

  grid      = (m0, k0, n0) macro-steps, k innermost (C accumulates in VMEM)
  VMEM use  = 2*(bm*bk + bk*bn)*in_bytes (double-buffered) + bm*bn*4 (acc)
              -> inf ("fails to build") above the budget, like a TVM
              measurement failure
  compute   = #MXU calls * padded-call-flops / peak;  each call is
              (sub_m x bk) @ (bk x sub_n), padded to sublane/lane/MXU
              granularity -> misaligned tiles waste systolic cycles
  memory    = HBM traffic with k-innermost reuse:
              A read n0 times, B read m0 times, C written once
  overhead  = per-grid-step DMA/dispatch cost + per-MXU-call issue cost

  cost      = max(compute, memory) + overheads   [+ lognormal noise]
"""

from __future__ import annotations

import math

import numpy as np

from ..analysis import ScheduleAnalyzer, gemm_working_set_bytes
from ..config_space import GemmConfigSpace, TilingState
from .base import CostBackend

__all__ = ["TpuSpec", "AnalyticalTPUCost"]


def _analytical_from_spec(
    m: int, k: int, n: int, d_m: int, d_k: int, d_n: int,
    n_repeats: int, in_bytes: int, out_bytes: int,
    noise_sigma: float, seed: int,
) -> "AnalyticalTPUCost":
    """Worker-process factory (see ``CostBackend.worker_spec``)."""
    return AnalyticalTPUCost(
        GemmConfigSpace(m, k, n, d_m, d_k, d_n),
        n_repeats=n_repeats,
        in_bytes=in_bytes,
        out_bytes=out_bytes,
        noise_sigma=noise_sigma,
        seed=seed,
    )


class TpuSpec:
    """TPU v5e-like single-chip constants (shared with §Roofline)."""

    peak_flops = 197e12  # bf16 FLOP/s
    vpu_flops = 19.7e12  # elementwise f32 throughput (softmax path)
    hbm_bw = 819e9  # B/s
    ici_bw = 50e9  # B/s per link (used by the distributed roofline)
    vmem_bytes = 16 * 1024 * 1024  # usable VMEM budget for one kernel
    sublane = {2: 16, 4: 8}  # dtype bytes -> sublane granularity
    lane = 128
    mxu_k = 128  # contraction granularity fed to the systolic array
    grid_step_overhead_s = 2.0e-7  # DMA issue + grid bookkeeping per step
    mxu_call_overhead_s = 5.0e-9  # per dot issue (pipelined, small)


def _pad(x: int, g: int) -> int:
    return ((x + g - 1) // g) * g


class AnalyticalTPUCost(CostBackend):
    name = "analytical_tpu_v5e"

    def __init__(
        self,
        space: GemmConfigSpace,
        n_repeats: int = 1,
        in_bytes: int = 2,  # bf16 inputs
        out_bytes: int = 2,
        noise_sigma: float = 0.0,
        seed: int = 0,
        spec: TpuSpec | None = None,
    ):
        super().__init__(space, n_repeats)
        self.in_bytes = in_bytes
        self.out_bytes = out_bytes
        self.noise_sigma = noise_sigma
        self.seed = seed
        self.spec = spec or TpuSpec()
        # the shared static analyzer owns the feasibility cliff, so this
        # oracle and the engine's pre-filter can never disagree
        self.analyzer = ScheduleAnalyzer(
            self.space, spec=self.spec, in_bytes=self.in_bytes
        )

    # -- components -----------------------------------------------------------
    def vmem_bytes(self, s: TilingState) -> int:
        return self.analyzer.vmem_bytes(s)

    def compute_time(self, s: TilingState) -> float:
        sp = self.spec
        gm, gk, gn = s.grid
        bm, bk, bn = s.block_m, s.block_k, s.block_n
        sub_m, sub_n = s.sub_m, s.sub_n
        sub_gran = sp.sublane.get(self.in_bytes, 8)
        n_calls = gm * gk * gn * (bm // sub_m) * (bn // sub_n)
        call_flops = (
            2.0
            * _pad(sub_m, sub_gran)
            * _pad(bk, sp.mxu_k)
            * _pad(sub_n, sp.lane)
        )
        return n_calls * call_flops / sp.peak_flops + n_calls * sp.mxu_call_overhead_s

    def memory_time(self, s: TilingState) -> float:
        sp = self.spec
        gm, gk, gn = s.grid
        M, K, N = self.space.m, self.space.k, self.space.n
        a_traffic = M * K * gn * self.in_bytes  # A streamed once per n0 slice
        b_traffic = K * N * gm * self.in_bytes  # B streamed once per m0 slice
        c_traffic = M * N * self.out_bytes  # k-innermost: C written once
        return (a_traffic + b_traffic + c_traffic) / sp.hbm_bw

    def overhead_time(self, s: TilingState) -> float:
        gm, gk, gn = s.grid
        return gm * gk * gn * self.spec.grid_step_overhead_s

    def breakdown(self, s: TilingState) -> dict:
        return {
            "vmem_bytes": self.vmem_bytes(s),
            "compute_s": self.compute_time(s),
            "memory_s": self.memory_time(s),
            "overhead_s": self.overhead_time(s),
        }

    # -- CostBackend ------------------------------------------------------------
    def measure_fingerprint(self) -> str:
        return (
            f"r{self.n_repeats}|noise{self.noise_sigma:g}|seed{self.seed}"
            f"|io{self.in_bytes}.{self.out_bytes}"
            + self.space_fingerprint()
        )

    def worker_spec(self):
        # extra_constraint is an arbitrary closure and self.spec could be
        # subclassed — neither survives the spec round-trip, so refuse to
        # ship rather than rebuild a subtly different model
        if self.space.extra_constraint is not None or type(self.spec) is not TpuSpec:
            return None
        sp = self.space
        return (
            "repro.core.cost.analytical:_analytical_from_spec",
            {
                "m": sp.m, "k": sp.k, "n": sp.n,
                "d_m": sp.d_m, "d_k": sp.d_k, "d_n": sp.d_n,
                "n_repeats": self.n_repeats,
                "in_bytes": self.in_bytes, "out_bytes": self.out_bytes,
                "noise_sigma": self.noise_sigma, "seed": self.seed,
            },
        )

    def _noise_factor(self, s: TilingState, repeat_idx: int) -> float:
        # Deterministic per-(state, repeat) measurement jitter.  Stable
        # across processes (python's hash() is salted per process).
        import zlib

        h = zlib.crc32(f"{self.seed}|{s.key()}|{repeat_idx}".encode()) & 0xFFFFFFFF
        rng = np.random.default_rng(h)
        return rng.lognormal(0.0, self.noise_sigma)

    def cost_once(self, s: TilingState, repeat_idx: int) -> float:
        if self.analyzer.exceeds_vmem(s):
            return math.inf  # kernel does not fit VMEM: measurement failure
        base = max(self.compute_time(s), self.memory_time(s)) + self.overhead_time(s)
        if self.noise_sigma <= 0.0:
            return base
        return float(base * self._noise_factor(s, repeat_idx))

    def _base_batch(self, states: list[TilingState]) -> np.ndarray:
        """Vectorized noise-free model: one numpy pass over the batch.

        Intermediate tile counts/FLOPs are accumulated as exact Python
        ints (they can exceed 2**53) and only converted to float64 for
        the final divisions, which keeps every element bit-identical to
        the scalar ``cost_once`` path.
        """
        sp = self.spec
        sub_gran = sp.sublane.get(self.in_bytes, 8)
        M, K, N = self.space.m, self.space.k, self.space.n
        vmem, n_calls, flops, steps, traffic = [], [], [], [], []
        for s in states:
            gm, gk, gn = s.grid
            bm, bk, bn = s.block_m, s.block_k, s.block_n
            vmem.append(gemm_working_set_bytes(bm, bk, bn, self.in_bytes))
            nc = gm * gk * gn * (bm // s.sub_m) * (bn // s.sub_n)
            cf = (
                2
                * _pad(s.sub_m, sub_gran)
                * _pad(bk, sp.mxu_k)
                * _pad(s.sub_n, sp.lane)
            )
            n_calls.append(nc)
            flops.append(nc * cf)
            steps.append(gm * gk * gn)
            traffic.append(
                M * K * gn * self.in_bytes
                + K * N * gm * self.in_bytes
                + M * N * self.out_bytes
            )
        compute = (
            np.asarray(flops, np.float64) / sp.peak_flops
            + np.asarray(n_calls, np.float64) * sp.mxu_call_overhead_s
        )
        memory = np.asarray(traffic, np.float64) / sp.hbm_bw
        base = np.maximum(compute, memory) + np.asarray(steps, np.float64) * sp.grid_step_overhead_s
        base[np.asarray(vmem) > sp.vmem_bytes] = math.inf
        return base

    def batch_cost(self, states) -> list[float]:
        """Vectorized batch measurement; value-identical to ``cost`` per
        state (the measurement engine's parallel-lane fast path)."""
        states = list(states)
        base = self._base_batch(states)
        out: list[float] = []
        for i, s in enumerate(states):
            b = float(base[i])
            if not self.space.is_legitimate(s) or math.isinf(b):
                out.append(math.inf)
                continue
            if self.noise_sigma <= 0.0 and self.n_repeats == 1:
                out.append(b)
                continue
            total = 0.0  # replicate cost()'s repeat-mean summation order
            for r in range(self.n_repeats):
                total += (
                    b
                    if self.noise_sigma <= 0.0
                    else float(b * self._noise_factor(s, r))
                )
            out.append(total / self.n_repeats)
        return out

    def optimum(self, max_states: int = 2_000_000) -> tuple[TilingState, float]:
        """Brute-force the space (only for small spaces / tests)."""
        if self.space.size() > max_states:
            raise ValueError("space too large to brute force")
        best_s, best_c = None, math.inf
        for s in self.space.enumerate():
            c = self.cost(s)
            if c < best_c:
                best_s, best_c = s, c
        assert best_s is not None
        return best_s, best_c

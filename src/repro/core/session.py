"""Tuning sessions: run one-or-many tuners over one-or-many GEMM
workloads, persist best configs, and emit comparison tables.

``TuningSession`` is what `launch/tune.py` and the benchmark harness
drive; it is also the integration point for per-architecture tuning
(``workloads_for_arch`` extracts every distinct GEMM an ArchConfig
executes and tunes each)."""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

from .config_space import GemmConfigSpace
from .cost import AnalyticalTPUCost, CostBackend
from .records import TuningRecords, workload_key
from .tuners import TUNERS, Budget, TuneResult

__all__ = ["GemmWorkload", "TuningSession"]


@dataclasses.dataclass(frozen=True)
class GemmWorkload:
    m: int
    k: int
    n: int
    dtype: str = "bfloat16"
    d_m: int = 4
    d_k: int = 2
    d_n: int = 4
    label: str = ""

    def space(self) -> GemmConfigSpace:
        return GemmConfigSpace(self.m, self.k, self.n, self.d_m, self.d_k, self.d_n)

    def key(self, backend: str) -> str:
        return workload_key(self.m, self.k, self.n, self.dtype, backend)


class TuningSession:
    def __init__(
        self,
        records: Optional[TuningRecords] = None,
        cost_factory: Optional[Callable[[GemmConfigSpace], CostBackend]] = None,
        seed: int = 0,
        verbose: bool = True,
    ):
        # NOTE: TuningRecords defines __len__, so an EMPTY store is falsy —
        # `records or TuningRecords()` would silently drop it
        self.records = records if records is not None else TuningRecords()
        self.cost_factory = cost_factory or (
            lambda space: AnalyticalTPUCost(space, n_repeats=1)
        )
        self.seed = seed
        self.verbose = verbose

    def tune_workload(
        self,
        wl: GemmWorkload,
        tuner_name: str = "g-bfs",
        budget: Optional[Budget] = None,
        tuner_kwargs: Optional[dict] = None,
        seed: Optional[int] = None,
    ) -> TuneResult:
        space = wl.space()
        cost = self.cost_factory(space)
        budget = budget or Budget(max_fraction=0.001)
        tuner_cls = TUNERS[tuner_name]
        tuner = tuner_cls(space, cost, seed=self.seed if seed is None else seed,
                          **(tuner_kwargs or {}))
        result = tuner.tune(budget)
        if result.best_state is not None and math.isfinite(result.best_cost):
            self.records.update(
                wl.key(cost.name),
                result.best_state,
                result.best_cost,
                tuner_name,
                result.n_trials,
                extra={"label": wl.label},
            )
        if self.verbose:
            print(
                f"[tune] {wl.label or wl.key(cost.name)} {tuner_name}: "
                f"best={result.best_cost:.3e}s trials={result.n_trials} "
                f"frac={result.fraction:.5f} wall={result.wall_s:.1f}s"
            )
        return result

    def compare(
        self,
        wl: GemmWorkload,
        tuner_names: Sequence[str],
        budget: Budget,
        n_seeds: int = 1,
        tuner_kwargs: Optional[dict[str, dict]] = None,
    ) -> dict[str, list[TuneResult]]:
        """Paper-style head-to-head under an identical budget."""
        out: dict[str, list[TuneResult]] = {}
        for name in tuner_names:
            out[name] = []
            for s in range(n_seeds):
                kw = (tuner_kwargs or {}).get(name, {})
                out[name].append(
                    self.tune_workload(wl, name, budget, tuner_kwargs=kw, seed=self.seed + s)
                )
        return out

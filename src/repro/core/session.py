"""Tuning sessions: run one-or-many tuners over one-or-many operator
workloads through the batched measurement engine, persist the results,
and emit comparison tables.

``TuningSession`` is what `launch/tune.py` and the benchmark harness
drive.  It is operator-agnostic: a :class:`Workload` names an op from
the registry (``repro.core.ops``) plus its dimension sizes, and the
session resolves the op's search space and analytical oracle through
that registry — GEMM is just the default op (and ``GemmWorkload``
remains as the back-compat constructor).

The session owns the two persistence layers — the keep-best
:class:`TuningRecords` table that `kernels/ops.py` consults at trace
time, and the append-only :class:`TrialJournal` that the
:class:`~repro.core.measure.MeasureEngine` serves repeat measurements
from across sessions — and wires both into every search it launches:

* :meth:`tune_workload` builds a per-workload engine (``n_workers``
  measurement lanes + shared journal) and can **warm-start** the search
  from the best record of this workload, or — via the space's
  ``transplant`` — from the *nearest previously-tuned shape of the same
  op* in log-shape space;
* :meth:`tune_arch` fans every distinct workload an ArchConfig executes
  through one shared engine budget: duplicate shapes are tuned once,
  the trial/time budget is a single pool split over the remaining
  workloads, and engine statistics (dispatches, cache hits) aggregate
  across the whole arch so speedups are attributable;
* :meth:`compare` runs the paper-style head-to-head under an identical
  budget.
"""

from __future__ import annotations

import dataclasses
import inspect
import math
from typing import Callable, Optional, Sequence

from .cost import CostBackend
from .executor import LaneExecutor, make_executor
from .fault import RetryPolicy
from .learn import ProposalFilter
from .measure import MeasureEngine, MeasureStats
from .records import (
    TrialJournal,
    TuningRecords,
    donor_distance,
    parse_workload_key_generic,
    workload_key_for,
)
from .shard import (
    ShardSpec,
    await_markers,
    elect_best,
    shard_dir_for,
    write_done_marker,
)
from .snapshot import TuneCheckpointer, TuneInterrupted
from .space import SearchSpace, State
from .tuners import TUNERS, Budget, Trial, TuneResult
from .tuners.base import decode_cost, encode_cost

__all__ = ["Workload", "GemmWorkload", "TuningSession", "ArchTuneReport"]


@dataclasses.dataclass(frozen=True)
class Workload:
    """One tunable operator instance: op name + dimension sizes (plus
    nesting depths, defaulted from the op registry)."""

    op: str
    dims: tuple[int, ...]
    dtype: str = "bfloat16"
    depths: tuple[int, ...] = ()
    label: str = ""

    def __post_init__(self):
        object.__setattr__(self, "dims", tuple(int(d) for d in self.dims))
        if self.depths:
            object.__setattr__(
                self, "depths", tuple(int(d) for d in self.depths)
            )
        else:
            from .ops import get_op  # lazy: ops imports cost modules

            object.__setattr__(self, "depths", get_op(self.op).default_depths)

    # -- GEMM-era accessors (kept so shape-listing code reads naturally) -----
    @property
    def m(self) -> int:
        return self.dims[0]

    @property
    def k(self) -> int:
        return self.dims[1]

    @property
    def n(self) -> int:
        return self.dims[2]

    @property
    def d_m(self) -> int:
        return self.depths[0]

    @property
    def d_k(self) -> int:
        return self.depths[1]

    @property
    def d_n(self) -> int:
        return self.depths[2]

    def space(self) -> SearchSpace:
        from .ops import get_op

        return get_op(self.op).make_space(self.dims, self.depths)

    def key(self, backend: str) -> str:
        return workload_key_for(self.op, self.dims, self.dtype, backend)


def GemmWorkload(
    m: int,
    k: int,
    n: int,
    dtype: str = "bfloat16",
    d_m: int = 4,
    d_k: int = 2,
    d_n: int = 4,
    label: str = "",
) -> Workload:
    """Back-compat constructor for the pre-registry GEMM workload type;
    returns the generic :class:`Workload` with ``op="gemm"``."""
    return Workload(
        op="gemm", dims=(m, k, n), dtype=dtype, depths=(d_m, d_k, d_n),
        label=label,
    )


@dataclasses.dataclass
class ArchTuneReport:
    """What ``tune_arch`` hands back: per-label results + engine totals."""

    results: dict[str, TuneResult]
    stats: MeasureStats
    n_workers: int
    n_unique_shapes: int
    executor: str = "sim"  # lane executor the arch's engines measured through

    @property
    def total_trials(self) -> int:
        return sum(r.n_trials for r in self.distinct_results())

    @property
    def total_clock_s(self) -> float:
        return sum(r.clock_s for r in self.distinct_results())

    def distinct_results(self) -> list[TuneResult]:
        seen: set[int] = set()
        out = []
        for r in self.results.values():
            if id(r) not in seen:
                seen.add(id(r))
                out.append(r)
        return out


#: Snapshot step reserved for the "workload finished" marker — larger
#: than any round index, so it always survives the checkpointer's GC and
#: ``latest_step`` finds it first on resume.
_DONE_STEP = 99_999_999


def _result_to_jsonable(result: TuneResult) -> dict:
    return {
        "tuner": result.tuner,
        "best": None if result.best_state is None else result.best_state.as_lists(),
        "best_cost": encode_cost(result.best_cost),
        "trials": [
            [t.state.as_lists(), encode_cost(t.cost), t.clock_s]
            for t in result.trials
        ],
        "fraction": result.fraction,
        "wall_s": result.wall_s,
        "clock_s": result.clock_s,
        "n_workers": result.n_workers,
        "n_cache_hits": result.n_cache_hits,
        "executor": result.executor,
    }


def _result_from_jsonable(data: dict, space: SearchSpace) -> TuneResult:
    trials = [
        Trial(space.state_from_lists(lists), decode_cost(c), i, float(tc))
        for i, (lists, c, tc) in enumerate(data["trials"])
    ]
    return TuneResult(
        tuner=data["tuner"],
        best_state=(
            None if data["best"] is None else space.state_from_lists(data["best"])
        ),
        best_cost=decode_cost(data["best_cost"]),
        trials=trials,
        n_trials=len(trials),
        fraction=data["fraction"],
        wall_s=data["wall_s"],
        clock_s=data["clock_s"],
        n_workers=data["n_workers"],
        n_cache_hits=data["n_cache_hits"],
        executor=data["executor"],
    )


def _default_cost_factory(space: SearchSpace) -> CostBackend:
    """The op's analytical oracle, resolved through the registry."""
    from .ops import get_op

    return get_op(space.op).analytical_cost(space, n_repeats=1)


class TuningSession:
    def __init__(
        self,
        records: Optional[TuningRecords] = None,
        cost_factory: Optional[Callable[[SearchSpace], CostBackend]] = None,
        seed: int = 0,
        verbose: bool = True,
        journal: Optional[TrialJournal] = None,
    ):
        # NOTE: TuningRecords defines __len__, so an EMPTY store is falsy —
        # `records or TuningRecords()` would silently drop it
        self.records = records if records is not None else TuningRecords()
        self.cost_factory = cost_factory or _default_cost_factory
        self.seed = seed
        self.verbose = verbose
        # persistent measurement cache; None disables cross-session serving
        self.journal = journal

    # -- warm start ----------------------------------------------------------
    def warm_start_state(
        self,
        wl: Workload,
        space: SearchSpace,
        backend_name: str,
        fingerprint: Optional[str] = None,
    ) -> Optional[State]:
        """Initial state for a warm-started search: this workload's own
        best record if one exists, else the best state of the nearest
        previously-tuned shape of the *same op* transplanted into this
        space.  Donor scans are scoped to the workload's op and dtype —
        a bf16-tuned best must never seed an int8 search (the tile
        economics differ), and a flash schedule must never seed a GEMM.
        ``fingerprint`` scopes the journal search to entries measured
        under the same backend settings (see ``measure_fingerprint``)."""
        wkey = wl.key(backend_name)
        s = self.records.lookup_state(wkey)
        if s is not None and space.is_legitimate(s):
            return s
        # trailing non-factored dims (e.g. flash's head_dim) are workload
        # identity: a donor tuned for a different value has different
        # VMEM/MXU economics and must never seed this search
        n_fixed = space.n_fixed_dims
        donors: list[tuple[float, str, State]] = []
        for key in self.records.keys():
            parsed = parse_workload_key_generic(key)
            if parsed is None or key == wkey:
                continue
            d = donor_distance(parsed, wl.op, wl.dims, dtype=wl.dtype,
                               backend=backend_name, fixed_tail=n_fixed)
            if d is None:
                continue
            src = self.records.lookup_state(key)
            if src is None:
                continue
            donors.append((d, key, src))
        if self.journal is not None:
            jbackend = (
                backend_name if fingerprint is None else f"{backend_name}?{fingerprint}"
            )
            near = self.journal.nearest(
                wl.op, wl.dims, dtype=wl.dtype, backend=jbackend,
                exclude=wkey if fingerprint is None else f"{wkey}?{fingerprint}",
                fixed_tail=n_fixed,
            )
            if near is not None:
                best = self.journal.best_state(near)
                parsed = parse_workload_key_generic(near)
                if best is not None and parsed is not None:
                    d = donor_distance(parsed, wl.op, wl.dims,
                                       fixed_tail=n_fixed)
                    if d is not None:
                        donors.append((d, near, best[0]))
        for d, _key, src in sorted(donors, key=lambda t: (t[0], t[1])):
            s = space.transplant(src)
            if s is not None:
                return s
        return None

    # -- single workload -----------------------------------------------------
    def tune_workload(
        self,
        wl: Workload,
        tuner_name: str = "g-bfs",
        budget: Optional[Budget] = None,
        tuner_kwargs: Optional[dict] = None,
        seed: Optional[int] = None,
        n_workers: int = 1,
        warm_start: bool = False,
        engine: Optional[MeasureEngine] = None,
        stats: Optional[MeasureStats] = None,
        executor: Optional[LaneExecutor] = None,
        reload_every: int = 0,
        analyze: str = "off",
        retry: Optional[RetryPolicy] = None,
        checkpointer: Optional[TuneCheckpointer] = None,
        resume: bool = False,
        learned_filter: str = "off",
        filter_keep: float = 0.5,
        filter_retrain_every: int = 8,
        filter_min_rows: int = 32,
        shard: Optional[ShardSpec] = None,
        shard_wait_s: float = 60.0,
    ) -> TuneResult:
        if learned_filter not in ("off", "on"):
            raise ValueError(
                f"learned_filter must be 'off' or 'on', got {learned_filter!r}"
            )
        if shard is not None and not shard.enabled:
            shard = None  # 0/1 is the unsharded engine, bit-identical
        if shard is not None and self.journal is None:
            raise ValueError(
                "sharded tuning (shard I/N with N > 1) needs a shared journal "
                "— siblings exchange measurements and done markers through it"
            )
        space = wl.space()
        cost = self.cost_factory(space)
        wkey = wl.key(cost.name)
        if engine is not None and executor is not None and engine.executor is not executor:
            # same convention as TuningContext: the engine owns the
            # measurement model — reject conflicts, don't silently drop
            raise ValueError(
                "executor=... conflicts with the provided engine's executor"
            )
        if engine is not None and analyze != "off" and engine.analyze != analyze:
            raise ValueError(
                "analyze=... conflicts with the provided engine's analyze mode"
            )
        if engine is not None and retry is not None and retry.enabled and engine.retry != retry:
            raise ValueError(
                "retry=... conflicts with the provided engine's retry policy"
            )
        if engine is not None and learned_filter == "on" and engine.learned_filter is None:
            raise ValueError(
                "learned_filter='on' conflicts with the provided engine "
                "(it has no ProposalFilter)"
            )
        if engine is not None and shard is not None and engine.shard != shard:
            raise ValueError(
                f"shard={shard} conflicts with the provided engine's "
                f"{engine.shard}"
            )
        # each shard owns its own search state: a shard-suffixed snapshot
        # identity keeps two hosts resuming one workload from colliding
        tuner_id = (
            tuner_name if shard is None
            else f"{tuner_name}@shard{shard.index}of{shard.count}"
        )
        # -- crash-safe resume: serve finished workloads from their done
        # snapshot, restore interrupted ones mid-search -----------------------
        restore = None
        if checkpointer is not None and resume:
            payload = checkpointer.load(wkey, tuner_id)
            if payload is not None and payload.get("done"):
                result = _result_from_jsonable(payload["result"], space)
                if self.verbose:
                    print(
                        f"[tune] {wl.label or wkey} {tuner_name}: "
                        f"already complete (resumed from done snapshot, "
                        f"best={result.best_cost:.3e}s trials={result.n_trials})"
                    )
                return result
            restore = payload
        elif checkpointer is not None:
            # fresh run: stale snapshots (incl. a previous done marker)
            # must not shadow this run for a later --resume
            checkpointer.clear(wkey, tuner_id)
        if engine is None:
            flt = None
            if learned_filter == "on":
                # per-workload filter: the model's scope is this space's
                # op/feature-width + the backend's dtype/fingerprint, and
                # its cache lives next to the session journal
                flt = ProposalFilter(
                    space,
                    self.journal,
                    dtype=wl.dtype,
                    fingerprint=cost.measure_fingerprint(),
                    keep=filter_keep,
                    retrain_every=filter_retrain_every,
                    min_rows=filter_min_rows,
                )
            engine = MeasureEngine(
                cost,
                n_workers=n_workers,
                journal=self.journal,
                workload_key=wkey,
                stats=stats,
                executor=executor,
                reload_every=reload_every,
                analyze=analyze,
                retry=retry,
                learned_filter=flt,
                shard=shard,
            )
        budget = budget or Budget(max_fraction=0.001)
        tuner_cls = TUNERS[tuner_name]
        kwargs = dict(tuner_kwargs or {})
        if warm_start and "s0" not in kwargs:
            s0 = self.warm_start_state(
                wl, space, cost.name, fingerprint=cost.measure_fingerprint()
            )
            if s0 is not None and "s0" in inspect.signature(
                tuner_cls.__init__
            ).parameters:
                kwargs["s0"] = s0
        tuner = tuner_cls(space, cost, seed=self.seed if seed is None else seed,
                          **kwargs)
        checkpoint_fn = None
        if checkpointer is not None:
            def checkpoint_fn(t, ctx, _ck=checkpointer):
                # periodic snapshot at the cadence; an interrupt always
                # flushes a final one, then unwinds the whole session
                if _ck.interrupted or ctx.round_idx % _ck.every_rounds == 0:
                    _ck.save(
                        wkey,
                        tuner_id,
                        {
                            "tuner": tuner_name,
                            "tuner_state": t.state_dict(),
                            "ctx": ctx.snapshot(),
                        },
                        step=ctx.round_idx,
                    )
                if _ck.interrupted:
                    raise TuneInterrupted(wkey)

        result = tuner.tune(
            budget, engine=engine, checkpoint_fn=checkpoint_fn, restore=restore
        )
        if shard is not None:
            # elect-and-merge: publish this shard's best, wait for the
            # siblings' done markers, and keep-best-merge the elected
            # winner (lowest journaled cost, ties -> lowest shard index)
            # into the records table.  Every shard runs this — the merge
            # is idempotent, so no coordinator is needed.
            root = shard_dir_for(self.journal.path)
            write_done_marker(
                root,
                engine.journal_key,
                shard,
                None if result.best_state is None else result.best_state.as_lists(),
                result.best_cost,
                result.n_trials,
            )
            markers = await_markers(
                root, engine.journal_key, shard, timeout_s=shard_wait_s
            )
            if len(markers) < shard.count and self.verbose:
                missing = sorted(set(range(shard.count)) - set(markers))
                print(
                    f"[tune] {wl.label or wkey} shard {shard}: warning — "
                    f"sibling shard(s) {missing} never reported within "
                    f"{shard_wait_s:.0f}s; electing over the partial set"
                )
            # pick up the siblings' measurements before anyone reads best_state
            self.journal.reload()
            won = elect_best(markers)
            if won is not None:
                win_idx, win_lists, win_cost = won
                self.records.update(
                    wkey,
                    space.state_from_lists(win_lists),
                    win_cost,
                    tuner_name,
                    result.n_trials,
                    extra={
                        "label": wl.label,
                        "n_workers": engine.n_workers,
                        "shard_winner": win_idx,
                        "n_shards": shard.count,
                    },
                )
        elif result.best_state is not None and math.isfinite(result.best_cost):
            self.records.update(
                wkey,
                result.best_state,
                result.best_cost,
                tuner_name,
                result.n_trials,
                extra={"label": wl.label, "n_workers": engine.n_workers},
            )
        if checkpointer is not None:
            # mark the workload finished AFTER records.update so a crash
            # between the two re-runs the search instead of losing the record
            checkpointer.save(
                wkey,
                tuner_id,
                {"done": True, "tuner": tuner_name,
                 "result": _result_to_jsonable(result)},
                step=_DONE_STEP,
            )
        if self.verbose:
            print(
                f"[tune] {wl.label or wkey} {tuner_name}: "
                f"best={result.best_cost:.3e}s trials={result.n_trials} "
                f"frac={result.fraction:.5f} wall={result.wall_s:.1f}s "
                f"clock={result.clock_s:.1f}s workers={result.n_workers} "
                f"cache_hit={result.cache_hit_rate:.2f}"
            )
        return result

    # -- whole architecture --------------------------------------------------
    def tune_arch(
        self,
        arch: Optional[str] = None,
        shape: str = "train_4k",
        tuner_name: str = "g-bfs",
        budget: Optional[Budget] = None,
        n_workers: int = 1,
        warm_start: bool = False,
        workloads: Optional[Sequence[Workload]] = None,
        tuner_kwargs: Optional[dict] = None,
        executor: Optional[LaneExecutor | str] = None,
        reload_every: int = 0,
        analyze: str = "off",
        retry: Optional[RetryPolicy] = None,
        checkpointer: Optional[TuneCheckpointer] = None,
        resume: bool = False,
        learned_filter: str = "off",
        filter_keep: float = 0.5,
        filter_retrain_every: int = 8,
        filter_min_rows: int = 32,
        shard: Optional[ShardSpec] = None,
        shard_wait_s: float = 60.0,
    ) -> ArchTuneReport:
        """Tune every distinct workload an architecture executes through
        one shared engine configuration and one shared budget pool.

        ``budget.max_trials`` / ``max_time_s`` are treated as the TOTAL
        across the arch — a hard ceiling: each remaining workload is
        allocated an equal share of whatever is left, capped at the
        remainder, so the sum over workloads can never exceed the pool
        (``max_fraction`` stays per-workload, being space-relative).
        Workloads with identical ``(op, dims, dtype)`` are tuned once
        and share the result; all engines share the session journal and
        one :class:`MeasureStats`, so the report can attribute the
        arch-level speedup to lanes vs cache.

        ``executor`` selects how measurement lanes run — a
        :class:`~repro.core.executor.LaneExecutor` instance, or a name
        (``"sim"``/``"thread"``/``"process"``) which is built here and
        closed when the arch finishes.  All workloads share the one
        executor, so process lanes pay worker start-up once.

        ``reload_every=N`` makes every workload engine merge sibling
        journal rows every N waves (mid-search cache sharing between
        concurrent engines on a common journal file; 0 disables).

        ``shard=ShardSpec(i, n)`` makes this process shard ``i`` of an
        ``n``-way sharded search: every workload engine measures only
        the candidates it owns (stable hash, see ``repro.core.shard``),
        defers the rest to the sibling processes running the remaining
        shards over the same journal, and elect-and-merges the per-shard
        bests into one records entry when the workload finishes.
        """
        if workloads is None:
            if arch is None:
                raise ValueError("tune_arch needs an arch name or explicit workloads")
            from repro.launch.tune import workloads_for_arch  # lazy: avoids cycle

            workloads = workloads_for_arch(arch, shape)
        budget = budget or Budget(max_fraction=0.001)
        stats = MeasureStats()
        unique: dict[tuple, Workload] = {}
        labels: dict[tuple, list[str]] = {}
        for i, wl in enumerate(workloads):
            shape_key = (wl.op, wl.dims, wl.dtype, wl.depths)
            unique.setdefault(shape_key, wl)
            labels.setdefault(shape_key, []).append(wl.label or f"wl{i}")
        results: dict[str, TuneResult] = {}
        left_trials = budget.max_trials
        left_time = budget.max_time_s
        n_left = len(unique)
        owns_executor = isinstance(executor, str)
        exec_obj = make_executor(executor) if isinstance(executor, str) else executor
        try:
            for shape_key, wl in unique.items():
                if (left_trials is not None and left_trials <= 0) or (
                    left_time is not None and left_time <= 0.0
                ):
                    break  # shared pool exhausted
                alloc = Budget(
                    # equal share of the remainder, but never more than the
                    # remainder itself: the pool is a hard ceiling
                    max_trials=None
                    if left_trials is None
                    else min(left_trials, max(1, left_trials // n_left)),
                    max_time_s=None if left_time is None else left_time / n_left,
                    max_fraction=budget.max_fraction,
                )
                res = self.tune_workload(
                    wl,
                    tuner_name,
                    alloc,
                    tuner_kwargs,
                    n_workers=n_workers,
                    warm_start=warm_start,
                    stats=stats,
                    executor=exec_obj,
                    reload_every=reload_every,
                    analyze=analyze,
                    retry=retry,
                    checkpointer=checkpointer,
                    resume=resume,
                    learned_filter=learned_filter,
                    filter_keep=filter_keep,
                    filter_retrain_every=filter_retrain_every,
                    filter_min_rows=filter_min_rows,
                    shard=shard,
                    shard_wait_s=shard_wait_s,
                )
                if left_trials is not None:
                    left_trials -= res.n_trials
                if left_time is not None:
                    left_time -= res.clock_s
                n_left -= 1
                for lbl in labels[shape_key]:
                    results[lbl] = res
        finally:
            if owns_executor and exec_obj is not None:
                exec_obj.close()
            if self.journal is not None:
                # drop the append descriptor between archs; the journal
                # stays usable (record() reopens lazily)
                self.journal.close()
        report = ArchTuneReport(
            results=results,
            stats=stats,
            n_workers=max(1, n_workers),
            n_unique_shapes=len(unique),
            executor=exec_obj.name if exec_obj is not None else "sim",
        )
        if self.verbose:
            print(
                f"[tune-arch] {len(results)} workloads / "
                f"{report.n_unique_shapes} distinct shapes: "
                f"trials={report.total_trials} clock={report.total_clock_s:.1f}s "
                f"workers={report.n_workers} executor={report.executor} "
                f"cache_hit={stats.cache_hit_rate():.2f} "
                f"lane_failures={stats.n_failures}"
            )
        return report

    def compare(
        self,
        wl: Workload,
        tuner_names: Sequence[str],
        budget: Budget,
        n_seeds: int = 1,
        tuner_kwargs: Optional[dict[str, dict]] = None,
        n_workers: int = 1,
    ) -> dict[str, list[TuneResult]]:
        """Paper-style head-to-head under an identical budget."""
        out: dict[str, list[TuneResult]] = {}
        for name in tuner_names:
            out[name] = []
            for s in range(n_seeds):
                kw = (tuner_kwargs or {}).get(name, {})
                out[name].append(
                    self.tune_workload(
                        wl, name, budget, tuner_kwargs=kw, seed=self.seed + s,
                        n_workers=n_workers,
                    )
                )
        return out

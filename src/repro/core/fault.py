"""Failure taxonomy, retry policy, and the deterministic
fault-injection harness for the measurement stack.

Real ``--cost xla`` measurement on shared hardware sees transient
compile crashes, stragglers, and preemption — Chen et al. run their
timing workers on an RPC farm precisely because workers fail routinely
and the search must shrug it off.  This module gives the stack the
vocabulary and the knobs:

* **Taxonomy** — every lane failure gets a ``kind``.  *Transient* kinds
  (worker crash, lane timeout, spawn failure, corrupt result) say
  nothing about the schedule and may be retried; *permanent* kinds
  (deterministic raise, failed build, static-illegal) are properties of
  the schedule and are exactly as cacheable as a runtime.
* :class:`RetryPolicy` — how :class:`~repro.core.measure.MeasureEngine`
  re-queues transient failures into later waves instead of surfacing
  ``inf`` to the tuner, with exponential backoff and *deterministic*
  jitter (hashed from seed/state/attempt, so two runs with the same
  seed charge the same clock).
* :class:`FaultPlan` / :class:`FaultInjectionCost` — a seeded, picklable
  schedule of crash/hang/raise/outlier/corrupt faults wrapped around any
  backend, promoting the ad-hoc ``raise_keys``/``exit_keys`` hooks of
  :class:`~repro.core.cost.base.SleepingCost` into a harness that can
  drive executor-hardening tests and benchmarks reproducibly.  Which
  states fault is a pure function of ``(plan.seed, state.key())``;
  *whether a transient fault fires again on retry* is tracked in a
  shared ``fault_dir`` on disk, so the plan behaves identically across
  process boundaries and across interrupted-and-resumed sessions.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import time
from typing import Optional, Sequence

from .cost.base import CostBackend, backend_from_spec
from .space import State

__all__ = [
    "TRANSIENT_KINDS",
    "PERMANENT_KINDS",
    "classify_error",
    "RetryPolicy",
    "FaultPlan",
    "FaultInjectionCost",
]


#: Failure kinds that say nothing about the schedule itself — the lane
#: died, not the candidate.  Safe (and worthwhile) to retry; must never
#: be served from the journal as "this config is infeasible".
TRANSIENT_KINDS = frozenset({"crash", "timeout", "spawn", "corrupt"})

#: Failure kinds that are properties of the schedule: a deterministic
#: exception from the backend, a failed build (the historical
#: ``inf``-cost row), or a static-analyzer rejection.  Exactly as
#: cacheable as a measured runtime.
PERMANENT_KINDS = frozenset({"build", "raise", "static"})


def classify_error(error: Optional[str]) -> Optional[str]:
    """Map a legacy ``LaneResult.error`` note to a failure kind.

    Executors populated free-form error strings before the taxonomy
    existed; this keeps old call sites (and any third-party executor
    that only sets ``error``) classified.  Returns ``None`` for no
    error."""
    if error is None:
        return None
    e = error.lower()
    if "timeout" in e:
        return "timeout"
    if "before dispatch" in e:
        return "spawn"
    if "crash" in e:
        return "crash"
    return "raise"


def _unit_hash(*parts) -> float:
    """Deterministic uniform-ish draw in ``[0, 1)`` from hashed parts —
    the seeded randomness source for jitter and fault assignment
    (``random.Random`` state would couple these draws to the tuner's
    RNG stream and break resume/retry determinism)."""
    h = hashlib.blake2b(
        "\x1f".join(str(p) for p in parts).encode("utf-8"), digest_size=8
    ).digest()
    return int.from_bytes(h, "big") / 2.0**64


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How the engine retries transient lane failures.

    ``max_attempts`` counts *total* attempts per candidate (1 = no
    retry).  Attempt ``k``'s failure backs off
    ``backoff_s * 2**(k-1) * (1 + jitter * u)`` with ``u`` drawn
    deterministically from ``(seed, state_key, k)`` — real executors
    sleep it, the simulated executor merely charges it to the clock, and
    either way two runs with the same seed see the same charges."""

    max_attempts: int = 3
    backoff_s: float = 0.25
    jitter: float = 0.25
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")

    @property
    def enabled(self) -> bool:
        return self.max_attempts > 1

    def delay_s(self, state_key: str, attempt: int) -> float:
        """Backoff charged after failed attempt number ``attempt`` (1-based)."""
        base = self.backoff_s * (2.0 ** max(0, attempt - 1))
        u = _unit_hash("retry", self.seed, state_key, attempt)
        return base * (1.0 + self.jitter * u)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded schedule of measurement faults.

    Each state's fate is a pure function of ``(seed, state.key())``: one
    uniform draw is partitioned into probability bands, so raising any
    single probability never reshuffles which states take the *other*
    fault kinds.  Kinds:

    * ``crash``   — the measuring process hard-exits (transient);
    * ``hang``    — sleeps ``hang_s`` to trip the lane timeout (transient);
    * ``raise``   — deterministic exception, fires on *every* attempt
      (permanent — retrying a schedule that always raises is futile);
    * ``outlier`` — correct value after an extra ``outlier_s`` of lane
      wall (a straggler, not a failure);
    * ``corrupt`` — returns an invalid (negative) cost (transient).

    ``fires`` bounds how many times each planned *transient* fault
    actually triggers (then the state measures cleanly — the retry-able
    scenario); ``-1`` means every attempt (the exhaustion scenario).
    """

    seed: int = 0
    p_crash: float = 0.0
    p_hang: float = 0.0
    p_raise: float = 0.0
    p_outlier: float = 0.0
    p_corrupt: float = 0.0
    hang_s: float = 30.0
    outlier_s: float = 1.0
    fires: int = 1

    def fault_for(self, state_key: str) -> Optional[str]:
        """The fault kind planned for this state, or None."""
        u = _unit_hash("fault", self.seed, state_key)
        for kind, p in (
            ("crash", self.p_crash),
            ("hang", self.p_hang),
            ("raise", self.p_raise),
            ("outlier", self.p_outlier),
            ("corrupt", self.p_corrupt),
        ):
            if u < p:
                return kind
            u -= p
        return None

    def as_kwargs(self) -> dict:
        return dataclasses.asdict(self)


def _fault_injection_from_spec(
    inner: tuple, plan: dict, fault_dir: str, delay_s: float
) -> "FaultInjectionCost":
    return FaultInjectionCost(
        backend_from_spec(tuple(inner)),
        FaultPlan(**plan),
        fault_dir=fault_dir,
        delay_s=delay_s,
    )


class FaultInjectionCost(CostBackend):
    """Wraps any backend with a :class:`FaultPlan`.

    Transient fire counts live as files under ``fault_dir`` (one
    append-only counter file per faulting state), so "this crash already
    fired" is shared across worker processes and survives a session
    restart — which is what makes a faulted run deterministic end to
    end.  ``delay_s`` adds real lane occupancy per measurement (the
    :class:`~repro.core.cost.base.SleepingCost` role) so process-lane
    tests and benchmarks have a wall-clock to overlap.

    Values are untouched (an outlier is slow, not wrong), so the
    measurement fingerprint delegates to the inner backend and journal
    rows stay interchangeable with fault-free runs.
    """

    def __init__(
        self,
        inner: CostBackend,
        plan: FaultPlan,
        fault_dir: str,
        delay_s: float = 0.0,
    ):
        super().__init__(inner.space, n_repeats=1)
        self.inner = inner
        self.plan = plan
        self.fault_dir = fault_dir
        self.delay_s = delay_s
        self.name = f"faulty({inner.name})"

    def cost_once(self, s: State, repeat_idx: int) -> float:  # pragma: no cover
        raise RuntimeError("FaultInjectionCost delegates via cost()")

    def _should_fire(self, state_key: str) -> bool:
        """Consume one fire from this state's budget (True = fault now).
        One byte is appended to the state's counter file per consumed
        fire; O_APPEND keeps concurrent workers from double-counting."""
        if self.plan.fires < 0:
            return True
        if self.plan.fires == 0:
            return False
        os.makedirs(self.fault_dir, exist_ok=True)
        digest = hashlib.blake2b(state_key.encode("utf-8"), digest_size=10).hexdigest()
        path = os.path.join(self.fault_dir, f"fire_{digest}")
        fd = os.open(path, os.O_WRONLY | os.O_APPEND | os.O_CREAT, 0o644)
        try:
            fired_before = os.fstat(fd).st_size
            if fired_before >= self.plan.fires:
                return False
            os.write(fd, b"x")
            return True
        finally:
            os.close(fd)

    def cost(self, s: State) -> float:
        key = s.key()
        kind = self.plan.fault_for(key)
        if self.delay_s:
            time.sleep(self.delay_s)
        if kind == "raise":
            # deterministic: the schedule itself is broken, every attempt
            # fails identically — the permanent arm of the taxonomy
            raise RuntimeError(f"injected permanent failure for {key}")
        if kind is not None and self._should_fire(key):
            if kind == "crash":
                os._exit(13)  # simulated segfault: no exception, no cleanup
            if kind == "hang":
                time.sleep(self.plan.hang_s)  # trips the per-lane timeout
            elif kind == "outlier":
                time.sleep(self.plan.outlier_s)  # straggler: slow, then correct
            elif kind == "corrupt":
                return -1.0  # impossible runtime: engine flags it transient
        return self.inner.cost(s)

    def batch_cost(self, states: Sequence[State]) -> list[float]:
        return [self.cost(s) for s in states]

    def measure_fingerprint(self) -> str:
        # faults change availability/occupancy, never the measured value
        return self.inner.measure_fingerprint()

    def compile_stats(self) -> Optional[dict]:
        return self.inner.compile_stats()

    def worker_spec(self) -> Optional[tuple[str, dict]]:
        inner_spec = self.inner.worker_spec()
        if inner_spec is None:
            return None
        return (
            "repro.core.fault:_fault_injection_from_spec",
            {
                "inner": inner_spec,
                "plan": self.plan.as_kwargs(),
                "fault_dir": self.fault_dir,
                "delay_s": self.delay_s,
            },
        )

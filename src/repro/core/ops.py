"""Operator registry — binds each tunable op to its search space, cost
oracles, and build recipes.

This is the single point where a new workload plugs into the tuner
stack.  An :class:`OpSpec` names:

* ``make_space``       — dims/depths -> :class:`~repro.core.space.SearchSpace`
* ``analytical_cost``  — the op's deterministic roofline oracle
* ``timed_operands`` / ``timed_fn`` — how :class:`XLATimedCost` realizes
  a schedule as a *timed XLA:CPU program* (operands + traceable fn)
* ``pallas_run``       — how :class:`PallasInterpretCost` executes the
  op's real Pallas kernel under a schedule (interpret mode on CPU)

Everything downstream (tuners, the measurement engine, journals,
``TuningSession``, the tune CLI) resolves ops through :func:`get_op` and
never mentions GEMM concretely.  Registering here also registers the
op's state type (via the space modules), so persisted records/journal
rows deserialize for any bundled op.

Built-in ops:

  ``gemm``  — the paper's tiled matrix multiply (canonical instance)
  ``flash`` — blocked flash attention over ``(seq_q, seq_kv, head_dim)``
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import numpy as np

from .config_space import GemmConfigSpace, TilingState
from .flash_space import FlashAttnConfigSpace, FlashScheduleState
from .space import SearchSpace

__all__ = ["OpSpec", "OPS", "register_op", "get_op", "op_names"]


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """Everything the tuner stack needs to know about one operator."""

    name: str
    state_type: type
    default_depths: tuple[int, ...]
    #: (dims, depths, **spec_kwargs) -> SearchSpace
    make_space: Callable[..., SearchSpace]
    #: (space, **kwargs) -> CostBackend (the op's analytical oracle)
    analytical_cost: Callable[..., object]
    #: (space, dtype, seed) -> operand arrays for the timed XLA program
    timed_operands: Callable[..., tuple]
    #: (space, state, dtype) -> traceable fn(*operands) realizing the schedule
    timed_fn: Callable[..., Callable]
    #: (space, state, operands, interpret) -> output array via the real
    #: Pallas kernel, or None when the op has no kernel binding
    pallas_run: Optional[Callable] = None


OPS: dict[str, OpSpec] = {}


def register_op(spec: OpSpec) -> None:
    OPS[spec.name] = spec


def get_op(name: str) -> OpSpec:
    try:
        return OPS[name]
    except KeyError:
        raise KeyError(
            f"unknown op {name!r}; registered ops: {sorted(OPS)}"
        ) from None


def op_names() -> list[str]:
    return sorted(OPS)


# ---------------------------------------------------------------------------
# gemm — the paper's tiled matmul
# ---------------------------------------------------------------------------


def _gemm_space(dims: Sequence[int], depths: Sequence[int] = (), **kw) -> GemmConfigSpace:
    m, k, n = dims
    d_m, d_k, d_n = depths or (4, 2, 4)
    return GemmConfigSpace(m, k, n, d_m, d_k, d_n, **kw)


def _gemm_analytical(space, **kw):
    from .cost.analytical import AnalyticalTPUCost

    return AnalyticalTPUCost(space, **kw)


def _gemm_timed_operands(space: GemmConfigSpace, dtype: str, seed: int) -> tuple:
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.standard_normal((space.m, space.k)), dtype=dtype)
    B = jnp.asarray(rng.standard_normal((space.k, space.n)), dtype=dtype)
    return (A, B)


def _gemm_timed_fn(space: GemmConfigSpace, s: TilingState, dtype: str) -> Callable:
    """The tiled loop structure of ``s`` as an XLA program: fori_loop
    over the macro-grid with dynamic-sliced blocks, k innermost with
    VMEM-style accumulation."""
    import jax
    import jax.numpy as jnp

    lax = jax.lax
    gm, gk, gn = s.grid
    bm, bk, bn = s.block_m, s.block_k, s.block_n
    M, N = space.m, space.n

    def fn(A, B):
        C = jnp.zeros((M, N), dtype=dtype)

        def body(idx, C):
            ik = idx % gk
            rest = idx // gk
            i_n = rest % gn
            i_m = rest // gn
            a = lax.dynamic_slice(A, (i_m * bm, ik * bk), (bm, bk))
            b = lax.dynamic_slice(B, (ik * bk, i_n * bn), (bk, bn))
            c = jnp.dot(a, b)
            old = lax.dynamic_slice(C, (i_m * bm, i_n * bn), (bm, bn))
            return lax.dynamic_update_slice(C, old + c, (i_m * bm, i_n * bn))

        return lax.fori_loop(0, gm * gk * gn, body, C)

    return fn


def _gemm_pallas_run(space: GemmConfigSpace, s: TilingState, operands, interpret=True):
    from repro.kernels.gemm import gemm_pallas, kernel_config_from_state

    cfg = kernel_config_from_state(s)  # ValueError -> inf at the caller
    A, B = operands
    return gemm_pallas(A, B, cfg, interpret=interpret)


# ---------------------------------------------------------------------------
# flash — blocked flash attention
# ---------------------------------------------------------------------------


def _flash_space(
    dims: Sequence[int], depths: Sequence[int] = (), **kw
) -> FlashAttnConfigSpace:
    seq_q, seq_kv, head_dim = dims
    d_q, d_kv = depths or (2, 2)
    return FlashAttnConfigSpace(seq_q, seq_kv, head_dim, d_q, d_kv, **kw)


def _flash_analytical(space, **kw):
    from .cost.flash_analytical import FlashAnalyticalCost

    return FlashAnalyticalCost(space, **kw)


def _flash_timed_operands(space: FlashAttnConfigSpace, dtype: str, seed: int) -> tuple:
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((space.seq_q, space.head_dim)), dtype=dtype)
    k = jnp.asarray(rng.standard_normal((space.seq_kv, space.head_dim)), dtype=dtype)
    v = jnp.asarray(rng.standard_normal((space.seq_kv, space.head_dim)), dtype=dtype)
    return (q, k, v)


def _flash_timed_fn(
    space: FlashAttnConfigSpace, s: FlashScheduleState, dtype: str
) -> Callable:
    """The blocked online-softmax loop of ``s`` as an XLA program —
    the CPU-timeable realization of the Pallas kernel's schedule
    (fori_loop over q grid cells, inner fori over kv blocks with the
    causal early exit)."""
    import jax
    import jax.numpy as jnp

    lax = jax.lax
    bq, bkv = s.block_q, s.block_kv
    n_q, n_kv = s.n_q_blocks, s.n_kv_blocks
    sq, hd = space.seq_q, space.head_dim
    causal = space.causal
    scale = 1.0 / math.sqrt(hd)

    def fn(q, k, v):
        out = jnp.zeros((sq, hd), dtype=dtype)

        def q_body(iq, out):
            qb = lax.dynamic_slice(q, (iq * bq, 0), (bq, hd)).astype(jnp.float32)
            qb = qb * scale

            def kv_body(ik, carry):
                acc, m_run, l_run = carry
                kb = lax.dynamic_slice(k, (ik * bkv, 0), (bkv, hd)).astype(jnp.float32)
                vb = lax.dynamic_slice(v, (ik * bkv, 0), (bkv, hd)).astype(jnp.float32)
                logits = qb @ kb.T  # (bq, bkv)
                if causal:
                    q_pos = iq * bq + lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
                    k_pos = ik * bkv + lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
                    logits = jnp.where(q_pos >= k_pos, logits, -1e30)
                m_new = jnp.maximum(m_run, logits.max(axis=-1))
                p = jnp.exp(logits - m_new[:, None])
                corr = jnp.exp(m_run - m_new)
                l_new = l_run * corr + p.sum(axis=-1)
                acc = acc * corr[:, None] + p @ vb
                return (acc, m_new, l_new)

            carry0 = (
                jnp.zeros((bq, hd), jnp.float32),
                jnp.full((bq,), -1e30, jnp.float32),
                jnp.zeros((bq,), jnp.float32),
            )
            # causal: skip kv blocks entirely above the diagonal
            last = n_kv
            if causal:
                last = jnp.minimum(n_kv, ((iq + 1) * bq + bkv - 1) // bkv)
            acc, _, l_run = lax.fori_loop(0, last, kv_body, carry0)
            ob = (acc / jnp.maximum(l_run, 1e-30)[:, None]).astype(dtype)
            return lax.dynamic_update_slice(out, ob, (iq * bq, 0))

        return lax.fori_loop(0, n_q, q_body, out)

    return fn


def _flash_pallas_run(
    space: FlashAttnConfigSpace, s: FlashScheduleState, operands, interpret=True
):
    from repro.kernels.flash_attention import flash_attention

    q, k, v = operands
    q4 = q.reshape(1, space.seq_q, 1, space.head_dim)
    k4 = k.reshape(1, space.seq_kv, 1, space.head_dim)
    v4 = v.reshape(1, space.seq_kv, 1, space.head_dim)
    return flash_attention(
        q4, k4, v4,
        block_q=s.block_q,
        block_k=s.block_kv,
        causal=space.causal,
        interpret=interpret,
    )


register_op(
    OpSpec(
        name="gemm",
        state_type=TilingState,
        default_depths=(4, 2, 4),
        make_space=_gemm_space,
        analytical_cost=_gemm_analytical,
        timed_operands=_gemm_timed_operands,
        timed_fn=_gemm_timed_fn,
        pallas_run=_gemm_pallas_run,
    )
)

register_op(
    OpSpec(
        name="flash",
        state_type=FlashScheduleState,
        default_depths=(2, 2),
        make_space=_flash_space,
        analytical_cost=_flash_analytical,
        timed_operands=_flash_timed_operands,
        timed_fn=_flash_timed_fn,
        pallas_run=_flash_pallas_run,
    )
)

"""GEMM tiling configuration space — the paper's MDP (Sec. 3.3 / 4.1).

A *state* (Eqn. 5) is ``s = [s_m, s_k, s_n, J]`` where ``s_x`` is an
ordered factor list whose product equals the matrix dimension and ``J``
is a legitimacy bit.  The *action space* (Eqn. 6) doubles one factor and
halves another within the same dimension:

    A = { s_x[i] <- 2*s_x[i],  s_x[j] <- s_x[j]/2 }   x in {m,k,n}, i != j

which preserves the product — the paper's central structural insight is
that the cost surface is smooth under these product-preserving moves.

For power-of-two dims (the paper's benchmarks: 512^3, 1024^3, 2048^3) the
reachable space is exactly the set of ordered power-of-two compositions;
its size reproduces the paper's reported counts:

    (512,512,512):    C(12,3) * 10 * C(12,3) = 220*10*220   =   484,000
    (1024,1024,1024): C(13,3) * 11 * C(13,3) = 286*11*286   =   899,756
    (2048,2048,2048): C(14,3) * 12 * C(14,3) = 364*12*364   = 1,589,952

TPU interpretation of a state (hardware adaptation, DESIGN.md §2):
``s_m=[m0,m1,m2,m3]`` → grid dim ``m0``; VMEM block ``bm = m1*m2*m3``;
MXU sub-tile loop ``m2*m3``; lane/register granularity ``m3`` (same for
n; ``s_k=[k0,k1]`` → grid ``k0``, VMEM depth ``bk=k1``).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import random as _random
from typing import Callable, Iterator, Optional, Sequence

import numpy as np

__all__ = [
    "TilingState",
    "Action",
    "GemmConfigSpace",
    "compositions_pow2",
    "count_compositions_pow2",
]


@dataclasses.dataclass(frozen=True)
class TilingState:
    """One configuration ``s = [s_m, s_k, s_n]`` (legitimacy via space)."""

    m: tuple[int, ...]
    k: tuple[int, ...]
    n: tuple[int, ...]

    # -- convenience views (TPU mapping) ------------------------------------
    @property
    def grid(self) -> tuple[int, int, int]:
        """(m0, k0, n0): the HBM->VMEM macro-tile grid."""
        return (self.m[0], self.k[0], self.n[0])

    @property
    def block_m(self) -> int:
        return math.prod(self.m[1:]) if len(self.m) > 1 else 1

    @property
    def block_k(self) -> int:
        return math.prod(self.k[1:]) if len(self.k) > 1 else 1

    @property
    def block_n(self) -> int:
        return math.prod(self.n[1:]) if len(self.n) > 1 else 1

    @property
    def sub_m(self) -> int:
        """MXU-facing inner sub-tile (second-level split)."""
        return math.prod(self.m[2:]) if len(self.m) > 2 else 1

    @property
    def sub_n(self) -> int:
        return math.prod(self.n[2:]) if len(self.n) > 2 else 1

    @property
    def reg_m(self) -> int:
        return self.m[-1]

    @property
    def reg_n(self) -> int:
        return self.n[-1]

    def dims(self) -> tuple[int, int, int]:
        return (math.prod(self.m), math.prod(self.k), math.prod(self.n))

    def as_lists(self) -> list[list[int]]:
        return [list(self.m), list(self.k), list(self.n)]

    @staticmethod
    def from_lists(lists: Sequence[Sequence[int]]) -> "TilingState":
        m, k, n = lists
        return TilingState(tuple(m), tuple(k), tuple(n))

    def key(self) -> str:
        return (
            ",".join(map(str, self.m))
            + "|"
            + ",".join(map(str, self.k))
            + "|"
            + ",".join(map(str, self.n))
        )

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"[{list(self.m)} x {list(self.k)} x {list(self.n)}]"


@dataclasses.dataclass(frozen=True)
class Action:
    """Double ``s_x[i]``, halve ``s_x[j]`` (paper Eqn. 6)."""

    dim: int  # 0=m, 1=k, 2=n
    i: int
    j: int

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"({'mkn'[self.dim]}: x2@{self.i}, /2@{self.j})"


def count_compositions_pow2(value: int, parts: int) -> int:
    """Number of ordered factorizations of ``value`` into ``parts`` factors
    reachable under the doubling/halving moves (= power-of-two compositions
    times the fixed placement of the odd part, which rides along factor
    moves two-at-a-time).  For ``value = odd * 2^e`` this is the number of
    ways to distribute ``e`` twos into ``parts`` ordered slots, times the
    number of slots the odd part can occupy — except the odd part is only
    movable in factors of 2, i.e. it cannot move at all; it stays where the
    initial state put it.  Hence ``C(e + parts - 1, parts - 1)``.
    """
    e = (value & -value).bit_length() - 1  # exponent of 2 in value
    return math.comb(e + parts - 1, parts - 1)


def compositions_pow2(value: int, parts: int) -> Iterator[tuple[int, ...]]:
    """Enumerate ordered factor tuples ``(f_0..f_{parts-1})`` with
    ``prod == value`` where all variation is in powers of two and the odd
    part of ``value`` stays on factor 0 (the reachable set from the
    paper's initial state ``[value, 1, .., 1]``)."""
    odd = value
    e = 0
    while odd % 2 == 0:
        odd //= 2
        e += 1
    # distribute e twos into `parts` slots
    for cut in itertools.combinations(range(e + parts - 1), parts - 1):
        prev = -1
        exps = []
        for c in cut:
            exps.append(c - prev - 1)
            prev = c
        exps.append(e + parts - 2 - prev)
        factors = [2**x for x in exps]
        factors[0] *= odd
        yield tuple(factors)


class GemmConfigSpace:
    """The search space for one GEMM workload ``(M, K, N)`` with nesting
    depths ``(d_m, d_k, d_n)`` (paper defaults 4, 2, 4 for GPUs; same
    defaults kept for the TPU adaptation — see DESIGN.md §2)."""

    def __init__(
        self,
        m: int,
        k: int,
        n: int,
        d_m: int = 4,
        d_k: int = 2,
        d_n: int = 4,
        extra_constraint: Optional[Callable[[TilingState], bool]] = None,
    ):
        if min(m, k, n) < 1:
            raise ValueError(f"bad GEMM dims ({m},{k},{n})")
        self.m, self.k, self.n = m, k, n
        self.d_m, self.d_k, self.d_n = d_m, d_k, d_n
        self.extra_constraint = extra_constraint
        self._actions = self._build_actions()

    # -- basic protocol ------------------------------------------------------
    def initial_state(self) -> TilingState:
        """Paper Sec. 5: ``s0 = [[m,1,..], [k,1], [n,1,..]]`` (no tiling)."""
        return TilingState(
            (self.m,) + (1,) * (self.d_m - 1),
            (self.k,) + (1,) * (self.d_k - 1),
            (self.n,) + (1,) * (self.d_n - 1),
        )

    def _build_actions(self) -> list[Action]:
        acts = []
        for dim, d in enumerate((self.d_m, self.d_k, self.d_n)):
            for i in range(d):
                for j in range(d):
                    if i != j:
                        acts.append(Action(dim, i, j))
        return acts

    @property
    def actions(self) -> list[Action]:
        return self._actions

    @property
    def n_actions(self) -> int:
        return len(self._actions)

    def step(self, s: TilingState, a: Action) -> Optional[TilingState]:
        """Apply Eqn. 6/7; returns None when the move is illegitimate
        (halving an odd factor)."""
        lists = s.as_lists()
        row = lists[a.dim]
        if row[a.j] % 2 != 0:
            return None
        row[a.i] *= 2
        row[a.j] //= 2
        s2 = TilingState.from_lists(lists)
        if not self.is_legitimate(s2):
            return None
        return s2

    def neighbors(self, s: TilingState) -> list[TilingState]:
        """g(s) of Eqn. 9 — all legitimate one-action successors."""
        out = []
        for a in self._actions:
            s2 = self.step(s, a)
            if s2 is not None:
                out.append(s2)
        return out

    def is_legitimate(self, s: TilingState) -> bool:
        """J of Eqn. 5: exact products, positive integers, plus optional
        hardware constraint (e.g. VMEM budget)."""
        if any(f < 1 for f in s.m + s.k + s.n):
            return False
        if math.prod(s.m) != self.m or math.prod(s.k) != self.k:
            return False
        if math.prod(s.n) != self.n:
            return False
        if len(s.m) != self.d_m or len(s.k) != self.d_k or len(s.n) != self.d_n:
            return False
        if self.extra_constraint is not None and not self.extra_constraint(s):
            return False
        return True

    # -- enumeration / sampling ----------------------------------------------
    def size(self) -> int:
        return (
            count_compositions_pow2(self.m, self.d_m)
            * count_compositions_pow2(self.k, self.d_k)
            * count_compositions_pow2(self.n, self.d_n)
        )

    def enumerate(self) -> Iterator[TilingState]:
        for fm in compositions_pow2(self.m, self.d_m):
            for fk in compositions_pow2(self.k, self.d_k):
                for fn in compositions_pow2(self.n, self.d_n):
                    s = TilingState(fm, fk, fn)
                    if self.extra_constraint is None or self.extra_constraint(s):
                        yield s

    def random_state(self, rng: _random.Random) -> TilingState:
        def rand_comp(value: int, parts: int) -> tuple[int, ...]:
            odd = value
            e = 0
            while odd % 2 == 0:
                odd //= 2
                e += 1
            exps = [0] * parts
            for _ in range(e):
                exps[rng.randrange(parts)] += 1
            factors = [2**x for x in exps]
            factors[0] *= odd
            return tuple(factors)

        for _ in range(64):
            s = TilingState(
                rand_comp(self.m, self.d_m),
                rand_comp(self.k, self.d_k),
                rand_comp(self.n, self.d_n),
            )
            if self.is_legitimate(s):
                return s
        return self.initial_state()

    def transplant(self, s: TilingState) -> Optional[TilingState]:
        """Map a state tuned for *another* workload into this space —
        the warm-start translation.

        Tiling quality is carried by the inner factors (VMEM block, MXU
        sub-tile, register granularity), which transfer across GEMM
        shapes; the grid factor merely covers whatever dimension is
        left.  So: keep the donor's inner factors (resized to this
        space's nesting depth, register factor kept innermost), shrink
        them until their product divides the new dimension, and absorb
        the remainder — including the dimension's odd part, which keeps
        the state inside the reachable set — into the grid factor.
        Returns None when no legitimate translation exists.
        """
        dims = (self.m, self.k, self.n)
        depths = (self.d_m, self.d_k, self.d_n)
        rows = []
        for row, dim, d in zip(s.as_lists(), dims, depths):
            inner = list(row[1:])
            if len(inner) > d - 1:  # merge overflow into the outermost inner slot
                keep = len(inner) - (d - 1)
                inner = [math.prod(inner[: keep + 1])] + inner[keep + 1:]
            while len(inner) < d - 1:  # pad outermost, keep register innermost
                inner.insert(0, 1)
            for _ in range(64):
                p = math.prod(inner) if inner else 1
                if p >= 1 and dim % p == 0:
                    break
                big = max(range(len(inner)), key=lambda i: inner[i])
                inner[big] = inner[big] // 2 if inner[big] % 2 == 0 else 1
            p = math.prod(inner) if inner else 1
            if dim % p != 0:
                inner, p = [1] * (d - 1), 1
            rows.append([dim // p] + inner)
        s2 = TilingState.from_lists(rows)
        return s2 if self.is_legitimate(s2) else None

    # -- featurization (for surrogate / policy models) ------------------------
    FEATURE_NAMES = None  # set lazily per space

    def features(self, s: TilingState) -> np.ndarray:
        """Dense feature vector: log2 of every factor plus derived tile
        descriptors.  Used by the GBT surrogate, the RNN controller
        baseline, and N-A2C's actor/critic networks."""
        lg = lambda v: math.log2(max(v, 1))
        raw = [lg(f) for f in (s.m + s.k + s.n)]
        bm, bk, bn = s.block_m, s.block_k, s.block_n
        derived = [
            lg(bm),
            lg(bk),
            lg(bn),
            lg(s.sub_m),
            lg(s.sub_n),
            lg(s.reg_m),
            lg(s.reg_n),
            lg(s.grid[0] * s.grid[1] * s.grid[2]),
            float(bn % 128 == 0),
            float(bm % 8 == 0),
            lg(bm * bk + bk * bn + bm * bn),  # ~VMEM working set (elements)
        ]
        return np.asarray(raw + derived, dtype=np.float32)

    @property
    def n_features(self) -> int:
        return self.d_m + self.d_k + self.d_n + 11

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"GemmConfigSpace(({self.m},{self.k},{self.n}), "
            f"d=({self.d_m},{self.d_k},{self.d_n}), size={self.size()})"
        )

"""GEMM tiling configuration space — the paper's MDP (Sec. 3.3 / 4.1),
the canonical :class:`~repro.core.space.SearchSpace` implementation.

A *state* (Eqn. 5) is ``s = [s_m, s_k, s_n, J]`` where ``s_x`` is an
ordered factor list whose product equals the matrix dimension and ``J``
is a legitimacy bit.  The *action space* (Eqn. 6) doubles one factor and
halves another within the same dimension:

    A = { s_x[i] <- 2*s_x[i],  s_x[j] <- s_x[j]/2 }   x in {m,k,n}, i != j

which preserves the product — the paper's central structural insight is
that the cost surface is smooth under these product-preserving moves.
The row-generic machinery (actions, stepping, enumeration, sampling,
transplanting) lives in :class:`~repro.core.space.FactoredSearchSpace`;
this module fixes the three ``m/k/n`` rows, the GEMM featurization, and
the TPU working-set model.

For power-of-two dims (the paper's benchmarks: 512^3, 1024^3, 2048^3) the
reachable space is exactly the set of ordered power-of-two compositions;
its size reproduces the paper's reported counts:

    (512,512,512):    C(12,3) * 10 * C(12,3) = 220*10*220   =   484,000
    (1024,1024,1024): C(13,3) * 11 * C(13,3) = 286*11*286   =   899,756
    (2048,2048,2048): C(14,3) * 12 * C(14,3) = 364*12*364   = 1,589,952

TPU interpretation of a state (hardware adaptation, DESIGN.md §2):
``s_m=[m0,m1,m2,m3]`` → grid dim ``m0``; VMEM block ``bm = m1*m2*m3``;
MXU sub-tile loop ``m2*m3``; lane/register granularity ``m3`` (same for
n; ``s_k=[k0,k1]`` → grid ``k0``, VMEM depth ``bk=k1``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import numpy as np

from .analysis import gemm_working_set_bytes
from .space import (
    Action,
    FactoredSearchSpace,
    compositions_pow2,
    count_compositions_pow2,
    register_state_type,
)

__all__ = [
    "TilingState",
    "Action",
    "GemmConfigSpace",
    "compositions_pow2",
    "count_compositions_pow2",
]


@dataclasses.dataclass(frozen=True)
class TilingState:
    """One configuration ``s = [s_m, s_k, s_n]`` (legitimacy via space)."""

    m: tuple[int, ...]
    k: tuple[int, ...]
    n: tuple[int, ...]

    # -- convenience views (TPU mapping) ------------------------------------
    @property
    def grid(self) -> tuple[int, int, int]:
        """(m0, k0, n0): the HBM->VMEM macro-tile grid."""
        return (self.m[0], self.k[0], self.n[0])

    @property
    def block_m(self) -> int:
        return math.prod(self.m[1:]) if len(self.m) > 1 else 1

    @property
    def block_k(self) -> int:
        return math.prod(self.k[1:]) if len(self.k) > 1 else 1

    @property
    def block_n(self) -> int:
        return math.prod(self.n[1:]) if len(self.n) > 1 else 1

    @property
    def sub_m(self) -> int:
        """MXU-facing inner sub-tile (second-level split)."""
        return math.prod(self.m[2:]) if len(self.m) > 2 else 1

    @property
    def sub_n(self) -> int:
        return math.prod(self.n[2:]) if len(self.n) > 2 else 1

    @property
    def reg_m(self) -> int:
        return self.m[-1]

    @property
    def reg_n(self) -> int:
        return self.n[-1]

    def dims(self) -> tuple[int, int, int]:
        return (math.prod(self.m), math.prod(self.k), math.prod(self.n))

    def as_lists(self) -> list[list[int]]:
        return [list(self.m), list(self.k), list(self.n)]

    @staticmethod
    def from_lists(lists: Sequence[Sequence[int]]) -> "TilingState":
        m, k, n = lists
        return TilingState(tuple(m), tuple(k), tuple(n))

    def key(self) -> str:
        return (
            ",".join(map(str, self.m))
            + "|"
            + ",".join(map(str, self.k))
            + "|"
            + ",".join(map(str, self.n))
        )

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"[{list(self.m)} x {list(self.k)} x {list(self.n)}]"


class GemmConfigSpace(FactoredSearchSpace):
    """The search space for one GEMM workload ``(M, K, N)`` with nesting
    depths ``(d_m, d_k, d_n)`` (paper defaults 4, 2, 4 for GPUs; same
    defaults kept for the TPU adaptation — see DESIGN.md §2)."""

    op = "gemm"

    def __init__(
        self,
        m: int,
        k: int,
        n: int,
        d_m: int = 4,
        d_k: int = 2,
        d_n: int = 4,
        extra_constraint: Optional[Callable[[TilingState], bool]] = None,
    ):
        if min(m, k, n) < 1:
            raise ValueError(f"bad GEMM dims ({m},{k},{n})")
        self.m, self.k, self.n = m, k, n
        self.d_m, self.d_k, self.d_n = d_m, d_k, d_n
        super().__init__((m, k, n), (d_m, d_k, d_n), extra_constraint)

    def state_from_rows(self, rows: Sequence[Sequence[int]]) -> TilingState:
        return TilingState.from_lists(rows)

    # -- hardware footprint ---------------------------------------------------
    def working_set_bytes(self, s: TilingState, in_bytes: int = 2) -> int:
        """Double-buffered A/B blocks plus the f32 accumulator — the VMEM
        working set every cost backend guards with.  The arithmetic
        lives in ``repro.core.analysis`` (the analyzer's single budget
        function), so filter and oracle can never disagree."""
        return gemm_working_set_bytes(s.block_m, s.block_k, s.block_n, in_bytes)

    # -- featurization (for surrogate / policy models) ------------------------
    def features(self, s: TilingState) -> np.ndarray:
        """Dense feature vector: log2 of every factor plus derived tile
        descriptors.  Used by the GBT surrogate, the RNN controller
        baseline, and N-A2C's actor/critic networks."""
        lg = lambda v: math.log2(max(v, 1))
        raw = [lg(f) for f in (s.m + s.k + s.n)]
        bm, bk, bn = s.block_m, s.block_k, s.block_n
        derived = [
            lg(bm),
            lg(bk),
            lg(bn),
            lg(s.sub_m),
            lg(s.sub_n),
            lg(s.reg_m),
            lg(s.reg_n),
            lg(s.grid[0] * s.grid[1] * s.grid[2]),
            float(bn % 128 == 0),
            float(bm % 8 == 0),
            lg(bm * bk + bk * bn + bm * bn),  # ~VMEM working set (elements)
        ]
        return np.asarray(raw + derived, dtype=np.float32)

    @property
    def n_features(self) -> int:
        return self.d_m + self.d_k + self.d_n + 11

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"GemmConfigSpace(({self.m},{self.k},{self.n}), "
            f"d=({self.d_m},{self.d_k},{self.d_n}), size={self.size()})"
        )


register_state_type("gemm", TilingState)

"""Measurement lane executors — how a wave of candidate states actually
runs.

PR 1 gave :class:`~repro.core.measure.MeasureEngine` ``n_workers``
*simulated* lanes: the search clock compresses by the wave critical
path, but the backend work itself still runs in the calling thread.
This module makes the lane a pluggable boundary, the way TVM's tuners
ship measurement batches to an RPC/executor pool:

* :class:`SimulatedExecutor` — the PR-1 semantics, bit for bit: a
  single-miss wave takes the backend's scalar ``cost`` path, a
  multi-miss wave takes ``batch_cost``, nothing leaves the calling
  thread, and lane occupancy is *modeled* (overhead + capped runtime).
  This is the default and keeps every ``--workers 1`` parity guarantee.
* :class:`ThreadExecutor` — each lane is a thread running
  ``backend.cost``; real wall-clock overlap for backends that release
  the GIL (XLA compile/execute, sleeps).  A lane that raises is an
  ``inf``-cost outcome; a lane that exceeds the timeout is abandoned
  (the thread cannot be killed — it keeps running detached, which is
  why crash-grade isolation needs processes).

Real executors own their **kill timeout** (``timeout_s``, default 60 s):
it bounds how long a lane may *really* run before being abandoned or
killed.  This is deliberately distinct from ``MeasureEngine.timeout_s``,
which is the simulated clock's AutoTVM-style *charging cap* — a slow
config charges at most that much search clock, it is never killed for
it.  Conflating the two would kill every legitimately slow real
measurement (an XLA compile easily outlives a 4 s charging cap).
* :class:`ProcessExecutor` — each lane is a persistent worker *process*
  fed ``(backend_spec, state)`` jobs over a pipe.  The backend is
  rebuilt worker-side from ``CostBackend.worker_spec()`` and cached
  per spec, so per-job cost is one pipe round-trip.  A worker that
  raises reports the error and lives on; a worker that dies (segfault,
  ``os._exit``, OOM-kill) or blows the per-lane timeout is reaped and
  respawned, and its lane resolves to ``inf`` — a backend crash can no
  longer take down the tuning session.

Executors with ``real_time = True`` report *measured* per-lane wall
seconds; the engine charges those to the search clock instead of the
simulated occupancy model, so benchmark speedups separate clock
compression (simulated) from genuine parallel measurement (real).
"""

from __future__ import annotations

import abc
import dataclasses
import math
import multiprocessing
import time
from typing import Optional, Sequence

from .cost.base import CostBackend, backend_from_spec
from .space import State

__all__ = [
    "LaneExecutor",
    "LaneResult",
    "SimulatedExecutor",
    "ThreadExecutor",
    "ProcessExecutor",
    "EXECUTORS",
    "make_executor",
]


@dataclasses.dataclass
class LaneResult:
    """What one measurement lane hands back for one state."""

    cost: float
    wall_s: float = 0.0  # measured lane wall time (0 under simulation)
    error: Optional[str] = None  # crash / timeout / raised-exception note
    #: build-cache counter delta this job incurred worker-side (process
    #: lanes only — in-process executors let the engine read the backend
    #: directly); see ``CostBackend.compile_stats``.
    compile: Optional[dict] = None


class LaneExecutor(abc.ABC):
    """Runs the cache-miss portion of one measurement wave."""

    name: str = "base"
    #: True when ``LaneResult.wall_s`` is measured wall-clock the engine
    #: should charge, False when occupancy must come from the clock model.
    real_time: bool = False

    @abc.abstractmethod
    def run_wave(
        self,
        backend: CostBackend,
        states: Sequence[State],
        timeout_s: Optional[float] = None,
    ) -> list[LaneResult]:
        """Measure ``states`` (one per lane); results align with input."""

    def close(self) -> None:
        """Release lanes (threads/processes). Idempotent."""

    def __enter__(self) -> "LaneExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class SimulatedExecutor(LaneExecutor):
    """The historical in-thread path: scalar ``cost`` for single-miss
    waves (n_workers=1 parity), ``batch_cost`` otherwise."""

    name = "sim"
    real_time = False

    def run_wave(self, backend, states, timeout_s=None):
        if len(states) == 1:
            costs = [backend.cost(states[0])]
        else:
            costs = list(backend.batch_cost(states))
        return [LaneResult(cost=c) for c in costs]


class ThreadExecutor(LaneExecutor):
    """One daemon thread per lane (waves are measurement-bound, so
    per-wave thread spawn is noise).  Real overlap only where the
    backend drops the GIL; a timed-out lane is abandoned — daemon
    threads mean an abandoned lane can never block interpreter
    shutdown the way a ThreadPoolExecutor's atexit join would."""

    name = "thread"
    real_time = True

    def __init__(self, timeout_s: Optional[float] = 60.0):
        self.timeout_s = timeout_s  # kill timeout; None = never abandon

    def run_wave(self, backend, states, timeout_s=None):
        import threading

        timeout = timeout_s if timeout_s is not None else self.timeout_s
        box: list[Optional[LaneResult]] = [None] * len(states)

        def lane(i: int, s: State) -> None:
            t0 = time.perf_counter()
            try:
                c = backend.cost(s)
                box[i] = LaneResult(cost=c, wall_s=time.perf_counter() - t0)
            except BaseException as e:  # noqa: BLE001 — lane isolation
                box[i] = LaneResult(
                    cost=math.inf,
                    wall_s=time.perf_counter() - t0,
                    error=f"{type(e).__name__}: {e}",
                )

        threads = [
            threading.Thread(
                target=lane, args=(i, s), daemon=True, name=f"measure-lane-{i}"
            )
            for i, s in enumerate(states)
        ]
        t_start = time.perf_counter()
        for t in threads:
            t.start()
        results: list[LaneResult] = []
        for i, t in enumerate(threads):
            remaining = (
                None
                if timeout is None
                else max(0.0, t_start + timeout - time.perf_counter())
            )
            t.join(remaining)
            if t.is_alive():  # abandoned: its eventual box write is dropped
                results.append(
                    LaneResult(
                        cost=math.inf,
                        wall_s=time.perf_counter() - t_start,
                        error=f"lane timeout after {timeout:g}s",
                    )
                )
            else:
                results.append(box[i])
        return results


def _worker_main(conn) -> None:
    """Measurement worker loop: rebuild backends from specs (cached per
    spec — so a backend's warm executable cache survives across jobs),
    measure one state per job, report ``("ok", cost, wall, compile_delta)``
    or ``("err", message)``.  ``compile_delta`` is the job's increment of
    ``backend.compile_stats()`` (None for backends without a build step)
    so the engine can attribute compile-cache hits across the process
    boundary.  Runs until the sentinel ``None`` or parent death."""
    backends: dict = {}
    while True:
        try:
            job = conn.recv()
        except (EOFError, OSError):
            return
        if job is None:
            return
        if job == "ping":  # liveness probe (see ProcessExecutor.warm_up)
            conn.send("pong")
            continue
        spec, state_lists = job
        backend, before = None, None
        try:
            key = repr(spec)
            backend = backends.get(key)
            if backend is None:
                backend = backends[key] = backend_from_spec(spec)
            before = backend.compile_stats()
            t0 = time.perf_counter()
            # the state class is op-specific: the rebuilt backend's space
            # owns the deserialization (operator-agnostic lane protocol)
            cost = backend.cost(backend.space.state_from_lists(state_lists))
            wall = time.perf_counter() - t0
            conn.send(("ok", cost, wall, _compile_delta(backend, before)))
        except BaseException as e:  # noqa: BLE001 — the worker must survive
            try:
                # compile work paid before the failure still gets
                # attributed (a raised measurement is not free)
                conn.send(
                    ("err", f"{type(e).__name__}: {e}",
                     _compile_delta(backend, before))
                )
            except (BrokenPipeError, OSError):
                return


def _compile_delta(backend, before) -> Optional[dict]:
    """Increment of ``backend.compile_stats()`` since ``before`` (None
    for backends without a build step or when stats are unreadable)."""
    if backend is None or before is None:
        return None
    try:
        after = backend.compile_stats()
        if after is None:
            return None
        return {k: after[k] - before.get(k, 0) for k in after}
    except Exception:  # noqa: BLE001 — attribution must never kill a job
        return None


class _Worker:
    """One lane: a persistent process plus its duplex pipe."""

    def __init__(self, ctx):
        self.conn, child = ctx.Pipe()
        self.proc = ctx.Process(target=_worker_main, args=(child,), daemon=True)
        self.proc.start()
        child.close()  # parent keeps only its end

    def alive(self) -> bool:
        return self.proc.is_alive()

    def kill(self) -> None:
        try:
            self.proc.terminate()
            self.proc.join(timeout=2.0)
        except (ValueError, OSError):
            pass
        self.conn.close()

    def stop(self) -> None:
        """Graceful: sentinel, short join, then terminate."""
        try:
            self.conn.send(None)
        except (BrokenPipeError, OSError):
            pass
        self.proc.join(timeout=2.0)
        if self.proc.is_alive():
            self.proc.terminate()
            self.proc.join(timeout=2.0)
        self.conn.close()


class ProcessExecutor(LaneExecutor):
    """Persistent worker-process lanes with per-lane timeouts and crash
    isolation (TVM's measure-worker pattern, pipes instead of RPC).

    Requires ``backend.worker_spec()`` — the backend is rebuilt inside
    each worker, never pickled.  ``mp_context`` defaults to
    ``forkserver`` where available (workers fork from a clean server
    process: no ``__main__`` re-import, and safe once JAX/XLA threads
    exist in the parent — which plain ``fork`` is not), falling back to
    ``spawn`` elsewhere.
    """

    name = "process"
    real_time = True

    def __init__(
        self,
        timeout_s: Optional[float] = 60.0,
        mp_context: Optional[str] = None,
        spawn_timeout_s: float = 120.0,
    ):
        self.timeout_s = timeout_s  # per-lane kill timeout; None = wait forever
        self.spawn_timeout_s = spawn_timeout_s
        if mp_context is None:
            methods = multiprocessing.get_all_start_methods()
            mp_context = "forkserver" if "forkserver" in methods else "spawn"
        self._ctx = multiprocessing.get_context(mp_context)
        self._workers: list[_Worker] = []

    def _ensure_workers(self, n: int) -> None:
        """Reap dead workers and spawn up to ``n``, blocking until fresh
        ones answer a liveness ping — interpreter start-up and repro
        imports must never count against a lane's measurement timeout."""
        self._workers = [w for w in self._workers if w.alive()]
        fresh: list[_Worker] = []
        while len(self._workers) < n:
            w = _Worker(self._ctx)
            self._workers.append(w)
            fresh.append(w)
        for w in fresh:
            try:
                w.conn.send("ping")
            except (BrokenPipeError, OSError):
                pass
        deadline = time.perf_counter() + self.spawn_timeout_s
        for w in fresh:
            try:
                if w.conn.poll(max(0.0, deadline - time.perf_counter())):
                    w.conn.recv()
            except (EOFError, OSError):
                pass  # dead at birth: run_wave resolves its lane to inf

    def run_wave(self, backend, states, timeout_s=None):
        spec = backend.worker_spec()
        if spec is None:
            raise ValueError(
                f"backend {backend.name!r} has no worker_spec(); "
                "ProcessExecutor needs a process-shippable backend recipe "
                "(use ThreadExecutor or SimulatedExecutor instead)"
            )
        timeout = timeout_s if timeout_s is not None else self.timeout_s
        self._ensure_workers(len(states))
        lanes = self._workers[: len(states)]
        sent_t: list[float] = []
        dead_on_send: set[int] = set()
        for i, (w, s) in enumerate(zip(lanes, states)):
            try:
                w.conn.send((spec, s.as_lists()))
            except (BrokenPipeError, OSError):
                dead_on_send.add(i)
            sent_t.append(time.perf_counter())
        results: list[LaneResult] = []
        for i, w in enumerate(lanes):
            if i in dead_on_send:
                w.kill()
                results.append(
                    LaneResult(cost=math.inf, error="worker died before dispatch")
                )
                continue
            remaining = (
                None
                if timeout is None
                else max(0.0, sent_t[i] + timeout - time.perf_counter())
            )
            try:
                if not w.conn.poll(remaining):
                    w.kill()
                    results.append(
                        LaneResult(
                            cost=math.inf,
                            wall_s=time.perf_counter() - sent_t[i],
                            error=f"lane timeout after {timeout:g}s (worker killed)",
                        )
                    )
                    continue
                msg = w.conn.recv()
            except (EOFError, OSError):
                w.kill()
                results.append(
                    LaneResult(
                        cost=math.inf,
                        wall_s=time.perf_counter() - sent_t[i],
                        error="worker crashed mid-measurement",
                    )
                )
                continue
            if msg[0] == "ok":
                results.append(
                    LaneResult(
                        cost=msg[1],
                        wall_s=msg[2],
                        compile=msg[3] if len(msg) > 3 else None,
                    )
                )
            else:
                results.append(
                    LaneResult(
                        cost=math.inf,
                        wall_s=time.perf_counter() - sent_t[i],
                        error=msg[1],
                        compile=msg[2] if len(msg) > 2 else None,
                    )
                )
        return results

    def warm_up(self, n_lanes: int) -> None:
        """Pre-spawn ``n_lanes`` ready workers so not even the *first*
        wave's wall-clock includes process start-up (``run_wave`` already
        excludes start-up from lane timeouts via ``_ensure_workers``)."""
        self._ensure_workers(n_lanes)

    def close(self) -> None:
        workers, self._workers = self._workers, []
        for w in workers:
            if w.alive():
                w.stop()
            else:
                w.kill()


EXECUTORS = {
    "sim": SimulatedExecutor,
    "thread": ThreadExecutor,
    "process": ProcessExecutor,
}


def make_executor(name: str, **kwargs) -> LaneExecutor:
    """Build a lane executor by CLI name (``sim``/``thread``/``process``)."""
    try:
        cls = EXECUTORS[name]
    except KeyError:
        raise ValueError(f"unknown executor {name!r}; pick from {sorted(EXECUTORS)}")
    return cls(**kwargs)

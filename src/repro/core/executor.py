"""Measurement lane executors — how a wave of candidate states actually
runs.

PR 1 gave :class:`~repro.core.measure.MeasureEngine` ``n_workers``
*simulated* lanes: the search clock compresses by the wave critical
path, but the backend work itself still runs in the calling thread.
This module makes the lane a pluggable boundary, the way TVM's tuners
ship measurement batches to an RPC/executor pool:

* :class:`SimulatedExecutor` — the PR-1 semantics, bit for bit: a
  single-miss wave takes the backend's scalar ``cost`` path, a
  multi-miss wave takes ``batch_cost``, nothing leaves the calling
  thread, and lane occupancy is *modeled* (overhead + capped runtime).
  This is the default and keeps every ``--workers 1`` parity guarantee.
* :class:`ThreadExecutor` — each lane is a thread running
  ``backend.cost``; real wall-clock overlap for backends that release
  the GIL (XLA compile/execute, sleeps).  A lane that raises is an
  ``inf``-cost outcome; a lane that exceeds the timeout is abandoned
  (the thread cannot be killed — it keeps running detached, which is
  why crash-grade isolation needs processes).

Real executors own their **kill timeout** (``timeout_s``, default 60 s):
it bounds how long a lane may *really* run before being abandoned or
killed.  This is deliberately distinct from ``MeasureEngine.timeout_s``,
which is the simulated clock's AutoTVM-style *charging cap* — a slow
config charges at most that much search clock, it is never killed for
it.  Conflating the two would kill every legitimately slow real
measurement (an XLA compile easily outlives a 4 s charging cap).
* :class:`ProcessExecutor` — each lane is a persistent worker *process*
  fed ``(backend_spec, state)`` jobs over a pipe.  The backend is
  rebuilt worker-side from ``CostBackend.worker_spec()`` and cached
  per spec, so per-job cost is one pipe round-trip.  A worker that
  raises reports the error and lives on; a worker that dies (segfault,
  ``os._exit``, OOM-kill) or blows the per-lane timeout is reaped and
  respawned, and its lane resolves to ``inf`` — a backend crash can no
  longer take down the tuning session.

Executors with ``real_time = True`` report *measured* per-lane wall
seconds; the engine charges those to the search clock instead of the
simulated occupancy model, so benchmark speedups separate clock
compression (simulated) from genuine parallel measurement (real).
"""

from __future__ import annotations

import abc
import dataclasses
import math
import multiprocessing
import time
from typing import Optional, Sequence

from .cost.base import CostBackend, backend_from_spec
from .space import State

__all__ = [
    "LaneExecutor",
    "LaneResult",
    "SimulatedExecutor",
    "ThreadExecutor",
    "ProcessExecutor",
    "EXECUTORS",
    "make_executor",
]


@dataclasses.dataclass
class LaneResult:
    """What one measurement lane hands back for one state."""

    cost: float
    wall_s: float = 0.0  # measured lane wall time (0 under simulation)
    error: Optional[str] = None  # crash / timeout / raised-exception note
    #: build-cache counter delta this job incurred worker-side (process
    #: lanes only — in-process executors let the engine read the backend
    #: directly); see ``CostBackend.compile_stats``.
    compile: Optional[dict] = None
    #: failure taxonomy (see ``repro.core.fault``): ``"crash"`` /
    #: ``"timeout"`` / ``"spawn"`` are transient (retry-able), ``"raise"``
    #: is permanent.  ``None`` on success; executors that only set
    #: ``error`` are classified by the engine via ``classify_error``.
    kind: Optional[str] = None


class LaneExecutor(abc.ABC):
    """Runs the cache-miss portion of one measurement wave."""

    name: str = "base"
    #: True when ``LaneResult.wall_s`` is measured wall-clock the engine
    #: should charge, False when occupancy must come from the clock model.
    real_time: bool = False

    @abc.abstractmethod
    def run_wave(
        self,
        backend: CostBackend,
        states: Sequence[State],
        timeout_s: Optional[float] = None,
    ) -> list[LaneResult]:
        """Measure ``states`` (one per lane); results align with input."""

    def close(self) -> None:
        """Release lanes (threads/processes). Idempotent."""

    def __enter__(self) -> "LaneExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class SimulatedExecutor(LaneExecutor):
    """The historical in-thread path: scalar ``cost`` for single-miss
    waves (n_workers=1 parity), ``batch_cost`` otherwise.  A backend
    exception is isolated per lane as a ``kind="raise"`` result rather
    than unwinding the whole tuning session — the batched path falls
    back to per-state scalar calls to attribute the raise (legal because
    ``batch_cost(states)[i] == cost(states[i])`` by contract)."""

    name = "sim"
    real_time = False

    def _lane(self, backend, s) -> LaneResult:
        try:
            return LaneResult(cost=backend.cost(s))
        except BaseException as e:  # noqa: BLE001 — lane isolation
            return LaneResult(
                cost=math.inf, error=f"{type(e).__name__}: {e}", kind="raise"
            )

    def run_wave(self, backend, states, timeout_s=None):
        if len(states) == 1:
            return [self._lane(backend, states[0])]
        try:
            costs = list(backend.batch_cost(states))
        except BaseException:  # noqa: BLE001 — re-run per lane to attribute
            return [self._lane(backend, s) for s in states]
        return [LaneResult(cost=c) for c in costs]


class ThreadExecutor(LaneExecutor):
    """One daemon thread per lane (waves are measurement-bound, so
    per-wave thread spawn is noise).  Real overlap only where the
    backend drops the GIL; a timed-out lane is abandoned — daemon
    threads mean an abandoned lane can never block interpreter
    shutdown the way a ThreadPoolExecutor's atexit join would."""

    name = "thread"
    real_time = True

    def __init__(self, timeout_s: Optional[float] = 60.0):
        self.timeout_s = timeout_s  # kill timeout; None = never abandon

    def run_wave(self, backend, states, timeout_s=None):
        import threading

        timeout = timeout_s if timeout_s is not None else self.timeout_s
        box: list[Optional[LaneResult]] = [None] * len(states)

        def lane(i: int, s: State) -> None:
            t0 = time.perf_counter()
            try:
                c = backend.cost(s)
                box[i] = LaneResult(cost=c, wall_s=time.perf_counter() - t0)
            except BaseException as e:  # noqa: BLE001 — lane isolation
                box[i] = LaneResult(
                    cost=math.inf,
                    wall_s=time.perf_counter() - t0,
                    error=f"{type(e).__name__}: {e}",
                    kind="raise",
                )

        threads = [
            threading.Thread(
                target=lane, args=(i, s), daemon=True, name=f"measure-lane-{i}"
            )
            for i, s in enumerate(states)
        ]
        t_start = time.perf_counter()
        for t in threads:
            t.start()
        results: list[LaneResult] = []
        for i, t in enumerate(threads):
            remaining = (
                None
                if timeout is None
                else max(0.0, t_start + timeout - time.perf_counter())
            )
            t.join(remaining)
            if t.is_alive():  # abandoned: its eventual box write is dropped
                results.append(
                    LaneResult(
                        cost=math.inf,
                        wall_s=time.perf_counter() - t_start,
                        error=f"lane timeout after {timeout:g}s",
                        kind="timeout",
                    )
                )
            else:
                results.append(box[i])
        return results


def _worker_main(conn) -> None:
    """Measurement worker loop: rebuild backends from specs (cached per
    spec — so a backend's warm executable cache survives across jobs),
    measure one state per job, report ``("ok", cost, wall, compile_delta)``
    or ``("err", message)``.  ``compile_delta`` is the job's increment of
    ``backend.compile_stats()`` (None for backends without a build step)
    so the engine can attribute compile-cache hits across the process
    boundary.  Runs until the sentinel ``None`` or parent death."""
    backends: dict = {}
    while True:
        try:
            job = conn.recv()
        except (EOFError, OSError):
            return
        if job is None:
            return
        if job == "ping":  # liveness probe (see ProcessExecutor.warm_up)
            conn.send("pong")
            continue
        if job[0] == "prewarm":
            # build the backend ahead of the first measurement so lane
            # wall-clocks never include the worker's jax import + backend
            # construction (see ProcessExecutor.warm_up(backend=...))
            try:
                key = repr(job[1])
                if key not in backends:
                    backends[key] = backend_from_spec(job[1])
            except BaseException:  # noqa: BLE001 — surface it on the real job
                pass
            conn.send("prewarmed")
            continue
        spec, state_lists = job
        backend, before = None, None
        try:
            key = repr(spec)
            backend = backends.get(key)
            if backend is None:
                backend = backends[key] = backend_from_spec(spec)
            before = backend.compile_stats()
            t0 = time.perf_counter()
            # the state class is op-specific: the rebuilt backend's space
            # owns the deserialization (operator-agnostic lane protocol)
            cost = backend.cost(backend.space.state_from_lists(state_lists))
            wall = time.perf_counter() - t0
            conn.send(("ok", cost, wall, _compile_delta(backend, before)))
        except BaseException as e:  # noqa: BLE001 — the worker must survive
            try:
                # compile work paid before the failure still gets
                # attributed (a raised measurement is not free)
                conn.send(
                    ("err", f"{type(e).__name__}: {e}",
                     _compile_delta(backend, before))
                )
            except (BrokenPipeError, OSError):
                return


def _compile_delta(backend, before) -> Optional[dict]:
    """Increment of ``backend.compile_stats()`` since ``before`` (None
    for backends without a build step or when stats are unreadable)."""
    if backend is None or before is None:
        return None
    try:
        after = backend.compile_stats()
        if after is None:
            return None
        return {k: after[k] - before.get(k, 0) for k in after}
    except Exception:  # noqa: BLE001 — attribution must never kill a job
        return None


class _Worker:
    """One lane: a persistent process plus its duplex pipe."""

    def __init__(self, ctx):
        self.conn, child = ctx.Pipe()
        self.proc = ctx.Process(target=_worker_main, args=(child,), daemon=True)
        self.proc.start()
        child.close()  # parent keeps only its end

    def alive(self) -> bool:
        return self.proc.is_alive()

    def kill(self) -> None:
        # idempotent: a lane may be killed at timeout AND reaped again
        # by the next wave's _ensure_workers
        try:
            self.proc.terminate()
            self.proc.join(timeout=2.0)
        except (ValueError, OSError):
            pass
        try:
            self.conn.close()
        except OSError:
            pass

    def stop(self) -> None:
        """Graceful: sentinel, short join, then terminate."""
        try:
            self.conn.send(None)
        except (BrokenPipeError, OSError):
            pass
        self.proc.join(timeout=2.0)
        if self.proc.is_alive():
            self.proc.terminate()
            self.proc.join(timeout=2.0)
        self.conn.close()


class ProcessExecutor(LaneExecutor):
    """Persistent worker-process lanes with per-lane timeouts and crash
    isolation (TVM's measure-worker pattern, pipes instead of RPC).

    Requires ``backend.worker_spec()`` — the backend is rebuilt inside
    each worker, never pickled.  ``mp_context`` defaults to
    ``forkserver`` where available (workers fork from a clean server
    process: no ``__main__`` re-import, and safe once JAX/XLA threads
    exist in the parent — which plain ``fork`` is not), falling back to
    ``spawn`` elsewhere.
    """

    name = "process"
    real_time = True

    def __init__(
        self,
        timeout_s: Optional[float] = 60.0,
        mp_context: Optional[str] = None,
        spawn_timeout_s: float = 120.0,
        max_respawns: int = 3,
        respawn_backoff_s: float = 0.05,
    ):
        self.timeout_s = timeout_s  # per-lane kill timeout; None = wait forever
        self.spawn_timeout_s = spawn_timeout_s
        # per-lane-slot respawn budget: after ``max_respawns`` worker
        # deaths a slot stops burning processes and degrades to the
        # in-thread (ThreadExecutor) path for the rest of the run — a
        # deterministic crasher must not respawn forever, once per wave
        self.max_respawns = max(0, int(max_respawns))
        self.respawn_backoff_s = respawn_backoff_s
        if mp_context is None:
            methods = multiprocessing.get_all_start_methods()
            mp_context = "forkserver" if "forkserver" in methods else "spawn"
        self._ctx = multiprocessing.get_context(mp_context)
        # positional lane slots: slot i keeps its respawn count across
        # worker generations (None = never spawned, or degraded)
        self._workers: list[Optional[_Worker]] = []
        self._respawns: list[int] = []
        self._degraded: set[int] = set()
        self.n_respawns = 0  # lifetime worker respawns (all slots)
        self.n_spare_adoptions = 0  # deaths absorbed by a warm spare

    def fault_stats(self) -> dict:
        """Lifetime hardening counters; the engine snapshot-diffs these
        per wave into :class:`~repro.core.measure.MeasureStats`."""
        return {
            "n_respawns": self.n_respawns,
            "n_degraded_lanes": len(self._degraded),
            "n_spare_adoptions": self.n_spare_adoptions,
        }

    def _ensure_workers(self, n: int) -> None:
        """Reap dead workers and (re)spawn slots up to ``n``, blocking
        until fresh ones answer a liveness ping — interpreter start-up
        and repro imports must never count against a lane's measurement
        timeout.  Each observed worker death consumes one respawn from
        its slot's budget, with exponential backoff between respawns;
        a slot whose budget is exhausted is degraded (logged once) and
        served in-thread by ``run_wave`` from then on."""
        while len(self._workers) < n:
            self._workers.append(None)
            self._respawns.append(0)
        fresh: list[_Worker] = []
        for i in range(n):
            if i in self._degraded:
                continue
            w = self._workers[i]
            if w is not None and w.alive():
                continue
            if w is not None:
                # an observed death: reap it and charge the slot budget
                w.kill()
                self._workers[i] = None
                self._respawns[i] += 1
                self.n_respawns += 1
                if self._respawns[i] > self.max_respawns:
                    self._degraded.add(i)
                    print(
                        f"[executor] lane {i}: worker died "
                        f"{self._respawns[i]} times (respawn budget "
                        f"{self.max_respawns} exhausted); degrading to "
                        "in-thread measurement for the rest of the run"
                    )
                    continue
                # hot-spare adoption: ``warm_up(n_lanes + spares)`` parks
                # warm workers beyond the wave; a dead lane adopts one
                # instantly instead of paying a cold interpreter start-up
                # on the respawn path (the death still charges the budget)
                for j in range(n, len(self._workers)):
                    cand = self._workers[j]
                    if j not in self._degraded and cand is not None and cand.alive():
                        self._workers[i] = cand
                        self._workers[j] = None
                        self.n_spare_adoptions += 1
                        break
                if self._workers[i] is not None:
                    continue
                if self.respawn_backoff_s > 0:
                    time.sleep(
                        self.respawn_backoff_s * (2.0 ** (self._respawns[i] - 1))
                    )
            self._workers[i] = w2 = _Worker(self._ctx)
            fresh.append(w2)
        for w in fresh:
            try:
                w.conn.send("ping")
            except (BrokenPipeError, OSError):
                pass
        deadline = time.perf_counter() + self.spawn_timeout_s
        for w in fresh:
            try:
                if w.conn.poll(max(0.0, deadline - time.perf_counter())):
                    w.conn.recv()
            except (EOFError, OSError):
                pass  # dead at birth: run_wave resolves its lane to inf

    def run_wave(self, backend, states, timeout_s=None):
        import threading

        spec = backend.worker_spec()
        if spec is None:
            raise ValueError(
                f"backend {backend.name!r} has no worker_spec(); "
                "ProcessExecutor needs a process-shippable backend recipe "
                "(use ThreadExecutor or SimulatedExecutor instead)"
            )
        timeout = timeout_s if timeout_s is not None else self.timeout_s
        self._ensure_workers(len(states))
        results: list[Optional[LaneResult]] = [None] * len(states)

        # degraded slots run the ThreadExecutor path on the engine-side
        # backend, overlapping the process lanes dispatched below
        def deg_lane(box: list, s: State, t0: float) -> None:
            try:
                c = backend.cost(s)
                box[0] = LaneResult(cost=c, wall_s=time.perf_counter() - t0)
            except BaseException as e:  # noqa: BLE001 — lane isolation
                box[0] = LaneResult(
                    cost=math.inf,
                    wall_s=time.perf_counter() - t0,
                    error=f"{type(e).__name__}: {e}",
                    kind="raise",
                )

        deg: dict[int, tuple] = {}
        for i, s in enumerate(states):
            if i in self._degraded:
                box: list = [None]
                t0 = time.perf_counter()
                th = threading.Thread(
                    target=deg_lane, args=(box, s, t0), daemon=True,
                    name=f"degraded-lane-{i}",
                )
                th.start()
                deg[i] = (th, box, t0)
        sent_t: list[float] = [0.0] * len(states)
        dead_on_send: set[int] = set()
        for i, s in enumerate(states):
            if i in deg:
                continue
            w = self._workers[i]
            if w is None:
                dead_on_send.add(i)
                sent_t[i] = time.perf_counter()
                continue
            try:
                w.conn.send((spec, s.as_lists()))
            except (BrokenPipeError, OSError):
                dead_on_send.add(i)
            sent_t[i] = time.perf_counter()
        for i in range(len(states)):
            if i in deg:
                continue
            w = self._workers[i]
            if i in dead_on_send:
                if w is not None:
                    w.kill()
                results[i] = LaneResult(
                    cost=math.inf,
                    error="worker died before dispatch",
                    kind="spawn",
                )
                continue
            remaining = (
                None
                if timeout is None
                else max(0.0, sent_t[i] + timeout - time.perf_counter())
            )
            try:
                if not w.conn.poll(remaining):
                    w.kill()
                    results[i] = LaneResult(
                        cost=math.inf,
                        wall_s=time.perf_counter() - sent_t[i],
                        error=f"lane timeout after {timeout:g}s (worker killed)",
                        kind="timeout",
                    )
                    continue
                msg = w.conn.recv()
            except (EOFError, OSError):
                w.kill()
                results[i] = LaneResult(
                    cost=math.inf,
                    wall_s=time.perf_counter() - sent_t[i],
                    error="worker crashed mid-measurement",
                    kind="crash",
                )
                continue
            if msg[0] == "ok":
                results[i] = LaneResult(
                    cost=msg[1],
                    wall_s=msg[2],
                    compile=msg[3] if len(msg) > 3 else None,
                )
            else:
                results[i] = LaneResult(
                    cost=math.inf,
                    wall_s=time.perf_counter() - sent_t[i],
                    error=msg[1],
                    kind="raise",
                    compile=msg[2] if len(msg) > 2 else None,
                )
        for i, (th, box, t0) in deg.items():
            remaining = (
                None
                if timeout is None
                else max(0.0, t0 + timeout - time.perf_counter())
            )
            th.join(remaining)
            if th.is_alive():  # abandoned, same as ThreadExecutor
                results[i] = LaneResult(
                    cost=math.inf,
                    wall_s=time.perf_counter() - t0,
                    error=f"lane timeout after {timeout:g}s (degraded lane)",
                    kind="timeout",
                )
            else:
                results[i] = box[0]
        return results

    def warm_up(self, n_lanes: int, backend=None) -> None:
        """Pre-spawn ``n_lanes`` ready workers so not even the *first*
        wave's wall-clock includes process start-up (``run_wave`` already
        excludes start-up from lane timeouts via ``_ensure_workers``).

        With ``backend``, each worker also pre-builds the backend from
        its ``worker_spec()`` — the worker-side jax import, backend
        construction, and persistent-cache open all happen here instead
        of inside the first measurement wave.  Spawning more lanes than
        the wave width parks warm spares that dead lanes adopt instantly
        (see ``_ensure_workers``)."""
        self._ensure_workers(n_lanes)
        if backend is None:
            return
        spec = backend.worker_spec()
        if spec is None:
            return
        warmed: list[_Worker] = []
        for w in self._workers[:n_lanes]:
            if w is None or not w.alive():
                continue
            try:
                w.conn.send(("prewarm", spec))
                warmed.append(w)
            except (BrokenPipeError, OSError):
                pass
        deadline = time.perf_counter() + self.spawn_timeout_s
        for w in warmed:
            try:
                if w.conn.poll(max(0.0, deadline - time.perf_counter())):
                    w.conn.recv()
            except (EOFError, OSError):
                pass  # dead during prewarm: run_wave resolves it later

    def close(self) -> None:
        workers, self._workers = self._workers, []
        self._respawns = []
        for w in workers:
            if w is None:
                continue
            if w.alive():
                w.stop()
            else:
                w.kill()


EXECUTORS = {
    "sim": SimulatedExecutor,
    "thread": ThreadExecutor,
    "process": ProcessExecutor,
}


def make_executor(name: str, **kwargs) -> LaneExecutor:
    """Build a lane executor by CLI name (``sim``/``thread``/``process``)."""
    try:
        cls = EXECUTORS[name]
    except KeyError:
        raise ValueError(f"unknown executor {name!r}; pick from {sorted(EXECUTORS)}")
    return cls(**kwargs)

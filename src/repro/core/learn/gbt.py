"""Gradient-boosted-tree machinery shared by the SMBO tuner and the
learned cost model.

The container has no xgboost package, so the boosters here are
implemented from scratch in numpy: depth-limited regression trees fit
with a vectorized SSE split search, combined by shrinkage.  Two losses
share the tree fitter:

* :class:`GradientBoostedTrees` — squared loss on absolute targets, the
  surrogate :class:`~repro.core.tuners.gbt.GBTTuner` refits every SMBO
  round (lifted out of ``tuners/gbt.py``; the old import path re-exports
  it).
* :class:`PairwiseRankGBT` — a pairwise logistic *rank* objective (the
  LambdaMART/"Learning to Optimize Tensor Programs" recipe): only the
  relative order of costs *within a group* (one workload shape) enters
  the loss, so corpora from different shapes — whose absolute runtimes
  differ by orders of magnitude — train one transferable model without
  any per-shape normalization.

Both boosters are deterministic: tree fitting uses stable sorts and the
rank loss pairs each sample with fixed neighbor offsets in the
within-group cost order instead of sampling pairs with an RNG, so a
retrain over the same corpus reproduces the same model bit-for-bit.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "GradientBoostedTrees",
    "PairwiseRankGBT",
    "tree_to_jsonable",
    "tree_from_jsonable",
]


class _Tree:
    __slots__ = ("feature", "threshold", "left", "right", "value")

    def __init__(self):
        self.feature = -1
        self.threshold = 0.0
        self.left = None
        self.right = None
        self.value = 0.0


def _fit_tree(X: np.ndarray, y: np.ndarray, depth: int, min_samples: int) -> _Tree:
    node = _Tree()
    node.value = float(y.mean())
    if depth == 0 or len(y) < 2 * min_samples or np.allclose(y, y[0]):
        return node
    best_gain, best = 0.0, None
    n, f = X.shape
    parent_sse = float(((y - y.mean()) ** 2).sum())
    idx = np.arange(1, n, dtype=np.float64)
    for j in range(f):
        xs = X[:, j]
        order = np.argsort(xs, kind="stable")
        xs_s, ys_s = xs[order], y[order]
        cums = np.cumsum(ys_s)[:-1]
        cums2 = np.cumsum(ys_s**2)[:-1]
        # vectorized SSE for every split position i in [1, n)
        left_n, right_n = idx, n - idx
        sse = (cums2 - cums * cums / left_n) + (
            (cums2[-1] + ys_s[-1] ** 2 - cums2)
            - (cums[-1] + ys_s[-1] - cums) ** 2 / right_n
        )
        valid = (xs_s[1:] != xs_s[:-1]) & (left_n >= min_samples) & (right_n >= min_samples)
        if not valid.any():
            continue
        sse = np.where(valid, sse, np.inf)
        i = int(np.argmin(sse))
        gain = parent_sse - float(sse[i])
        if gain > best_gain + 1e-12:
            best_gain = gain
            best = (j, 0.5 * (xs_s[i + 1] + xs_s[i]))
    if best is None:
        return node
    j, thr = best
    mask = X[:, j] <= thr
    node.feature, node.threshold = j, thr
    node.left = _fit_tree(X[mask], y[mask], depth - 1, min_samples)
    node.right = _fit_tree(X[~mask], y[~mask], depth - 1, min_samples)
    return node


def _tree_predict(node: _Tree, X: np.ndarray) -> np.ndarray:
    if node.feature < 0:
        return np.full(len(X), node.value)
    out = np.empty(len(X))
    mask = X[:, node.feature] <= node.threshold
    out[mask] = _tree_predict(node.left, X[mask]) if mask.any() else 0
    out[~mask] = _tree_predict(node.right, X[~mask]) if (~mask).any() else 0
    return out


def tree_to_jsonable(node: _Tree) -> dict:
    """Recursive plain-dict form of one fitted tree (for the versioned
    model cache next to the journal — see ``learn.model``)."""
    if node.feature < 0:
        return {"v": node.value}
    return {
        "f": node.feature,
        "t": node.threshold,
        "v": node.value,
        "l": tree_to_jsonable(node.left),
        "r": tree_to_jsonable(node.right),
    }


def tree_from_jsonable(data: dict) -> _Tree:
    node = _Tree()
    node.value = float(data["v"])
    if "f" in data:
        node.feature = int(data["f"])
        node.threshold = float(data["t"])
        node.left = tree_from_jsonable(data["l"])
        node.right = tree_from_jsonable(data["r"])
    return node


class GradientBoostedTrees:
    """Squared-loss GBT with shrinkage — enough of xgboost for SMBO."""

    def __init__(self, n_trees: int = 50, depth: int = 4, lr: float = 0.2,
                 min_samples: int = 2):
        self.n_trees, self.depth, self.lr = n_trees, depth, lr
        self.min_samples = min_samples
        self.base = 0.0
        self.trees: list[_Tree] = []

    def fit(self, X: np.ndarray, y: np.ndarray) -> "GradientBoostedTrees":
        self.base = float(y.mean())
        self.trees = []
        pred = np.full(len(y), self.base)
        for _ in range(self.n_trees):
            resid = y - pred
            t = _fit_tree(X, resid, self.depth, self.min_samples)
            self.trees.append(t)
            pred = pred + self.lr * _tree_predict(t, X)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        pred = np.full(len(X), self.base)
        for t in self.trees:
            pred = pred + self.lr * _tree_predict(t, X)
        return pred


#: Neighbor offsets in the within-group cost order that form training
#: pairs: each sample is compared against its 1st/2nd/4th/8th-better
#: neighbor.  Local pairs teach fine ranking near the optimum, the
#: longer strides anchor the global order — with no RNG involved.
_PAIR_OFFSETS = (1, 2, 4, 8)


class PairwiseRankGBT:
    """Gradient boosting on a pairwise logistic rank loss.

    ``fit(X, y, groups)`` learns a scalar score that *sorts like* ``y``
    within every group (lower score = lower cost); absolute values carry
    no meaning across groups, which is exactly what makes journal rows
    from different workload shapes one training corpus.  For each pair
    (i better, j worse) the loss is ``log(1 + exp(f_i - f_j))``; each
    round fits a regression tree to the negative gradient via the same
    vectorized tree fitter the squared-loss booster uses.
    """

    def __init__(self, n_trees: int = 60, depth: int = 4, lr: float = 0.2,
                 min_samples: int = 2):
        self.n_trees, self.depth, self.lr = n_trees, depth, lr
        self.min_samples = min_samples
        self.trees: list[_Tree] = []

    @staticmethod
    def _pairs(y: np.ndarray, groups: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Deterministic (better_idx, worse_idx) training pairs: within
        each group, sort by cost and pair each sample with its better
        neighbor at the fixed strides.  Ties produce no pair."""
        better, worse = [], []
        for g in np.unique(groups):
            idx = np.flatnonzero(groups == g)
            if len(idx) < 2:
                continue
            order = idx[np.argsort(y[idx], kind="stable")]
            ys = y[order]
            for off in _PAIR_OFFSETS:
                if off >= len(order):
                    break
                a = order[:-off]  # the better (lower-cost) side
                b = order[off:]
                tie = ys[:-off] == ys[off:]
                better.append(a[~tie])
                worse.append(b[~tie])
        if not better:
            return np.empty(0, np.intp), np.empty(0, np.intp)
        return np.concatenate(better), np.concatenate(worse)

    def fit(self, X: np.ndarray, y: np.ndarray,
            groups: np.ndarray | None = None) -> "PairwiseRankGBT":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        if groups is None:
            groups = np.zeros(len(y), dtype=np.intp)
        bi, wi = self._pairs(y, np.asarray(groups))
        self.trees = []
        if len(bi) == 0:
            return self
        f = np.zeros(len(y))
        for _ in range(self.n_trees):
            # d loss / d f_better = sigma, with sigma -> 0 once the pair
            # is ordered correctly by a margin; residual = -gradient
            sigma = 1.0 / (1.0 + np.exp(np.clip(f[wi] - f[bi], -60, 60)))
            resid = np.zeros(len(y))
            np.subtract.at(resid, bi, sigma)
            np.add.at(resid, wi, sigma)
            t = _fit_tree(X, resid, self.depth, self.min_samples)
            self.trees.append(t)
            f = f + self.lr * _tree_predict(t, X)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        """Scores ascending with cost: lower = predicted better."""
        X = np.asarray(X, dtype=np.float64)
        pred = np.zeros(len(X))
        for t in self.trees:
            pred = pred + self.lr * _tree_predict(t, X)
        return pred

    # -- persistence (see learn.model for the cache layout) ------------------
    def to_jsonable(self) -> dict:
        return {
            "n_trees": self.n_trees,
            "depth": self.depth,
            "lr": self.lr,
            "min_samples": self.min_samples,
            "trees": [tree_to_jsonable(t) for t in self.trees],
        }

    @classmethod
    def from_jsonable(cls, data: dict) -> "PairwiseRankGBT":
        m = cls(
            n_trees=int(data["n_trees"]),
            depth=int(data["depth"]),
            lr=float(data["lr"]),
            min_samples=int(data["min_samples"]),
        )
        m.trees = [tree_from_jsonable(t) for t in data["trees"]]
        return m

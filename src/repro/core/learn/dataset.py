"""Journal-to-corpus assembly for the learned cost model.

A :class:`TrialJournal` file already holds exactly the training set a
rank-based cost model needs: every measured row carries the state's
factor lists (features via ``space.features``), the measured cost, and a
journal key that scopes it to op / dims / dtype / backend / measurement
fingerprint.  :func:`build_dataset` turns one or more journal files into
a :class:`JournalDataset` — an op/dtype/fingerprint-scoped
``(features, log-cost, group)`` corpus where the *group* is the full
journal key (workload shape + measurement settings), i.e. the unit the
pairwise rank loss compares within.  Grouping is what makes the corpus
cross-shape: rows from a 512^3 and a 4096x256 GEMM train one model
without normalizing their incommensurable absolute runtimes.

Excluded from training, but counted for observability (the analyze CLI
prints these per op/dtype so users can tell when a workload has enough
data to train on):

* fail rows (``c=null, fail=true``) — permanent or transient, neither
  carries a runtime to rank against;
* static audit rows (``"static"``) — pruned, never measured;
* predicted rows (``"pred"``) — the learned filter's own skip
  provenance; training on them would be feedback, not data.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from ..records import iter_journal_rows, parse_workload_key_generic
from ..space import state_from_lists

__all__ = ["CorpusCounts", "JournalDataset", "build_dataset", "scan_corpus"]


@dataclasses.dataclass
class CorpusCounts:
    """Row census of one corpus scope (or one op/dtype in a scan)."""

    n_trainable: int = 0  # finite measured rows that entered the corpus
    n_fail: int = 0  # failure rows (permanent + transient)
    n_static: int = 0  # analyzer audit rows
    n_predicted: int = 0  # learned-filter skip provenance rows
    n_duplicate: int = 0  # repeat (workload, state) measurements
    n_foreign: int = 0  # out of scope: other op/dtype/fingerprint, malformed
    n_incompatible: int = 0  # in scope but feature width differs (depths)

    @property
    def n_rows(self) -> int:
        return (
            self.n_trainable + self.n_fail + self.n_static + self.n_predicted
            + self.n_duplicate + self.n_foreign + self.n_incompatible
        )


def _row_category(row: dict) -> str:
    """Schema triage shared with the audit CLI: measured / fail /
    static / pred.  Order matters — ``static`` and ``pred`` rows also
    have ``c=null`` and must not read as failures."""
    if "static" in row:
        return "static"
    if "pred" in row:
        return "pred"
    if row.get("fail") or row.get("c") is None:
        return "fail"
    return "measured"


@dataclasses.dataclass
class JournalDataset:
    """One training corpus: features, log-costs, and rank groups.

    ``groups[i]`` indexes ``group_keys`` — the full journal key
    (``workload?fingerprint``) row ``i`` was measured under.  The rank
    objective only compares rows within one group."""

    op: str
    dtype: Optional[str]
    fingerprint: Optional[str]
    n_features: int
    X: np.ndarray  # (n, n_features) float32
    y: np.ndarray  # (n,) float64 — log cost
    groups: np.ndarray  # (n,) intp
    group_keys: list[str]
    counts: CorpusCounts

    def __len__(self) -> int:
        return len(self.y)

    @property
    def n_groups(self) -> int:
        return len(self.group_keys)

    def subset(self, mask: np.ndarray) -> "JournalDataset":
        """Row-masked view (group ids are preserved, not renumbered) —
        the held-out-shape split the eval CLI uses."""
        mask = np.asarray(mask, dtype=bool)
        return dataclasses.replace(
            self, X=self.X[mask], y=self.y[mask], groups=self.groups[mask]
        )

    def split_group(self, group: int) -> tuple["JournalDataset", "JournalDataset"]:
        """(train, held-out) leave-one-shape-out split."""
        held = self.groups == group
        return self.subset(~held), self.subset(held)


def _space_for(op: str, dims: tuple[int, ...], depths: tuple[int, ...], cache: dict):
    key = (op, dims, depths)
    sp = cache.get(key)
    if sp is None:
        from ..ops import get_op  # lazy: ops imports cost modules

        sp = get_op(op).make_space(dims, depths)
        cache[key] = sp
    return sp


def build_dataset(
    paths: Sequence[str] | str,
    op: str,
    dtype: Optional[str] = None,
    fingerprint: Optional[str] = None,
) -> JournalDataset:
    """Assemble the ``(features, log-cost, group)`` corpus for one op
    (optionally narrowed to one dtype and one measurement fingerprint)
    from one or more journal files.  Rows outside the scope, duplicate
    measurements, and provenance-only rows are excluded but censused in
    ``counts``."""
    if isinstance(paths, str):
        paths = [paths]
    counts = CorpusCounts()
    feats: list[np.ndarray] = []
    ys: list[float] = []
    gids: list[int] = []
    group_ids: dict[str, int] = {}
    group_keys: list[str] = []
    seen: set[tuple[str, str]] = set()
    space_cache: dict = {}
    n_features: Optional[int] = None
    for path in paths:
        for row in iter_journal_rows(path):
            try:
                jkey, skey, lists = row["w"], row["k"], row["s"]
            except KeyError:
                counts.n_foreign += 1
                continue
            wkey, _, fp = jkey.partition("?")
            parsed = parse_workload_key_generic(wkey)
            if parsed is None:
                counts.n_foreign += 1
                continue
            row_op, dims, row_dtype, _backend = parsed
            if (
                row_op != op
                or row.get("op", "gemm") != op
                or (dtype is not None and row_dtype != dtype)
                or (fingerprint is not None and fp != fingerprint)
            ):
                counts.n_foreign += 1
                continue
            cat = _row_category(row)
            if cat != "measured":
                counts.n_fail += int(cat == "fail")
                counts.n_static += int(cat == "static")
                counts.n_predicted += int(cat == "pred")
                continue
            if (jkey, skey) in seen:
                counts.n_duplicate += 1
                continue
            try:
                c = float(row["c"])
                depths = tuple(len(r) for r in lists)
                sp = _space_for(op, dims, depths, space_cache)
                if n_features is None:
                    n_features = sp.n_features
                elif sp.n_features != n_features:
                    # a different nesting depth means a different feature
                    # width — one model can't consume both
                    counts.n_incompatible += 1
                    continue
                x = sp.features(state_from_lists(op, lists))
            except (KeyError, ValueError, TypeError):
                counts.n_foreign += 1
                continue
            if not (math.isfinite(c) and c > 0.0 and np.isfinite(x).all()):
                counts.n_foreign += 1
                continue
            seen.add((jkey, skey))
            gid = group_ids.setdefault(jkey, len(group_keys))
            if gid == len(group_keys):
                group_keys.append(jkey)
            feats.append(x)
            ys.append(math.log(c))
            gids.append(gid)
            counts.n_trainable += 1
    nf = n_features if n_features is not None else 0
    return JournalDataset(
        op=op,
        dtype=dtype,
        fingerprint=fingerprint,
        n_features=nf,
        X=(np.stack(feats).astype(np.float32) if feats
           else np.empty((0, nf), np.float32)),
        y=np.asarray(ys, dtype=np.float64),
        groups=np.asarray(gids, dtype=np.intp),
        group_keys=group_keys,
        counts=counts,
    )


def scan_corpus(paths: Sequence[str] | str) -> dict[tuple[str, str], CorpusCounts]:
    """Per-(op, dtype) row census across journal files — the analyze
    CLI's corpus-size report (no features computed, just triage)."""
    if isinstance(paths, str):
        paths = [paths]
    out: dict[tuple[str, str], CorpusCounts] = {}
    for path in paths:
        for row in iter_journal_rows(path):
            wkey = str(row.get("w", "")).partition("?")[0]
            parsed = parse_workload_key_generic(wkey)
            if parsed is None:
                continue
            _row_op, _dims, row_dtype, _backend = parsed
            op = row.get("op", "gemm")
            counts = out.setdefault((op, row_dtype), CorpusCounts())
            cat = _row_category(row)
            if cat == "measured":
                counts.n_trainable += 1
            elif cat == "fail":
                counts.n_fail += 1
            elif cat == "static":
                counts.n_static += 1
            else:
                counts.n_predicted += 1
    return out

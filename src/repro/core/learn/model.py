"""The learned cost model: a pairwise-rank GBT scoped to one
op/dtype/fingerprint, with versioned content-keyed persistence next to
the journal.

Persistence mirrors the executable cache (``compile_cache_dir_for``):
models live in a ``<journal>.learncache/`` directory, one JSON file per
*content key* — a hash over the schema version, the model's scope
(op/dtype/fingerprint/feature width), and its hyper-parameters.  A
schema bump, a different measurement fingerprint, or different
hyper-parameters land in a different file, so a stale or foreign model
can never be loaded as this configuration's model; the corpus row count
is stored alongside, so the filter knows whether a cached model is
behind the journal it is filtering for.

Quality is reported the way the transfer literature does: Spearman rank
correlation (the model's job is ordering, not absolute prediction) and
top-k recall (does the predicted top fraction contain the truly best
candidates — exactly what the proposal filter relies on), both computed
per group (= per workload shape) and averaged.
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Optional

import numpy as np

from .dataset import JournalDataset
from .gbt import PairwiseRankGBT

__all__ = [
    "RankingCostModel",
    "learn_cache_dir_for",
    "spearman_rank_corr",
    "top_k_recall",
]

#: Bump on any change to the serialized layout or the feature contract —
#: old cache files simply stop matching their content key.
SCHEMA_VERSION = 1


def learn_cache_dir_for(journal_path: str) -> str:
    """Default location of the persistent learned-model cache: a
    directory next to the :class:`~repro.core.records.TrialJournal`,
    like the compiled-program cache — the journal and every model
    trained from it travel together."""
    return journal_path + ".learncache"


def _ranks(v: np.ndarray) -> np.ndarray:
    """Double-argsort ranks (ties broken by position — both sides of
    the correlation get the same tie policy, which is all Spearman
    needs here)."""
    order = np.argsort(v, kind="stable")
    r = np.empty(len(v))
    r[order] = np.arange(len(v))
    return r


def spearman_rank_corr(
    y_true: np.ndarray, y_pred: np.ndarray, groups: Optional[np.ndarray] = None
) -> float:
    """Per-group Spearman correlation between true costs and predicted
    scores, averaged over groups with >= 3 rows.  NaN when no group is
    big enough to rank."""
    y_true = np.asarray(y_true, dtype=np.float64)
    y_pred = np.asarray(y_pred, dtype=np.float64)
    if groups is None:
        groups = np.zeros(len(y_true), dtype=np.intp)
    vals = []
    for g in np.unique(groups):
        idx = np.flatnonzero(groups == g)
        if len(idx) < 3:
            continue
        rt, rp = _ranks(y_true[idx]), _ranks(y_pred[idx])
        st, sp = rt.std(), rp.std()
        if st == 0.0 or sp == 0.0:
            continue
        vals.append(float(np.mean((rt - rt.mean()) * (rp - rp.mean())) / (st * sp)))
    return float(np.mean(vals)) if vals else float("nan")


def top_k_recall(
    y_true: np.ndarray,
    y_pred: np.ndarray,
    k: int,
    groups: Optional[np.ndarray] = None,
) -> float:
    """Fraction of each group's true best-k found in its predicted
    best-k, averaged over groups with > k rows — the filter's success
    metric (a kept fraction only helps if the real winners are in it)."""
    y_true = np.asarray(y_true, dtype=np.float64)
    y_pred = np.asarray(y_pred, dtype=np.float64)
    if groups is None:
        groups = np.zeros(len(y_true), dtype=np.intp)
    vals = []
    for g in np.unique(groups):
        idx = np.flatnonzero(groups == g)
        if len(idx) <= k:
            continue
        true_top = set(idx[np.argsort(y_true[idx], kind="stable")[:k]].tolist())
        pred_top = set(idx[np.argsort(y_pred[idx], kind="stable")[:k]].tolist())
        vals.append(len(true_top & pred_top) / k)
    return float(np.mean(vals)) if vals else float("nan")


class RankingCostModel:
    """A :class:`PairwiseRankGBT` plus the scope it is valid for.

    ``predict`` returns scores ascending with cost — only the order
    carries meaning.  A model only ever scores candidates whose
    op/dtype/fingerprint/feature-width match its training scope
    (:meth:`compatible_with` enforces this; the proposal filter and the
    eval CLI both go through it)."""

    def __init__(
        self,
        op: str,
        dtype: Optional[str],
        fingerprint: Optional[str],
        n_features: int,
        n_trees: int = 60,
        depth: int = 4,
        lr: float = 0.2,
        min_samples: int = 2,
    ):
        self.op = op
        self.dtype = dtype
        self.fingerprint = fingerprint
        self.n_features = int(n_features)
        self.booster = PairwiseRankGBT(
            n_trees=n_trees, depth=depth, lr=lr, min_samples=min_samples
        )
        self.n_rows_trained = 0  # corpus size at fit time (cache freshness)
        self.n_groups_trained = 0

    # -- training -------------------------------------------------------------
    @classmethod
    def fit_dataset(cls, ds: JournalDataset, **hyper) -> "RankingCostModel":
        m = cls(ds.op, ds.dtype, ds.fingerprint, ds.n_features, **hyper)
        m.booster.fit(ds.X, ds.y, ds.groups)
        m.n_rows_trained = len(ds)
        m.n_groups_trained = ds.n_groups
        return m

    @property
    def is_fitted(self) -> bool:
        return bool(self.booster.trees)

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        if X.ndim != 2 or X.shape[1] != self.n_features:
            raise ValueError(
                f"feature width {X.shape[-1] if X.ndim == 2 else X.shape} "
                f"does not match model's {self.n_features}"
            )
        return self.booster.predict(X)

    def compatible_with(self, op: str, dtype: Optional[str],
                        fingerprint: Optional[str], n_features: int) -> bool:
        return (
            self.op == op
            and (self.dtype is None or dtype is None or self.dtype == dtype)
            and (
                self.fingerprint is None
                or fingerprint is None
                or self.fingerprint == fingerprint
            )
            and self.n_features == int(n_features)
        )

    # -- evaluation -----------------------------------------------------------
    def evaluate(self, ds: JournalDataset, k: int = 8) -> dict:
        """Rank quality on a (held-out) dataset: per-group Spearman and
        top-k recall."""
        if len(ds) == 0:
            return {"n_rows": 0, "rank_corr": float("nan"),
                    "top_k_recall": float("nan"), "k": k}
        pred = self.predict(ds.X)
        return {
            "n_rows": len(ds),
            "n_groups": len(np.unique(ds.groups)),
            "rank_corr": spearman_rank_corr(ds.y, pred, ds.groups),
            "top_k_recall": top_k_recall(ds.y, pred, k, ds.groups),
            "k": k,
        }

    # -- persistence ----------------------------------------------------------
    def content_key(self) -> str:
        """Hash of everything that decides whether a cached model may be
        reused for a given configuration (NOT of the training data: the
        row count is stored in the payload for freshness checks)."""
        h = hashlib.sha256()
        b = self.booster
        ident = json.dumps(
            [
                SCHEMA_VERSION, self.op, self.dtype, self.fingerprint,
                self.n_features, b.n_trees, b.depth, b.lr, b.min_samples,
            ],
            sort_keys=True,
        )
        h.update(ident.encode("utf-8"))
        return h.hexdigest()[:24]

    def cache_path(self, cache_dir: str) -> str:
        return os.path.join(cache_dir, f"rankmodel-{self.content_key()}.json")

    def save(self, cache_dir: str) -> str:
        """Atomic write into the cache directory; returns the path."""
        os.makedirs(cache_dir, exist_ok=True)
        payload = {
            "schema": SCHEMA_VERSION,
            "op": self.op,
            "dtype": self.dtype,
            "fingerprint": self.fingerprint,
            "n_features": self.n_features,
            "n_rows_trained": self.n_rows_trained,
            "n_groups_trained": self.n_groups_trained,
            "booster": self.booster.to_jsonable(),
        }
        path = self.cache_path(cache_dir)
        fd, tmp = tempfile.mkstemp(dir=cache_dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, separators=(",", ":"))
            os.replace(tmp, path)  # atomic publish
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return path

    @classmethod
    def load(cls, path: str) -> Optional["RankingCostModel"]:
        """Load one cache file; None if unreadable or schema-mismatched
        (a missing/foreign model is an expected cache miss, not an
        error)."""
        try:
            with open(path) as f:
                payload = json.load(f)
            if payload.get("schema") != SCHEMA_VERSION:
                return None
            b = payload["booster"]
            m = cls(
                payload["op"], payload["dtype"], payload["fingerprint"],
                payload["n_features"], n_trees=int(b["n_trees"]),
                depth=int(b["depth"]), lr=float(b["lr"]),
                min_samples=int(b["min_samples"]),
            )
            m.booster = PairwiseRankGBT.from_jsonable(b)
            m.n_rows_trained = int(payload.get("n_rows_trained", 0))
            m.n_groups_trained = int(payload.get("n_groups_trained", 0))
            return m
        except (OSError, ValueError, KeyError, TypeError):
            return None

    @classmethod
    def load_for(
        cls,
        cache_dir: str,
        op: str,
        dtype: Optional[str],
        fingerprint: Optional[str],
        n_features: int,
        **hyper,
    ) -> Optional["RankingCostModel"]:
        """Cache lookup by content key: build the identity the caller
        wants, hash it, load that file if present and compatible."""
        probe = cls(op, dtype, fingerprint, n_features, **hyper)
        m = cls.load(probe.cache_path(cache_dir))
        if m is not None and m.compatible_with(op, dtype, fingerprint, n_features):
            return m
        return None

"""The measurement proposal filter: score a wave's candidates with the
learned rank model, really measure only the predicted-best fraction.

This is the measurement-reduction analogue of the static pre-filter
(``MeasureEngine(analyze="prune")``), one stage later in the funnel: the
analyzer rejects *provably broken* schedules for free, the learned
filter skips *predictably slow* legal ones.  The contract mirrors the
static path deliberately —

* a skipped candidate gets an ``inf`` outcome carrying its predicted
  score (``MeasureOutcome.predicted``) and is journaled as a compile-free
  ``{"c": null, "pred": score}`` provenance row that NEVER enters the
  cost table: a later unfiltered run must re-measure it, not cache-hit a
  guess;
* the trial is still charged against the tuner's budget (the tuner
  proposed it; the saving is real measurements, ``stats.n_dispatched``,
  not trial count);
* at least one candidate per wave is always measured, so the search can
  never starve and every wave still feeds the next retrain.

The filter retrains itself mid-search: every ``retrain_every`` waves it
rebuilds the corpus from the journal file (which by then contains the
rows the search itself just measured — including sibling engines' rows,
via the shared journal) and refits once the corpus has grown.  Models
persist content-keyed next to the journal (``<journal>.learncache/``),
so a later session starts filtering from wave one instead of measuring
``min_rows`` candidates first.
"""

from __future__ import annotations

import os
import time
from typing import Optional, Sequence

import numpy as np

from ..records import TrialJournal
from ..space import SearchSpace, State
from .dataset import build_dataset
from .model import RankingCostModel, learn_cache_dir_for

__all__ = ["ProposalFilter"]


class ProposalFilter:
    """Wave-level candidate filter for one workload's engine.

    ``keep`` is the fraction of each wave's cache-missing candidates
    that really reaches a measurement lane (at least 1).  Until the
    journal holds ``min_rows`` trainable rows in this filter's scope the
    filter passes everything through — identical to an unfiltered
    engine."""

    def __init__(
        self,
        space: SearchSpace,
        journal: Optional[TrialJournal],
        dtype: Optional[str] = None,
        fingerprint: Optional[str] = None,
        keep: float = 0.5,
        retrain_every: int = 8,
        min_rows: int = 32,
        cache_dir: Optional[str] = None,
        **hyper,
    ):
        if not (0.0 < keep <= 1.0):
            raise ValueError(f"filter keep fraction must be in (0, 1], got {keep}")
        self.space = space
        self.journal = journal
        self.dtype = dtype
        self.fingerprint = fingerprint
        self.keep = float(keep)
        self.retrain_every = max(1, int(retrain_every))
        self.min_rows = max(2, int(min_rows))
        self.hyper = hyper
        if cache_dir is None and journal is not None and journal.path:
            cache_dir = learn_cache_dir_for(journal.path)
        self.cache_dir = cache_dir
        self.model: Optional[RankingCostModel] = None
        self.n_retrains = 0
        self.learn_s = 0.0  # wall spent scoring + retraining
        self._waves_since_check = None  # None -> check on the first wave
        self._rows_at_fit = 0
        if self.cache_dir is not None:
            cached = RankingCostModel.load_for(
                self.cache_dir, space.op, dtype, fingerprint,
                space.n_features, **hyper,
            )
            if cached is not None and cached.is_fitted:
                self.model = cached
                self._rows_at_fit = cached.n_rows_trained

    @property
    def active(self) -> bool:
        """Whether :meth:`select` can currently drop candidates."""
        return self.model is not None and self.model.is_fitted

    # -- crash-safe resume ----------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-serializable filter state for crash-safe resume: the
        retrain cadence counters plus the fitted model's provenance
        (content key + corpus size).  The model weights themselves are
        NOT serialized — they already persist content-keyed in
        ``cache_dir`` (every refit saves before the next round
        boundary), so the snapshot only has to name the file."""
        return {
            "waves_since_check": self._waves_since_check,
            "rows_at_fit": self._rows_at_fit,
            "n_retrains": self.n_retrains,
            "model_key": None if self.model is None else self.model.content_key(),
            "model_rows": 0 if self.model is None else self.model.n_rows_trained,
        }

    def load_state_dict(self, state: dict) -> None:
        """Restore :meth:`state_dict` output.  Without this, a resumed
        ``--learned-filter on`` run resets ``_waves_since_check`` to
        None (an immediate retrain check on the first resumed wave) and
        loses ``_rows_at_fit``, so it skips different candidates than
        the uninterrupted run — the resume-parity bug this fixes."""
        wsc = state.get("waves_since_check")
        self._waves_since_check = None if wsc is None else int(wsc)
        self._rows_at_fit = int(state.get("rows_at_fit", 0))
        self.n_retrains = int(state.get("n_retrains", 0))
        key = state.get("model_key")
        if key is None:
            self.model = None
            return
        if self.cache_dir is not None:
            cached = RankingCostModel.load(
                os.path.join(self.cache_dir, f"rankmodel-{key}.json")
            )
            if cached is not None and cached.compatible_with(
                self.space.op, self.dtype, self.fingerprint,
                self.space.n_features,
            ):
                self.model = cached

    # -- retraining -----------------------------------------------------------
    def maybe_retrain(self) -> bool:
        """Once per wave: at the cadence, rebuild the corpus from the
        journal file and refit if it grew.  Returns True when a new
        model was fit."""
        if self.journal is None or not self.journal.path:
            return False
        if self._waves_since_check is not None:
            self._waves_since_check += 1
            if self._waves_since_check < self.retrain_every:
                return False
        self._waves_since_check = 0
        t0 = time.perf_counter()
        try:
            ds = build_dataset(
                self.journal.path, self.space.op,
                dtype=self.dtype, fingerprint=self.fingerprint,
            )
            if (
                ds.counts.n_trainable < self.min_rows
                or ds.counts.n_trainable <= self._rows_at_fit
                or ds.n_features != self.space.n_features
            ):
                return False
            model = RankingCostModel.fit_dataset(ds, **self.hyper)
            if not model.is_fitted:
                return False
            self.model = model
            self._rows_at_fit = ds.counts.n_trainable
            self.n_retrains += 1
            if self.cache_dir is not None:
                model.save(self.cache_dir)
            return True
        finally:
            self.learn_s += time.perf_counter() - t0

    # -- selection ------------------------------------------------------------
    def select(
        self, states: Sequence[State]
    ) -> tuple[list[int], list[tuple[int, float]]]:
        """Partition one wave's candidates into (measure, skip).

        Returns ``(kept_indices, [(skipped_index, predicted_score), ...])``
        — both in ascending index order, so the surviving wave keeps the
        engine's deterministic dispatch order.  Scores are the model's
        raw rank outputs (lower = predicted better); they are what the
        skip provenance rows journal."""
        n = len(states)
        if not self.active or n < 2:
            return list(range(n)), []
        n_keep = max(1, int(np.ceil(self.keep * n)))
        if n_keep >= n:
            return list(range(n)), []
        t0 = time.perf_counter()
        X = np.stack([self.space.features(s) for s in states])
        scores = self.model.predict(X)
        self.learn_s += time.perf_counter() - t0
        order = np.argsort(scores, kind="stable")
        kept = sorted(int(i) for i in order[:n_keep])
        skipped = [
            (int(i), float(scores[int(i)])) for i in sorted(order[n_keep:])
        ]
        return kept, skipped

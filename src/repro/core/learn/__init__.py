"""Learned cost-model subsystem: journal-trained ranking models and the
measurement proposal filter.

The tuning stack accumulates exactly the training set a learned cost
model needs — every measurement ever taken, journaled with its state's
factor lists and scoped to op/dtype/backend/measurement-fingerprint.
This package closes the loop (the "Learning to Optimize Tensor
Programs" recipe, see PAPERS.md):

* :mod:`~repro.core.learn.gbt` — the shared gradient-boosted-tree
  machinery (lifted out of ``tuners/gbt.py``) plus the pairwise-rank
  booster;
* :mod:`~repro.core.learn.dataset` — :class:`JournalDataset`, the
  cross-shape ``(features, log-cost, group)`` corpus builder;
* :mod:`~repro.core.learn.model` — :class:`RankingCostModel` with
  content-keyed persistence next to the journal and rank-quality
  metrics (Spearman, top-k recall);
* :mod:`~repro.core.learn.filter` — :class:`ProposalFilter`, the
  :class:`~repro.core.measure.MeasureEngine` stage that measures only
  each wave's predicted-best fraction and journals the rest as
  ``{"c": null, "pred": score}`` provenance rows.
"""

from .dataset import CorpusCounts, JournalDataset, build_dataset, scan_corpus
from .filter import ProposalFilter
from .gbt import GradientBoostedTrees, PairwiseRankGBT
from .model import (
    RankingCostModel,
    learn_cache_dir_for,
    spearman_rank_corr,
    top_k_recall,
)

__all__ = [
    "CorpusCounts",
    "JournalDataset",
    "build_dataset",
    "scan_corpus",
    "ProposalFilter",
    "GradientBoostedTrees",
    "PairwiseRankGBT",
    "RankingCostModel",
    "learn_cache_dir_for",
    "spearman_rank_corr",
    "top_k_recall",
]

"""XGBoost-style tuner: SMBO with a gradient-boosted-tree surrogate.

This is the paper's primary baseline ("state-of-the-art XGBoost method"
= AutoTVM's cost-model tuner, Chen et al. 2018b).  The container has no
xgboost package, so the surrogate — depth-limited regression trees fit on
residuals with shrinkage — is implemented from scratch in numpy
(:class:`~repro.core.learn.gbt.GradientBoostedTrees`, shared with the
learned-cost-model subsystem and re-exported here for back-compat).
The SMBO loop mirrors AutoTVM:

  1. measure a random warmup batch,
  2. fit the surrogate on log-costs of everything measured,
  3. propose candidates (random pool + neighbors of incumbents),
     rank by predicted cost, ε-diversify,
  4. measure the top batch in one batched engine call, go to 2.

Both the warmup and the per-round top batch go through
``TuningContext.measure_many`` so the engine can spread each batch
across its ``n_workers`` measurement lanes (AutoTVM measures its
proposal batches on parallel device workers the same way).
"""

from __future__ import annotations

import math

import numpy as np

from ..learn.gbt import GradientBoostedTrees
from ..space import State
from .base import Tuner, TuningContext

__all__ = ["GBTTuner", "GradientBoostedTrees"]


class GBTTuner(Tuner):
    name = "xgboost-like"

    def __init__(
        self,
        space,
        cost,
        seed: int = 0,
        warmup: int = 16,
        batch_size: int = 16,
        pool_size: int = 512,
        eps_random: float = 0.15,
        n_trees: int = 50,
        depth: int = 4,
        refit_every: int = 1,
    ):
        super().__init__(space, cost, seed)
        self.warmup = warmup
        self.batch_size = batch_size
        self.pool_size = pool_size
        self.eps_random = eps_random
        self.n_trees, self.depth = n_trees, depth
        self.refit_every = refit_every
        self._it = 0
        self._needs_refit = False

    # -- crash-safe resume ---------------------------------------------------
    # The surrogate itself is not serialized: it is a pure function of
    # ctx.trials, so a restored tuner refits from the restored trial log
    # on its first round (bit-identical to an uninterrupted run when
    # refit_every == 1, the default).
    def state_dict(self) -> dict:
        d = super().state_dict()
        d["it"] = self._it
        return d

    def load_state_dict(self, state: dict) -> None:
        super().load_state_dict(state)
        self._it = state["it"]
        self._needs_refit = True

    def _propose_pool(self, ctx: TuningContext) -> list[State]:
        pool: dict[str, State] = {}
        for _ in range(self.pool_size):
            s = self.space.random_state(self.rng)
            pool.setdefault(s.key(), s)
        # exploit: neighborhoods of the best measured states
        ranked = sorted(
            (t for t in ctx.trials if math.isfinite(t.cost)), key=lambda t: t.cost
        )[:8]
        for t in ranked:
            for s2 in self.space.neighbors(t.state):
                pool.setdefault(s2.key(), s2)
        return [s for k, s in pool.items() if k not in ctx.visited]

    def run(self, ctx: TuningContext) -> None:
        # 1. warmup — random states proposed in lane-sized waves
        ctx.measure(self.space.initial_state())
        while len(ctx.trials) < self.warmup and not ctx.done():
            want = min(max(1, ctx.n_workers), self.warmup - len(ctx.trials))
            wave: list[State] = []
            keys: set[str] = set()
            attempts = 0
            while len(wave) < want and attempts < 64 * want:
                attempts += 1
                s = self.space.random_state(self.rng)
                if not ctx.seen(s) and s.key() not in keys:
                    wave.append(s)
                    keys.add(s.key())
            if not wave:
                break
            ctx.measure_many(wave)
        model = GradientBoostedTrees(self.n_trees, self.depth)
        while not ctx.done():
            ctx.checkpoint(self)
            # 2. fit surrogate on log-costs
            xs, ys = [], []
            for t in ctx.trials:
                xs.append(self.space.features(t.state))
                ys.append(
                    math.log(t.cost) if math.isfinite(t.cost) else math.log(1e3)
                )
            if self._needs_refit or self._it % self.refit_every == 0:
                model.fit(np.stack(xs), np.asarray(ys))
                self._needs_refit = False
            self._it += 1
            # 3. rank pool
            pool = self._propose_pool(ctx)
            if not pool:
                s = self.space.random_state(self.rng)
                if not ctx.seen(s):
                    ctx.measure(s)
                continue
            feats = np.stack([self.space.features(s) for s in pool])
            pred = model.predict(feats)
            order = np.argsort(pred)
            batch: list[State] = [pool[i] for i in order[: self.batch_size]]
            # ε-diversification (AutoTVM's ε-greedy proposal mix)
            n_rand = max(1, int(self.eps_random * len(batch)))
            for _ in range(n_rand):
                batch[self.rng.randrange(len(batch))] = pool[
                    int(order[self.rng.randrange(len(order))])
                ]
            # 4. measure the surviving batch in one engine round
            fresh: list[State] = []
            keys = set()
            for s in batch:
                if not ctx.seen(s) and s.key() not in keys:
                    fresh.append(s)
                    keys.add(s.key())
            if fresh:
                ctx.measure_many(fresh)

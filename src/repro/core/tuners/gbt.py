"""XGBoost-style tuner: SMBO with a gradient-boosted-tree surrogate.

This is the paper's primary baseline ("state-of-the-art XGBoost method"
= AutoTVM's cost-model tuner, Chen et al. 2018b).  The container has no
xgboost package, so the surrogate — depth-limited regression trees fit on
residuals with shrinkage — is implemented from scratch in numpy
(:class:`GradientBoostedTrees`).  The SMBO loop mirrors AutoTVM:

  1. measure a random warmup batch,
  2. fit the surrogate on log-costs of everything measured,
  3. propose candidates (random pool + neighbors of incumbents),
     rank by predicted cost, ε-diversify,
  4. measure the top batch in one batched engine call, go to 2.

Both the warmup and the per-round top batch go through
``TuningContext.measure_many`` so the engine can spread each batch
across its ``n_workers`` measurement lanes (AutoTVM measures its
proposal batches on parallel device workers the same way).
"""

from __future__ import annotations

import math

import numpy as np

from ..space import State
from .base import Tuner, TuningContext

__all__ = ["GBTTuner", "GradientBoostedTrees"]


class _Tree:
    __slots__ = ("feature", "threshold", "left", "right", "value")

    def __init__(self):
        self.feature = -1
        self.threshold = 0.0
        self.left = None
        self.right = None
        self.value = 0.0


def _fit_tree(X: np.ndarray, y: np.ndarray, depth: int, min_samples: int) -> _Tree:
    node = _Tree()
    node.value = float(y.mean())
    if depth == 0 or len(y) < 2 * min_samples or np.allclose(y, y[0]):
        return node
    best_gain, best = 0.0, None
    n, f = X.shape
    parent_sse = float(((y - y.mean()) ** 2).sum())
    idx = np.arange(1, n, dtype=np.float64)
    for j in range(f):
        xs = X[:, j]
        order = np.argsort(xs, kind="stable")
        xs_s, ys_s = xs[order], y[order]
        cums = np.cumsum(ys_s)[:-1]
        cums2 = np.cumsum(ys_s**2)[:-1]
        # vectorized SSE for every split position i in [1, n)
        left_n, right_n = idx, n - idx
        sse = (cums2 - cums * cums / left_n) + (
            (cums2[-1] + ys_s[-1] ** 2 - cums2)
            - (cums[-1] + ys_s[-1] - cums) ** 2 / right_n
        )
        valid = (xs_s[1:] != xs_s[:-1]) & (left_n >= min_samples) & (right_n >= min_samples)
        if not valid.any():
            continue
        sse = np.where(valid, sse, np.inf)
        i = int(np.argmin(sse))
        gain = parent_sse - float(sse[i])
        if gain > best_gain + 1e-12:
            best_gain = gain
            best = (j, 0.5 * (xs_s[i + 1] + xs_s[i]))
    if best is None:
        return node
    j, thr = best
    mask = X[:, j] <= thr
    node.feature, node.threshold = j, thr
    node.left = _fit_tree(X[mask], y[mask], depth - 1, min_samples)
    node.right = _fit_tree(X[~mask], y[~mask], depth - 1, min_samples)
    return node


def _tree_predict(node: _Tree, X: np.ndarray) -> np.ndarray:
    if node.feature < 0:
        return np.full(len(X), node.value)
    out = np.empty(len(X))
    mask = X[:, node.feature] <= node.threshold
    out[mask] = _tree_predict(node.left, X[mask]) if mask.any() else 0
    out[~mask] = _tree_predict(node.right, X[~mask]) if (~mask).any() else 0
    return out


class GradientBoostedTrees:
    """Squared-loss GBT with shrinkage — enough of xgboost for SMBO."""

    def __init__(self, n_trees: int = 50, depth: int = 4, lr: float = 0.2,
                 min_samples: int = 2):
        self.n_trees, self.depth, self.lr = n_trees, depth, lr
        self.min_samples = min_samples
        self.base = 0.0
        self.trees: list[_Tree] = []

    def fit(self, X: np.ndarray, y: np.ndarray) -> "GradientBoostedTrees":
        self.base = float(y.mean())
        self.trees = []
        pred = np.full(len(y), self.base)
        for _ in range(self.n_trees):
            resid = y - pred
            t = _fit_tree(X, resid, self.depth, self.min_samples)
            self.trees.append(t)
            pred = pred + self.lr * _tree_predict(t, X)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        pred = np.full(len(X), self.base)
        for t in self.trees:
            pred = pred + self.lr * _tree_predict(t, X)
        return pred


class GBTTuner(Tuner):
    name = "xgboost-like"

    def __init__(
        self,
        space,
        cost,
        seed: int = 0,
        warmup: int = 16,
        batch_size: int = 16,
        pool_size: int = 512,
        eps_random: float = 0.15,
        n_trees: int = 50,
        depth: int = 4,
        refit_every: int = 1,
    ):
        super().__init__(space, cost, seed)
        self.warmup = warmup
        self.batch_size = batch_size
        self.pool_size = pool_size
        self.eps_random = eps_random
        self.n_trees, self.depth = n_trees, depth
        self.refit_every = refit_every
        self._it = 0
        self._needs_refit = False

    # -- crash-safe resume ---------------------------------------------------
    # The surrogate itself is not serialized: it is a pure function of
    # ctx.trials, so a restored tuner refits from the restored trial log
    # on its first round (bit-identical to an uninterrupted run when
    # refit_every == 1, the default).
    def state_dict(self) -> dict:
        d = super().state_dict()
        d["it"] = self._it
        return d

    def load_state_dict(self, state: dict) -> None:
        super().load_state_dict(state)
        self._it = state["it"]
        self._needs_refit = True

    def _propose_pool(self, ctx: TuningContext) -> list[State]:
        pool: dict[str, State] = {}
        for _ in range(self.pool_size):
            s = self.space.random_state(self.rng)
            pool.setdefault(s.key(), s)
        # exploit: neighborhoods of the best measured states
        ranked = sorted(
            (t for t in ctx.trials if math.isfinite(t.cost)), key=lambda t: t.cost
        )[:8]
        for t in ranked:
            for s2 in self.space.neighbors(t.state):
                pool.setdefault(s2.key(), s2)
        return [s for k, s in pool.items() if k not in ctx.visited]

    def run(self, ctx: TuningContext) -> None:
        # 1. warmup — random states proposed in lane-sized waves
        ctx.measure(self.space.initial_state())
        while len(ctx.trials) < self.warmup and not ctx.done():
            want = min(max(1, ctx.n_workers), self.warmup - len(ctx.trials))
            wave: list[State] = []
            keys: set[str] = set()
            attempts = 0
            while len(wave) < want and attempts < 64 * want:
                attempts += 1
                s = self.space.random_state(self.rng)
                if not ctx.seen(s) and s.key() not in keys:
                    wave.append(s)
                    keys.add(s.key())
            if not wave:
                break
            ctx.measure_many(wave)
        model = GradientBoostedTrees(self.n_trees, self.depth)
        while not ctx.done():
            ctx.checkpoint(self)
            # 2. fit surrogate on log-costs
            xs, ys = [], []
            for t in ctx.trials:
                xs.append(self.space.features(t.state))
                ys.append(
                    math.log(t.cost) if math.isfinite(t.cost) else math.log(1e3)
                )
            if self._needs_refit or self._it % self.refit_every == 0:
                model.fit(np.stack(xs), np.asarray(ys))
                self._needs_refit = False
            self._it += 1
            # 3. rank pool
            pool = self._propose_pool(ctx)
            if not pool:
                s = self.space.random_state(self.rng)
                if not ctx.seen(s):
                    ctx.measure(s)
                continue
            feats = np.stack([self.space.features(s) for s in pool])
            pred = model.predict(feats)
            order = np.argsort(pred)
            batch: list[State] = [pool[i] for i in order[: self.batch_size]]
            # ε-diversification (AutoTVM's ε-greedy proposal mix)
            n_rand = max(1, int(self.eps_random * len(batch)))
            for _ in range(n_rand):
                batch[self.rng.randrange(len(batch))] = pool[
                    int(order[self.rng.randrange(len(order))])
                ]
            # 4. measure the surviving batch in one engine round
            fresh: list[State] = []
            keys = set()
            for s in batch:
                if not ctx.seen(s) and s.key() not in keys:
                    fresh.append(s)
                    keys.add(s.key())
            if fresh:
                ctx.measure_many(fresh)

"""Tuner protocol + shared bookkeeping under the batched measurement
engine (budgets, dedup, incumbent, the simulated search clock).

Every tuner (the paper's G-BFS and N-A2C, and the baselines it compares
against) runs through the same :class:`TuningContext` so that "fraction
of configuration space explored" and "search time" are counted
identically across methods — what the paper's Figs. 7–8 plot.

The measurement contract is **batch-first**: tuners propose candidate
*batches* per round and call :meth:`TuningContext.measure_many`, which

  1. dedups against the visited table (repeat states are free),
  2. slices the fresh states into waves of ``n_workers`` and hands each
     wave to the :class:`~repro.core.measure.MeasureEngine` (which may
     serve states from a persistent cross-session trial cache),
  3. charges one trial per fresh state against the budget — capping the
     final wave so a parallel engine can never overshoot ``max_trials``
     — and advances the search clock by each wave's *critical path*
     (max lane time), not the lane sum,
  4. tracks the incumbent and raises :class:`BudgetExhausted` to unwind
     the tuner when the budget is spent.

With ``n_workers=1`` every wave is a single state measured via the
backend's scalar path, so the visited-state sequence, trial order, and
clock are bit-identical to the historical serial ``measure()`` loop —
Fig. 7/8 reproductions do not shift.  ``measure()`` survives as the
single-state convenience wrapper.
"""

from __future__ import annotations

import abc
import dataclasses
import math
import random
import time
from typing import Callable, Optional, Sequence

from ..space import SearchSpace, State
from ..cost.base import CostBackend
from ..measure import MeasureEngine
from ..shard import ShardSpec

__all__ = [
    "Budget",
    "Trial",
    "TuneResult",
    "TuningContext",
    "Tuner",
    "BudgetExhausted",
    "encode_cost",
    "decode_cost",
]


def encode_cost(c: float) -> Optional[float]:
    """JSON-safe cost: ``inf`` (a failure) round-trips as ``null`` —
    same convention as the journal's fail rows."""
    return c if math.isfinite(c) else None


def decode_cost(c: Optional[float]) -> float:
    return math.inf if c is None else float(c)


@dataclasses.dataclass
class Budget:
    """Stop conditions; any satisfied one ends the search (paper: T_max)."""

    max_trials: Optional[int] = None
    max_time_s: Optional[float] = None
    max_fraction: Optional[float] = None  # of space.size(), e.g. 0.001

    def resolve_trials(self, space_size: int) -> int:
        n = self.max_trials if self.max_trials is not None else space_size
        if self.max_fraction is not None:
            n = min(n, max(1, int(space_size * self.max_fraction)))
        return n


@dataclasses.dataclass
class Trial:
    state: State
    cost: float
    index: int
    clock_s: float  # simulated search clock at measurement time


@dataclasses.dataclass
class TuneResult:
    tuner: str
    best_state: Optional[State]
    best_cost: float
    trials: list[Trial]
    n_trials: int
    fraction: float
    wall_s: float
    clock_s: float
    n_workers: int = 1
    n_cache_hits: int = 0  # trials served from the persistent journal
    executor: str = "sim"  # lane executor the engine measured through

    @property
    def cache_hit_rate(self) -> float:
        return self.n_cache_hits / max(1, self.n_trials)

    def best_curve(self) -> list[tuple[int, float]]:
        """(n_trials, best_cost_so_far) — the paper's Fig. 7a series."""
        out, best = [], math.inf
        for t in self.trials:
            best = min(best, t.cost)
            out.append((t.index + 1, best))
        return out

    def best_time_curve(self) -> list[tuple[float, float]]:
        """(clock_s, best_cost_so_far) — the paper's Fig. 7b series."""
        out, best = [], math.inf
        for t in self.trials:
            best = min(best, t.cost)
            out.append((t.clock_s, best))
        return out


class BudgetExhausted(Exception):
    pass


class TuningContext:
    """Search-side measurement broker: dedups states, charges the budget,
    tracks the incumbent, and drives the engine's measurement waves.
    Raising :class:`BudgetExhausted` unwinds the tuner."""

    def __init__(
        self,
        space: SearchSpace,
        cost: CostBackend,
        budget: Budget,
        overhead_s: Optional[float] = None,
        measure_timeout_s: Optional[float] = None,
        n_workers: Optional[int] = None,
        engine: Optional[MeasureEngine] = None,
        checkpoint_fn: Optional[Callable[["Tuner", "TuningContext"], None]] = None,
        shard: Optional[ShardSpec] = None,
    ):
        self.space = space
        self.cost_backend = cost
        self.budget = budget
        self.max_trials = budget.resolve_trials(space.size())
        self.visited: dict[str, float] = {}
        self.trials: list[Trial] = []
        self.best_state: Optional[State] = None
        self.best_cost = math.inf
        self.clock_s = 0.0
        # crash-safe search state: tuners announce round boundaries via
        # checkpoint(); the session-installed callback snapshots tuner +
        # context state and may raise TuneInterrupted on SIGTERM
        self.round_idx = 0
        self._checkpoint_fn = checkpoint_fn
        if engine is None:
            engine = MeasureEngine(
                cost,
                n_workers=1 if n_workers is None else n_workers,
                overhead_s=0.35 if overhead_s is None else overhead_s,
                timeout_s=4.0 if measure_timeout_s is None else measure_timeout_s,
                shard=shard,
            )
        else:
            # the engine owns the measurement model: reject conflicting
            # explicit arguments instead of silently dropping them
            for arg, val in (
                ("overhead_s", overhead_s),
                ("measure_timeout_s", measure_timeout_s),
            ):
                got = engine.overhead_s if arg == "overhead_s" else engine.timeout_s
                if val is not None and val != got:
                    raise ValueError(
                        f"{arg}={val} conflicts with the provided engine's {got}"
                    )
            if n_workers is not None and n_workers != engine.n_workers:
                raise ValueError(
                    f"n_workers={n_workers} conflicts with the provided "
                    f"engine's {engine.n_workers}"
                )
            if shard is not None and shard.enabled and shard != engine.shard:
                raise ValueError(
                    f"shard={shard} conflicts with the provided "
                    f"engine's {engine.shard}"
                )
        self.engine = engine
        self.n_workers = engine.n_workers
        self.overhead_s = engine.overhead_s  # per-measurement codegen/launch charge
        # AutoTVM-style measurement timeout: a pathological config (the
        # untiled s0 runs for minutes under the model) charges at most
        # this much search clock — without it, time-budget comparisons
        # degenerate for tuners that start at s0
        self.measure_timeout_s = engine.timeout_s
        # engine stats may be shared across contexts (tune_arch): snapshot
        # so result() reports this search's deltas only
        self._stats0 = (engine.stats.n_dispatched, engine.stats.n_cache_hits)
        self.wall_start = time.monotonic()

    # -- crash safety --------------------------------------------------------
    def checkpoint(self, tuner: "Tuner") -> None:
        """Announce a round boundary — every tuner calls this at the top
        of its proposal loop.  A consistent cut of the search lives here:
        the tuner's own state (``state_dict``) plus this context's
        visited/trials/best/clock.  The installed callback decides
        whether to snapshot (periodic cadence) and raises
        :class:`~repro.core.snapshot.TuneInterrupted` after flushing a
        final snapshot when an interrupt was requested.  No-op without a
        callback — the historical path is untouched."""
        self.round_idx += 1
        if self._checkpoint_fn is not None:
            self._checkpoint_fn(tuner, self)

    def snapshot(self) -> dict:
        """JSON-serializable search state (the context half of a
        snapshot; the tuner half is ``Tuner.state_dict``)."""
        snap = {
            "visited": [[k, encode_cost(c)] for k, c in self.visited.items()],
            "trials": [
                [t.state.as_lists(), encode_cost(t.cost), t.clock_s]
                for t in self.trials
            ],
            "best": None if self.best_state is None else self.best_state.as_lists(),
            "best_cost": encode_cost(self.best_cost),
            "clock_s": self.clock_s,
            "round": self.round_idx,
        }
        flt = self.engine.learned_filter
        if flt is not None:
            # without this, a resumed --learned-filter run restarts the
            # retrain cadence and re-derives the model, skipping a
            # different candidate sequence than the uninterrupted run
            snap["filter"] = flt.state_dict()
        return snap

    def restore_snapshot(self, snap: dict) -> None:
        """Rebuild visited/trials/best/clock from :meth:`snapshot` output
        (states rebuilt through this context's space)."""
        self.visited = {k: decode_cost(c) for k, c in snap["visited"]}
        self.trials = [
            Trial(self.space.state_from_lists(lists), decode_cost(c), i, float(tc))
            for i, (lists, c, tc) in enumerate(snap["trials"])
        ]
        self.best_state = (
            None if snap["best"] is None
            else self.space.state_from_lists(snap["best"])
        )
        self.best_cost = decode_cost(snap["best_cost"])
        self.clock_s = float(snap["clock_s"])
        self.round_idx = int(snap.get("round", 0))
        flt = self.engine.learned_filter
        if flt is not None and "filter" in snap:  # pre-filter snapshots lack it
            flt.load_state_dict(snap["filter"])

    # -- paper bookkeeping ---------------------------------------------------
    def seen(self, s: State) -> bool:
        return s.key() in self.visited

    def done(self) -> bool:
        if len(self.trials) >= self.max_trials:
            return True
        if self.budget.max_time_s is not None and self.clock_s >= self.budget.max_time_s:
            return True
        return False

    def measure_many(self, states: Sequence[State]) -> list[float]:
        """Measure a candidate batch; returns costs aligned with ``states``.

        Already-visited states (and intra-batch duplicates) are served
        from the visited table without charging the budget.  Fresh states
        are measured in proposal order, ``n_workers`` at a time; each
        *new* state charges one trial and each wave charges its critical
        path on the clock.  Raises :class:`BudgetExhausted` when the
        budget runs out mid-batch (the already-measured prefix is kept).
        """
        fresh: list[State] = []
        fresh_keys: set[str] = set()
        for s in states:
            key = s.key()
            if key not in self.visited and key not in fresh_keys:
                fresh.append(s)
                fresh_keys.add(key)
        i = 0
        while i < len(fresh):
            if self.done():
                raise BudgetExhausted()
            room = self.max_trials - len(self.trials)
            wave = fresh[i : i + min(self.n_workers, room)]
            outcomes = self.engine.measure_wave(wave)
            self.clock_s += max(o.lane_s for o in outcomes)
            for o in outcomes:
                self.visited[o.state.key()] = o.cost
                self.trials.append(Trial(o.state, o.cost, len(self.trials), self.clock_s))
                if o.cost < self.best_cost:
                    self.best_cost, self.best_state = o.cost, o.state
            i += len(wave)
        return [self.visited[s.key()] for s in states]

    def measure(self, s: State) -> float:
        """Single-state convenience wrapper over :meth:`measure_many`."""
        return self.measure_many([s])[0]

    def result(self, tuner_name: str) -> TuneResult:
        d0, h0 = self._stats0
        return TuneResult(
            tuner=tuner_name,
            best_state=self.best_state,
            best_cost=self.best_cost,
            trials=self.trials,
            n_trials=len(self.trials),
            fraction=len(self.trials) / max(1, self.space.size()),
            wall_s=time.monotonic() - self.wall_start,
            clock_s=self.clock_s,
            n_workers=self.n_workers,
            n_cache_hits=self.engine.stats.n_cache_hits - h0,
            executor=self.engine.executor.name,
        )


class Tuner(abc.ABC):
    name: str = "tuner"

    def __init__(self, space: SearchSpace, cost: CostBackend, seed: int = 0):
        self.space = space
        self.cost = cost
        self.seed = seed
        self.rng = random.Random(seed)

    @abc.abstractmethod
    def run(self, ctx: TuningContext) -> None:
        """Search until ctx.done() or BudgetExhausted."""

    # -- crash-safe resume ---------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-serializable tuner state for crash-safe resume.  The base
        captures the RNG stream (every tuner draws from ``self.rng``);
        subclasses extend via ``super()`` with their search memory
        (frontier, population, network weights, counters).  ``run`` must
        treat restored state as already-initialized and continue from
        it."""
        st = self.rng.getstate()
        return {
            "tuner": self.name,
            "seed": self.seed,
            "rng": [st[0], list(st[1]), st[2]],
        }

    def load_state_dict(self, state: dict) -> None:
        got = state.get("tuner")
        if got is not None and got != self.name:
            raise ValueError(
                f"snapshot belongs to tuner {got!r}, cannot restore {self.name!r}"
            )
        version, internal, gauss = state["rng"]
        self.rng.setstate((version, tuple(internal), gauss))

    def tune(
        self,
        budget: Budget,
        overhead_s: Optional[float] = None,  # defaults to 0.35 without an engine
        n_workers: Optional[int] = None,  # defaults to 1 without an engine
        engine: Optional[MeasureEngine] = None,
        checkpoint_fn: Optional[Callable[["Tuner", TuningContext], None]] = None,
        restore: Optional[dict] = None,
    ) -> TuneResult:
        """Run the search.  ``checkpoint_fn`` receives ``(tuner, ctx)``
        at every round boundary (see ``TuningContext.checkpoint``);
        ``restore`` is a snapshot payload (``{"tuner_state": ...,
        "ctx": ...}``) to continue from instead of starting fresh.  A
        :class:`~repro.core.snapshot.TuneInterrupted` raised by the
        callback propagates to the caller — the snapshot is already
        flushed by then."""
        ctx = TuningContext(
            self.space,
            self.cost,
            budget,
            overhead_s=overhead_s,
            n_workers=n_workers,
            engine=engine,
            checkpoint_fn=checkpoint_fn,
        )
        if restore is not None:
            self.load_state_dict(restore["tuner_state"])
            ctx.restore_snapshot(restore["ctx"])
        try:
            self.run(ctx)
        except BudgetExhausted:
            pass
        return ctx.result(self.name)

"""Tuner protocol + shared bookkeeping (budgets, history, dedup).

Every tuner (the paper's G-BFS and N-A2C, and the baselines it compares
against) runs through the same :class:`TuningContext` so that
"fraction of configuration space explored" and "search time" are counted
identically across methods — which is what the paper's Figs. 7–8 plot.
"""

from __future__ import annotations

import abc
import dataclasses
import math
import random
import time
from typing import Optional

from ..config_space import GemmConfigSpace, TilingState
from ..cost.base import CostBackend

__all__ = ["Budget", "Trial", "TuneResult", "TuningContext", "Tuner", "BudgetExhausted"]


@dataclasses.dataclass
class Budget:
    """Stop conditions; any satisfied one ends the search (paper: T_max)."""

    max_trials: Optional[int] = None
    max_time_s: Optional[float] = None
    max_fraction: Optional[float] = None  # of space.size(), e.g. 0.001

    def resolve_trials(self, space_size: int) -> int:
        n = self.max_trials if self.max_trials is not None else space_size
        if self.max_fraction is not None:
            n = min(n, max(1, int(space_size * self.max_fraction)))
        return n


@dataclasses.dataclass
class Trial:
    state: TilingState
    cost: float
    index: int
    clock_s: float  # simulated search clock at measurement time


@dataclasses.dataclass
class TuneResult:
    tuner: str
    best_state: Optional[TilingState]
    best_cost: float
    trials: list[Trial]
    n_trials: int
    fraction: float
    wall_s: float
    clock_s: float

    def best_curve(self) -> list[tuple[int, float]]:
        """(n_trials, best_cost_so_far) — the paper's Fig. 7a series."""
        out, best = [], math.inf
        for t in self.trials:
            best = min(best, t.cost)
            out.append((t.index + 1, best))
        return out

    def best_time_curve(self) -> list[tuple[float, float]]:
        """(clock_s, best_cost_so_far) — the paper's Fig. 7b series."""
        out, best = [], math.inf
        for t in self.trials:
            best = min(best, t.cost)
            out.append((t.clock_s, best))
        return out


class BudgetExhausted(Exception):
    pass


class TuningContext:
    """Measurement broker: dedups states, charges the budget, tracks the
    incumbent.  Raising :class:`BudgetExhausted` unwinds the tuner."""

    def __init__(
        self,
        space: GemmConfigSpace,
        cost: CostBackend,
        budget: Budget,
        overhead_s: float = 0.35,
        measure_timeout_s: float = 4.0,
    ):
        self.space = space
        self.cost_backend = cost
        self.budget = budget
        self.max_trials = budget.resolve_trials(space.size())
        self.visited: dict[str, float] = {}
        self.trials: list[Trial] = []
        self.best_state: Optional[TilingState] = None
        self.best_cost = math.inf
        self.clock_s = 0.0
        self.overhead_s = overhead_s  # per-measurement codegen/launch charge
        # AutoTVM-style measurement timeout: a pathological config (the
        # untiled s0 runs for minutes under the model) charges at most
        # this much search clock — without it, time-budget comparisons
        # degenerate for tuners that start at s0
        self.measure_timeout_s = measure_timeout_s
        self.wall_start = time.monotonic()

    # -- paper bookkeeping ---------------------------------------------------
    def seen(self, s: TilingState) -> bool:
        return s.key() in self.visited

    def done(self) -> bool:
        if len(self.trials) >= self.max_trials:
            return True
        if self.budget.max_time_s is not None and self.clock_s >= self.budget.max_time_s:
            return True
        return False

    def measure(self, s: TilingState) -> float:
        """cost(s) with dedup; each *new* state charges one trial."""
        key = s.key()
        if key in self.visited:
            return self.visited[key]
        if self.done():
            raise BudgetExhausted()
        c = self.cost_backend.cost(s)
        self.clock_s += self.overhead_s + (
            0.0 if math.isinf(c) else min(c, self.measure_timeout_s)
        )
        self.visited[key] = c
        self.trials.append(Trial(s, c, len(self.trials), self.clock_s))
        if c < self.best_cost:
            self.best_cost, self.best_state = c, s
        return c

    def result(self, tuner_name: str) -> TuneResult:
        return TuneResult(
            tuner=tuner_name,
            best_state=self.best_state,
            best_cost=self.best_cost,
            trials=self.trials,
            n_trials=len(self.trials),
            fraction=len(self.trials) / max(1, self.space.size()),
            wall_s=time.monotonic() - self.wall_start,
            clock_s=self.clock_s,
        )


class Tuner(abc.ABC):
    name: str = "tuner"

    def __init__(self, space: GemmConfigSpace, cost: CostBackend, seed: int = 0):
        self.space = space
        self.cost = cost
        self.seed = seed
        self.rng = random.Random(seed)

    @abc.abstractmethod
    def run(self, ctx: TuningContext) -> None:
        """Search until ctx.done() or BudgetExhausted."""

    def tune(self, budget: Budget, overhead_s: float = 0.35) -> TuneResult:
        ctx = TuningContext(self.space, self.cost, budget, overhead_s=overhead_s)
        try:
            self.run(ctx)
        except BudgetExhausted:
            pass
        return ctx.result(self.name)

"""RNN-controller tuner — the paper's second baseline ("the general
configuration optimization method using a RNN controller by Google
researchers", i.e. the NAS-style controller of Zoph & Le / Bello et al.).

A GRU emits the configuration as a sequence of categorical decisions:
for each dimension row of the space (``space.dim_specs()`` — m/k/n for
GEMM, q/kv for flash attention) it distributes the power-of-two
exponent budget e_x over d_x ordered slots, one slot at a time, each
choice conditioned on the running remainder via masking.
Sampled configurations are measured; the controller is trained with
REINFORCE (reward = c_ref / cost, EMA baseline, entropy bonus).
"""

from __future__ import annotations

import math

import numpy as np

from ..snapshot import tree_from_jsonable, tree_to_jsonable
from ..space import State
from .base import Tuner, TuningContext

__all__ = ["RNNControllerTuner"]


def _exponent_budget(value: int) -> int:
    e = 0
    while value % 2 == 0:
        value //= 2
        e += 1
    return e


class RNNControllerTuner(Tuner):
    name = "rnn-controller"

    def __init__(
        self,
        space,
        cost,
        seed: int = 0,
        hidden: int = 64,
        lr: float = 4e-3,
        batch_size: int = 8,
        entropy_beta: float = 5e-3,
        baseline_decay: float = 0.9,
    ):
        super().__init__(space, cost, seed)
        self.hidden = hidden
        self.lr = lr
        self.batch_size = batch_size
        self.entropy_beta = entropy_beta
        self.baseline_decay = baseline_decay
        self._ready = False
        self._baseline = None
        self._c_ref = None

    # -- crash-safe resume ---------------------------------------------------
    def state_dict(self) -> dict:
        d = super().state_dict()
        d["baseline"] = self._baseline
        d["c_ref"] = self._c_ref
        if self._ready:
            d["params"] = tree_to_jsonable(self.params)
            d["opt_state"] = tree_to_jsonable(self.opt_state)
        return d

    def load_state_dict(self, state: dict) -> None:
        super().load_state_dict(state)
        self._baseline = state["baseline"]
        self._c_ref = state["c_ref"]
        if "params" in state:
            if not self._ready:
                self._setup()  # builds jitted fns + shapes, then overwrite
            leaf = self._jnp.asarray
            self.params = tree_from_jsonable(state["params"], leaf)
            self.opt_state = tree_from_jsonable(state["opt_state"], leaf)

    def _setup(self):
        import jax
        import jax.numpy as jnp

        from .nn import adam_init, adam_update, init_gru, init_linear, gru_step, linear_apply

        self._jax, self._jnp = jax, jnp
        sp = self.space
        # one (exponent budget, depth) pair per dimension row — the
        # op-agnostic decision sequence
        self.budgets = [
            (_exponent_budget(value), depth) for value, depth in sp.dim_specs()
        ]
        self.max_e = max(b for b, _ in self.budgets)
        # decision sequence: for each dim, d_x - 1 free slots (last is forced)
        self.seq_spec: list[tuple[int, int]] = []  # (dim_idx, slot_idx)
        for di, (_, d) in enumerate(self.budgets):
            for slot in range(d - 1):
                self.seq_spec.append((di, slot))
        key = jax.random.PRNGKey(self.seed)
        k1, k2, k3 = jax.random.split(key, 3)
        n_in = self.max_e + 2  # one-hot prev choice + start token
        self.params = {
            "gru": init_gru(k1, n_in, self.hidden),
            "head": init_linear(k2, self.hidden, self.max_e + 1),
            "emb0": jax.random.normal(k3, (n_in,), jnp.float32) * 0.1,
        }
        self.opt_state = adam_init(self.params)
        self._gru_step = gru_step
        self._linear_apply = linear_apply
        self._adam_update = adam_update

        seq_len = len(self.seq_spec)
        max_e = self.max_e

        def sample_logp(params, choices, masks):
            """log-prob + entropy of a fixed choice sequence (for grads)."""
            h = params["gru"]["h0"]
            x = params["emb0"]
            logp_total = 0.0
            ent_total = 0.0
            for t in range(seq_len):
                h = gru_step(params["gru"], h, x)
                logits = linear_apply(params["head"], h)
                logits = jnp.where(masks[t], logits, -1e9)
                lp = jax.nn.log_softmax(logits)
                logp_total = logp_total + lp[choices[t]]
                p = jnp.exp(lp)
                ent_total = ent_total - jnp.sum(jnp.where(masks[t], p * lp, 0.0))
                x = jax.nn.one_hot(choices[t] + 1, max_e + 2)
            return logp_total, ent_total

        def loss_fn(params, choices_b, masks_b, adv_b):
            def one(choices, masks, adv):
                logp, ent = sample_logp(params, choices, masks)
                return -logp * adv - self.entropy_beta * ent

            return jnp.mean(jax.vmap(one)(choices_b, masks_b, adv_b))

        @jax.jit
        def train_step(params, opt_state, choices_b, masks_b, adv_b):
            g = jax.grad(loss_fn)(params, choices_b, masks_b, adv_b)
            return adam_update(params, g, opt_state, lr=self.lr)

        @jax.jit
        def logits_step(params, h, x):
            h2 = gru_step(params["gru"], h, x)
            return h2, linear_apply(params["head"], h2)

        self._train_step = train_step
        self._logits_step = logits_step
        self._ready = True

    # -- sampling ----------------------------------------------------------------
    def _sample_config(self) -> tuple[State, np.ndarray, np.ndarray]:
        jnp = self._jnp
        h = self.params["gru"]["h0"]
        x = self.params["emb0"]
        remaining = [b for b, _ in self.budgets]
        exps: list[list[int]] = [[0] * d for _, d in self.budgets]
        choices, masks = [], []
        for (di, slot) in self.seq_spec:
            h, logits = self._logits_step(self.params, h, x)
            logits = np.asarray(logits, dtype=np.float64)
            mask = np.zeros(self.max_e + 1, dtype=bool)
            mask[: remaining[di] + 1] = True
            logits[~mask] = -1e9
            z = logits - logits.max()
            p = np.exp(z)
            p /= p.sum()
            c = int(np.searchsorted(np.cumsum(p), self.rng.random()))
            c = min(c, remaining[di])
            choices.append(c)
            masks.append(mask)
            exps[di][slot] = c
            remaining[di] -= c
            x = jnp.asarray(
                np.eye(self.max_e + 2, dtype=np.float32)[c + 1]
            )
        for di, (_, d) in enumerate(self.budgets):
            exps[di][d - 1] = remaining[di]
        rows = []
        for di, (value, _depth) in enumerate(self.space.dim_specs()):
            odd = value >> _exponent_budget(value)
            row = [2 ** e for e in exps[di]]
            row[0] *= odd
            rows.append(row)
        s = self.space.state_from_lists(rows)
        return s, np.asarray(choices, np.int32), np.stack(masks)

    # -- REINFORCE loop ------------------------------------------------------------
    def run(self, ctx: TuningContext) -> None:
        # Controller samples are drawn first, then the whole batch is
        # measured in ONE engine call — the controller's parameters only
        # update between batches, so deferring measurement changes
        # nothing about the sampling distribution while letting the
        # engine spread the batch across its measurement lanes.
        if not self._ready:
            self._setup()
        np_ = np
        if self._c_ref is None:
            c_ref = ctx.measure(self.space.initial_state())
            self._c_ref = c_ref if math.isfinite(c_ref) else 1.0
        c_ref = self._c_ref
        while not ctx.done():
            ctx.checkpoint(self)
            sampled = []  # (state, choices, masks) pending measurement
            round_keys: set[str] = set()
            guard = 0
            while len(sampled) < self.batch_size and guard < 64:
                guard += 1
                s, choices, masks = self._sample_config()
                if not self.space.is_legitimate(s):
                    continue
                if ctx.seen(s) or s.key() in round_keys:
                    continue
                round_keys.add(s.key())
                sampled.append((s, choices, masks))
            if not sampled:
                continue
            costs = ctx.measure_many([s for s, _, _ in sampled])
            batch = [
                (choices, masks, 0.0 if not math.isfinite(c) else float(c_ref / c))
                for (_, choices, masks), c in zip(sampled, costs)
            ]
            rewards = np_.asarray([b[2] for b in batch], np_.float32)
            if self._baseline is None:
                self._baseline = float(rewards.mean())
            adv = rewards - self._baseline
            self._baseline = self.baseline_decay * self._baseline + (
                1 - self.baseline_decay
            ) * float(rewards.mean())
            choices_b = np_.stack([b[0] for b in batch])
            masks_b = np_.stack([b[1] for b in batch])
            self.params, self.opt_state = self._train_step(
                self.params, self.opt_state, choices_b, masks_b, adv
            )

"""Classic baseline tuners: random, grid, simulated annealing, genetic.

Random/grid/GA are the baselines the TVM papers (Chen et al. 2018a/b)
compare XGBoost against; the paper inherits those comparisons.  Simulated
annealing is included as an extra neighborhood-aware control (beyond
paper) since it uses the same MDP moves as G-BFS but no frontier memory.
"""

from __future__ import annotations

import math

from ..config_space import TilingState
from .base import Tuner, TuningContext

__all__ = ["RandomTuner", "GridTuner", "AnnealingTuner", "GeneticTuner"]


class RandomTuner(Tuner):
    name = "random"

    def run(self, ctx: TuningContext) -> None:
        while not ctx.done():
            s = self.space.random_state(self.rng)
            if not ctx.seen(s):
                ctx.measure(s)


class GridTuner(Tuner):
    """Sequential sweep in enumeration order (paper Sec. 2: grid search)."""

    name = "grid"

    def run(self, ctx: TuningContext) -> None:
        for s in self.space.enumerate():
            if ctx.done():
                return
            ctx.measure(s)


class AnnealingTuner(Tuner):
    name = "sim-anneal"

    def __init__(self, space, cost, seed: int = 0, t0: float = 1.0,
                 decay: float = 0.995, restarts: int = 8):
        super().__init__(space, cost, seed)
        self.t0, self.decay, self.restarts = t0, decay, restarts

    def run(self, ctx: TuningContext) -> None:
        r = 0
        while not ctx.done():  # keep restarting until the budget is spent
            s = self.space.initial_state() if r == 0 else self.space.random_state(self.rng)
            r += 1
            c = ctx.measure(s) if not ctx.seen(s) else ctx.visited[s.key()]
            temp = self.t0
            while not ctx.done():
                neigh = self.space.neighbors(s)
                if not neigh:
                    break
                s2 = self.rng.choice(neigh)
                c2 = ctx.measure(s2) if not ctx.seen(s2) else ctx.visited[s2.key()]
                if not math.isfinite(c2):
                    temp *= self.decay
                    continue
                # Metropolis on relative cost (scale-free)
                if c2 < c or self.rng.random() < math.exp(-(c2 - c) / max(c * temp, 1e-30)):
                    s, c = s2, c2
                temp *= self.decay
                if temp < 1e-3:
                    break


class GeneticTuner(Tuner):
    """GA over exponent vectors; mutation = one MDP move, crossover =
    per-dimension factor-list swap (keeps products exact)."""

    name = "genetic"

    def __init__(self, space, cost, seed: int = 0, pop: int = 32,
                 elite: int = 8, mut_p: float = 0.6):
        super().__init__(space, cost, seed)
        self.pop_size, self.elite, self.mut_p = pop, elite, mut_p

    def _crossover(self, a: TilingState, b: TilingState) -> TilingState:
        rows_a, rows_b = a.as_lists(), b.as_lists()
        child = [rows_a[d] if self.rng.random() < 0.5 else rows_b[d] for d in range(3)]
        return TilingState.from_lists(child)

    def _mutate(self, s: TilingState) -> TilingState:
        neigh = self.space.neighbors(s)
        return self.rng.choice(neigh) if neigh else s

    def run(self, ctx: TuningContext) -> None:
        pop: list[tuple[float, TilingState]] = []
        seeds = [self.space.initial_state()] + [
            self.space.random_state(self.rng) for _ in range(self.pop_size - 1)
        ]
        for s in seeds:
            if not ctx.seen(s):
                pop.append((ctx.measure(s), s))
        while not ctx.done():
            pop.sort(key=lambda t: t[0])
            elites = pop[: self.elite]
            children: list[TilingState] = []
            attempts = 0
            while len(children) < self.pop_size and attempts < 20 * self.pop_size:
                attempts += 1
                pa = self.rng.choice(elites)[1]
                pb = self.rng.choice(elites)[1]
                ch = self._crossover(pa, pb)
                if self.rng.random() < self.mut_p:
                    ch = self._mutate(ch)
                if self.space.is_legitimate(ch) and not ctx.seen(ch):
                    children.append(ch)
            nxt = list(elites)
            measured = 0
            for ch in children:
                if not ctx.seen(ch):
                    nxt.append((ctx.measure(ch), ch))
                    measured += 1
            if measured == 0:  # converged population: inject fresh genes
                for _ in range(self.pop_size):
                    s = self.space.random_state(self.rng)
                    if not ctx.seen(s):
                        nxt.append((ctx.measure(s), s))
                        break
            pop = nxt

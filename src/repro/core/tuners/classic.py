"""Classic baseline tuners: random, grid, simulated annealing, genetic.

Random/grid/GA are the baselines the TVM papers (Chen et al. 2018a/b)
compare XGBoost against; the paper inherits those comparisons.  Simulated
annealing is included as an extra neighborhood-aware control (beyond
paper) since it uses the same MDP moves as G-BFS but no frontier memory.

All four propose candidate *batches* per round through
``TuningContext.measure_many`` so the measurement engine can spread each
round across its ``n_workers`` lanes: random and grid propose lane-sized
waves, the GA measures its seed population and each generation's
children as one batch, and annealing runs ``n_workers`` independent
Metropolis chains whose per-round proposals are measured together.  With
``n_workers=1`` each of them degenerates to the historical serial loop
(identical RNG consumption, identical trial order).

Crash-safe resume: random and grid carry no search memory beyond the RNG
stream / enumeration cursor, so their ``state_dict`` is (nearly) the base
one; the GA externalizes its population.  Annealing's chains are live
generators and resume *coarsely*: the RNG and visited set are restored
but chains restart from fresh seeds — documented exception, its resumed
trajectory is deterministic but not bit-identical to an uninterrupted
run.
"""

from __future__ import annotations

import itertools
import math
from typing import Optional

from ..space import State
from .base import Tuner, TuningContext, decode_cost, encode_cost

__all__ = ["RandomTuner", "GridTuner", "AnnealingTuner", "GeneticTuner"]


class RandomTuner(Tuner):
    name = "random"

    def run(self, ctx: TuningContext) -> None:
        while not ctx.done():
            ctx.checkpoint(self)
            wave: list[State] = []
            keys: set[str] = set()
            attempts = 0
            want = max(1, ctx.n_workers)
            while len(wave) < want and attempts < 64 * want:
                attempts += 1
                s = self.space.random_state(self.rng)
                if not ctx.seen(s) and s.key() not in keys:
                    wave.append(s)
                    keys.add(s.key())
            if not wave:
                return  # space (effectively) exhausted
            ctx.measure_many(wave)


class GridTuner(Tuner):
    """Sequential sweep in enumeration order (paper Sec. 2: grid search),
    chunked into lane-sized waves.  The enumeration cursor (`_drawn`) is
    instance state so a restored tuner re-enters the sweep exactly where
    the snapshot left it."""

    name = "grid"

    def __init__(self, space, cost, seed: int = 0):
        super().__init__(space, cost, seed)
        self._drawn = 0

    def state_dict(self) -> dict:
        d = super().state_dict()
        d["drawn"] = self._drawn
        return d

    def load_state_dict(self, state: dict) -> None:
        super().load_state_dict(state)
        self._drawn = state["drawn"]

    def run(self, ctx: TuningContext) -> None:
        it = itertools.islice(self.space.enumerate(), self._drawn, None)
        while not ctx.done():
            ctx.checkpoint(self)
            chunk = list(itertools.islice(it, max(1, ctx.n_workers)))
            if not chunk:
                return
            self._drawn += len(chunk)
            ctx.measure_many(chunk)


class AnnealingTuner(Tuner):
    """Metropolis chains over the MDP neighborhood.  One chain per engine
    lane; each round every chain advances to its next *unvisited*
    proposal (cached states are folded in for free along the way) and the
    proposals are measured as one wave."""

    name = "sim-anneal"

    def __init__(self, space, cost, seed: int = 0, t0: float = 1.0,
                 decay: float = 0.995, restarts: int = 8):
        super().__init__(space, cost, seed)
        self.t0, self.decay, self.restarts = t0, decay, restarts

    def _chain(self, ctx: TuningContext, first: bool):
        """Generator form of one annealing chain: yields states that need
        a measurement and receives their cost via ``send`` — cached
        states are consumed inline without occupying a lane.  The body is
        statement-for-statement the historical serial loop, so a single
        chain reproduces it exactly."""
        while not ctx.done():  # keep restarting until the budget is spent
            s = self.space.initial_state() if first else self.space.random_state(self.rng)
            first = False
            c = (yield s) if not ctx.seen(s) else ctx.visited[s.key()]
            temp = self.t0
            while not ctx.done():
                neigh = self.space.neighbors(s)
                if not neigh:
                    break
                s2 = self.rng.choice(neigh)
                c2 = (yield s2) if not ctx.seen(s2) else ctx.visited[s2.key()]
                if not math.isfinite(c2):
                    temp *= self.decay
                    continue
                # Metropolis on relative cost (scale-free)
                if c2 < c or self.rng.random() < math.exp(-(c2 - c) / max(c * temp, 1e-30)):
                    s, c = s2, c2
                temp *= self.decay
                if temp < 1e-3:
                    break

    def run(self, ctx: TuningContext) -> None:
        chains = [
            self._chain(ctx, first=(i == 0)) for i in range(max(1, ctx.n_workers))
        ]
        requests: list[tuple] = []
        for ch in chains:
            try:
                requests.append((ch, next(ch)))
            except StopIteration:
                pass
        while requests:
            ctx.checkpoint(self)
            batch = [s for _, s in requests]
            costs = ctx.measure_many(batch)  # raises BudgetExhausted at the limit
            cost_of = {s.key(): c for s, c in zip(batch, costs)}
            nxt = []
            for ch, s in requests:
                try:
                    nxt.append((ch, ch.send(cost_of[s.key()])))
                except StopIteration:
                    pass
            requests = nxt


class GeneticTuner(Tuner):
    """GA over exponent vectors; mutation = one MDP move, crossover =
    per-dimension-row factor-list swap (keeps products exact).  The
    population is instance state so a snapshot restores the exact gene
    pool the interrupted generation was breeding from."""

    name = "genetic"

    def __init__(self, space, cost, seed: int = 0, pop: int = 32,
                 elite: int = 8, mut_p: float = 0.6):
        super().__init__(space, cost, seed)
        self.pop_size, self.elite, self.mut_p = pop, elite, mut_p
        self._pop: Optional[list[tuple[float, State]]] = None

    # -- crash-safe resume ---------------------------------------------------
    def state_dict(self) -> dict:
        d = super().state_dict()
        d["pop"] = (
            None
            if self._pop is None
            else [[encode_cost(c), s.as_lists()] for c, s in self._pop]
        )
        return d

    def load_state_dict(self, state: dict) -> None:
        super().load_state_dict(state)
        pop = state["pop"]
        self._pop = (
            None
            if pop is None
            else [
                (decode_cost(c), self.space.state_from_lists(rows))
                for c, rows in pop
            ]
        )

    def _crossover(self, a: State, b: State) -> State:
        rows_a, rows_b = a.as_lists(), b.as_lists()
        child = [
            rows_a[d] if self.rng.random() < 0.5 else rows_b[d]
            for d in range(len(rows_a))
        ]
        return self.space.state_from_lists(child)

    def _mutate(self, s: State) -> State:
        neigh = self.space.neighbors(s)
        return self.rng.choice(neigh) if neigh else s

    def _measure_fresh(self, ctx: TuningContext,
                       cands: list[State]) -> list[tuple[float, State]]:
        """Batch-measure the unvisited, intra-batch-unique candidates."""
        fresh: list[State] = []
        keys: set[str] = set()
        for s in cands:
            if not ctx.seen(s) and s.key() not in keys:
                fresh.append(s)
                keys.add(s.key())
        if not fresh:
            return []
        costs = ctx.measure_many(fresh)
        return list(zip(costs, fresh))

    def run(self, ctx: TuningContext) -> None:
        if self._pop is None:
            seeds = [self.space.initial_state()] + [
                self.space.random_state(self.rng) for _ in range(self.pop_size - 1)
            ]
            self._pop = self._measure_fresh(ctx, seeds)
        while not ctx.done():
            ctx.checkpoint(self)
            pop = self._pop
            pop.sort(key=lambda t: t[0])
            elites = pop[: self.elite]
            children: list[State] = []
            attempts = 0
            while len(children) < self.pop_size and attempts < 20 * self.pop_size:
                attempts += 1
                pa = self.rng.choice(elites)[1]
                pb = self.rng.choice(elites)[1]
                ch = self._crossover(pa, pb)
                if self.rng.random() < self.mut_p:
                    ch = self._mutate(ch)
                if self.space.is_legitimate(ch) and not ctx.seen(ch):
                    children.append(ch)
            nxt = list(elites)
            measured = self._measure_fresh(ctx, children)
            nxt.extend(measured)
            if not measured:  # converged population: inject fresh genes
                for _ in range(self.pop_size):
                    s = self.space.random_state(self.rng)
                    if not ctx.seen(s):
                        nxt.append((ctx.measure(s), s))
                        break
            self._pop = nxt

from .base import Budget, Trial, TuneResult, Tuner, TuningContext, BudgetExhausted
from .gbfs import GBFSTuner
from .na2c import NA2CTuner
from .gbt import GBTTuner, GradientBoostedTrees
from .rnn_controller import RNNControllerTuner
from .classic import RandomTuner, GridTuner, AnnealingTuner, GeneticTuner

TUNERS = {
    "g-bfs": GBFSTuner,
    "n-a2c": NA2CTuner,
    "xgboost-like": GBTTuner,
    "rnn-controller": RNNControllerTuner,
    "random": RandomTuner,
    "grid": GridTuner,
    "sim-anneal": AnnealingTuner,
    "genetic": GeneticTuner,
}

__all__ = [
    "Budget",
    "Trial",
    "TuneResult",
    "Tuner",
    "TuningContext",
    "BudgetExhausted",
    "GBFSTuner",
    "NA2CTuner",
    "GBTTuner",
    "GradientBoostedTrees",
    "RNNControllerTuner",
    "RandomTuner",
    "GridTuner",
    "AnnealingTuner",
    "GeneticTuner",
    "TUNERS",
]

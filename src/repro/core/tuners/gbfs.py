"""G-BFS — Greedy Best-First-Search tuner (paper Algorithm 1, Fig. 5).

A priority queue ordered by measured cost holds the frontier.  Each
iteration pops the cheapest state, samples ``rho`` of its legitimate
unvisited neighbors (Eqn. 9), measures the whole ρ-sample in **one
engine call** (`measure_many`), and pushes the results back.  With
``n_workers >= rho`` the entire sample is measured as one concurrent
wave, so each round costs one critical-path measurement on the search
clock instead of ρ sequential ones.  With ``rho = len(g(s))`` and
unlimited budget the search visits the entire reachable space (paper
Sec. 4.2).
"""

from __future__ import annotations

import heapq
import itertools
from typing import Optional

from ..space import State
from .base import Tuner, TuningContext

__all__ = ["GBFSTuner"]


class GBFSTuner(Tuner):
    name = "g-bfs"

    def __init__(self, space, cost, seed: int = 0, rho: int = 5,
                 s0: Optional[State] = None):
        super().__init__(space, cost, seed)
        self.rho = rho
        self.s0 = s0

    def run(self, ctx: TuningContext) -> None:
        s0 = self.s0 or self.space.initial_state()
        c0 = ctx.measure(s0)
        tie = itertools.count()  # stable heap order for equal costs
        pq: list[tuple[float, int, State]] = [(c0, next(tie), s0)]
        while pq and not ctx.done():
            cost_s, _, s = heapq.heappop(pq)
            neigh = [s2 for s2 in self.space.neighbors(s) if not ctx.seen(s2)]
            if not neigh:
                continue
            rho = min(self.rho, len(neigh))
            batch = self.rng.sample(neigh, rho)
            # one engine round per ρ-sample; raises BudgetExhausted at the limit
            costs = ctx.measure_many(batch)
            for s2, c2 in zip(batch, costs):
                heapq.heappush(pq, (c2, next(tie), s2))

"""G-BFS — Greedy Best-First-Search tuner (paper Algorithm 1, Fig. 5).

A priority queue ordered by measured cost holds the frontier.  Each
iteration pops the cheapest state, samples ``rho`` of its legitimate
unvisited neighbors (Eqn. 9), measures the whole ρ-sample in **one
engine call** (`measure_many`), and pushes the results back.  With
``n_workers >= rho`` the entire sample is measured as one concurrent
wave, so each round costs one critical-path measurement on the search
clock instead of ρ sequential ones.  With ``rho = len(g(s))`` and
unlimited budget the search visits the entire reachable space (paper
Sec. 4.2).

The frontier and its tie-break counter live on the instance (not run's
stack) so a crash-safe snapshot (``state_dict``) can capture them; a
restored tuner resumes popping the exact frontier the interrupted run
would have popped next.
"""

from __future__ import annotations

import heapq
from typing import Optional

from ..space import State
from .base import Tuner, TuningContext, decode_cost, encode_cost

__all__ = ["GBFSTuner"]


class GBFSTuner(Tuner):
    name = "g-bfs"

    def __init__(self, space, cost, seed: int = 0, rho: int = 5,
                 s0: Optional[State] = None):
        super().__init__(space, cost, seed)
        self.rho = rho
        self.s0 = s0
        self._pq: Optional[list[tuple[float, int, State]]] = None
        self._tie = 0  # stable heap order for equal costs

    def _next_tie(self) -> int:
        t = self._tie
        self._tie += 1
        return t

    # -- crash-safe resume ---------------------------------------------------
    def state_dict(self) -> dict:
        d = super().state_dict()
        d["tie"] = self._tie
        d["pq"] = (
            None
            if self._pq is None
            else [[encode_cost(c), t, s.as_lists()] for c, t, s in self._pq]
        )
        return d

    def load_state_dict(self, state: dict) -> None:
        super().load_state_dict(state)
        self._tie = state["tie"]
        pq = state["pq"]
        # a heap serialized in list order deserializes as a valid heap
        self._pq = (
            None
            if pq is None
            else [
                (decode_cost(c), t, self.space.state_from_lists(rows))
                for c, t, rows in pq
            ]
        )

    def run(self, ctx: TuningContext) -> None:
        if self._pq is None:
            s0 = self.s0 or self.space.initial_state()
            c0 = ctx.measure(s0)
            self._pq = [(c0, self._next_tie(), s0)]
        while self._pq and not ctx.done():
            ctx.checkpoint(self)  # snapshot sees the un-popped frontier
            cost_s, _, s = heapq.heappop(self._pq)
            neigh = [s2 for s2 in self.space.neighbors(s) if not ctx.seen(s2)]
            if not neigh:
                continue
            rho = min(self.rho, len(neigh))
            batch = self.rng.sample(neigh, rho)
            # one engine round per ρ-sample; raises BudgetExhausted at the limit
            costs = ctx.measure_many(batch)
            for s2, c2 in zip(batch, costs):
                heapq.heappush(self._pq, (c2, self._next_tie(), s2))

"""N-A2C — Neighborhood Actor Advantage Critic tuner (paper Algorithm 2,
Fig. 6).

Per episode the agent rolls out ``T`` steps from the neighborhood center
(the best state ever visited), collecting *unvisited* states into a
candidate batch; when the batch is full, all candidates are measured in
**one batched engine call** (``measure_many`` — with ``n_workers`` lanes
the whole episode batch costs one wave of search clock, the refactor the
TVM line of work uses to win wall-clock), the replay memory is updated
with transitions and rewards ``r = 1/cost(s')`` (Eqn. 8), and the
actor/critic networks are trained from replay.  Rollout bookkeeping is
vectorized where it does not perturb the sampling sequence: action masks
are memoized per episode (each is 26 ``space.step`` probes) and replay
features are stacked once per round.  The center re-anchors to the
incumbent (line 22 of Algorithm 2).

Faithfulness notes:
  * The paper's ε-greedy is stated as "with probability ε follow π,
    otherwise random" — we keep that orientation and anneal ε upward
    (start exploratory, end policy-driven), plus the paper's suggested
    T-decay heuristic as an option.
  * Rewards are normalized by the initial state's cost (a fixed positive
    scale on Eqn. 8 that leaves the ordering and the argmax unchanged)
    so network training is well-conditioned across GEMM sizes.
  * Actor/critic are small MLPs over the space's tiling features;
    illegitimate actions are masked out of the policy.
"""

from __future__ import annotations

import collections
import math
from typing import Optional

import numpy as np

from ..snapshot import tree_from_jsonable, tree_to_jsonable
from ..space import State
from .base import Tuner, TuningContext

__all__ = ["NA2CTuner"]


class NA2CTuner(Tuner):
    name = "n-a2c"

    def __init__(
        self,
        space,
        cost,
        seed: int = 0,
        steps_per_episode: int = 3,  # paper: T = 3 for the GPU experiments
        batch_size: int = 16,  # len(B_test)
        epsilon0: float = 0.35,
        epsilon1: float = 0.9,
        gamma: float = 0.9,
        hidden: int = 64,
        lr: float = 3e-3,
        entropy_beta: float = 1e-2,
        replay_cap: int = 4096,
        train_iters: int = 8,
        t_decay: bool = False,
        s0: Optional[State] = None,
    ):
        super().__init__(space, cost, seed)
        self.T = steps_per_episode
        self.batch_size = batch_size
        self.eps0, self.eps1 = epsilon0, epsilon1
        self.gamma = gamma
        self.hidden = hidden
        self.lr = lr
        self.entropy_beta = entropy_beta
        self.replay_cap = replay_cap
        self.train_iters = train_iters
        self.t_decay = t_decay
        self.s0 = s0
        self._jax_ready = False
        # search memory (externalized so snapshots can capture it)
        self._center: Optional[State] = None
        self._c_ref: Optional[float] = None
        self._replay: Optional[collections.deque] = None
        self._episode = 0
        self._T = steps_per_episode

    # -- crash-safe resume ---------------------------------------------------
    def state_dict(self) -> dict:
        d = super().state_dict()
        d["center"] = None if self._center is None else self._center.as_lists()
        d["c_ref"] = self._c_ref
        d["episode"] = self._episode
        d["T"] = self._T
        d["replay"] = (
            None
            if self._replay is None
            else [tree_to_jsonable(e) for e in self._replay]
        )
        if self._jax_ready:
            d["params"] = tree_to_jsonable(self.params)
            d["opt_state"] = tree_to_jsonable(self.opt_state)
        return d

    def load_state_dict(self, state: dict) -> None:
        super().load_state_dict(state)
        self._center = (
            None
            if state["center"] is None
            else self.space.state_from_lists(state["center"])
        )
        self._c_ref = state["c_ref"]
        self._episode = state["episode"]
        self._T = state["T"]
        self._replay = (
            None
            if state["replay"] is None
            else collections.deque(
                (tree_from_jsonable(e) for e in state["replay"]),
                maxlen=self.replay_cap,
            )
        )
        if "params" in state:
            if not self._jax_ready:
                self._setup()  # builds jitted fns + shapes, then overwrite
            leaf = self._jnp.asarray
            self.params = tree_from_jsonable(state["params"], leaf)
            self.opt_state = tree_from_jsonable(state["opt_state"], leaf)

    # -- lazy jax setup (keeps import cheap for non-RL users) -----------------
    def _setup(self):
        import jax
        import jax.numpy as jnp

        from .nn import adam_init, adam_update, init_mlp, mlp_apply

        self._jax, self._jnp = jax, jnp
        F, A = self.space.n_features, self.space.n_actions
        key = jax.random.PRNGKey(self.seed)
        ka, kc = jax.random.split(key)
        self.params = {
            "actor": init_mlp(ka, [F, self.hidden, self.hidden, A]),
            "critic": init_mlp(kc, [F, self.hidden, self.hidden, 1]),
        }
        self.opt_state = adam_init(self.params)
        self._mlp_apply = mlp_apply
        self._adam_update = adam_update

        def loss_fn(params, feats, acts, rewards, feats2, mask, mask2):
            logits = mlp_apply(params["actor"], feats)
            logits = jnp.where(mask, logits, -1e9)
            logp = jax.nn.log_softmax(logits, axis=-1)
            v = mlp_apply(params["critic"], feats)[:, 0]
            v2 = mlp_apply(params["critic"], feats2)[:, 0]
            target = rewards + self.gamma * jax.lax.stop_gradient(v2)
            adv = target - v
            critic_loss = jnp.mean(adv**2)
            sel_logp = jnp.take_along_axis(logp, acts[:, None], axis=-1)[:, 0]
            actor_loss = -jnp.mean(sel_logp * jax.lax.stop_gradient(adv))
            p = jnp.exp(logp)
            entropy = -jnp.mean(jnp.sum(jnp.where(mask, p * logp, 0.0), axis=-1))
            return actor_loss + 0.5 * critic_loss - self.entropy_beta * entropy

        @jax.jit
        def train_step(params, opt_state, feats, acts, rewards, feats2, mask, mask2):
            g = jax.grad(loss_fn)(params, feats, acts, rewards, feats2, mask, mask2)
            return adam_update(params, g, opt_state, lr=self.lr)

        @jax.jit
        def policy_logits(params, feat, mask):
            logits = mlp_apply(params["actor"], feat[None, :])[0]
            return jnp.where(mask, logits, -1e9)

        self._train_step = train_step
        self._policy_logits = policy_logits
        self._jax_ready = True

    # -- helpers ---------------------------------------------------------------
    def _action_mask(self, s: State) -> np.ndarray:
        return np.array(
            [self.space.step(s, a) is not None for a in self.space.actions],
            dtype=bool,
        )

    def _policy_action(self, s: State, mask: np.ndarray) -> int:
        logits = np.asarray(self._policy_logits(self.params, self.space.features(s), mask))
        # sample from the masked softmax
        z = logits - logits.max()
        p = np.exp(z)
        p = p / p.sum()
        return int(np.searchsorted(np.cumsum(p), self.rng.random()))

    # -- Algorithm 2 -------------------------------------------------------------
    def run(self, ctx: TuningContext) -> None:
        if not self._jax_ready:
            self._setup()
        np_ = np
        if self._replay is None:
            self._center = self.s0 or self.space.initial_state()
            c_ref = ctx.measure(self._center)
            self._c_ref = c_ref if math.isfinite(c_ref) else 1.0
            self._replay = collections.deque(maxlen=self.replay_cap)
        c_ref = self._c_ref
        replay = self._replay
        while not ctx.done():
            ctx.checkpoint(self)
            T = self._T
            center = self._center
            frac = len(ctx.trials) / max(1, ctx.max_trials)
            eps = self.eps0 + (self.eps1 - self.eps0) * frac
            collected: list[State] = []
            collected_keys: set[str] = set()
            transitions: list[tuple[State, int, State]] = []
            # per-episode mask memo: each mask is 26 space.step probes and
            # rollouts + replay revisit the same states repeatedly
            masks: dict[str, np.ndarray] = {}

            def mask_of(s: State) -> np.ndarray:
                m = masks.get(s.key())
                if m is None:
                    m = self._action_mask(s)
                    masks[s.key()] = m
                return m

            # -- collect candidates by T-step rollouts around the center ------
            guard = 0
            while len(collected) < self.batch_size and guard < 50:
                guard += 1
                s = center
                for _ in range(max(1, T)):
                    mask = mask_of(s)
                    if not mask.any():
                        break
                    if self.rng.random() < eps:
                        a_idx = self._policy_action(s, mask)
                        if not mask[a_idx]:
                            a_idx = self.rng.choice(np_.flatnonzero(mask).tolist())
                    else:
                        a_idx = self.rng.choice(np_.flatnonzero(mask).tolist())
                    s2 = self.space.step(s, self.space.actions[a_idx])
                    assert s2 is not None
                    transitions.append((s, a_idx, s2))
                    if not ctx.seen(s2) and s2.key() not in collected_keys:
                        collected.append(s2)
                        collected_keys.add(s2.key())
                    s = s2
            if not collected:
                # neighborhood exhausted: hop the center to a random state
                self._center = self.space.random_state(self.rng)
                if not ctx.seen(self._center):
                    ctx.measure(self._center)
                continue
            # -- measure the batch on "hardware": one engine round ---------------
            ctx.measure_many(collected)  # may raise BudgetExhausted — fine (line 4)
            # -- replay update: rewards from the visited-cost table -------------
            for (s, a_idx, s2) in transitions:
                c2 = ctx.visited.get(s2.key())
                if c2 is None:
                    continue
                r = 0.0 if not math.isfinite(c2) else float(c_ref / c2)
                replay.append(
                    (
                        self.space.features(s),
                        a_idx,
                        r,
                        self.space.features(s2),
                        mask_of(s),
                        mask_of(s2),
                    )
                )
            # -- re-anchor the neighborhood center (Algorithm 2 line 22) --------
            if ctx.best_state is not None:
                self._center = ctx.best_state
            # -- train actor + critic from replay -------------------------------
            if len(replay) >= 8:
                for _ in range(self.train_iters):
                    idx = [self.rng.randrange(len(replay)) for _ in range(min(64, len(replay)))]
                    batch = [replay[i] for i in idx]
                    feats = np_.stack([b[0] for b in batch])
                    acts = np_.array([b[1] for b in batch], dtype=np_.int32)
                    rewards = np_.array([b[2] for b in batch], dtype=np_.float32)
                    feats2 = np_.stack([b[3] for b in batch])
                    mask = np_.stack([b[4] for b in batch])
                    mask2 = np_.stack([b[5] for b in batch])
                    self.params, self.opt_state = self._train_step(
                        self.params, self.opt_state, feats, acts, rewards, feats2, mask, mask2
                    )
            self._episode += 1
            if self.t_decay and self._episode % 16 == 0 and self._T > 1:
                self._T -= 1

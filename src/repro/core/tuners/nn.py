"""Tiny pure-JAX neural-net toolkit shared by the learned tuners
(N-A2C actor/critic MLPs, RNN-controller GRU).  No flax/optax in this
container, so layers and Adam are implemented directly on pytrees."""

from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "init_mlp",
    "mlp_apply",
    "init_gru",
    "gru_step",
    "init_linear",
    "linear_apply",
    "adam_init",
    "adam_update",
]


def init_linear(key, n_in: int, n_out: int) -> dict:
    wk, _ = jax.random.split(key)
    scale = math.sqrt(2.0 / n_in)
    return {
        "w": jax.random.normal(wk, (n_in, n_out), jnp.float32) * scale,
        "b": jnp.zeros((n_out,), jnp.float32),
    }


def linear_apply(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"] + p["b"]


def init_mlp(key, sizes: Sequence[int]) -> list[dict]:
    keys = jax.random.split(key, len(sizes) - 1)
    return [init_linear(k, a, b) for k, a, b in zip(keys, sizes[:-1], sizes[1:])]


def mlp_apply(params: list[dict], x: jnp.ndarray) -> jnp.ndarray:
    for i, p in enumerate(params):
        x = linear_apply(p, x)
        if i < len(params) - 1:
            x = jnp.tanh(x)
    return x


def init_gru(key, n_in: int, n_hidden: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = math.sqrt(1.0 / n_in)
    s_h = math.sqrt(1.0 / n_hidden)
    return {
        "wi": jax.random.normal(k1, (n_in, 3 * n_hidden), jnp.float32) * s_in,
        "wh": jax.random.normal(k2, (n_hidden, 3 * n_hidden), jnp.float32) * s_h,
        "b": jnp.zeros((3 * n_hidden,), jnp.float32),
        "h0": jax.random.normal(k3, (n_hidden,), jnp.float32) * 0.01,
    }


def gru_step(p: dict, h: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    nh = h.shape[-1]
    xi = x @ p["wi"]
    hh = h @ p["wh"]
    r = jax.nn.sigmoid(xi[..., :nh] + hh[..., :nh] + p["b"][:nh])
    z = jax.nn.sigmoid(xi[..., nh : 2 * nh] + hh[..., nh : 2 * nh] + p["b"][nh : 2 * nh])
    cand = jnp.tanh(xi[..., 2 * nh :] + r * hh[..., 2 * nh :] + p["b"][2 * nh :])
    return (1.0 - z) * h + z * cand


# ----------------------------------------------------------------------------
# Adam on arbitrary pytrees
# ----------------------------------------------------------------------------

def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}

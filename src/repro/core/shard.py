"""Deterministic candidate sharding — the multi-host partitioner over
one shared trial journal.

The substrate for distributed search already exists: the
:class:`~repro.core.records.TrialJournal` is an O_APPEND shared log any
number of processes can write without tearing, ``reload_every`` merges
sibling rows mid-search, and the executable cache is content-keyed.
What was missing is the *partitioner*: a rule that makes two hosts
running the same search never measure the same candidate, plus a final
election that reconciles their per-shard bests into one records entry.

Both live here:

* :func:`shard_of` — the ownership rule.  A candidate belongs to
  ``blake2b(workload_key | state_key) mod n_shards``.  Hashing the
  workload key *into* the digest seeds the partition per workload, so
  the same tiling state lands on different shards for different
  workloads — no shard is systematically starved of good candidates
  across an arch.  The hash is stable across processes, hosts, and
  Python versions (unlike ``hash()``), so every participant computes
  the same owner without coordination.
* :class:`ShardSpec` — ``index/count`` with ``owns()``; ``0/1`` (the
  default everywhere) disables sharding entirely.
* **done markers** — tiny JSON files in a ``<journal>.shards/``
  directory, one per ``(workload, shard)``, written atomically when a
  shard finishes its search.  They carry the shard's journaled best, so
  the elect-and-merge step (:func:`elect_best`) needs no coordinator:
  every shard waits for its siblings' markers (:func:`await_markers`),
  then deterministically picks the winner — lowest journaled cost,
  ties broken by shard index — and keep-best-merges it into the
  records table (idempotent, so every shard may do it).

The :class:`~repro.core.measure.MeasureEngine` applies ownership *after*
the cache/static/learned funnel: a non-owned cache miss is first given
one journal reload (the sibling may have measured it already — a free
hit), and otherwise becomes a **deferred** outcome (``inf`` cost, zero
lane time) instead of occupying a lane.  ``repro.launch.analyze``
audits the result: a journal row claiming shard ``i`` whose recomputed
owner differs, or one candidate measured by two shards, is an error.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import re
import tempfile
import time
from typing import Optional

__all__ = [
    "ShardSpec",
    "parse_shard",
    "shard_of",
    "shard_dir_for",
    "write_done_marker",
    "read_done_markers",
    "await_markers",
    "elect_best",
]


def shard_of(workload_key: str, state_key: str, n_shards: int) -> int:
    """Owner shard of one candidate: a stable hash of the workload key
    and the state key, mod the shard count.  The workload key acts as a
    per-workload seed — the same state key maps to different owners for
    different workloads."""
    if n_shards <= 1:
        return 0
    h = hashlib.blake2b(
        f"{workload_key}|{state_key}".encode("utf-8"), digest_size=8
    )
    return int.from_bytes(h.digest(), "big") % n_shards


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """This engine's slice of a sharded search: shard ``index`` of
    ``count``.  ``count == 1`` means sharding is off (``enabled`` is
    False and ``owns`` accepts everything) — the engine stays
    bit-identical to an unsharded one."""

    index: int
    count: int

    def __post_init__(self):
        if self.count < 1:
            raise ValueError(f"shard count must be >= 1, got {self.count}")
        if not (0 <= self.index < self.count):
            raise ValueError(
                f"shard index must be in [0, {self.count}), got {self.index}"
            )

    @property
    def enabled(self) -> bool:
        return self.count > 1

    def owns(self, workload_key: str, state_key: str) -> bool:
        if not self.enabled:
            return True
        return shard_of(workload_key, state_key, self.count) == self.index

    def __str__(self) -> str:
        return f"{self.index}/{self.count}"


_SHARD_RE = re.compile(r"^(\d+)/(\d+)$")


def parse_shard(spec: str) -> ShardSpec:
    """Parse the CLI spelling ``I/N`` (e.g. ``0/2``) into a
    :class:`ShardSpec`; range errors surface from the dataclass."""
    m = _SHARD_RE.match(spec.strip())
    if m is None:
        raise ValueError(
            f"shard spec must look like I/N (e.g. 0/2), got {spec!r}"
        )
    return ShardSpec(int(m.group(1)), int(m.group(2)))


# -- done markers / election ---------------------------------------------------

def shard_dir_for(journal_path: str) -> str:
    """Default location of the shard done-markers: a directory next to
    the :class:`~repro.core.records.TrialJournal`, like the executable
    and learned-model caches — everything a sharded search shares
    travels with the journal file."""
    return journal_path + ".shards"


def _workload_dir(root: str, workload_key: str) -> str:
    slug = re.sub(r"[^A-Za-z0-9._=-]+", "_", workload_key)[:80]
    h = hashlib.blake2b(workload_key.encode("utf-8"), digest_size=6).hexdigest()
    return os.path.join(root, f"{slug}-{h}")


def _marker_name(index: int, count: int) -> str:
    return f"shard_{index}_of_{count}.done.json"


_MARKER_RE = re.compile(r"^shard_(\d+)_of_(\d+)\.done\.json$")


def write_done_marker(
    root: str,
    workload_key: str,
    shard: ShardSpec,
    best_state_lists: Optional[list],
    best_cost: float,
    n_measured: int,
) -> str:
    """Atomically publish one shard's completion marker (staging file →
    ``os.replace``).  ``best_cost`` is the shard's lowest *journaled*
    cost (``inf`` → ``null``: the shard finished but found nothing
    finite, which the election skips)."""
    d = _workload_dir(root, workload_key)
    os.makedirs(d, exist_ok=True)
    payload = {
        "workload": workload_key,
        "shard": shard.index,
        "n_shards": shard.count,
        "best": best_state_lists,
        "best_cost": best_cost if math.isfinite(best_cost) else None,
        "n_measured": int(n_measured),
    }
    path = os.path.join(d, _marker_name(shard.index, shard.count))
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, separators=(",", ":"))
        os.replace(tmp, path)  # atomic publish
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def read_done_markers(
    root: str, workload_key: str, n_shards: int
) -> dict[int, dict]:
    """All committed markers for one workload at the given shard count
    (``{shard_index: payload}``); unreadable or foreign files are
    skipped — a marker either parsed or does not exist yet."""
    d = _workload_dir(root, workload_key)
    out: dict[int, dict] = {}
    for i in range(n_shards):
        path = os.path.join(d, _marker_name(i, n_shards))
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError):
            continue
        if isinstance(payload, dict):
            out[i] = payload
    return out


def await_markers(
    root: str,
    workload_key: str,
    shard: ShardSpec,
    timeout_s: float = 60.0,
    poll_s: float = 0.2,
) -> dict[int, dict]:
    """Poll for all ``shard.count`` done markers of one workload, up to
    ``timeout_s`` seconds.  Returns whatever is present at the end —
    the caller elects over the partial set when a sibling never reports
    (a dead host must not wedge the survivors forever)."""
    deadline = time.monotonic() + max(0.0, timeout_s)
    while True:
        markers = read_done_markers(root, workload_key, shard.count)
        if len(markers) >= shard.count or time.monotonic() >= deadline:
            return markers
        time.sleep(poll_s)


def elect_best(markers: dict[int, dict]) -> Optional[tuple[int, list, float]]:
    """The merged winner over a set of done markers: lowest journaled
    ``best_cost``, ties broken by the lower shard index (scanning in
    index order and using strict ``<`` makes the tie-break implicit).
    Returns ``(shard_index, best_state_lists, best_cost)``, or None
    when no shard reported a finite best."""
    winner: Optional[tuple[int, list, float]] = None
    for i in sorted(markers):
        m = markers[i]
        c = m.get("best_cost")
        lists = m.get("best")
        if c is None or lists is None:
            continue
        c = float(c)
        if winner is None or c < winner[2]:
            winner = (i, lists, c)
    return winner

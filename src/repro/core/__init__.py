"""repro.core — the paper's contribution, generalized: operator-level
schedule autotuning.

Public surface:
  SearchSpace / State / Action             — the op-agnostic MDP protocol
  GemmConfigSpace / TilingState            — the canonical (GEMM) instance
  FlashAttnConfigSpace / FlashScheduleState— the first non-GEMM instance
  ops.*  (OpSpec / get_op / OPS)           — the operator registry
  cost.*                                   — pluggable cost oracles
  analysis.* (ScheduleAnalyzer)            — compile-free static verdicts
  learn.*  (RankingCostModel / ProposalFilter) — journal-trained cost models
  tuners.*                                 — G-BFS, N-A2C + baselines
  TuningSession / Workload (GemmWorkload)  — orchestration
  TuningRecords                            — persisted best configs
"""

from .analysis import (
    ILLEGAL,
    OK,
    WASTEFUL,
    AnalysisResult,
    ScheduleAnalyzer,
    analyzer_for_backend,
    should_prune,
)
from .config_space import Action, GemmConfigSpace, TilingState
from .cost import (
    AnalyticalTPUCost,
    CostBackend,
    CountingCost,
    FlashAnalyticalCost,
    SleepingCost,
    TpuSpec,
)
from .executor import (
    EXECUTORS,
    LaneExecutor,
    LaneResult,
    ProcessExecutor,
    SimulatedExecutor,
    ThreadExecutor,
    make_executor,
)
from .fault import (
    PERMANENT_KINDS,
    TRANSIENT_KINDS,
    FaultInjectionCost,
    FaultPlan,
    RetryPolicy,
    classify_error,
)
from .flash_space import FlashAttnConfigSpace, FlashScheduleState
from .learn import (
    JournalDataset,
    ProposalFilter,
    RankingCostModel,
    build_dataset,
    learn_cache_dir_for,
)
from .measure import MeasureEngine, MeasureOutcome, MeasureStats
from .ops import OPS, OpSpec, get_op, op_names, register_op
from .records import (
    TrialJournal,
    TuningRecords,
    global_records,
    parse_workload_key,
    parse_workload_key_generic,
    set_global_records,
    workload_key,
    workload_key_for,
)
from .session import ArchTuneReport, GemmWorkload, TuningSession, Workload
from .shard import (
    ShardSpec,
    await_markers,
    elect_best,
    parse_shard,
    read_done_markers,
    shard_dir_for,
    shard_of,
    write_done_marker,
)
from .snapshot import TuneCheckpointer, TuneInterrupted
from .space import FactoredSearchSpace, SearchSpace, State
from .tuners import (
    TUNERS,
    Budget,
    GBFSTuner,
    GBTTuner,
    NA2CTuner,
    RNNControllerTuner,
    TuneResult,
    Tuner,
)

__all__ = [
    "ILLEGAL",
    "OK",
    "WASTEFUL",
    "AnalysisResult",
    "ScheduleAnalyzer",
    "analyzer_for_backend",
    "should_prune",
    "Action",
    "GemmConfigSpace",
    "TilingState",
    "FlashAttnConfigSpace",
    "FlashScheduleState",
    "SearchSpace",
    "FactoredSearchSpace",
    "State",
    "OPS",
    "OpSpec",
    "get_op",
    "op_names",
    "register_op",
    "AnalyticalTPUCost",
    "FlashAnalyticalCost",
    "CostBackend",
    "CountingCost",
    "SleepingCost",
    "TpuSpec",
    "EXECUTORS",
    "LaneExecutor",
    "LaneResult",
    "ProcessExecutor",
    "SimulatedExecutor",
    "ThreadExecutor",
    "make_executor",
    "JournalDataset",
    "ProposalFilter",
    "RankingCostModel",
    "build_dataset",
    "learn_cache_dir_for",
    "MeasureEngine",
    "MeasureOutcome",
    "MeasureStats",
    "PERMANENT_KINDS",
    "TRANSIENT_KINDS",
    "FaultInjectionCost",
    "FaultPlan",
    "RetryPolicy",
    "classify_error",
    "ShardSpec",
    "await_markers",
    "elect_best",
    "parse_shard",
    "read_done_markers",
    "shard_dir_for",
    "shard_of",
    "write_done_marker",
    "TuneCheckpointer",
    "TuneInterrupted",
    "TrialJournal",
    "TuningRecords",
    "global_records",
    "parse_workload_key",
    "parse_workload_key_generic",
    "set_global_records",
    "workload_key",
    "workload_key_for",
    "ArchTuneReport",
    "GemmWorkload",
    "Workload",
    "TuningSession",
    "TUNERS",
    "Budget",
    "GBFSTuner",
    "GBTTuner",
    "NA2CTuner",
    "RNNControllerTuner",
    "TuneResult",
    "Tuner",
]

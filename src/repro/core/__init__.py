"""repro.core — the paper's contribution: GEMM tiling autotuning.

Public surface:
  GemmConfigSpace / TilingState / Action   — the MDP (paper Sec. 4.1)
  cost.*                                   — pluggable cost oracles
  tuners.*                                 — G-BFS, N-A2C + baselines
  TuningSession / GemmWorkload             — orchestration
  TuningRecords                            — persisted best configs
"""

from .config_space import Action, GemmConfigSpace, TilingState
from .cost import AnalyticalTPUCost, CostBackend, CountingCost, SleepingCost, TpuSpec
from .executor import (
    EXECUTORS,
    LaneExecutor,
    LaneResult,
    ProcessExecutor,
    SimulatedExecutor,
    ThreadExecutor,
    make_executor,
)
from .measure import MeasureEngine, MeasureOutcome, MeasureStats
from .records import (
    TrialJournal,
    TuningRecords,
    global_records,
    parse_workload_key,
    set_global_records,
    workload_key,
)
from .session import ArchTuneReport, GemmWorkload, TuningSession
from .tuners import (
    TUNERS,
    Budget,
    GBFSTuner,
    GBTTuner,
    NA2CTuner,
    RNNControllerTuner,
    TuneResult,
    Tuner,
)

__all__ = [
    "Action",
    "GemmConfigSpace",
    "TilingState",
    "AnalyticalTPUCost",
    "CostBackend",
    "CountingCost",
    "SleepingCost",
    "TpuSpec",
    "EXECUTORS",
    "LaneExecutor",
    "LaneResult",
    "ProcessExecutor",
    "SimulatedExecutor",
    "ThreadExecutor",
    "make_executor",
    "MeasureEngine",
    "MeasureOutcome",
    "MeasureStats",
    "TrialJournal",
    "TuningRecords",
    "global_records",
    "parse_workload_key",
    "set_global_records",
    "workload_key",
    "ArchTuneReport",
    "GemmWorkload",
    "TuningSession",
    "TUNERS",
    "Budget",
    "GBFSTuner",
    "GBTTuner",
    "NA2CTuner",
    "RNNControllerTuner",
    "TuneResult",
    "Tuner",
]

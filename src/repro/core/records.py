"""Tuning-record store: persisted best configurations per GEMM workload,
plus the persistent trial journal the measurement engine caches from.

Two artifacts live here:

* :class:`TuningRecords` — the keep-best table the framework ships (the
  analogue of AutoTVM's tophub).  ``kernels/ops.py`` consults the
  process-global store at trace time to pick the Pallas BlockSpec config
  for each matmul shape; ``launch/tune.py`` writes it.  Plain JSON for
  diffability; crash-safe via atomic replace.
* :class:`TrialJournal` — an append-only JSONL log of *every*
  measurement ever taken, keyed by workload.  The
  :class:`~repro.core.measure.MeasureEngine` consults it before
  dispatching to hardware, so repeat queries — within a session, across
  sessions, or across workloads that share GEMM shapes — are served from
  cache; ``TuningSession`` also uses it to warm-start a workload from
  the nearest previously-tuned shape.
"""

from __future__ import annotations

import json
import math
import os
import re
import tempfile
import threading
import time
from typing import Iterable, Optional, Sequence

from .fault import PERMANENT_KINDS, TRANSIENT_KINDS
from .space import State, state_from_lists

__all__ = [
    "TuningRecords",
    "TrialJournal",
    "workload_key",
    "workload_key_for",
    "parse_workload_key",
    "parse_workload_key_generic",
    "op_of_workload_key",
    "donor_distance",
    "iter_journal_rows",
    "compile_cache_dir_for",
    "global_records",
    "set_global_records",
    "add_change_listener",
]


# -- change notification -------------------------------------------------------
# Trace-time consumers (kernels/ops.py memoizes its per-shape record
# lookups) must drop their caches whenever the visible records change:
# a keep-best update, or the process-global store being swapped for a
# freshly loaded one.  Listeners must be idempotent and cheap.

_CHANGE_LISTENERS: list = []


def add_change_listener(fn) -> None:
    """Register ``fn()`` to run after any TuningRecords mutation or
    global-store swap.  Exceptions in listeners propagate — a broken
    invalidation hook must fail loudly, not serve stale schedules."""
    _CHANGE_LISTENERS.append(fn)


def _notify_change() -> None:
    for fn in list(_CHANGE_LISTENERS):
        fn()


def compile_cache_dir_for(journal_path: str) -> str:
    """Default location of the persistent compiled-program cache for
    measured backends (``XLATimedCost``): a directory next to the
    :class:`TrialJournal`, so the two cross-session caches — measured
    costs and compiled executables — travel together and sibling
    engines/hosts sharing the journal path share the executables too."""
    return journal_path + ".xlacache"


def workload_key_for(op: str, dims: Sequence[int], dtype: str = "bfloat16",
                     backend: str = "analytical_tpu_v5e") -> str:
    """Persistent-store key for one op workload.  GEMM keeps its legacy
    ``gemm/m{M}k{K}n{N}/...`` spelling bit-for-bit (old records files and
    journals stay valid); every other op gets the generic
    ``{op}/{d0}x{d1}x../{dtype}/{backend}`` form.  Either way the key
    leads with the op, so cross-op rows can never collide."""
    if op == "gemm":
        m, k, n = dims
        return f"gemm/m{m}k{k}n{n}/{dtype}/{backend}"
    return f"{op}/" + "x".join(str(d) for d in dims) + f"/{dtype}/{backend}"


def workload_key(m: int, k: int, n: int, dtype: str = "bfloat16",
                 backend: str = "analytical_tpu_v5e") -> str:
    """Back-compat GEMM spelling of :func:`workload_key_for`."""
    return workload_key_for("gemm", (m, k, n), dtype, backend)


_KEY_RE = re.compile(r"^gemm/m(\d+)k(\d+)n(\d+)/([^/]+)/(.+)$")
_GENERIC_KEY_RE = re.compile(r"^([A-Za-z0-9_-]+)/(\d+(?:x\d+)*)/([^/]+)/(.+)$")


def parse_workload_key(key: str) -> Optional[tuple[int, int, int, str, str]]:
    """Inverse of :func:`workload_key`: ``(m, k, n, dtype, backend)``
    (GEMM keys only; returns None for other ops)."""
    m = _KEY_RE.match(key)
    if m is None:
        return None
    return int(m.group(1)), int(m.group(2)), int(m.group(3)), m.group(4), m.group(5)


def parse_workload_key_generic(
    key: str,
) -> Optional[tuple[str, tuple[int, ...], str, str]]:
    """Inverse of :func:`workload_key_for`:
    ``(op, dims, dtype, backend)`` for any op (legacy GEMM keys
    included)."""
    m = _KEY_RE.match(key)
    if m is not None:
        return (
            "gemm",
            (int(m.group(1)), int(m.group(2)), int(m.group(3))),
            m.group(4),
            m.group(5),
        )
    g = _GENERIC_KEY_RE.match(key)
    if g is None:
        return None
    dims = tuple(int(x) for x in g.group(2).split("x"))
    return g.group(1), dims, g.group(3), g.group(4)


def donor_distance(
    parsed: tuple[str, tuple[int, ...], str, str],
    op: str,
    dims: Sequence[int],
    dtype: Optional[str] = None,
    backend: Optional[str] = None,
    fixed_tail: int = 0,
) -> Optional[float]:
    """THE warm-start donor filter, shared by the records and journal
    scans: log-shape distance from a parsed donor workload key (see
    :func:`parse_workload_key_generic`) to ``(op, dims)``, or ``None``
    when the donor is out of scope — different op, dims arity, trailing
    identity dims (``fixed_tail``, e.g. flash's head_dim), dtype, or
    backend."""
    op2, dims2, dt2, be2 = parsed
    dims = tuple(dims)
    if op2 != op or len(dims2) != len(dims):
        return None
    if fixed_tail and dims2[-fixed_tail:] != dims[-fixed_tail:]:
        return None
    if backend is not None and be2 != backend:
        return None
    if dtype is not None and dt2 != dtype:
        return None
    return sum(abs(math.log2(a / b)) for a, b in zip(dims2, dims))


def op_of_workload_key(key: str) -> str:
    """The op a workload key (or ``key?fingerprint`` journal key)
    belongs to; pre-op-registry keys are all GEMM."""
    op = key.split("/", 1)[0]
    return op if "/" in key else "gemm"


class TuningRecords:
    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._lock = threading.Lock()
        self._data: dict[str, dict] = {}
        if path and os.path.exists(path):
            with open(path) as f:
                self._data = json.load(f)

    # -- read ------------------------------------------------------------------
    def lookup(self, key: str) -> Optional[dict]:
        return self._data.get(key)

    def lookup_state(self, key: str) -> Optional[State]:
        rec = self.lookup(key)
        if rec is None:
            return None
        op = rec.get("op") or op_of_workload_key(key)
        try:
            return state_from_lists(op, rec["state"])
        except KeyError:  # op's space module not available here
            return None

    def best_cost(self, key: str) -> float:
        rec = self.lookup(key)
        return rec["cost"] if rec else math.inf

    def __len__(self) -> int:
        return len(self._data)

    def keys(self):
        return self._data.keys()

    # -- write -----------------------------------------------------------------
    def update(
        self,
        key: str,
        state: State,
        cost: float,
        tuner: str,
        n_trials: int,
        extra: Optional[dict] = None,
    ) -> bool:
        """Keep-best merge; returns True if the record improved."""
        with self._lock:
            old = self._data.get(key)
            if old is not None and old["cost"] <= cost:
                return False
            self._data[key] = {
                "op": op_of_workload_key(key),
                "state": state.as_lists(),
                "cost": cost,
                "tuner": tuner,
                "n_trials": n_trials,
                "timestamp": time.time(),
                **(extra or {}),
            }
            self._flush_locked()
        # outside the lock: listeners may read back through this store
        _notify_change()
        return True

    def _flush_locked(self) -> None:
        if not self.path:
            return
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self._data, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)  # atomic publish
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)


class TrialJournal:
    """Append-only measurement log: ``(workload, state) -> cost``.

    Persists as strict JSONL — one row per measurement, written as a
    **single ``write()`` on an ``O_APPEND`` descriptor**, so any number
    of engines *and processes* can share one journal file without ever
    interleaving torn rows (POSIX serialises O_APPEND writes).  Failed
    builds (``math.inf``) are journaled too — knowing a config fails is
    exactly as cacheable as knowing its runtime — but encoded as
    ``{"c": null, "fail": true}`` so every row survives strict
    ``json.loads``; legacy ``Infinity`` rows are still understood on
    load.  A crash mid-append leaves at most one unterminated tail line,
    which loading skips (and a later :meth:`reload` re-reads once some
    surviving writer completes it).

    Rows carry an ``op`` schema field (rows from before the op
    registry load as ``op="gemm"``); a workload key belongs to
    exactly one op, and lookups can assert it (:meth:`get` with
    ``op=``), so a mixed-op journal can never serve a flash row to a
    GEMM search.  The in-memory view is a per-workload cost table plus a running best
    (state, cost) pair used for warm starts.  :meth:`reload` merges rows
    appended by sibling engines/processes since the last read — the
    multi-engine sharing primitive.  The journal is a context manager;
    ``close()`` drops the append descriptor (reopened lazily by the next
    ``record``).
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._lock = threading.Lock()
        self._costs: dict[str, dict[str, float]] = {}
        self._best: dict[str, tuple[float, list]] = {}
        self._ops: dict[str, str] = {}  # workload -> op (schema guard)
        self._static_seen: dict[str, set] = {}  # audit rows already journaled
        # transient-failure provenance rows already journaled (kept OUT of
        # the cost table — see record_failure)
        self._transient_seen: dict[str, set] = {}
        # learned-filter skip rows already journaled (provenance only —
        # a prediction must never be served as a measurement)
        self._pred_seen: dict[str, set] = {}
        self._fd: Optional[int] = None
        self._read_pos = 0  # how far reload() has consumed the file
        if path:
            self.reload()

    def __enter__(self) -> "TrialJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @staticmethod
    def _row_cost(row: dict) -> float:
        c = row.get("c")
        if row.get("fail") or c is None:
            return math.inf
        return float(c)  # legacy rows: json.loads already accepts Infinity

    def reload(self) -> int:
        """Ingest rows appended to the file since the last load —
        including rows written by *other* engines or processes sharing
        this journal path.  Returns the number of new rows ingested
        (rows this instance already holds dedup to zero).  Only complete
        (newline-terminated) lines are consumed; a torn tail stays
        unread until a later reload sees it completed."""
        if not self.path or not os.path.exists(self.path):
            return 0
        n_new = 0
        with self._lock:
            with open(self.path, "rb") as f:
                f.seek(self._read_pos)
                data = f.read()
            end = data.rfind(b"\n")
            if end < 0:
                return 0
            self._read_pos += end + 1
            for line in data[: end + 1].splitlines():
                if not line.strip():
                    continue
                try:
                    row = json.loads(line)
                    if isinstance(row, dict) and "static" in row:
                        # analyzer audit row (a pruned candidate, not a
                        # measurement): remember it for dedup but keep it
                        # out of the cost table — a later analyze=off run
                        # must re-measure the state, not cache-hit inf
                        self._static_seen.setdefault(
                            row["w"], set()
                        ).add(row["k"])
                        continue
                    if isinstance(row, dict) and "pred" in row:
                        # learned-filter skip row (a *prediction*, not a
                        # measurement): provenance only — without this
                        # branch the row would fall through below and be
                        # ingested as a cacheable inf "failure", poisoning
                        # the cost table with guesses
                        self._pred_seen.setdefault(
                            row["w"], set()
                        ).add(row["k"])
                        continue
                    if (
                        isinstance(row, dict)
                        and (row.get("fail") or row.get("c") is None)
                        # failure taxonomy: rows from before it load as
                        # kind="build" (a failed build — permanent, and
                        # exactly as cacheable as a runtime).  Transient
                        # kinds (crash/timeout/spawn/corrupt) say nothing
                        # about the schedule: provenance only, a later
                        # run must re-measure, never cache-hit inf.
                        and row.get("kind", "build") in TRANSIENT_KINDS
                    ):
                        self._transient_seen.setdefault(
                            row["w"], set()
                        ).add(row["k"])
                        continue
                    ingested = self._ingest(
                        row["w"], row["k"], row["s"], self._row_cost(row),
                        # schema field added with the op registry; every
                        # pre-registry row is a GEMM measurement
                        op=row.get("op", "gemm"),
                    )
                except (ValueError, KeyError, TypeError):
                    continue  # torn/foreign line from a crashed writer
                n_new += int(ingested)
        return n_new

    # -- read ------------------------------------------------------------------
    def get(self, workload: str, state_key: str,
            op: Optional[str] = None) -> Optional[float]:
        """Cached cost, or None.  ``op`` (when given) must match the
        workload's journaled op — a flash row must never be served to a
        GEMM lookup even if the key strings were ever to collide."""
        if op is not None and self._ops.get(workload, "gemm") != op:
            return None
        return self._costs.get(workload, {}).get(state_key)

    def n_trials(self, workload: str) -> int:
        return len(self._costs.get(workload, ()))

    def workloads(self) -> Iterable[str]:
        return self._costs.keys()

    def __len__(self) -> int:
        return sum(len(d) for d in self._costs.values())

    def op_of(self, workload: str) -> str:
        return self._ops.get(workload, "gemm")

    def best_state(self, workload: str) -> Optional[tuple[State, float]]:
        rec = self._best.get(workload)
        if rec is None:
            return None
        cost, lists = rec
        try:
            return state_from_lists(self.op_of(workload), lists), cost
        except KeyError:
            return None

    def nearest(
        self,
        op: str,
        dims: Sequence[int],
        dtype: Optional[str] = None,
        backend: Optional[str] = None,
        exclude: Optional[str] = None,
        fixed_tail: int = 0,
    ) -> Optional[str]:
        """The previously-journaled workload of ``op`` closest to
        ``dims`` in log-shape space — the warm-start donor for a new
        shape.  Donors are scoped to the op: a flash schedule can never
        seed a GEMM search.  ``fixed_tail`` is the count of trailing
        dims that are workload identity rather than factored rows
        (``SearchSpace.n_fixed_dims``): donors must match them exactly
        (e.g. flash's head_dim)."""
        best_key, best_d = None, math.inf
        for key in self._costs:
            if key == exclude or key not in self._best:
                continue
            parsed = parse_workload_key_generic(key)
            if parsed is None or self.op_of(key) != op:
                continue
            d = donor_distance(parsed, op, dims, dtype=dtype,
                               backend=backend, fixed_tail=fixed_tail)
            if d is not None and d < best_d:
                best_key, best_d = key, d
        return best_key

    def nearest_workload(
        self,
        m: int,
        k: int,
        n: int,
        dtype: Optional[str] = None,
        backend: Optional[str] = None,
        exclude: Optional[str] = None,
    ) -> Optional[str]:
        """Back-compat GEMM spelling of :meth:`nearest`."""
        return self.nearest("gemm", (m, k, n), dtype=dtype, backend=backend,
                            exclude=exclude)

    # -- write -----------------------------------------------------------------
    def _ingest(self, workload: str, state_key: str, state_lists: list,
                cost: float, op: str = "gemm") -> bool:
        known = self._ops.setdefault(workload, op)
        if known != op:
            # schema guard: a workload key belongs to exactly one op —
            # never let a foreign row shadow (or serve) another op's
            # measurements
            return False
        table = self._costs.setdefault(workload, {})
        if state_key in table:
            return False
        table[state_key] = cost
        if math.isfinite(cost):
            best = self._best.get(workload)
            if best is None or cost < best[0]:
                self._best[workload] = (cost, state_lists)
        return True

    def _append_row(self, row: dict) -> None:
        """Append one JSONL row (caller holds the lock, ``self.path`` set).

        One write() per row: O_APPEND makes concurrent appends from
        sibling engines/processes atomic, never interleaved.  A short
        write (disk full, NFS) would tear the row AND swallow the next
        sibling's O_APPEND line, so finish or fail loudly rather than
        continue with a corrupt tail."""
        if self._fd is None:
            d = os.path.dirname(os.path.abspath(self.path))
            os.makedirs(d, exist_ok=True)
            self._fd = os.open(
                self.path, os.O_WRONLY | os.O_APPEND | os.O_CREAT, 0o644
            )
        line = json.dumps(row, allow_nan=False, separators=(",", ":"))
        view = memoryview((line + "\n").encode("utf-8"))
        while view:
            view = view[os.write(self._fd, view):]

    def record(self, workload: str, state: State, cost: float,
               op: Optional[str] = None, kind: Optional[str] = None,
               attempts: Optional[int] = None,
               shard: Optional[Sequence[int]] = None) -> None:
        """Journal one measurement.  ``inf`` costs are failure rows; they
        carry a failure ``kind`` (default ``"build"`` — the historical
        backend-says-infeasible case) and optionally the number of
        measurement ``attempts`` that led to the verdict.  ``shard`` is
        the measuring engine's ``(index, count)`` in a sharded search —
        pure provenance (the audit CLI recomputes ownership from it);
        unsharded rows stay byte-identical to the historical format."""
        if op is None:
            op = op_of_workload_key(workload)
        with self._lock:
            lists = state.as_lists()
            if not self._ingest(workload, state.key(), lists, cost, op=op):
                return
            if self.path:
                row: dict = {"w": workload, "k": state.key(), "s": lists,
                             "op": op}
                if math.isfinite(cost):
                    row["c"] = cost
                else:
                    row["c"] = None
                    row["fail"] = True
                    row["kind"] = kind or "build"
                    if attempts is not None and attempts > 1:
                        row["attempts"] = int(attempts)
                if shard is not None:
                    row["shard"] = [int(shard[0]), int(shard[1])]
                self._append_row(row)

    def record_failure(self, workload: str, state: State, kind: str,
                       attempts: int = 1, op: Optional[str] = None,
                       shard: Optional[Sequence[int]] = None) -> None:
        """Journal a lane failure with taxonomy provenance.

        *Permanent* kinds (a deterministic raise) are cacheable facts
        about the schedule: they enter the cost table as ``inf`` exactly
        like a failed build.  *Transient* kinds (crash/timeout/spawn/
        corrupt — written after retry exhaustion) are provenance-only
        audit rows: the journal documents what happened and how many
        attempts were spent, but the state stays out of the cost table so
        no later session ever cache-hits a worker death as "this config
        is infeasible"."""
        if kind in PERMANENT_KINDS:
            self.record(workload, state, math.inf, op=op, kind=kind,
                        attempts=attempts, shard=shard)
            return
        if op is None:
            op = op_of_workload_key(workload)
        with self._lock:
            seen = self._transient_seen.setdefault(workload, set())
            key = state.key()
            if key in seen:
                return
            seen.add(key)
            if not self.path:
                return
            row = {"w": workload, "k": key, "s": state.as_lists(), "op": op,
                   "c": None, "fail": True, "kind": str(kind),
                   "attempts": int(attempts)}
            if shard is not None:
                row["shard"] = [int(shard[0]), int(shard[1])]
            self._append_row(row)

    def record_static(self, workload: str, state: State, reason: str,
                      op: Optional[str] = None) -> None:
        """Journal an analyzer rejection as an **audit row**:
        ``{"c": null, "static": "<reason>"}``.  Unlike :meth:`record`
        this never enters the cost table — the row documents *why* the
        candidate was pruned without ever being measured, and a later
        ``analyze=off`` run must re-measure it rather than cache-hit an
        inferred failure.  Legacy readers that ignore the ``static``
        field see ``c=None`` (a failure row), which is safe."""
        if op is None:
            op = op_of_workload_key(workload)
        with self._lock:
            seen = self._static_seen.setdefault(workload, set())
            key = state.key()
            if key in seen:
                return
            seen.add(key)
            if not self.path:
                return
            row = {"w": workload, "k": key, "s": state.as_lists(),
                   "op": op, "c": None, "static": str(reason)}
            self._append_row(row)

    def record_predicted(self, workload: str, state: State, score: float,
                         op: Optional[str] = None) -> None:
        """Journal a learned-filter skip as a **provenance row**:
        ``{"c": null, "pred": <score>}`` — the model's rank score, not a
        runtime.  Like :meth:`record_static` this never enters the cost
        table: the candidate was never measured, and a later unfiltered
        run must measure it rather than cache-hit a guess.  Legacy
        readers that ignore the ``pred`` field see ``c=None`` (a
        failure row), which is safe."""
        if op is None:
            op = op_of_workload_key(workload)
        with self._lock:
            seen = self._pred_seen.setdefault(workload, set())
            key = state.key()
            if key in seen:
                return
            seen.add(key)
            if not self.path:
                return
            row = {"w": workload, "k": key, "s": state.as_lists(),
                   "op": op, "c": None, "pred": float(score)}
            self._append_row(row)

    def close(self) -> None:
        """Release the append descriptor; the in-memory view (and
        ``_read_pos``) survive, so the journal stays usable — the next
        ``record`` reopens lazily."""
        with self._lock:
            if self._fd is not None:
                os.close(self._fd)
                self._fd = None


def iter_journal_rows(path: str) -> Iterable[dict]:
    """Yield every parseable row dict of a journal file, skipping blank
    and torn lines — the audit CLI's raw view (it needs the rows, not
    the deduped cost table :class:`TrialJournal` builds)."""
    if not os.path.exists(path):
        return
    with open(path, "rb") as f:
        for line in f:
            if not line.strip():
                continue
            try:
                row = json.loads(line)
            except ValueError:
                continue  # torn tail from a crashed writer
            if isinstance(row, dict):
                yield row


_GLOBAL = TuningRecords()


def global_records() -> TuningRecords:
    return _GLOBAL


def set_global_records(records: TuningRecords) -> None:
    global _GLOBAL
    _GLOBAL = records
    _notify_change()

"""Tuning-record store: persisted best configurations per GEMM workload.

This is the compile-time artifact the framework ships — the analogue of
AutoTVM's tophub tables.  ``kernels/ops.py`` consults the process-global
store at trace time to pick the Pallas BlockSpec config for each matmul
shape; ``launch/tune.py`` writes it.  Records are plain JSON for
diffability and survive crashes via atomic replace.
"""

from __future__ import annotations

import json
import math
import os
import tempfile
import threading
import time
from typing import Optional

from .config_space import TilingState

__all__ = ["TuningRecords", "workload_key", "global_records", "set_global_records"]


def workload_key(m: int, k: int, n: int, dtype: str = "bfloat16",
                 backend: str = "analytical_tpu_v5e") -> str:
    return f"gemm/m{m}k{k}n{n}/{dtype}/{backend}"


class TuningRecords:
    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._lock = threading.Lock()
        self._data: dict[str, dict] = {}
        if path and os.path.exists(path):
            with open(path) as f:
                self._data = json.load(f)

    # -- read ------------------------------------------------------------------
    def lookup(self, key: str) -> Optional[dict]:
        return self._data.get(key)

    def lookup_state(self, key: str) -> Optional[TilingState]:
        rec = self.lookup(key)
        if rec is None:
            return None
        return TilingState.from_lists(rec["state"])

    def best_cost(self, key: str) -> float:
        rec = self.lookup(key)
        return rec["cost"] if rec else math.inf

    def __len__(self) -> int:
        return len(self._data)

    def keys(self):
        return self._data.keys()

    # -- write -----------------------------------------------------------------
    def update(
        self,
        key: str,
        state: TilingState,
        cost: float,
        tuner: str,
        n_trials: int,
        extra: Optional[dict] = None,
    ) -> bool:
        """Keep-best merge; returns True if the record improved."""
        with self._lock:
            old = self._data.get(key)
            if old is not None and old["cost"] <= cost:
                return False
            self._data[key] = {
                "state": state.as_lists(),
                "cost": cost,
                "tuner": tuner,
                "n_trials": n_trials,
                "timestamp": time.time(),
                **(extra or {}),
            }
            self._flush_locked()
            return True

    def _flush_locked(self) -> None:
        if not self.path:
            return
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self._data, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)  # atomic publish
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)


_GLOBAL = TuningRecords()


def global_records() -> TuningRecords:
    return _GLOBAL


def set_global_records(records: TuningRecords) -> None:
    global _GLOBAL
    _GLOBAL = records

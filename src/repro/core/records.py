"""Tuning-record store: persisted best configurations per GEMM workload,
plus the persistent trial journal the measurement engine caches from.

Two artifacts live here:

* :class:`TuningRecords` — the keep-best table the framework ships (the
  analogue of AutoTVM's tophub).  ``kernels/ops.py`` consults the
  process-global store at trace time to pick the Pallas BlockSpec config
  for each matmul shape; ``launch/tune.py`` writes it.  Plain JSON for
  diffability; crash-safe via atomic replace.
* :class:`TrialJournal` — an append-only JSONL log of *every*
  measurement ever taken, keyed by workload.  The
  :class:`~repro.core.measure.MeasureEngine` consults it before
  dispatching to hardware, so repeat queries — within a session, across
  sessions, or across workloads that share GEMM shapes — are served from
  cache; ``TuningSession`` also uses it to warm-start a workload from
  the nearest previously-tuned shape.
"""

from __future__ import annotations

import json
import math
import os
import re
import tempfile
import threading
import time
from typing import Iterable, Optional

from .config_space import TilingState

__all__ = [
    "TuningRecords",
    "TrialJournal",
    "workload_key",
    "parse_workload_key",
    "compile_cache_dir_for",
    "global_records",
    "set_global_records",
]


def compile_cache_dir_for(journal_path: str) -> str:
    """Default location of the persistent compiled-program cache for
    measured backends (``XLATimedCost``): a directory next to the
    :class:`TrialJournal`, so the two cross-session caches — measured
    costs and compiled executables — travel together and sibling
    engines/hosts sharing the journal path share the executables too."""
    return journal_path + ".xlacache"


def workload_key(m: int, k: int, n: int, dtype: str = "bfloat16",
                 backend: str = "analytical_tpu_v5e") -> str:
    return f"gemm/m{m}k{k}n{n}/{dtype}/{backend}"


_KEY_RE = re.compile(r"^gemm/m(\d+)k(\d+)n(\d+)/([^/]+)/(.+)$")


def parse_workload_key(key: str) -> Optional[tuple[int, int, int, str, str]]:
    """Inverse of :func:`workload_key`: ``(m, k, n, dtype, backend)``."""
    m = _KEY_RE.match(key)
    if m is None:
        return None
    return int(m.group(1)), int(m.group(2)), int(m.group(3)), m.group(4), m.group(5)


class TuningRecords:
    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._lock = threading.Lock()
        self._data: dict[str, dict] = {}
        if path and os.path.exists(path):
            with open(path) as f:
                self._data = json.load(f)

    # -- read ------------------------------------------------------------------
    def lookup(self, key: str) -> Optional[dict]:
        return self._data.get(key)

    def lookup_state(self, key: str) -> Optional[TilingState]:
        rec = self.lookup(key)
        if rec is None:
            return None
        return TilingState.from_lists(rec["state"])

    def best_cost(self, key: str) -> float:
        rec = self.lookup(key)
        return rec["cost"] if rec else math.inf

    def __len__(self) -> int:
        return len(self._data)

    def keys(self):
        return self._data.keys()

    # -- write -----------------------------------------------------------------
    def update(
        self,
        key: str,
        state: TilingState,
        cost: float,
        tuner: str,
        n_trials: int,
        extra: Optional[dict] = None,
    ) -> bool:
        """Keep-best merge; returns True if the record improved."""
        with self._lock:
            old = self._data.get(key)
            if old is not None and old["cost"] <= cost:
                return False
            self._data[key] = {
                "state": state.as_lists(),
                "cost": cost,
                "tuner": tuner,
                "n_trials": n_trials,
                "timestamp": time.time(),
                **(extra or {}),
            }
            self._flush_locked()
            return True

    def _flush_locked(self) -> None:
        if not self.path:
            return
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self._data, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)  # atomic publish
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)


class TrialJournal:
    """Append-only measurement log: ``(workload, state) -> cost``.

    Persists as strict JSONL — one row per measurement, written as a
    **single ``write()`` on an ``O_APPEND`` descriptor**, so any number
    of engines *and processes* can share one journal file without ever
    interleaving torn rows (POSIX serialises O_APPEND writes).  Failed
    builds (``math.inf``) are journaled too — knowing a config fails is
    exactly as cacheable as knowing its runtime — but encoded as
    ``{"c": null, "fail": true}`` so every row survives strict
    ``json.loads``; legacy ``Infinity`` rows are still understood on
    load.  A crash mid-append leaves at most one unterminated tail line,
    which loading skips (and a later :meth:`reload` re-reads once some
    surviving writer completes it).

    The in-memory view is a per-workload cost table plus a running best
    (state, cost) pair used for warm starts.  :meth:`reload` merges rows
    appended by sibling engines/processes since the last read — the
    multi-engine sharing primitive.  The journal is a context manager;
    ``close()`` drops the append descriptor (reopened lazily by the next
    ``record``).
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._lock = threading.Lock()
        self._costs: dict[str, dict[str, float]] = {}
        self._best: dict[str, tuple[float, list]] = {}
        self._fd: Optional[int] = None
        self._read_pos = 0  # how far reload() has consumed the file
        if path:
            self.reload()

    def __enter__(self) -> "TrialJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @staticmethod
    def _row_cost(row: dict) -> float:
        c = row.get("c")
        if row.get("fail") or c is None:
            return math.inf
        return float(c)  # legacy rows: json.loads already accepts Infinity

    def reload(self) -> int:
        """Ingest rows appended to the file since the last load —
        including rows written by *other* engines or processes sharing
        this journal path.  Returns the number of new rows ingested
        (rows this instance already holds dedup to zero).  Only complete
        (newline-terminated) lines are consumed; a torn tail stays
        unread until a later reload sees it completed."""
        if not self.path or not os.path.exists(self.path):
            return 0
        n_new = 0
        with self._lock:
            with open(self.path, "rb") as f:
                f.seek(self._read_pos)
                data = f.read()
            end = data.rfind(b"\n")
            if end < 0:
                return 0
            self._read_pos += end + 1
            for line in data[: end + 1].splitlines():
                if not line.strip():
                    continue
                try:
                    row = json.loads(line)
                    ingested = self._ingest(
                        row["w"], row["k"], row["s"], self._row_cost(row)
                    )
                except (ValueError, KeyError, TypeError):
                    continue  # torn/foreign line from a crashed writer
                n_new += int(ingested)
        return n_new

    # -- read ------------------------------------------------------------------
    def get(self, workload: str, state_key: str) -> Optional[float]:
        return self._costs.get(workload, {}).get(state_key)

    def n_trials(self, workload: str) -> int:
        return len(self._costs.get(workload, ()))

    def workloads(self) -> Iterable[str]:
        return self._costs.keys()

    def __len__(self) -> int:
        return sum(len(d) for d in self._costs.values())

    def best_state(self, workload: str) -> Optional[tuple[TilingState, float]]:
        rec = self._best.get(workload)
        if rec is None:
            return None
        cost, lists = rec
        return TilingState.from_lists(lists), cost

    def nearest_workload(
        self,
        m: int,
        k: int,
        n: int,
        dtype: Optional[str] = None,
        backend: Optional[str] = None,
        exclude: Optional[str] = None,
    ) -> Optional[str]:
        """The previously-journaled workload closest to ``(m, k, n)`` in
        log-shape space — the warm-start donor for a new shape."""
        best_key, best_d = None, math.inf
        for key in self._costs:
            if key == exclude or key not in self._best:
                continue
            parsed = parse_workload_key(key)
            if parsed is None:
                continue
            m2, k2, n2, dt2, be2 = parsed
            if backend is not None and be2 != backend:
                continue
            if dtype is not None and dt2 != dtype:
                continue
            d = (
                abs(math.log2(m2 / m))
                + abs(math.log2(k2 / k))
                + abs(math.log2(n2 / n))
            )
            if d < best_d:
                best_key, best_d = key, d
        return best_key

    # -- write -----------------------------------------------------------------
    def _ingest(self, workload: str, state_key: str, state_lists: list,
                cost: float) -> bool:
        table = self._costs.setdefault(workload, {})
        if state_key in table:
            return False
        table[state_key] = cost
        if math.isfinite(cost):
            best = self._best.get(workload)
            if best is None or cost < best[0]:
                self._best[workload] = (cost, state_lists)
        return True

    def record(self, workload: str, state: TilingState, cost: float) -> None:
        with self._lock:
            lists = state.as_lists()
            if not self._ingest(workload, state.key(), lists, cost):
                return
            if self.path:
                if self._fd is None:
                    d = os.path.dirname(os.path.abspath(self.path))
                    os.makedirs(d, exist_ok=True)
                    self._fd = os.open(
                        self.path, os.O_WRONLY | os.O_APPEND | os.O_CREAT, 0o644
                    )
                row: dict = {"w": workload, "k": state.key(), "s": lists}
                if math.isfinite(cost):
                    row["c"] = cost
                else:
                    row["c"] = None
                    row["fail"] = True
                # one write() per row: O_APPEND makes concurrent appends
                # from sibling engines/processes atomic, never interleaved.
                # A short write (disk full, NFS) would tear the row AND
                # swallow the next sibling's O_APPEND line, so finish or
                # fail loudly rather than continue with a corrupt tail.
                line = json.dumps(row, allow_nan=False, separators=(",", ":"))
                view = memoryview((line + "\n").encode("utf-8"))
                while view:
                    view = view[os.write(self._fd, view):]

    def close(self) -> None:
        """Release the append descriptor; the in-memory view (and
        ``_read_pos``) survive, so the journal stays usable — the next
        ``record`` reopens lazily."""
        with self._lock:
            if self._fd is not None:
                os.close(self._fd)
                self._fd = None


_GLOBAL = TuningRecords()


def global_records() -> TuningRecords:
    return _GLOBAL


def set_global_records(records: TuningRecords) -> None:
    global _GLOBAL
    _GLOBAL = records

"""Post-SPMD HLO text analysis: collective bytes per device.

``collective_stats(hlo_text)`` scans every computation, resolves operand
shapes from their defining lines, and sums operand bytes per collective
kind (all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute).  Shapes in the partitioned module are shard-local,
so the totals are per-device wire bytes (algorithmic ring factors are
applied in utils/roofline.py, not here).
"""

from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["collective_stats", "parse_shape_bytes", "COLLECTIVE_KINDS"]

COLLECTIVE_KINDS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def parse_shape_bytes(shape_str: str) -> int:
    """Bytes of one (possibly tuple) HLO shape string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


# one HLO instruction:  %name = <shape> opcode(...operands...)
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*([\w\-]+)\("
)
_OPERAND_RE = re.compile(r"%?([\w.\-]+)")


_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def collective_stats(hlo_text: str, tpu_equivalence: bool = True) -> dict:
    """Returns {kind: {"count": int, "operand_bytes": int,
    "result_bytes": int}} plus a "total_operand_bytes" rollup.

    ``tpu_equivalence`` applies two corrections for XLA:CPU lowering
    artifacts so the numbers reflect what the TPU backend would emit:
      * bf16 all-reduces are promoted to f32 on CPU (the reduction
        computation is named ``*_promoted``) — payload halved back;
      * CPU skips the all-reduce+dynamic-slice -> reduce-scatter fusion;
        an all-reduce whose every consumer is a (tuple-element +)
        dynamic-slice of 1/group_size is counted as a reduce-scatter
        (operand bytes / group_size)."""
    shapes: dict[str, str] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _DEF_RE.match(ln)
        if m:
            shapes[m.group(1)] = m.group(2)

    # consumer map: producer name -> list of (opcode, result_shape)
    consumers: dict[str, list] = defaultdict(list)
    if tpu_equivalence:
        for ln in lines:
            m = _DEF_RE.match(ln)
            if not m:
                continue
            res_name, res_shape, opcode = m.group(1), m.group(2), m.group(3)
            paren = ln.find(opcode + "(")
            if paren < 0:
                continue
            seg = ln[paren + len(opcode) + 1 :]
            for mm in re.finditer(r"%([\w.\-]+)", seg.split("),")[0]):
                consumers[mm.group(1)].append((opcode, res_shape, res_name))

    stats: dict[str, dict] = defaultdict(
        lambda: {"count": 0, "operand_bytes": 0, "result_bytes": 0}
    )

    def _slice_only(name: str, depth: int = 0) -> bool:
        """All consumers are dynamic-slice (possibly via get-tuple-element)."""
        cons = consumers.get(name, [])
        if not cons:
            return False
        for opcode, _shape, res in cons:
            if opcode == "dynamic-slice":
                continue
            if opcode == "get-tuple-element" and depth < 1:
                if not _slice_only(res, depth + 1):
                    return False
                continue
            return False
        return True

    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, shape_str, op = m.group(1), m.group(2), m.group(3)
        kind = None
        for k in COLLECTIVE_KINDS:
            if op == k or op.startswith(k):
                kind = k
                break
        if kind is None:
            continue
        # operands: tokens inside the first top-level paren group
        start = ln.find(op + "(") + len(op) + 1
        depth = 1
        end = start
        while end < len(ln) and depth > 0:
            if ln[end] == "(":
                depth += 1
            elif ln[end] == ")":
                depth -= 1
            end += 1
        operand_str = ln[start : end - 1]
        op_bytes = 0
        for tok in operand_str.split(","):
            tok = tok.strip()
            mm = re.match(r"^%?([\w.\-]+)$", tok)
            if mm and mm.group(1) in shapes:
                op_bytes += parse_shape_bytes(shapes[mm.group(1)])
        res_bytes = parse_shape_bytes(shape_str)

        if tpu_equivalence and kind == "all-reduce":
            if "promoted" in ln:  # CPU promoted a bf16 payload to f32
                op_bytes //= 2
                res_bytes //= 2
            gm = _GROUP_RE.search(ln)
            group = int(gm.group(2)) if gm else 1
            if group > 1 and _slice_only(name):
                kind = "reduce-scatter"  # TPU fuses AR+DS -> RS
                op_bytes //= group
                res_bytes //= group

        st = stats[kind]
        st["count"] += 1
        st["operand_bytes"] += op_bytes
        st["result_bytes"] += res_bytes

    out = {k: dict(v) for k, v in stats.items()}
    out["total_operand_bytes"] = sum(v["operand_bytes"] for v in stats.values())
    return out

"""Roofline math for the dry-run (TPU v5e constants per assignment).

Three terms, all in seconds, derived from the compiled artifact:

  compute   = HLO_FLOPs_per_device / peak_FLOP/s
  memory    = HLO_bytes_per_device / HBM_bw
  collective= wire_bytes_per_device / link_bw      (ring factors applied)

``cost_analysis()`` of a partitioned executable reports per-device
numbers (verified empirically in tests/test_roofline.py), so no extra
division by chip count is applied here; the assignment's
"HLO_FLOPs / (chips × peak)" is the same quantity computed from the
global pre-partition FLOPs.
"""

from __future__ import annotations

import dataclasses

__all__ = ["V5E", "RooflineTerms", "roofline_from_costs", "model_flops"]


@dataclasses.dataclass(frozen=True)
class V5E:
    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # B/s per chip
    ici_bw: float = 50e9  # B/s per link (≈ per-axis ring bandwidth)
    hbm_bytes: float = 16e9  # capacity per chip


# ring-algorithm wire factors (fraction of payload actually serialized
# on the slowest link): all-reduce moves ~2x the shard, gather/scatter ~1x
_WIRE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    model_flops: float  # 6·N·D (or serve-step equivalent)
    useful_ratio: float  # model_flops / (flops_per_device × chips)
    dominant: str

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline_from_costs(
    flops_per_device: float,
    bytes_per_device: float,
    collective: dict,
    chips: int,
    mflops: float,
    hw: V5E = V5E(),
) -> RooflineTerms:
    wire = 0.0
    for kind, factor in _WIRE_FACTOR.items():
        st = collective.get(kind)
        if st:
            wire += factor * st["operand_bytes"]
    compute_s = flops_per_device / hw.peak_flops
    memory_s = bytes_per_device / hw.hbm_bw
    collective_s = wire / hw.ici_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    total_flops = flops_per_device * chips
    return RooflineTerms(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        flops_per_device=flops_per_device,
        bytes_per_device=bytes_per_device,
        wire_bytes_per_device=wire,
        model_flops=mflops,
        useful_ratio=(mflops / total_flops) if total_flops else 0.0,
        dominant=dominant,
    )


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS per the assignment: 6·N·D for training (N = params,
    MoE: active params), 2·N·D for a forward-only prefill, 2·N per token
    for decode (D = tokens processed in the step)."""
    n = cfg.n_active_params()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    # decode: one token per sequence in the batch
    return 2.0 * n * shape.global_batch

"""Record-aware kernel dispatch — the trace-time bridge from tuning
records to the ops models actually execute.

``gemm(x, w)`` is what the model stack calls for every projection /
FFN / expert matmul; ``models/common.attention_dispatch`` routes long
self-attention through :func:`flash_schedule`.  Dispatch policy (trace
time, all static):

  1. If the process-global kernel policy disables Pallas (default on this
     CPU-only container, and for full-scale dry-runs where interpret-mode
     grids would explode the HLO), lower to the pure-XLA path — XLA picks
     its own tiling.  On a real TPU deployment the policy flips on.
  2. Otherwise consult the tuned record for the op's workload key
     (``records.workload_key_for`` under the policy's cost-backend
     namespace — written by `launch/tune.py`); fall back to the op's
     heuristic default when there is no record, or to XLA when shapes
     don't divide.

The lookup layer is **op-generic and memoized**: any op registered in
`repro.core.ops` resolves its tuned schedule state through
:func:`lookup_tuned_state`, keyed ``(op, dims, dtype, backend)``.  The
memo would otherwise hit the records store on every trace (a single
``gemm`` trace triggers three lookups: forward + both backward shapes);
it is invalidated by :func:`set_kernel_policy` and by any records
mutation/reload (via ``records.add_change_listener``).  Per-op dispatch
counters (:func:`dispatch_stats`) record — at trace time, so once per
compiled shape — whether a tuned record, the built-in heuristic, or the
XLA fallback drove each dispatch; the serving bench surfaces them.

The GEMM op is differentiable either way: the Pallas path installs a
custom_vjp whose backward passes are themselves tiled GEMMs (dA = g Bᵀ,
dB = Aᵀ g) so tuned kernels serve training too.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.records import add_change_listener, global_records, workload_key_for
from .gemm import KernelConfig, default_config, gemm_pallas, kernel_config_from_state

__all__ = [
    "gemm",
    "KernelPolicy",
    "set_kernel_policy",
    "kernel_policy",
    "lookup_tuned_state",
    "flash_schedule",
    "invalidate_dispatch_cache",
    "dispatch_stats",
    "reset_dispatch_stats",
    "note_dispatch",
]


@dataclasses.dataclass
class KernelPolicy:
    use_pallas: bool = False  # flipped on for TPU deployments / kernel tests
    interpret: bool = True  # CPU container: interpret=True is the only mode
    cost_backend: str = "analytical_tpu_v5e"  # records namespace to consult
    #: ops that consult TuningRecords at trace time; an op not listed
    #: here always uses its heuristic default (the opt-in knob for
    #: record-aware dispatch)
    record_ops: tuple[str, ...] = ("gemm", "flash")
    #: ops that actually run their Pallas kernel when ``use_pallas`` is
    #: on — lets a deployment (or bench) enable e.g. the flash kernel
    #: without routing every projection GEMM through Pallas too
    pallas_ops: tuple[str, ...] = ("gemm", "flash")


_POLICY = KernelPolicy()


def kernel_policy() -> KernelPolicy:
    return _POLICY


def set_kernel_policy(policy: KernelPolicy) -> None:
    global _POLICY
    _POLICY = policy
    invalidate_dispatch_cache()  # cost_backend / record_ops may differ


# -- memoized op-generic record lookup ----------------------------------------

_MISS = object()
_CACHE_LOCK = threading.Lock()
_DISPATCH_CACHE: dict[tuple, object] = {}
_DISPATCH_STATS: dict[str, dict[str, int]] = {}
_STAT_FIELDS = (
    "records", "heuristic", "xla", "memo_hits", "store_lookups",
    "static_reject",
)


def invalidate_dispatch_cache() -> None:
    """Drop every memoized record lookup (registered as a records change
    listener, also run on policy swaps)."""
    with _CACHE_LOCK:
        _DISPATCH_CACHE.clear()


add_change_listener(invalidate_dispatch_cache)


def note_dispatch(op: str, source: str) -> None:
    """Count one trace-time dispatch decision for ``op``:
    ``source`` in {"records", "heuristic", "xla"} (plus internal
    memo/store counters)."""
    with _CACHE_LOCK:
        per_op = _DISPATCH_STATS.setdefault(
            op, {f: 0 for f in _STAT_FIELDS}
        )
        per_op[source] = per_op.get(source, 0) + 1


def dispatch_stats() -> dict[str, dict[str, int]]:
    with _CACHE_LOCK:
        return {op: dict(d) for op, d in _DISPATCH_STATS.items()}


def reset_dispatch_stats() -> None:
    with _CACHE_LOCK:
        _DISPATCH_STATS.clear()


def _static_reject_record(op: str, dims: tuple, dtype: str, st) -> bool:
    """True when a tuned record is provably unusable on the current
    hardware spec: the static analyzer (see ``repro.core.analysis``)
    classifies it ILLEGAL for this op workload — a stale record for
    another shape, a corrupted state, or a schedule whose working set
    no longer fits VMEM.  Any failure to even build the space/analyzer
    also rejects: falling back to the heuristic is always safe, serving
    a broken record never is."""
    try:
        from repro.core.analysis import ScheduleAnalyzer, dtype_in_bytes
        from repro.core.ops import get_op

        depths = tuple(len(r) for r in st.as_lists())
        space = get_op(op).make_space(tuple(dims), depths)
        analyzer = ScheduleAnalyzer(space, in_bytes=dtype_in_bytes(str(dtype)))
        return analyzer.analyze(st).illegal
    except Exception:
        return True


def lookup_tuned_state(op: str, dims: tuple, dtype: str):
    """Tuned schedule :class:`~repro.core.space.State` for one op
    workload, or None.  Consults the process-global
    :class:`TuningRecords` under the policy's cost-backend namespace;
    records the static analyzer rejects as ILLEGAL on the current spec
    are refused (counted as ``static_reject`` in ``dispatch_stats``, the
    caller falls back to its heuristic).  Memoized per
    ``(op, dims, dtype, backend)`` until records change.  Ops opt in
    via ``KernelPolicy.record_ops``."""
    if op not in _POLICY.record_ops:
        return None
    key = (op, tuple(dims), str(dtype), _POLICY.cost_backend)
    with _CACHE_LOCK:
        hit = _DISPATCH_CACHE.get(key, _MISS)
    if hit is not _MISS:
        note_dispatch(op, "memo_hits")
        return hit
    note_dispatch(op, "store_lookups")
    st = global_records().lookup_state(
        workload_key_for(op, tuple(dims), str(dtype), _POLICY.cost_backend)
    )
    if st is not None and _static_reject_record(op, dims, dtype, st):
        note_dispatch(op, "static_reject")
        st = None  # memoized as a miss: refuse once per (shape, records)
    with _CACHE_LOCK:
        _DISPATCH_CACHE[key] = st
    return st


def _lookup_config(m: int, k: int, n: int, dtype: str) -> Optional[KernelConfig]:
    """GEMM spelling of the generic lookup: tuned state -> KernelConfig
    (None when there is no record or the record doesn't map)."""
    st = lookup_tuned_state("gemm", (m, k, n), dtype)
    if st is None:
        return None
    try:
        return kernel_config_from_state(st)
    except (ValueError, AttributeError):  # foreign/unmappable record
        return None


def flash_schedule(
    seq_q: int, seq_kv: int, head_dim: int, dtype: str
) -> Optional[tuple[int, int]]:
    """Tuned ``(block_q, block_kv)`` for one flash-attention workload, or
    None when no record fits.  Blocks must tile the sequences exactly —
    a record tuned for a different factorization never reaches the
    kernel."""
    st = lookup_tuned_state("flash", (seq_q, seq_kv, head_dim), dtype)
    if st is None:
        return None
    try:
        bq, bkv = st.block_q, st.block_kv
    except AttributeError:  # foreign record under a flash key
        return None
    if bq < 1 or bkv < 1 or seq_q % bq or seq_kv % bkv:
        return None
    return bq, bkv


def _pallas_ok(m: int, k: int, n: int, cfg: KernelConfig) -> bool:
    try:
        cfg.validate(m, k, n)
        return True
    except ValueError:
        return False


def _bwd(cfg, interpret, res, g):
    a, b = res
    m, k = a.shape
    n = b.shape[1]
    # backward GEMMs get their own tuned configs (shapes differ)
    cfg_da = _lookup_config(m, n, k, str(g.dtype)) or default_config(m, n, k)
    cfg_db = _lookup_config(k, m, n, str(g.dtype)) or default_config(k, m, n)
    da = (
        gemm_pallas(g, b.T, cfg_da, interpret=interpret)
        if _pallas_ok(m, n, k, cfg_da)
        else jnp.dot(g, b.T)
    ).astype(a.dtype)
    db = (
        gemm_pallas(a.T, g, cfg_db, interpret=interpret)
        if _pallas_ok(k, m, n, cfg_db)
        else jnp.dot(a.T, g)
    ).astype(b.dtype)
    return da, db


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _gemm_pallas_diff(cfg: KernelConfig, interpret: bool, a, b):
    return gemm_pallas(a, b, cfg, interpret=interpret)


def _gemm_fwd(cfg, interpret, a, b):
    return gemm_pallas(a, b, cfg, interpret=interpret), (a, b)


_gemm_pallas_diff.defvjp(_gemm_fwd, _bwd)


def gemm(
    a: jax.Array,
    b: jax.Array,
    config: Optional[KernelConfig] = None,
    use_pallas: Optional[bool] = None,
) -> jax.Array:
    """2-D matmul through the kernel policy (see module docstring).

    Higher-rank LHS is flattened to 2-D and restored — every dense layer
    in `repro.models` funnels through here."""
    if a.ndim < 2 or b.ndim != 2:
        raise ValueError(f"gemm expects (.., K) @ (K, N), got {a.shape} @ {b.shape}")
    lead = a.shape[:-1]
    k = a.shape[-1]
    n = b.shape[-1]
    a2 = a.reshape((-1, k))
    m = a2.shape[0]

    enabled = (
        (_POLICY.use_pallas and "gemm" in _POLICY.pallas_ops)
        if use_pallas is None
        else use_pallas
    )
    if enabled:
        tuned = None if config is not None else _lookup_config(m, k, n, str(a.dtype))
        cfg = config or tuned or default_config(m, k, n)
        if _pallas_ok(m, k, n, cfg):
            src = "records" if tuned else ("explicit" if config else "heuristic")
            note_dispatch("gemm", src)
            out = _gemm_pallas_diff(cfg, _POLICY.interpret, a2, b)
            return out.reshape(lead + (n,))
        note_dispatch("gemm", "xla")
    out = jnp.dot(a2, b)
    return out.reshape(lead + (n,))

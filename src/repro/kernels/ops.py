"""Public GEMM op: tuning-record-aware dispatch + differentiability.

``gemm(x, w)`` is what the model stack calls for every projection /
FFN / expert matmul.  Dispatch policy (trace time, all static):

  1. If the process-global kernel policy disables Pallas (default on this
     CPU-only container, and for full-scale dry-runs where interpret-mode
     grids would explode the HLO), lower to ``jnp.dot`` — XLA picks its
     own tiling.  On a real TPU deployment the policy flips on.
  2. Otherwise look up the tuned config for (M, K, N, dtype) in the
     global TuningRecords (written by `launch/tune.py`); fall back to the
     heuristic default when there is no record, or to XLA when shapes
     don't divide.

The op is differentiable either way: the Pallas path installs a
custom_vjp whose backward passes are themselves tiled GEMMs (dA = g Bᵀ,
dB = Aᵀ g) so tuned kernels serve training too.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.records import global_records, workload_key
from .gemm import KernelConfig, default_config, gemm_pallas, kernel_config_from_state

__all__ = ["gemm", "KernelPolicy", "set_kernel_policy", "kernel_policy"]


@dataclasses.dataclass
class KernelPolicy:
    use_pallas: bool = False  # flipped on for TPU deployments / kernel tests
    interpret: bool = True  # CPU container: interpret=True is the only mode
    cost_backend: str = "analytical_tpu_v5e"  # records namespace to consult


_POLICY = KernelPolicy()


def kernel_policy() -> KernelPolicy:
    return _POLICY


def set_kernel_policy(policy: KernelPolicy) -> None:
    global _POLICY
    _POLICY = policy


def _lookup_config(m: int, k: int, n: int, dtype: str) -> Optional[KernelConfig]:
    rec = global_records().lookup_state(
        workload_key(m, k, n, dtype, _POLICY.cost_backend)
    )
    if rec is None:
        return None
    try:
        return kernel_config_from_state(rec)
    except ValueError:
        return None


def _pallas_ok(m: int, k: int, n: int, cfg: KernelConfig) -> bool:
    try:
        cfg.validate(m, k, n)
        return True
    except ValueError:
        return False


def _bwd(cfg, interpret, res, g):
    a, b = res
    m, k = a.shape
    n = b.shape[1]
    # backward GEMMs get their own tuned configs (shapes differ)
    cfg_da = _lookup_config(m, n, k, str(g.dtype)) or default_config(m, n, k)
    cfg_db = _lookup_config(k, m, n, str(g.dtype)) or default_config(k, m, n)
    da = (
        gemm_pallas(g, b.T, cfg_da, interpret=interpret)
        if _pallas_ok(m, n, k, cfg_da)
        else jnp.dot(g, b.T)
    ).astype(a.dtype)
    db = (
        gemm_pallas(a.T, g, cfg_db, interpret=interpret)
        if _pallas_ok(k, m, n, cfg_db)
        else jnp.dot(a.T, g)
    ).astype(b.dtype)
    return da, db


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _gemm_pallas_diff(cfg: KernelConfig, interpret: bool, a, b):
    return gemm_pallas(a, b, cfg, interpret=interpret)


def _gemm_fwd(cfg, interpret, a, b):
    return gemm_pallas(a, b, cfg, interpret=interpret), (a, b)


_gemm_pallas_diff.defvjp(_gemm_fwd, _bwd)


def gemm(
    a: jax.Array,
    b: jax.Array,
    config: Optional[KernelConfig] = None,
    use_pallas: Optional[bool] = None,
) -> jax.Array:
    """2-D matmul through the kernel policy (see module docstring).

    Higher-rank LHS is flattened to 2-D and restored — every dense layer
    in `repro.models` funnels through here."""
    if a.ndim < 2 or b.ndim != 2:
        raise ValueError(f"gemm expects (.., K) @ (K, N), got {a.shape} @ {b.shape}")
    lead = a.shape[:-1]
    k = a.shape[-1]
    n = b.shape[-1]
    a2 = a.reshape((-1, k))
    m = a2.shape[0]

    enabled = _POLICY.use_pallas if use_pallas is None else use_pallas
    if enabled:
        cfg = config or _lookup_config(m, k, n, str(a.dtype)) or default_config(m, k, n)
        if _pallas_ok(m, k, n, cfg):
            out = _gemm_pallas_diff(cfg, _POLICY.interpret, a2, b)
            return out.reshape(lead + (n,))
    out = jnp.dot(a2, b)
    return out.reshape(lead + (n,))

"""Pallas TPU GEMM kernel with tuner-selected multi-level tiling.

This is the compute hot-spot the paper optimizes, adapted to the TPU
memory hierarchy (DESIGN.md §2):

  level 0 (grid):      (M/bm, N/bn, K/bk) macro-steps; k is the innermost
                       grid dimension so the f32 accumulator lives in
                       VMEM across the contraction ("arbitrary" semantics)
  level 1 (BlockSpec): A (bm, bk), B (bk, bn) VMEM blocks, double-buffered
                       by the Pallas pipeline
  level 2 (sub-tile):  an in-kernel loop over (sub_m, sub_n) tiles feeding
                       the MXU — the paper's inner nesting levels
  level 3 (register):  reg_m/reg_n granularity is folded into sub-tile
                       alignment (the MXU/VREG packing on TPU is not
                       software-addressable the way CUDA registers are)

A :class:`TilingState` from the tuner maps onto (bm, bk, bn, sub_m,
sub_n) via :func:`kernel_config_from_state`.  The kernel is validated
against ``ref.py`` in interpret mode on CPU (tests sweep shapes/dtypes);
on a real TPU the same code JITs natively.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

from repro.core.config_space import TilingState

__all__ = ["KernelConfig", "kernel_config_from_state", "gemm_pallas", "default_config"]


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    block_m: int
    block_k: int
    block_n: int
    sub_m: int = 0  # 0 = whole block (no inner split)
    sub_n: int = 0

    def resolved(self) -> "KernelConfig":
        sm = self.sub_m or self.block_m
        sn = self.sub_n or self.block_n
        return dataclasses.replace(self, sub_m=sm, sub_n=sn)

    def validate(self, m: int, k: int, n: int) -> None:
        c = self.resolved()
        if m % c.block_m or k % c.block_k or n % c.block_n:
            raise ValueError(
                f"blocks {(c.block_m, c.block_k, c.block_n)} do not divide "
                f"dims {(m, k, n)}"
            )
        if c.block_m % c.sub_m or c.block_n % c.sub_n:
            raise ValueError("sub-tiles must divide blocks")


def kernel_config_from_state(s: TilingState) -> KernelConfig:
    """Interpret a tuner state as a kernel config (DESIGN.md §2)."""
    cfg = KernelConfig(
        block_m=s.block_m,
        block_k=s.block_k,
        block_n=s.block_n,
        sub_m=s.sub_m,
        sub_n=s.sub_n,
    )
    m, k, n = s.dims()
    cfg.validate(m, k, n)
    return cfg


def default_config(m: int, k: int, n: int) -> KernelConfig:
    """Heuristic fallback when no tuning record exists: largest
    hardware-aligned blocks that fit the VMEM budget."""

    def best_div(dim: int, target: int) -> int:
        d = min(dim, target)
        while dim % d:
            d -= 1
        return d

    return KernelConfig(
        block_m=best_div(m, 256),
        block_k=best_div(k, 512),
        block_n=best_div(n, 256),
    )


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int, sub_m: int,
                 sub_n: int, out_dtype):
    """Kernel body: accumulate A-block @ B-block into the VMEM scratch
    accumulator; flush to the output block on the last k step."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bm, bk = a_ref.shape
    bn = b_ref.shape[1]
    n_sub_m = bm // sub_m
    n_sub_n = bn // sub_n
    if n_sub_m == 1 and n_sub_n == 1:
        acc_ref[...] += jnp.dot(
            a_ref[...], b_ref[...], preferred_element_type=jnp.float32
        )
    else:
        # level-2 tiling: explicit MXU-facing sub-tiles (paper's inner loops)
        a = a_ref[...]
        b = b_ref[...]
        for im in range(n_sub_m):
            for jn in range(n_sub_n):
                sl_m = slice(im * sub_m, (im + 1) * sub_m)
                sl_n = slice(jn * sub_n, (jn + 1) * sub_n)
                acc_ref[sl_m, sl_n] += jnp.dot(
                    a[sl_m, :], b[:, sl_n], preferred_element_type=jnp.float32
                )

    @pl.when(k_idx == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


@functools.partial(
    jax.jit, static_argnames=("config", "interpret", "out_dtype")
)
def gemm_pallas(
    a: jax.Array,
    b: jax.Array,
    config: KernelConfig,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """C = A @ B via the tiled Pallas kernel.  A: (M, K), B: (K, N)."""
    (m, k), (k2, n) = a.shape, b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {a.shape} @ {b.shape}")
    cfg = config.resolved()
    cfg.validate(m, k, n)
    out_dtype = out_dtype or a.dtype
    n_k = k // cfg.block_k
    grid = (m // cfg.block_m, n // cfg.block_n, n_k)

    kernel = functools.partial(
        _gemm_kernel,
        n_k=n_k,
        sub_m=cfg.sub_m,
        sub_n=cfg.sub_n,
        out_dtype=out_dtype,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((cfg.block_m, cfg.block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((cfg.block_k, cfg.block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((cfg.block_m, cfg.block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((cfg.block_m, cfg.block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)

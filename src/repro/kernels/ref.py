"""Pure-jnp oracle for the GEMM kernel (and its VJP)."""

from __future__ import annotations

import jax.numpy as jnp


def ref_gemm(a, b, out_dtype=None):
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def ref_gemm_vjp(a, b, g):
    """(dA, dB) for C = A @ B with upstream cotangent g."""
    da = jnp.dot(g, b.T, preferred_element_type=jnp.float32).astype(a.dtype)
    db = jnp.dot(a.T, g, preferred_element_type=jnp.float32).astype(b.dtype)
    return da, db

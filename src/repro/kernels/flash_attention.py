"""Pallas TPU flash-attention kernel (causal, GQA-aware).

The second compute hot spot after GEMM: the same online-softmax
algorithm the model stack uses in pure JAX
(models/common.chunked_causal_attention — which doubles as this kernel's
oracle), expressed as a pl.pallas_call with explicit VMEM tiling:

  grid:      (batch, kv_head, q_block)   — q blocks are parallel
  BlockSpec: Q (1, block_q, G, hd) · K/V (1, block_k, 1, hd) streamed
             through an inner fori_loop over kv blocks
  scratch:   f32 accumulator (G, block_q, hd) + running max/sum (G, block_q)

Like the tiled GEMM, (block_q, block_k) are tunable — the same
GemmConfigSpace machinery applies (2-factor compositions); see
tests/test_flash_kernel.py for the sweep.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["flash_attention"]


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  block_q: int, block_k: int, seq_k: int, causal: bool,
                  scale: float, out_dtype):
    """One (batch, kv_head, q_block) cell: stream kv blocks, online
    softmax into the VMEM accumulator."""
    iq = pl.program_id(2)

    acc_ref[...] = jnp.zeros_like(acc_ref)
    m_ref[...] = jnp.full_like(m_ref, -1e30)
    l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0].astype(jnp.float32) * scale  # (block_q, g, hd)
    n_k = seq_k // block_k

    def body(ik, _):
        sl = pl.dslice(ik * block_k, block_k)
        kb = k_ref[0, sl, 0].astype(jnp.float32)  # (block_k, hd)
        vb = v_ref[0, sl, 0].astype(jnp.float32)
        # logits: (g, block_q, block_k)
        logits = jnp.einsum("qgd,kd->gqk", q, kb)
        if causal:
            q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            logits = jnp.where((q_pos >= k_pos)[None], logits, -1e30)
        m_new = jnp.maximum(m_ref[...], logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m_ref[...] - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum("gqk,kd->gqd", p, vb)
        m_ref[...] = m_new
        return ()

    # causal: skip kv blocks entirely above the diagonal
    last = n_k if not causal else jnp.minimum(
        n_k, ((iq + 1) * block_q + block_k - 1) // block_k
    )
    jax.lax.fori_loop(0, last, body, ())
    out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
    o_ref[0, :, 0] = out.transpose(1, 0, 2).astype(out_dtype)  # (block_q, g, hd)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "causal", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    block_q: int = 256,
    block_k: int = 512,
    causal: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, S, H, hd); k/v: (B, S, KV, hd); returns (B, S, H, hd).

    GQA folds the H = KV x G query heads so each grid cell attends one
    KV head; K/V stream once per (batch, kv_head)."""
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if sq % block_q or sk % block_k:
        raise ValueError(f"blocks ({block_q},{block_k}) must divide ({sq},{sk})")
    qg = q.reshape(b, sq, kv, g, hd)
    grid = (b, kv, sq // block_q)

    kernel = functools.partial(
        _flash_kernel,
        block_q=block_q,
        block_k=block_k,
        seq_k=sk,
        causal=causal,
        scale=1.0 / math.sqrt(hd),
        out_dtype=q.dtype,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, g, hd), lambda ib, ih, iq: (ib, iq, ih, 0, 0)),
            pl.BlockSpec((1, sk, 1, hd), lambda ib, ih, iq: (ib, 0, ih, 0)),
            pl.BlockSpec((1, sk, 1, hd), lambda ib, ih, iq: (ib, 0, ih, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, 1, g, hd), lambda ib, ih, iq: (ib, iq, ih, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, sq, kv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, block_q, hd), jnp.float32),
            pltpu.VMEM((g, block_q), jnp.float32),
            pltpu.VMEM((g, block_q), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel"),
        ),
        interpret=interpret,
    )(qg, k, v)
    return out.reshape(b, sq, h, hd)

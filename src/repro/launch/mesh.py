"""Production mesh construction.

Functions, not module-level constants — importing this module never
touches jax device state (jax locks the device count at first backend
init, and smoke tests must see 1 CPU device while the dry-run sees 512).
"""

from __future__ import annotations

import jax

from repro.dist.api import MeshRules

__all__ = ["make_production_mesh", "rules_for_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod stacks 2 pods = 512 chips.

    Axes: ("pod",) data-parallel across DCI; "data" = in-pod DP (+ZeRO-1);
    "model" = TP/EP/SP."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def rules_for_mesh(mesh, sequence_parallel: bool = True) -> MeshRules:
    """Production rules: sequence parallelism ON by default — the
    residual stream between blocks is sharded over the model axis, which
    divides the scan-carry activation history by 16x (without it the
    dense train cells exceed per-chip HBM; see EXPERIMENTS.md §Perf)."""
    import dataclasses

    rules = MeshRules()
    if "pod" in mesh.shape:
        rules = rules.multipod()
    # production posture: ZeRO-3 params (scan-FSDP) + sequence parallelism
    rules = dataclasses.replace(rules, fsdp=True)
    if sequence_parallel:
        rules = dataclasses.replace(rules, sp="model")
    return rules


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver jits the real step function (train_step for
train_4k, prefill/serve_step otherwise) against ShapeDtypeStruct
stand-ins with full production shardings, compiles it, and records:

  * memory_analysis()           -> bytes per device (fits-in-HBM proof)
  * cost_analysis()             -> FLOPs / bytes   (roofline §compute/§memory)
  * HLO collective operand bytes -> roofline §collective (utils/hlo.py)

Results land as JSON under experiments/dryrun/<mesh>/<arch>__<shape>.json
and feed EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs.base import ShapeSpec
from repro.configs.registry import ARCHS, SHAPES, get_arch, get_shape
from repro.dist import sharding as shd
from repro.dist.api import MeshRules, mesh_context
from repro.launch.mesh import make_production_mesh, rules_for_mesh
from repro.models.api import Model
from repro.optim import make_optimizer
from repro.train.step import make_train_step
from repro.utils.hlo import collective_stats
from repro.utils.roofline import V5E, model_flops, roofline_from_costs

__all__ = ["run_cell", "main"]


def _mem_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    return out


def _tree_bytes(tree) -> int:
    return sum(
        x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree)
    )


def _sharded_tree_bytes(abstract, shardings, mesh) -> int:
    """Per-device bytes of a sharded pytree (params/opt/cache)."""
    total = 0
    flat_a = jax.tree_util.tree_leaves(abstract)
    flat_s = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda s: isinstance(s, jax.sharding.NamedSharding)
    )
    for a, s in zip(flat_a, flat_s):
        import math as _m

        shard_elems = a.size
        spec = s.spec
        for dim, name in enumerate(spec):
            if name is None:
                continue
            axes = name if isinstance(name, tuple) else (name,)
            k = _m.prod(mesh.shape[ax] for ax in axes)
            shard_elems //= k
        total += shard_elems * a.dtype.itemsize
    return total


def _depth_variant(cfg, units: float):
    """A same-family config with ``units`` depth units (see
    ``_depth_units``): dense/ssm layers, encdec (dec+enc) pairs, hybrid
    groups-of-interval."""
    import dataclasses

    n = int(units)
    if cfg.family == "encdec":
        return dataclasses.replace(cfg, n_layers=n, n_encoder_layers=n,
                                   scan_layers=False)
    if cfg.family == "hybrid" and cfg.hybrid_attn_interval:
        return dataclasses.replace(
            cfg, n_layers=n * cfg.hybrid_attn_interval, scan_layers=False
        )
    return dataclasses.replace(cfg, n_layers=n, scan_layers=False)


def _depth_units(cfg) -> float:
    if cfg.family == "hybrid" and cfg.hybrid_attn_interval:
        return cfg.n_layers / cfg.hybrid_attn_interval
    return float(cfg.n_layers)


def _compile_cell(cfg, shape: ShapeSpec, mesh, rules, donate: bool = True):
    """Lower + compile one step function; returns a measurement dict.

    cost_analysis of a lax.scan body is counted ONCE by XLA regardless of
    trip count, so run_cell calls this at two shallow depths and
    extrapolates linearly to the full depth (layers are homogeneous);
    the full-depth compile is still performed as the pass/fail gate and
    for memory_analysis."""
    model = Model(cfg)
    chips = mesh.devices.size
    abs_params = model.abstract_params()
    pspecs = shd.param_specs(cfg, abs_params, mesh, rules)
    psh = jax.tree_util.tree_map(
        lambda s: jax.sharding.NamedSharding(mesh, s), pspecs
    )
    batch = model.batch_specs(shape)
    bsh = shd.batch_shardings(batch, mesh, rules)
    t0 = time.monotonic()
    with mesh_context(mesh, rules):
        if shape.kind == "train":
            optimizer = make_optimizer(cfg.optimizer, 1e-4)
            abs_opt = jax.eval_shape(optimizer.init, abs_params)
            osh = shd.opt_state_shardings(
                cfg.optimizer, abs_opt, pspecs, mesh, rules
            )
            step = make_train_step(model, optimizer,
                                   grad_accum=cfg.dryrun_grad_accum)
            jitted = jax.jit(
                step,
                in_shardings=(psh, osh, bsh),
                out_shardings=(psh, osh, None),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(abs_params, abs_opt, batch)
            state_bytes = _sharded_tree_bytes(abs_opt, osh, mesh)
        elif shape.kind == "prefill":
            def prefill_step(params, b):
                return model.prefill(params, b, shape.seq_len)

            abs_cache = model.abstract_cache(shape.global_batch, shape.seq_len)
            csh = shd.cache_shardings(cfg, abs_cache, mesh, rules)
            jitted = jax.jit(
                prefill_step,
                in_shardings=(psh, bsh),
                out_shardings=(None, csh),
            )
            lowered = jitted.lower(abs_params, batch)
            state_bytes = _sharded_tree_bytes(abs_cache, csh, mesh)
        else:  # decode
            abs_cache = model.abstract_cache(shape.global_batch, shape.seq_len)
            csh = shd.cache_shardings(cfg, abs_cache, mesh, rules)

            def decode(params, cache, tokens):
                return model.decode_step(params, cache, tokens)

            jitted = jax.jit(
                decode,
                in_shardings=(psh, csh, bsh["tokens"]),
                out_shardings=(None, csh),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(abs_params, abs_cache, batch["tokens"])
            state_bytes = _sharded_tree_bytes(abs_cache, csh, mesh)

        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower

    costs = compiled.cost_analysis()
    if isinstance(costs, list):  # older jax returns [dict]
        costs = costs[0]
    costs = dict(costs or {})
    coll = collective_stats(compiled.as_text())
    return {
        "flops": float(costs.get("flops", 0.0)),
        "bytes": float(costs.get("bytes accessed", 0.0)),
        "collectives": coll,
        "mem": _mem_analysis_dict(compiled),
        "param_bytes": _sharded_tree_bytes(abs_params, psh, mesh),
        "state_bytes": state_bytes,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "chips": chips,
    }


def analytic_chunked_attn_flops(cfg, shape: ShapeSpec) -> float:
    """GLOBAL attention flops hidden from cost_analysis when the
    flash-chunked path runs (its q-block map / kv-chunk scan bodies are
    counted once by XLA).  2·B·S²·H·hd per attention layer-application
    (qk + pv einsums, causal halving); x3 for training (fwd + bwd)."""
    if shape.kind == "decode" or cfg.family == "ssm":
        return 0.0
    s = shape.seq_len
    if s <= cfg.attn_chunk_threshold:
        return 0.0  # full-attention path: flops visible to cost_analysis
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // max(cfg.hybrid_attn_interval, 1)
    else:
        n_attn = cfg.n_layers
    per_layer = 2.0 * shape.global_batch * float(s) * float(s) * cfg.n_heads * cfg.resolved_head_dim
    total = per_layer * n_attn
    if shape.kind == "train":
        total *= 3.0
    return total


def _extrapolate(m1: dict, m2: dict, u1: float, u2: float, u_full: float) -> dict:
    """Linear-in-depth extrapolation of flops/bytes/collective bytes."""
    def lin(a, b):
        slope = (b - a) / (u2 - u1)
        return a + slope * (u_full - u1)

    coll = {}
    kinds = set(m1["collectives"]) | set(m2["collectives"])
    kinds.discard("total_operand_bytes")
    for k in kinds:
        a = m1["collectives"].get(k, {"count": 0, "operand_bytes": 0, "result_bytes": 0})
        b = m2["collectives"].get(k, {"count": 0, "operand_bytes": 0, "result_bytes": 0})
        coll[k] = {
            "count": int(round(lin(a["count"], b["count"]))),
            "operand_bytes": lin(a["operand_bytes"], b["operand_bytes"]),
            "result_bytes": lin(a["result_bytes"], b["result_bytes"]),
        }
    coll["total_operand_bytes"] = sum(v["operand_bytes"] for v in coll.values())
    return {
        "flops": lin(m1["flops"], m2["flops"]),
        "bytes": lin(m1["bytes"], m2["bytes"]),
        "collectives": coll,
    }


def run_cell(arch_name: str, shape_name: str, mesh_kind: str,
             out_dir: str = "experiments/dryrun", donate: bool = True,
             rules_override: MeshRules | None = None,
             cfg_override=None, tag: str = "") -> dict:
    cfg = cfg_override or get_arch(arch_name)
    shape = get_shape(shape_name)
    model = Model(cfg)
    rec: dict = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": mesh_kind,
        "kind": shape.kind,
    }
    ok, reason = model.supports_shape(shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        _write(rec, out_dir, tag)
        return rec

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    rules = rules_override or rules_for_mesh(mesh)
    chips = mesh.devices.size
    try:
        # 1. full-depth compile: the pass/fail gate + memory analysis
        full = _compile_cell(cfg, shape, mesh, rules, donate)
        # 2. two shallow compiles for scan-corrected roofline terms
        u_full = _depth_units(cfg)
        u1, u2 = 1.0, 2.0
        m1 = _compile_cell(_depth_variant(cfg, u1), shape, mesh, rules, donate)
        m2 = _compile_cell(_depth_variant(cfg, u2), shape, mesh, rules, donate)
    except Exception as e:  # a failing cell is a bug — record it loudly
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        _write(rec, out_dir, tag)
        return rec

    ext = _extrapolate(m1, m2, u1, u2, u_full)
    ga = max(1, cfg.dryrun_grad_accum)
    if ga > 1 and shape.kind == "train":
        # the microbatch-accumulation scan body is also counted once by
        # cost_analysis: scale per-step totals back up (slightly
        # overcounts the once-per-step optimizer update; noted)
        ext["flops"] *= ga
        ext["bytes"] *= ga
        for v in ext["collectives"].values():
            if isinstance(v, dict):
                v["operand_bytes"] *= ga
                v["result_bytes"] *= ga
        ext["collectives"]["total_operand_bytes"] = sum(
            v["operand_bytes"] for v in ext["collectives"].values() if isinstance(v, dict)
        )
    mf = model_flops(cfg, shape)
    attn_fix = analytic_chunked_attn_flops(cfg, shape) / chips
    terms = roofline_from_costs(
        ext["flops"] + attn_fix, ext["bytes"], ext["collectives"], chips, mf
    )
    raw_terms = roofline_from_costs(
        full["flops"], full["bytes"], full["collectives"], chips, mf
    )
    hbm = V5E().hbm_bytes
    per_device_total = (
        full["param_bytes"] + full["state_bytes"] + full["mem"].get("temp_size_in_bytes", 0)
    )
    rec.update(
        {
            "status": "ok",
            "chips": chips,
            "lower_s": full["lower_s"],
            "compile_s": full["compile_s"],
            "cost_analysis_raw": {"flops": full["flops"], "bytes accessed": full["bytes"]},
            "cost_analysis_extrapolated": {"flops": ext["flops"], "bytes accessed": ext["bytes"]},
            "attn_flops_analytic_per_device": attn_fix,
            "depth_units": {"full": u_full, "probe": [u1, u2]},
            "memory_analysis": full["mem"],
            "param_bytes_per_device": full["param_bytes"],
            "state_bytes_per_device": full["state_bytes"],
            "bytes_per_device_total": per_device_total,
            "fits_hbm": bool(per_device_total < hbm),
            "collectives": ext["collectives"],
            "collectives_raw": full["collectives"],
            "roofline": terms.as_dict(),
            "roofline_raw_scanbody": raw_terms.as_dict(),
        }
    )
    _write(rec, out_dir, tag)
    return rec


def _write(rec: dict, out_dir: str, tag: str = "") -> None:
    d = os.path.join(out_dir, rec["mesh"] + (f"-{tag}" if tag else ""))
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{rec['arch']}__{rec['shape']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec["status"]
    extra = ""
    if status == "ok":
        r = rec["roofline"]
        extra = (
            f" compile={rec['compile_s']:.0f}s dominant={r['dominant']}"
            f" c/m/coll={r['compute_s']:.2e}/{r['memory_s']:.2e}/{r['collective_s']:.2e}s"
            f" fits_hbm={rec['fits_hbm']}"
        )
    elif status == "error":
        extra = " " + rec["error"][:200]
    elif status == "skipped":
        extra = " " + rec["reason"]
    print(f"[dryrun] {rec['mesh']:6s} {rec['arch']:24s} {rec['shape']:12s} {status}{extra}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a in ARCHS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    for mesh_kind in meshes:
        for arch, shp in cells:
            out_path = os.path.join(
                args.out, mesh_kind, f"{arch}__{shp}.json"
            )
            if args.skip_existing and os.path.exists(out_path):
                with open(out_path) as f:
                    if json.load(f).get("status") in ("ok", "skipped"):
                        print(f"[dryrun] skip existing {mesh_kind} {arch} {shp}")
                        continue
            run_cell(arch, shp, mesh_kind, out_dir=args.out)


if __name__ == "__main__":
    main()

"""Audit persistent tuning stores with the static schedule analyzer.

``python -m repro.launch.analyze`` re-derives a verdict (see
``repro.core.analysis``) for every record in a :class:`TuningRecords`
JSON and every row of a :class:`TrialJournal`, and exits nonzero when
anything is provably broken — the CI tripwire against shipping stale or
corrupted schedule stores:

* a record whose state is ILLEGAL for its own workload key — a factor
  product that no longer matches the dims (a stale record for another
  shape), a corrupted state list, or a working set over the VMEM budget;
* a record filed under an unparseable key, or whose ``op`` field
  disagrees with its key's op (cross-op contamination);
* a journal row carrying a *finite* measured cost for a schedule the
  analyzer proves ILLEGAL — every backend scores those ``inf`` (the
  oracles delegate the cliff to the same analyzer; ``XLATimedCost``
  guards VMEM with the same budget), so a finite cost means the store
  and the models disagree about reality.

WASTEFUL verdicts and unparseable *journal* keys are warnings: dominated
schedules are legal to serve, and a journal is an append-only log that
may carry foreign experiments.  ``--strict`` promotes warnings to the
exit code.  Journal ``static`` rows (the engine's pruned-candidate audit
trail) and ``pred`` rows (the learned filter's skip provenance, see
``repro.core.learn``) are counted and reported, never flagged — except a
``pred`` row claiming a finite measured cost, which is an error: a
prediction masquerading as a measurement.

The audit also reports the learned-model training corpus per op/dtype
(``[analyze] learn-corpus:`` lines — trainable measured rows vs
fail/static/predicted provenance rows), so users can tell when a
workload has accumulated enough data to train on.

Sharded searches (``tune --shard I/N``, see ``repro.core.shard``) leave
``"shard": [i, n]`` tags on their journal rows and done markers next to
the journal; the audit recomputes each tagged row's owner (a claimed
shard that doesn't own the candidate is an error), errors on candidates
measured by two shards, and warns on owner gaps — shards that never
wrote their done marker.  The ``[analyze] shard-coverage:`` line is the
machine-greppable summary CI asserts on.

Usage::

  python -m repro.launch.analyze                       # records/*.json + journals
  python -m repro.launch.analyze --records r.json      # one store
  python -m repro.launch.analyze --journal j.jsonl     # one journal
"""

from __future__ import annotations

import argparse
import collections
import glob
import json
import math
import os
import sys
from typing import Optional

from repro.core.analysis import ScheduleAnalyzer, dtype_in_bytes
from repro.core.fault import PERMANENT_KINDS, TRANSIENT_KINDS
from repro.core.learn import scan_corpus
from repro.core.ops import get_op
from repro.core.records import (
    TrialJournal,
    iter_journal_rows,
    parse_workload_key_generic,
)
from repro.core.shard import read_done_markers, shard_dir_for, shard_of
from repro.core.space import state_from_lists


class _Auditor:
    """Shared error/warning sink + per-workload analyzer cache."""

    def __init__(self) -> None:
        self.errors: list[str] = []
        self.warnings: list[str] = []
        self._analyzers: dict[tuple, Optional[ScheduleAnalyzer]] = {}
        # failure provenance (the journal's fail-row taxonomy)
        self.fail_kinds: collections.Counter = collections.Counter()
        self.n_retried_rows = 0  # fail rows that record >1 attempt
        self.n_permanent_legal = 0  # permanent failures on legal schedules
        self.n_predicted = 0  # learned-filter skip provenance rows
        # sharded-search coverage (rows tagged "shard": [i, n])
        self.n_shard_rows = 0
        self.shard_workloads: set[str] = set()
        self.n_shard_violations = 0  # row's claimed shard != recomputed owner
        self.n_cross_shard_dups = 0  # one candidate measured by two shards
        self.n_marker_gaps = 0  # done-marker sets missing a shard index
        self._shard_claims: dict[tuple[str, str], int] = {}

    def error(self, where: str, msg: str) -> None:
        self.errors.append(f"{where}: {msg}")
        print(f"[analyze] ERROR {where}: {msg}")

    def warn(self, where: str, msg: str) -> None:
        self.warnings.append(f"{where}: {msg}")
        print(f"[analyze] warning {where}: {msg}")

    def analyzer(self, op: str, dims: tuple, dtype: str,
                 depths: tuple) -> Optional[ScheduleAnalyzer]:
        """Analyzer for one workload identity, or None when the op's
        space cannot even be built (reported by the caller)."""
        key = (op, dims, dtype, depths)
        if key not in self._analyzers:
            try:
                space = get_op(op).make_space(dims, depths)
                self._analyzers[key] = ScheduleAnalyzer(
                    space, in_bytes=dtype_in_bytes(dtype)
                )
            except Exception:
                self._analyzers[key] = None
        return self._analyzers[key]


def _depths_of(lists) -> tuple:
    return tuple(len(r) for r in lists)


def audit_records(path: str, auditor: _Auditor) -> int:
    """Audit one TuningRecords JSON; returns the number of records seen."""
    try:
        with open(path) as f:
            data = json.load(f)
        assert isinstance(data, dict)
    except Exception as e:
        auditor.error(path, f"unreadable records file ({type(e).__name__}: {e})")
        return 0
    n = 0
    for key, rec in sorted(data.items()):
        n += 1
        where = f"{path} :: {key}"
        parsed = parse_workload_key_generic(key)
        if parsed is None:
            auditor.error(where, "unparseable workload key")
            continue
        op, dims, dtype, _backend = parsed
        rec_op = rec.get("op") if isinstance(rec, dict) else None
        if rec_op is not None and rec_op != op:
            auditor.error(
                where, f"cross-op record: op field {rec_op!r} under a {op!r} key"
            )
            continue
        try:
            lists = rec["state"]
            st = state_from_lists(op, lists)
        except Exception as e:
            auditor.error(
                where, f"undeserializable state ({type(e).__name__}: {e})"
            )
            continue
        an = auditor.analyzer(op, dims, dtype, _depths_of(lists))
        if an is None:
            auditor.error(where, f"cannot build the {op!r} search space")
            continue
        res = an.analyze(st)
        if res.illegal:
            auditor.error(where, f"ILLEGAL record ({res.reason}): {res.detail}")
        elif res.wasteful:
            auditor.warn(where, f"WASTEFUL record ({res.reason}): {res.detail}")
    return n


def audit_journal(path: str, auditor: _Auditor) -> tuple[int, int]:
    """Audit one trial journal; returns (rows seen, static audit rows)."""
    n = n_static = 0
    shard_counts: dict[str, int] = {}  # journal key -> shard count seen
    for row in iter_journal_rows(path):
        n += 1
        try:
            full_key = row["w"]
            base_key = full_key.split("?", 1)[0]
            state_key = row["k"]
        except (KeyError, AttributeError, TypeError):
            auditor.warn(path, f"malformed row (no w/k): {str(row)[:80]}")
            continue
        where = f"{path} :: {base_key} :: {state_key}"
        # sharded-search coverage: a row tagged "shard": [i, n] must be
        # owned by shard i under the deterministic partition, and no
        # candidate may carry measurements from two different shards
        tag = row.get("shard")
        if tag is not None:
            try:
                si, sn = int(tag[0]), int(tag[1])
            except (TypeError, ValueError, IndexError, KeyError):
                auditor.warn(where, f"malformed shard tag {tag!r}")
            else:
                auditor.n_shard_rows += 1
                auditor.shard_workloads.add(full_key)
                shard_counts[full_key] = max(shard_counts.get(full_key, 0), sn)
                owner = shard_of(full_key, state_key, sn)
                if owner != si:
                    auditor.n_shard_violations += 1
                    auditor.error(
                        where,
                        f"shard-ownership violation: row claims shard "
                        f"{si}/{sn} but the partition owner is {owner}",
                    )
                claim = auditor._shard_claims.setdefault(
                    (full_key, state_key), si
                )
                if claim != si:
                    auditor.n_cross_shard_dups += 1
                    auditor.error(
                        where,
                        f"candidate measured by two shards "
                        f"({claim} and {si}) — the partition must be disjoint",
                    )
        parsed = parse_workload_key_generic(base_key)
        if parsed is None:
            # journals are append-only logs that may carry foreign
            # experiments; an alien key is suspicious, not fatal
            auditor.warn(where, "unparseable journal workload key")
            continue
        op, dims, dtype, _backend = parsed
        row_op = row.get("op", "gemm")
        if row_op != op:
            auditor.error(
                where, f"cross-op row: op field {row_op!r} under a {op!r} key"
            )
            continue
        if "static" in row:
            n_static += 1  # the engine's pruned-candidate audit trail
            continue
        if "pred" in row:
            # learned-filter skip provenance: the model's rank score for
            # a candidate that never reached a lane.  Counted, never
            # audited as a measurement — but a finite "c" here means a
            # prediction is posing as a measured cost, which downstream
            # loaders would cache
            auditor.n_predicted += 1
            if row.get("c") is not None:
                auditor.error(
                    where,
                    "predicted row carries a measured cost "
                    f"(c={row.get('c')!r}) — predictions must be "
                    "provenance-only",
                )
            continue
        # failure provenance: every fail row carries a taxonomy kind
        # (legacy rows without one are the historical failed-build inf)
        fail_kind = None
        if row.get("fail") or row.get("c") is None:
            fail_kind = row.get("kind", "build")
            auditor.fail_kinds[fail_kind] += 1
            if int(row.get("attempts", 1)) > 1:
                auditor.n_retried_rows += 1
            if fail_kind in TRANSIENT_KINDS:
                # provenance-only rows: the lane died, not the schedule —
                # nothing about the state to audit
                continue
            if fail_kind not in PERMANENT_KINDS:
                auditor.warn(where, f"unknown failure kind {fail_kind!r}")
                continue
        try:
            lists = row["s"]
            st = state_from_lists(op, lists)
        except Exception as e:
            auditor.warn(
                where, f"undeserializable state ({type(e).__name__}: {e})"
            )
            continue
        an = auditor.analyzer(op, dims, dtype, _depths_of(lists))
        if an is None:
            auditor.warn(where, f"cannot build the {op!r} search space")
            continue
        res = an.analyze(st)
        if res.illegal and math.isfinite(TrialJournal._row_cost(row)):
            auditor.error(
                where,
                f"finite measured cost for an ILLEGAL schedule "
                f"({res.reason}): {res.detail}",
            )
        if fail_kind in PERMANENT_KINDS and not res.illegal:
            # a cacheable failure for a schedule the analyzer finds legal:
            # either the backend is flakier than the taxonomy thinks (a
            # transient miscast as permanent — it will never be retried)
            # or the static model disagrees with the backend about
            # feasibility; both deserve eyes
            auditor.n_permanent_legal += 1
            auditor.warn(
                where,
                f"permanent-failure row ({fail_kind}) cached for a schedule "
                f"the analyzer finds legal",
            )
    # done-marker coverage: every workload with sharded rows should have
    # all n shard markers once the searches finish — a gap means a shard
    # never completed (or timed out before electing), so its owned slice
    # of the space went unexplored
    root = shard_dir_for(path)
    if shard_counts and os.path.isdir(root):
        for jkey, sn in sorted(shard_counts.items()):
            markers = read_done_markers(root, jkey, sn)
            missing = sorted(set(range(sn)) - set(markers))
            if missing:
                auditor.n_marker_gaps += 1
                auditor.warn(
                    f"{path} :: {jkey}",
                    f"owner gap: shard(s) {missing} of {sn} never wrote a "
                    f"done marker — their owned candidates are unexplored",
                )
    return n, n_static


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.analyze",
        description="Audit tuning records and trial journals with the "
                    "static schedule analyzer; exits nonzero on provably "
                    "broken entries (CI tripwire).",
    )
    ap.add_argument("--records", action="append", default=None,
                    help="TuningRecords JSON to audit (repeatable; default: "
                         "records/*.json)")
    ap.add_argument("--journal", action="append", default=None,
                    help="trial-journal JSONL to audit (repeatable; default: "
                         "the <records>.journal.jsonl next to each records "
                         "file, when present)")
    ap.add_argument("--strict", action="store_true",
                    help="warnings (WASTEFUL records, alien journal rows) "
                         "also fail the exit code")
    args = ap.parse_args(argv)

    records = args.records
    journals = args.journal
    if records is None and journals is None:
        records = sorted(glob.glob("records/*.json"))
        journals = [
            p + ".journal.jsonl"
            for p in records
            if os.path.exists(p + ".journal.jsonl")
        ]
    records = records or []
    journals = journals or []
    if not records and not journals:
        print("[analyze] nothing to audit (no records/*.json here; "
              "pass --records/--journal)")
        return 0

    auditor = _Auditor()
    n_rec = sum(audit_records(p, auditor) for p in records)
    n_rows = n_static = 0
    for p in journals:
        rows, static = audit_journal(p, auditor)
        n_rows += rows
        n_static += static
    print(
        f"[analyze] audited {n_rec} records in {len(records)} file(s), "
        f"{n_rows} journal rows ({n_static} static audit rows, "
        f"{auditor.n_predicted} predicted rows) in "
        f"{len(journals)} file(s): {len(auditor.errors)} error(s), "
        f"{len(auditor.warnings)} warning(s)"
    )
    # learned-model corpus census: trainable measured rows vs provenance
    # rows, per op/dtype — "do I have enough data to train on yet?"
    if journals:
        for (op, dtype), c in sorted(scan_corpus(journals).items()):
            print(
                f"[analyze] learn-corpus: op={op} dtype={dtype} "
                f"trainable={c.n_trainable} fail={c.n_fail} "
                f"static={c.n_static} predicted={c.n_predicted}"
            )
    # machine-greppable failure-provenance summary (CI asserts on it)
    kinds = " ".join(
        f"{k}={auditor.fail_kinds[k]}" for k in sorted(auditor.fail_kinds)
    )
    print(
        f"[analyze] failure-provenance: {kinds or 'none'} "
        f"retried_rows={auditor.n_retried_rows} "
        f"permanent_for_legal={auditor.n_permanent_legal}"
    )
    # machine-greppable sharded-search coverage summary (CI asserts on it)
    print(
        f"[analyze] shard-coverage: sharded_rows={auditor.n_shard_rows} "
        f"workloads={len(auditor.shard_workloads)} "
        f"violations={auditor.n_shard_violations} "
        f"cross_shard_dups={auditor.n_cross_shard_dups} "
        f"marker_gaps={auditor.n_marker_gaps}"
    )
    if auditor.errors or (args.strict and auditor.warnings):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Train and evaluate learned cost models from trial journals.

``python -m repro.launch.learn train`` assembles the op/dtype-scoped
corpus from one or more journal files (``repro.core.learn.build_dataset``
— cross-shape: each workload's rows form one rank group), fits a
:class:`~repro.core.learn.RankingCostModel`, and persists it
content-keyed into the journal's ``.learncache`` directory — the same
cache the tune CLI's ``--learned-filter`` consults, so an offline
training run pre-warms the filter for every later search.

``python -m repro.launch.learn eval`` measures what actually matters
for transfer: **held-out-shape** rank quality.  Each workload group is
held out in turn, the model is refit on the remaining shapes, and the
held-out group's Spearman rank correlation and top-k recall are
reported (with ``--min-corr`` as a CI exit gate: a model that cannot
rank a shape it never saw is not safe to filter with).

Usage::

  python -m repro.launch.learn train --journal j.jsonl --op gemm
  python -m repro.launch.learn eval  --journal j.jsonl --op gemm --min-corr 0.0
"""

from __future__ import annotations

import argparse
import math
import sys

import numpy as np

from repro.core.learn import (
    RankingCostModel,
    build_dataset,
    learn_cache_dir_for,
    spearman_rank_corr,
    top_k_recall,
)


def _add_scope_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--journal", action="append", required=True,
                    help="trial-journal JSONL to read (repeatable)")
    ap.add_argument("--op", default="gemm",
                    help="operator whose rows form the corpus")
    ap.add_argument("--dtype", default=None,
                    help="narrow the corpus to one dtype (default: all)")
    ap.add_argument("--fingerprint", default=None,
                    help="narrow to one measurement fingerprint "
                         "(default: all — fine for eval; training for a "
                         "specific filter should match its backend)")
    ap.add_argument("--n-trees", type=int, default=60)
    ap.add_argument("--depth", type=int, default=4)
    ap.add_argument("--lr", type=float, default=0.2)


def _print_corpus(ds) -> None:
    c = ds.counts
    print(
        f"[learn] corpus: op={ds.op} dtype={ds.dtype or 'any'} "
        f"rows={c.n_trainable} groups={ds.n_groups} "
        f"features={ds.n_features} (excluded: fail={c.n_fail} "
        f"static={c.n_static} predicted={c.n_predicted} "
        f"dup={c.n_duplicate} foreign={c.n_foreign} "
        f"incompatible={c.n_incompatible})"
    )


def _hyper(args) -> dict:
    return {"n_trees": args.n_trees, "depth": args.depth, "lr": args.lr}


def cmd_train(args) -> int:
    ds = build_dataset(args.journal, args.op, dtype=args.dtype,
                       fingerprint=args.fingerprint)
    _print_corpus(ds)
    if len(ds) < 2:
        print("[learn] corpus too small to train on")
        return 1
    model = RankingCostModel.fit_dataset(ds, **_hyper(args))
    metrics = model.evaluate(ds, k=args.k)
    print(
        f"[learn] trained: trees={len(model.booster.trees)} "
        f"in-sample rank_corr={metrics['rank_corr']:.3f} "
        f"top{args.k}_recall={metrics['top_k_recall']:.3f}"
    )
    cache_dir = args.cache_dir or learn_cache_dir_for(args.journal[0])
    path = model.save(cache_dir)
    print(f"[learn] saved model to {path} (content key {model.content_key()})")
    return 0


def cmd_eval(args) -> int:
    ds = build_dataset(args.journal, args.op, dtype=args.dtype,
                       fingerprint=args.fingerprint)
    _print_corpus(ds)
    if len(ds) < 4:
        print("[learn] corpus too small to evaluate")
        return 1
    corrs, recalls = [], []
    groups = np.unique(ds.groups)
    if len(groups) >= 2:
        # held-out-shape: refit without each workload, score its rows
        for g in groups:
            train, held = ds.split_group(int(g))
            if len(held) < 3 or len(train) < 2:
                continue
            model = RankingCostModel.fit_dataset(train, **_hyper(args))
            if not model.is_fitted:
                continue
            pred = model.predict(held.X)
            corr = spearman_rank_corr(held.y, pred, held.groups)
            recall = top_k_recall(held.y, pred, args.k, held.groups)
            key = ds.group_keys[int(g)]
            print(
                f"[learn] held-out {key}: rows={len(held)} "
                f"rank_corr={corr:.3f} top{args.k}_recall={recall:.3f}"
            )
            if math.isfinite(corr):
                corrs.append(corr)
            if math.isfinite(recall):
                recalls.append(recall)
    else:
        # one shape only: no transfer to measure — fall back to an
        # interleaved in-shape split so the gate still means something
        print("[learn] single-shape corpus: evaluating an in-shape "
              "even/odd split (no held-out shape available)")
        mask = np.arange(len(ds)) % 2 == 0
        train, held = ds.subset(mask), ds.subset(~mask)
        model = RankingCostModel.fit_dataset(train, **_hyper(args))
        pred = model.predict(held.X)
        corr = spearman_rank_corr(held.y, pred, held.groups)
        recall = top_k_recall(held.y, pred, args.k, held.groups)
        if math.isfinite(corr):
            corrs.append(corr)
        if math.isfinite(recall):
            recalls.append(recall)
    if not corrs:
        print("[learn] no group large enough to rank")
        return 1
    mean_corr = float(np.mean(corrs))
    mean_recall = float(np.mean(recalls)) if recalls else float("nan")
    print(
        f"[learn] eval: held_out_rank_corr={mean_corr:.3f} "
        f"held_out_top{args.k}_recall={mean_recall:.3f} "
        f"over {len(corrs)} split(s)"
    )
    if args.min_corr is not None and not mean_corr > args.min_corr:
        print(
            f"[learn] FAIL: held-out rank correlation {mean_corr:.3f} "
            f"not above the --min-corr gate {args.min_corr}"
        )
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.learn",
        description="Train / evaluate journal-backed learned cost models "
                    "(repro.core.learn).",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)
    tr = sub.add_parser("train", help="fit a rank model and persist it "
                                      "content-keyed next to the journal")
    _add_scope_args(tr)
    tr.add_argument("--k", type=int, default=8,
                    help="k for the top-k recall report")
    tr.add_argument("--cache-dir", default=None,
                    help="model cache directory (default: "
                         "<first journal>.learncache)")
    tr.set_defaults(fn=cmd_train)
    ev = sub.add_parser("eval", help="held-out-shape rank-correlation and "
                                     "top-k-recall report")
    _add_scope_args(ev)
    ev.add_argument("--k", type=int, default=8)
    ev.add_argument("--min-corr", type=float, default=None,
                    help="exit nonzero unless the mean held-out rank "
                         "correlation exceeds this (CI gate)")
    ev.set_defaults(fn=cmd_eval)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())

"""Serving launcher: batched prefill + decode loop with simple
continuous-batching bookkeeping.

  python -m repro.launch.serve --arch yi-6b --reduced --requests 8 \
      --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.models.api import Model

__all__ = ["ServeEngine"]


class ServeEngine:
    """Minimal batched engine: fixed max batch, greedy sampling.
    Requests are padded into the batch; finished slots are refilled from
    the queue (continuous batching at step granularity)."""

    def __init__(self, cfg, params, max_batch: int, max_len: int):
        self.cfg = cfg
        self.model = Model(cfg)
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, max_len)
        )
        self._decode = jax.jit(self.model.decode_step)

    def generate(self, prompts: np.ndarray, gen_tokens: int) -> np.ndarray:
        """prompts: (B, P) int32; returns (B, gen_tokens)."""
        b = prompts.shape[0]
        assert b <= self.max_batch
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if self.cfg.family == "encdec":
            batch["enc_frames"] = jnp.zeros(
                (b, self.cfg.encoder_len, self.cfg.d_model),
                jnp.dtype(self.cfg.compute_dtype),
            )
        logits, cache = self._prefill(self.params, batch)
        out = np.zeros((b, gen_tokens), np.int32)
        tok = jnp.argmax(logits[:, -1, : self.cfg.vocab_size], -1)[:, None].astype(jnp.int32)
        for i in range(gen_tokens):
            out[:, i] = np.asarray(tok[:, 0])
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits[:, -1, : self.cfg.vocab_size], -1)[:, None].astype(jnp.int32)
        return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, max_batch=args.requests,
                         max_len=args.prompt_len + args.gen)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size, (args.requests, args.prompt_len)).astype(np.int32)
    t0 = time.monotonic()
    out = engine.generate(prompts, args.gen)
    dt = time.monotonic() - t0
    total_new = args.requests * args.gen
    print(
        f"[serve] {args.arch}: {args.requests} requests x {args.gen} tokens "
        f"in {dt:.2f}s = {total_new/dt:.1f} tok/s (greedy);"
        f" sample: {out[0][:8].tolist()}"
    )


if __name__ == "__main__":
    main()

"""Serving launcher: bucketed, AOT pre-warmed batched prefill + decode.

  python -m repro.launch.serve --arch yi-6b --reduced --requests 8 \
      --prompt-len 32 --gen 16 --cache-dir /tmp/serve.cache

The engine closes the tune->serve loop from the execution side:

* **Shape buckets** — incoming prompts are right-padded into a fixed set
  of prompt-length buckets (attention families only; SSM/hybrid state
  cannot tolerate pad tokens, so those run exact lengths), and decode
  runs a ``lax.scan`` loop compiled per generation-length bucket.
  Request-length jitter therefore never triggers a recompile: every
  request reuses one of a small, enumerable set of executables.
* **AOT pre-warm** — each (prefill, decode) executable is resolved
  through the persistent :class:`~repro.core.cost.measured.ExecutableCache`
  (the same two-layer memory+disk cache the measurement engine uses), so
  a warm restart deserializes prior compiles instead of redoing them;
  ``cache_report()`` exposes the compile/disk-hit counters the serving
  bench asserts on.  Cache keys fold in the kernel policy and the
  content of the global tuning records, because tuned records change the
  *traced program* (flash block sizes, GEMM tiles) — a stale executable
  can never be served for a different schedule.
* **Record-aware dispatch** — the traced prefill goes through
  ``models/common.attention_dispatch`` and ``kernels/ops.gemm``, so
  tuned schedules from `launch/tune.py` drive the actual kernels.
* **Single host transfer** — the decode loop accumulates tokens
  on-device inside the scan and transfers once per generate call
  (the per-token ``np.asarray`` sync of the naive engine is gone).

Correctness under padding: per-sequence seed logits come from each
prompt's own last real position (``Model.prefill(last_idx=...)``), pad
K/V rows are masked out of every decode step, and each sequence's
decode positions continue from its own true length
(``cache["valid_len"]``/``cache["prefill_len"]``, see
``models/common.decode_attention`` and ``transformer.decode_step``) —
so for dense/vlm/encdec a bucket-padded generation is bit-identical to
the exact-shape run, with the pad K/V slots simply dead weight in the
cache.  MoE is near-identical rather than exact: pad tokens contend for
expert capacity during prefill (GShard-style capacity buffers are a
function of every token in the fixed-shape batch), the standard
trade-off of any static-shape MoE server.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.core.cost.measured import ExecutableCache
from repro.core.records import global_records
from repro.kernels.ops import kernel_policy
from repro.models.api import Model

__all__ = ["ServeEngine"]

#: families whose causal-attention masking makes right-padded prompts safe
_PADDABLE = ("dense", "vlm", "moe", "encdec")


def _bucket_for(n: int, buckets: Optional[Sequence[int]]) -> int:
    """Smallest configured bucket that fits ``n``; ``n`` itself when no
    bucket does (exact-shape compile, counted as a bucket miss)."""
    if buckets:
        for b in buckets:
            if b >= n:
                return b
    return n


class ServeEngine:
    """Bucketed batched engine: fixed max batch, greedy sampling, AOT
    executables resolved through a persistent cache (see module doc)."""

    def __init__(
        self,
        cfg,
        params,
        max_batch: int,
        max_len: int,
        prompt_buckets: Optional[Sequence[int]] = None,
        gen_buckets: Optional[Sequence[int]] = None,
        cache_dir: Optional[str] = None,
        prewarm: Optional[bool] = None,
        cache_capacity: int = 64,
    ):
        self.cfg = cfg
        self.model = Model(cfg)
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.pad_prompts = cfg.family in _PADDABLE
        self.prompt_buckets = sorted(prompt_buckets) if prompt_buckets else None
        self.gen_buckets = sorted(gen_buckets) if gen_buckets else None
        if self.prompt_buckets:
            need = self.prompt_buckets[-1] + (
                self.gen_buckets[-1] if self.gen_buckets else 0
            )
            if need > max_len:
                raise ValueError(
                    f"largest prompt bucket + largest gen bucket = {need} "
                    f"exceeds max_len={max_len}; the KV cache cannot hold a "
                    f"full-bucket request"
                )
        self.cache = ExecutableCache(capacity=cache_capacity, cache_dir=cache_dir)
        self._abs_params = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params
        )
        self._fp = self._fingerprint()
        self.prewarm_s = 0.0
        self.stats = {
            "prefill_s": [],      # per generate() call
            "decode_s": [],       # per generate() call
            "prefill_buckets": {},  # bucket -> call count
            "bucket_misses": 0,   # prompts no configured bucket could hold
        }
        self.last_timing: dict = {}
        if prewarm is None:
            prewarm = bool(self.prompt_buckets or self.gen_buckets)
        if prewarm:
            self.prewarm()

    # -- executable resolution -------------------------------------------------
    def _fingerprint(self) -> str:
        """Everything that determines the traced program besides the
        input shapes: the arch config, the kernel policy, and the tuned
        records the trace-time dispatch will consult."""
        pol = kernel_policy()
        rec = global_records()
        rec_view = {k: rec.lookup(k).get("state") for k in sorted(rec.keys())}
        raw = json.dumps(
            {
                "cfg": dataclasses.asdict(self.cfg),
                "policy": dataclasses.asdict(pol),
                "records": rec_view,
            },
            sort_keys=True,
            default=str,
        )
        return hashlib.sha256(raw.encode()).hexdigest()[:20]

    def _raw_key(self, kind: str, dim: int) -> str:
        import jaxlib

        return (
            f"serve/{kind}/{self._fp}/b{self.max_batch}/maxlen{self.max_len}"
            f"/{kind[0]}{dim}/pad{int(self.pad_prompts)}"
            f"/jax{jax.__version__}/jaxlib{jaxlib.__version__}"
        )

    def _resolve(self, raw_key: str, build):
        """Memory LRU -> persistent disk layer -> fresh compile (then
        persisted for the next engine/restart)."""
        ckey = hashlib.sha256(raw_key.encode()).hexdigest()[:40]
        fn = self.cache.get_mem(ckey)
        if fn is not None:
            return fn
        fn = self.cache.get_disk(ckey)
        if fn is None:
            t0 = time.perf_counter()
            fn = build()
            self.cache.count_compile(time.perf_counter() - t0)
            self.cache.put_disk(ckey, fn)
        self.cache.put_mem(ckey, fn)
        return fn

    def _abstract_batch(self, p: int) -> dict:
        batch = {"tokens": jax.ShapeDtypeStruct((self.max_batch, p), jnp.int32)}
        if self.cfg.family == "encdec":
            batch["enc_frames"] = jax.ShapeDtypeStruct(
                (self.max_batch, self.cfg.encoder_len, self.cfg.d_model),
                jnp.dtype(self.cfg.compute_dtype),
            )
        return batch

    def _prefill_exec(self, p: int):
        def build():
            if self.pad_prompts:
                fn = lambda prm, b, last: self.model.prefill(
                    prm, b, self.max_len, last_idx=last
                )
                args = (
                    self._abs_params,
                    self._abstract_batch(p),
                    jax.ShapeDtypeStruct((self.max_batch,), jnp.int32),
                )
            else:
                fn = lambda prm, b: self.model.prefill(prm, b, self.max_len)
                args = (self._abs_params, self._abstract_batch(p))
            return jax.jit(fn).lower(*args).compile()

        return self._resolve(self._raw_key("prefill", p), build)

    def _abstract_cache(self) -> dict:
        cache = self.model.abstract_cache(self.max_batch, self.max_len)
        if self.pad_prompts:
            cache["valid_len"] = jax.ShapeDtypeStruct((self.max_batch,), jnp.int32)
            cache["prefill_len"] = jax.ShapeDtypeStruct((), jnp.int32)
        return cache

    def _decode_exec(self, g: int):
        def build():
            v = self.cfg.vocab_size

            def fn(prm, cache, logits):
                def step(carry, _):
                    cache, tok = carry
                    lg, cache = self.model.decode_step(prm, cache, tok)
                    nxt = jnp.argmax(lg[:, -1, :v], -1)[:, None].astype(jnp.int32)
                    return (cache, nxt), tok[:, 0]

                tok0 = jnp.argmax(logits[:, -1, :v], -1)[:, None].astype(jnp.int32)
                (_, _), toks = jax.lax.scan(step, (cache, tok0), None, length=g)
                return toks.T  # (B, g), accumulated on-device

            b_logits = jax.ShapeDtypeStruct(
                (self.max_batch, 1, self.cfg.padded_vocab), jnp.float32
            )
            return (
                jax.jit(fn)
                .lower(self._abs_params, self._abstract_cache(), b_logits)
                .compile()
            )

        return self._resolve(self._raw_key("decode", g), build)

    # -- warm path --------------------------------------------------------------
    def prewarm(self) -> None:
        """Resolve every configured (prefill, decode) bucket executable
        now — from disk on a warm restart (zero fresh compiles), from a
        compile on the first ever run."""
        t0 = time.perf_counter()
        for p in self.prompt_buckets or ():
            self._prefill_exec(p)
        for g in self.gen_buckets or ():
            self._decode_exec(g)
        self.prewarm_s = time.perf_counter() - t0

    def cache_report(self) -> dict:
        rep = dict(self.cache.stats())
        rep["prewarm_s"] = self.prewarm_s
        rep["bucket_misses"] = self.stats["bucket_misses"]
        return rep

    # -- serving ----------------------------------------------------------------
    def generate(
        self,
        prompts: np.ndarray,
        gen_tokens: int,
        prompt_lens: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """prompts: (B, P) int32; returns (B, gen_tokens).

        ``prompt_lens`` (B,) marks each row's true length when rows are
        already padded (the open-loop bench batches ragged requests);
        defaults to full-width prompts."""
        prompts = np.asarray(prompts, np.int32)
        b, p = prompts.shape
        assert b <= self.max_batch
        lens = (
            np.full((b,), p, np.int32)
            if prompt_lens is None
            else np.asarray(prompt_lens, np.int32)
        )

        if self.pad_prompts:
            bucket = _bucket_for(p, self.prompt_buckets)
            if self.prompt_buckets and bucket == p and p not in self.prompt_buckets:
                self.stats["bucket_misses"] += 1
        else:
            bucket = p  # exact shapes: SSM/hybrid state admits no pads
            if (lens != p).any():
                raise ValueError(
                    f"family {self.cfg.family} cannot serve ragged prompts"
                )
        assert bucket <= self.max_len

        toks = np.zeros((self.max_batch, bucket), np.int32)
        toks[:b, :p] = prompts
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "encdec":
            batch["enc_frames"] = jnp.zeros(
                (self.max_batch, self.cfg.encoder_len, self.cfg.d_model),
                jnp.dtype(self.cfg.compute_dtype),
            )

        t0 = time.perf_counter()
        if self.pad_prompts:
            true_len = np.full((self.max_batch,), bucket, np.int32)
            true_len[:b] = lens
            last_idx = jnp.asarray(true_len - 1, jnp.int32)
            logits, cache = self._prefill_exec(bucket)(self.params, batch, last_idx)
            cache["valid_len"] = jnp.asarray(true_len, jnp.int32)
            cache["prefill_len"] = jnp.asarray(bucket, jnp.int32)
        else:
            logits, cache = self._prefill_exec(bucket)(self.params, batch)
        logits.block_until_ready()
        prefill_s = time.perf_counter() - t0
        self.stats["prefill_s"].append(prefill_s)
        self.stats["prefill_buckets"][bucket] = (
            self.stats["prefill_buckets"].get(bucket, 0) + 1
        )

        g = _bucket_for(gen_tokens, self.gen_buckets)
        t0 = time.perf_counter()
        toks_dev = self._decode_exec(g)(self.params, cache, logits)
        out = np.asarray(toks_dev)  # the one host transfer
        decode_s = time.perf_counter() - t0
        self.stats["decode_s"].append(decode_s)
        self.last_timing = {
            "prefill_s": prefill_s,
            "decode_s": decode_s,
            "prompt_bucket": bucket,
            "gen_bucket": g,
        }
        return out[:b, :gen_tokens]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache-dir", default=None,
                    help="persistent AOT executable cache directory")
    ap.add_argument("--buckets", default=None,
                    help="comma-separated prompt-length buckets to pre-warm")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    buckets = (
        [int(x) for x in args.buckets.split(",")] if args.buckets else None
    )
    engine = ServeEngine(
        cfg, params, max_batch=args.requests,
        max_len=max([args.prompt_len] + (buckets or [])) + args.gen,
        prompt_buckets=buckets, gen_buckets=[args.gen] if buckets else None,
        cache_dir=args.cache_dir,
    )
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size, (args.requests, args.prompt_len)).astype(np.int32)
    t0 = time.monotonic()
    out = engine.generate(prompts, args.gen)
    dt = time.monotonic() - t0
    total_new = args.requests * args.gen
    rep = engine.cache_report()
    print(
        f"[serve] {args.arch}: {args.requests} requests x {args.gen} tokens "
        f"in {dt:.2f}s = {total_new/dt:.1f} tok/s (greedy); "
        f"compiles={rep['compiles']} disk_hits={rep['disk_hits']} "
        f"prewarm={rep['prewarm_s']:.2f}s; sample: {out[0][:8].tolist()}"
    )


if __name__ == "__main__":
    main()

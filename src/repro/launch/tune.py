"""Offline operator autotuning — the paper's technique as a first-class
framework feature, retargeted through the op registry
(``repro.core.ops``) so any registered operator tunes through the same
stack.

``--op gemm`` (default) extracts every distinct GEMM workload the arch
executes at the given shape (qkv / attn-out / ffn / experts / lm-head,
see ArchConfig.gemm_workloads); ``--op flash`` tunes the flash-attention
kernel's ``(block_q, block_kv)`` schedule for the arch's attention shape
(or a default 4k/128 shape when no arch is named).  Either way the
workloads fan through one shared measurement engine + budget
(``TuningSession.tune_arch``) and the best configs land in a
TuningRecords JSON that ``kernels/ops.py`` consults at trace time.

  # GEMM, as always
  python -m repro.launch.tune --arch yi-6b --shape train_4k \
      --tuner g-bfs --fraction 0.001 --records records/yi-6b.json \
      --workers 8 --executor process --warm-start

  # flash attention on crash-isolated process lanes
  python -m repro.launch.tune --op flash --tuner g-bfs --fraction 0.001 \
      --workers 2 --executor process

``--workers N`` measures candidate batches on N parallel engine lanes;
``--executor`` picks how those lanes run: ``sim`` (default) keeps the
bit-identical simulated clock, ``thread`` runs lanes on a thread pool,
and ``process`` ships each lane to a persistent worker process with a
per-lane timeout — a backend crash or hang costs one ``inf`` trial, not
the session.  ``--warm-start`` seeds each search from this workload's
previous best record (or the nearest previously-tuned shape of the same
op + dtype, transplanted).  Every measurement is journaled next to the
records file under op-scoped keys, so re-runs and overlapping shapes are
served from cache; the journal's append handle is closed when tuning
ends.

``--cost xla`` swaps the analytical oracle for :class:`XLATimedCost` —
real timed XLA:CPU programs built per op by the registry's
``timed_fn``.  Its compile cost is kept off the hot path:
``--n-build-workers`` compiles candidate batches in parallel, and a
persistent compiled-program cache (``--compile-cache-dir``, default next
to the journal; content keys carry the op) lets re-runs and process-lane
workers skip compilation entirely.  ``--reload-every N`` merges sibling
engines' journal rows every N waves, so concurrent tuning runs sharing
one journal file serve each other's fresh measurements mid-search.

``--shard I/N`` turns those concurrent runs into one *partitioned*
search: each process measures only the candidates it owns (a stable
hash of the state key, seeded per workload), defers the rest to its
siblings, and when the searches finish the shards elect the merged best
(lowest journaled cost) into the shared records — see
``repro.core.shard``:

  python -m repro.launch.tune --op flash --records r.json \
      --shard 0/2 --reload-every 2 &
  python -m repro.launch.tune --op flash --records r.json \
      --shard 1/2 --reload-every 2
"""

from __future__ import annotations

import argparse
import contextlib
import sys
from typing import Optional

from repro.configs.registry import get_arch, get_shape
from repro.core import (
    Budget,
    TrialJournal,
    TuningRecords,
    TuningSession,
    Workload,
    get_op,
    op_names,
)
from repro.core.cost import XLATimedCost
from repro.core.cost.base import SleepingCost
from repro.core.executor import EXECUTORS
from repro.core.fault import RetryPolicy
from repro.core.records import compile_cache_dir_for
from repro.core.shard import parse_shard
from repro.core.snapshot import TuneCheckpointer, TuneInterrupted


def _pad_dim(x: int) -> int:
    """Round a workload dim up so its odd part is small.  The paper's
    action space only moves powers of two between loop factors, so a
    large odd part (e.g. 29568 = 2^7·231) pins a >=231-way grid split on
    that dim; the kernel pads instead — exactly what Pallas BlockSpec
    padding does on TPU.  Multiples of 2048 keep the odd part <= 15 for
    every assigned arch while wasting < 7% FLOPs."""
    if x >= 2048:
        return ((x + 2047) // 2048) * 2048
    if x >= 128:
        return ((x + 127) // 128) * 128
    return x


def workloads_for_arch(arch_name: str, shape_name: str,
                       max_tokens: int = 8192) -> list[Workload]:
    """Per-arch GEMM list.  Token count is clamped: tiling choices
    saturate well below the full 1M-token batch and the search space for
    the M dimension explodes otherwise (the records are keyed by shape,
    so serving different M re-tunes or falls back to the heuristic)."""
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    tokens = min(shape.global_batch * shape.seq_len, max_tokens)
    out = []
    for (m, k, n, tag) in cfg.gemm_workloads(1, tokens):
        m = _pad_dim(min(m, max_tokens))
        out.append(
            Workload(
                "gemm", (m, _pad_dim(k), _pad_dim(n)),
                dtype=cfg.compute_dtype, label=f"{arch_name}/{tag}",
            )
        )
    return out


def flash_workloads_for_arch(
    arch_name: Optional[str], shape_name: str, max_seq: int = 8192
) -> list[Workload]:
    """Flash-attention workload list: the arch's causal self-attention
    shape ``(seq, seq, head_dim)`` at the given training shape, or a
    default 4k/128 shape when no arch is named."""
    shape = get_shape(shape_name)
    seq = _pad_dim(min(shape.seq_len, max_seq))
    if arch_name is None:
        head_dim, dtype, label = 128, "bfloat16", f"flash/s{seq}"
    else:
        cfg = get_arch(arch_name)
        head_dim = cfg.resolved_head_dim
        dtype = cfg.compute_dtype
        label = f"{arch_name}/flash_s{seq}"
    return [Workload("flash", (seq, seq, head_dim), dtype=dtype, label=label)]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--op", default="gemm",
                    help="which registered operator to tune (validated "
                         "against the op registry after parsing, so "
                         "late-registered ops work too)")
    ap.add_argument("--arch", default=None,
                    help="architecture whose workloads to tune "
                         "(required for --op gemm)")
    ap.add_argument("--shape", default="train_4k")
    from repro.core.tuners import TUNERS

    ap.add_argument("--tuner", default="g-bfs", choices=sorted(TUNERS))
    ap.add_argument("--fraction", type=float, default=0.001)
    ap.add_argument("--max-trials", type=int, default=None,
                    help="TOTAL trial pool shared across the workloads")
    ap.add_argument("--records", default="records/tuning.json")
    ap.add_argument("--noise", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=1,
                    help="parallel measurement lanes per engine")
    ap.add_argument("--executor", default="sim", choices=sorted(EXECUTORS),
                    help="how lanes run: simulated clock (bit-identical), "
                         "threads, or crash-isolated worker processes")
    ap.add_argument("--warm-start", action="store_true",
                    help="seed each search from the nearest tuned shape")
    ap.add_argument("--journal", default=None,
                    help="trial-journal path (default: <records>.journal.jsonl; "
                         "'none' disables the persistent cache)")
    ap.add_argument("--cost", default="analytical", choices=["analytical", "xla"],
                    help="cost oracle: the op's analytical TPU model, or real "
                         "timed XLA:CPU programs (XLATimedCost)")
    ap.add_argument("--n-build-workers", type=int, default=4,
                    help="parallel XLA compile threads per backend "
                         "(--cost xla only)")
    ap.add_argument("--compile-cache-dir", default=None,
                    help="persistent compiled-program cache directory "
                         "(--cost xla; default: <journal>.xlacache; "
                         "'none' disables the on-disk layer)")
    ap.add_argument("--reload-every", type=int, default=0,
                    help="merge sibling engines' journal rows every N "
                         "measurement waves (mid-search cache sharing "
                         "between concurrent runs; 0 disables)")
    ap.add_argument("--analyze", default="off", choices=["off", "warn", "prune"],
                    help="static schedule pre-filter (repro.core.analysis): "
                         "'warn' classifies candidates and counts advisory "
                         "flags, 'prune' rejects provably-bad ones before "
                         "they occupy a measurement lane")
    ap.add_argument("--learned-filter", default="off", choices=["off", "on"],
                    help="learned proposal filter (repro.core.learn): score "
                         "each wave's candidates with a journal-trained "
                         "rank model and really measure only the "
                         "predicted-best fraction; skipped candidates are "
                         "journaled as {'c': null, 'pred': score} "
                         "provenance rows ('off' is bit-identical to the "
                         "historical engine)")
    ap.add_argument("--filter-keep", type=float, default=0.5,
                    help="fraction of each wave's candidates the learned "
                         "filter really measures (at least 1 per wave)")
    ap.add_argument("--filter-retrain-every", type=int, default=8,
                    help="retrain the filter's model from fresh journal "
                         "rows every N measurement waves")
    ap.add_argument("--filter-min-rows", type=int, default=32,
                    help="journal rows (same op/dtype/fingerprint) required "
                         "before the filter starts dropping candidates; "
                         "below it the engine measures everything")
    ap.add_argument("--retries", type=int, default=1,
                    help="max measurement attempts per candidate: transient "
                         "lane failures (crash/timeout/spawn/corrupt) are "
                         "re-queued into later waves with exponential "
                         "backoff instead of surfacing inf to the tuner "
                         "(1 = no retry)")
    ap.add_argument("--retry-backoff", type=float, default=0.25,
                    help="base backoff seconds between retry attempts "
                         "(doubled per attempt, deterministic jitter)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="crash-safe session snapshot directory (default: "
                         "<records>.tunestate; 'none' disables snapshots)")
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    help="snapshot the search every N tuner rounds")
    ap.add_argument("--resume", action="store_true",
                    help="restore each workload's search from its latest "
                         "snapshot (finished workloads are served from "
                         "their done marker); measurements replay from "
                         "the journal, so the resumed search reaches the "
                         "same best state as an uninterrupted run")
    ap.add_argument("--shard", default="0/1",
                    help="run as shard I/N of an N-way sharded search: N "
                         "processes sharing one --journal each measure only "
                         "the candidates they own (stable hash of the state "
                         "key, seeded per workload), defer the rest to their "
                         "siblings, and elect the merged best into the "
                         "records when done (default 0/1: unsharded, "
                         "bit-identical to the plain engine)")
    ap.add_argument("--shard-wait", type=float, default=60.0,
                    help="seconds to wait for sibling shards' done markers "
                         "before electing over whatever reported")
    ap.add_argument("--measure-delay", type=float, default=0.0,
                    help="seconds of real lane occupancy added per "
                         "measurement (SleepingCost wrapper) — gives "
                         "interrupt/kill tests a window to land in")
    args = ap.parse_args()

    try:
        shard = parse_shard(args.shard)
    except ValueError as e:
        ap.error(str(e))
    if shard.enabled and (args.journal == "none"):
        ap.error("--shard needs a shared --journal (it is the shards' "
                 "only communication channel)")

    if args.op not in op_names():
        # a clear CLI error instead of a deep registry KeyError later
        ap.error(
            f"unknown op {args.op!r}: not in the operator registry "
            f"(registered ops: {', '.join(sorted(op_names()))})"
        )

    if args.op == "gemm":
        if args.arch is None:
            ap.error("--op gemm needs --arch (whose GEMMs to tune)")
        workloads = workloads_for_arch(args.arch, args.shape)
    elif args.op == "flash":
        workloads = flash_workloads_for_arch(args.arch, args.shape)
    else:  # a future registered op: tune its default workload list
        ap.error(f"--op {args.op} has no workload lister wired up yet")

    journal_path = args.journal
    if journal_path is None:
        journal_path = args.records + ".journal.jsonl"
    journal = None if journal_path == "none" else TrialJournal(journal_path)

    if args.cost == "xla":
        cache_dir = args.compile_cache_dir
        if cache_dir is None:
            cache_dir = (
                compile_cache_dir_for(journal_path)
                if journal_path != "none"
                else None
            )
        elif cache_dir == "none":
            cache_dir = None

        def cost_factory(space):
            # float32: the honest CPU-timed stand-in (CPU has no native
            # bf16 pipeline worth timing); seed fixes operand contents
            return XLATimedCost(
                space,
                n_repeats=3,
                seed=args.seed,
                n_build_workers=args.n_build_workers,
                cache_dir=cache_dir,
            )
    else:
        def cost_factory(space):
            # the op's own analytical oracle, resolved via the registry
            return get_op(space.op).analytical_cost(
                space, n_repeats=3, noise_sigma=args.noise, seed=args.seed
            )

    if args.measure_delay > 0:
        inner_factory = cost_factory

        def cost_factory(space, _inner=inner_factory):
            # real lane occupancy per measurement: the kill window that
            # interrupt/resume smoke tests land a SIGTERM inside
            return SleepingCost(_inner(space), delay_s=args.measure_delay)

    retry = (
        RetryPolicy(
            max_attempts=args.retries,
            backoff_s=args.retry_backoff,
            seed=args.seed,
        )
        if args.retries > 1
        else None
    )
    checkpoint_dir = args.checkpoint_dir
    if checkpoint_dir is None:
        checkpoint_dir = args.records + ".tunestate"
    checkpointer = (
        None
        if checkpoint_dir == "none"
        else TuneCheckpointer(checkpoint_dir, every_rounds=args.checkpoint_every)
    )
    if checkpointer is not None:
        checkpointer.install_signal_handlers()

    records = TuningRecords(args.records)
    session = TuningSession(
        records,
        cost_factory=cost_factory,
        seed=args.seed,
        journal=journal,
    )
    budget = Budget(max_fraction=args.fraction, max_trials=args.max_trials)
    try:
        with journal if journal is not None else contextlib.nullcontext():
            report = session.tune_arch(
                workloads=workloads,
                tuner_name=args.tuner,
                budget=budget,
                n_workers=args.workers,
                warm_start=args.warm_start,
                executor=args.executor,
                reload_every=args.reload_every,
                analyze=args.analyze,
                retry=retry,
                checkpointer=checkpointer,
                resume=args.resume,
                learned_filter=args.learned_filter,
                filter_keep=args.filter_keep,
                filter_retrain_every=args.filter_retrain_every,
                filter_min_rows=args.filter_min_rows,
                shard=shard,
                shard_wait_s=args.shard_wait,
            )
    except TuneInterrupted as e:
        print(
            f"[tune] interrupted at a round boundary ({e}); snapshot flushed "
            f"to {checkpoint_dir} — rerun with --resume to continue"
        )
        sys.exit(130)
    print(
        f"[tune] wrote {len(records)} records to {args.records} "
        f"(op={args.op} workers={report.n_workers} executor={args.executor} "
        f"cache_hit={report.stats.cache_hit_rate():.2f} "
        f"compile_cache_hit={report.stats.compile_cache_hit_rate():.2f} "
        f"compiles={report.stats.n_compiles} "
        f"trials_avoided={report.stats.trials_avoided} "
        f"trials_avoided_learned={report.stats.trials_avoided_learned} "
        f"learned_retrains={report.stats.n_learned_retrains} "
        f"deferred_to_sibling={report.stats.n_deferred_to_sibling} "
        f"served_by_sibling={report.stats.n_served_by_sibling} "
        f"lane_failures={report.stats.n_failures})"
    )


if __name__ == "__main__":
    main()

"""Training launcher.

CPU-scale example (runs in this container):
  python -m repro.launch.train --arch yi-6b --reduced --steps 100 \
      --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

Production posture (on a real TPU slice this is the same command the
per-host runner would execute; device count comes from the runtime):
  python -m repro.launch.train --arch qwen2-72b --mesh single --steps 1000
"""

from __future__ import annotations

import argparse

import jax

from repro.configs.registry import get_arch
from repro.data.pipeline import DataPipeline, SyntheticLM
from repro.dist.fault import StragglerWatchdog, run_with_restarts
from repro.train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU smoke scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "local", "single", "multi"])
    ap.add_argument("--data-axis", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    mesh = rules = None
    if args.mesh == "local":
        from repro.launch.mesh import make_local_mesh, rules_for_mesh

        mesh = make_local_mesh(args.data_axis, args.model_axis)
        rules = rules_for_mesh(mesh)
    elif args.mesh in ("single", "multi"):
        from repro.launch.mesh import make_production_mesh, rules_for_mesh

        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        rules = rules_for_mesh(mesh)

    ds = SyntheticLM(cfg.vocab_size, args.seq, seed=args.seed)
    pipe = DataPipeline(
        ds, args.batch,
        process_index=jax.process_index(), process_count=jax.process_count(),
    )

    def attempt(i: int):
        trainer = Trainer(
            cfg, pipe, args.ckpt_dir,
            mesh=mesh, rules=rules,
            lr=args.lr, total_steps=args.steps, grad_accum=args.grad_accum,
            ckpt_every=args.ckpt_every, log_path=args.log,
            watchdog=StragglerWatchdog(), seed=args.seed,
        )
        log = trainer.train(args.steps, resume=True)
        return trainer, log

    trainer, log = run_with_restarts(attempt, max_restarts=args.max_restarts)
    if log:
        print(
            f"[train] {args.arch} done: step={log[-1]['step']} "
            f"loss={log[-1]['loss']:.4f} "
            f"first_loss={log[0]['loss']:.4f}"
        )


if __name__ == "__main__":
    main()

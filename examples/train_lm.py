"""End-to-end driver: train a ~small LM for a few hundred steps with the
full production stack (data pipeline, AdamW, checkpoints, watchdog,
restart loop) and report the loss curve.

  PYTHONPATH=src python examples/train_lm.py [--arch yi-6b] [--steps 300]

This is the deliverable-(b) end-to-end example; at --steps 300 on CPU it
takes a few minutes and the loss drops well below uniform entropy.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.registry import get_arch
from repro.data.pipeline import DataPipeline, SyntheticLM
from repro.dist.fault import StragglerWatchdog, run_with_restarts
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M-scale variant of the chosen family (CPU-trainable)
    cfg = get_arch(args.arch).reduced(
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
        d_ff=512, vocab_size=2048,
    )
    ds = SyntheticLM(cfg.vocab_size, args.seq, seed=0)
    pipe = DataPipeline(ds, args.batch)

    def attempt(i):
        tr = Trainer(
            cfg, pipe, args.ckpt_dir, lr=1e-3, warmup_steps=20,
            total_steps=args.steps, ckpt_every=100,
            watchdog=StragglerWatchdog(),
        )
        return tr.train(args.steps, resume=True)

    log = run_with_restarts(attempt, max_restarts=2)
    losses = [r["loss"] for r in log]
    print(f"step   1: loss={losses[0]:.4f}")
    print(f"step {len(losses):3d}: loss={losses[-1]:.4f}")
    import math

    uniform = math.log(cfg.vocab_size)
    print(f"uniform entropy: {uniform:.4f} -> learned: {losses[-1]:.4f}")
    assert losses[-1] < uniform - 1.0, "model failed to learn"
    print("OK")


if __name__ == "__main__":
    main()

"""Quickstart: tune a GEMM with the paper's two methods and compare with
the baselines it compares against — 60 seconds on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import AnalyticalTPUCost, Budget, GemmConfigSpace
from repro.core.tuners import TUNERS


def main():
    # the paper's headline workload: C = A @ B at 1024^3, d = (4, 2, 4)
    space = GemmConfigSpace(1024, 1024, 1024)
    print(f"search space: {space.size():,} tiling configurations")
    print(f"initial (untiled) state: {space.initial_state()}")

    budget = Budget(max_fraction=0.001)  # the paper's 0.1% operating point
    for name in ["g-bfs", "n-a2c", "xgboost-like", "random"]:
        cost = AnalyticalTPUCost(space, n_repeats=3, noise_sigma=0.1, seed=0)
        tuner = TUNERS[name](space, cost, seed=0)
        res = tuner.tune(budget)
        # score the chosen config noise-free for a fair comparison
        final = AnalyticalTPUCost(space).cost(res.best_state)
        print(
            f"{name:14s} best={final*1e6:9.2f} us  trials={res.n_trials}  "
            f"explored={res.fraction*100:.2f}%  config={res.best_state}"
        )


if __name__ == "__main__":
    main()

"""Serving example: batched prefill + greedy decode through the unified
Model API (KV cache / recurrent state per family).

  PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-130m]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax

from repro.configs.registry import get_arch
from repro.launch.serve import ServeEngine
from repro.models.api import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, args.requests, args.prompt_len + args.gen)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (args.requests, args.prompt_len)).astype(np.int32)
    out = engine.generate(prompts, args.gen)
    print(f"arch={args.arch} family={cfg.family}")
    for i in range(min(2, args.requests)):
        print(f"  request {i}: prompt tail {prompts[i, -4:].tolist()} -> generated {out[i].tolist()}")
    print("OK")


if __name__ == "__main__":
    main()

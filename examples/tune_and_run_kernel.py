"""End-to-end kernel flow: tune a GEMM, persist the record, and execute
the real Pallas kernel (interpret mode on CPU) with the tuned BlockSpec,
validated against the jnp oracle.

  PYTHONPATH=src python examples/tune_and_run_kernel.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax.numpy as jnp

from repro.core import (
    AnalyticalTPUCost,
    Budget,
    GemmConfigSpace,
    TuningRecords,
    set_global_records,
    workload_key,
)
from repro.core.tuners import GBFSTuner
from repro.kernels import ops
from repro.kernels.ref import ref_gemm


def main():
    m = k = n = 256
    space = GemmConfigSpace(m, k, n)
    cost = AnalyticalTPUCost(space)
    res = GBFSTuner(space, cost, seed=0).tune(Budget(max_fraction=0.01))
    print(f"tuned config for {m}x{k}x{n}: {res.best_state} "
          f"(model cost {res.best_cost*1e6:.2f} us)")

    records = TuningRecords("records/example.json")
    records.update(
        workload_key(m, k, n, "float32"), res.best_state, res.best_cost,
        "g-bfs", res.n_trials,
    )
    set_global_records(records)

    ops.set_kernel_policy(ops.KernelPolicy(use_pallas=True, interpret=True))
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    out = ops.gemm(a, b)  # dispatches the Pallas kernel w/ tuned BlockSpec
    err = float(jnp.max(jnp.abs(out - ref_gemm(a, b))))
    print(f"pallas-vs-ref max abs err: {err:.2e}")
    assert err < 1e-3
    print("OK: tuned Pallas kernel matches the oracle")


if __name__ == "__main__":
    main()

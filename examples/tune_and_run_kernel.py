"""End-to-end kernel flow through the operator registry: tune a
workload per op, persist the records, and execute the real Pallas
kernels (interpret mode on CPU) with the tuned schedules, validated
against their oracles.

The op registry (`repro.core.ops`) is the only place that knows what a
"gemm" or a "flash" is — the tuner invocation below is identical for
both, and a new op plugs in the same way (space + cost + builds, one
`register_op` call).

  PYTHONPATH=src python examples/tune_and_run_kernel.py

The CLI equivalent of the flash half (any registered op tunes through
the same launcher):

  PYTHONPATH=src python -m repro.launch.tune --op flash --tuner g-bfs \
      --fraction 0.001 --workers 2 --executor process
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax.numpy as jnp

from repro.core import (
    Budget,
    TuningRecords,
    Workload,
    get_op,
    set_global_records,
    workload_key_for,
)
from repro.core.tuners import GBFSTuner
from repro.kernels import ops as kernel_ops
from repro.kernels.ref import ref_gemm


def tune(wl: Workload, fraction: float = 0.01):
    """One registry-driven tuning run — identical for every op."""
    spec = get_op(wl.op)
    space = spec.make_space(wl.dims, wl.depths)
    cost = spec.analytical_cost(space)
    res = GBFSTuner(space, cost, seed=0).tune(Budget(max_fraction=fraction))
    print(f"[{wl.op}] tuned {wl.dims}: {res.best_state} "
          f"(model cost {res.best_cost*1e6:.2f} us, {res.n_trials} trials)")
    return space, res


def main():
    records = TuningRecords("records/example.json")

    # ---- gemm: tune, record, dispatch the Pallas kernel -------------------
    m = k = n = 256
    gemm_wl = Workload("gemm", (m, k, n), dtype="float32")
    _, res = tune(gemm_wl)
    records.update(
        workload_key_for("gemm", (m, k, n), "float32"),
        res.best_state, res.best_cost, "g-bfs", res.n_trials,
    )
    set_global_records(records)

    kernel_ops.set_kernel_policy(
        kernel_ops.KernelPolicy(use_pallas=True, interpret=True)
    )
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    out = kernel_ops.gemm(a, b)  # dispatches Pallas w/ the tuned BlockSpec
    err = float(jnp.max(jnp.abs(out - ref_gemm(a, b))))
    print(f"pallas-vs-ref gemm max abs err: {err:.2e}")
    assert err < 1e-3

    # ---- flash: same registry, same tuner, different op -------------------
    seq, hd = 256, 64
    flash_wl = Workload("flash", (seq, seq, hd), dtype="float32")
    # the 256-token flash space is tiny (81 schedules): afford a full
    # sweep so the demo lands on the true optimum
    fspace, fres = tune(flash_wl, fraction=1.0)
    records.update(
        flash_wl.key("analytical_tpu_v5e"),
        fres.best_state, fres.best_cost, "g-bfs", fres.n_trials,
    )

    # run the real flash kernel with the tuned (block_q, block_kv)
    # schedule via the registry's kernel binding, vs a jnp oracle
    flash = get_op("flash")
    operands = flash.timed_operands(fspace, "float32", seed=0)
    tuned_out = flash.pallas_run(fspace, fres.best_state, operands,
                                 interpret=True)
    import jax

    q, kk, v = operands
    logits = (q @ kk.T) / np.sqrt(hd)
    mask = np.tril(np.ones((seq, seq), dtype=bool))
    logits = jnp.where(mask, logits, -1e30)
    ref = jax.nn.softmax(logits, axis=-1) @ v
    ferr = float(jnp.max(jnp.abs(tuned_out.reshape(seq, hd) - ref)))
    print(f"pallas-vs-ref flash max abs err: {ferr:.2e} "
          f"(block_q={fres.best_state.block_q}, "
          f"block_kv={fres.best_state.block_kv})")
    assert ferr < 1e-3
    print("OK: tuned Pallas kernels match their oracles for both ops")


if __name__ == "__main__":
    main()

"""The operator registry and the flash op riding the full tuner stack:
op-scoped journal/record keys, cross-op isolation, back-compat GEMM
aliases, process-shippable backends (including PallasInterpretCost),
and the ``--op flash`` tune CLI end-to-end."""

import json
import math
import sys

import pytest

from repro.core import (
    Budget,
    FlashAnalyticalCost,
    FlashAttnConfigSpace,
    FlashScheduleState,
    GBFSTuner,
    GemmConfigSpace,
    GemmWorkload,
    MeasureEngine,
    TilingState,
    TrialJournal,
    TuningRecords,
    TuningSession,
    Workload,
    get_op,
    op_names,
    parse_workload_key_generic,
    workload_key,
    workload_key_for,
)
from repro.core.cost import AnalyticalTPUCost
from repro.core.cost.base import backend_from_spec


# -- registry ----------------------------------------------------------------


def test_registry_has_both_ops():
    assert {"gemm", "flash"} <= set(op_names())
    gemm = get_op("gemm")
    flash = get_op("flash")
    assert gemm.state_type is TilingState
    assert flash.state_type is FlashScheduleState
    assert isinstance(gemm.make_space((64, 64, 64), (4, 2, 4)), GemmConfigSpace)
    assert isinstance(
        flash.make_space((256, 256, 64), (2, 2)), FlashAttnConfigSpace
    )
    with pytest.raises(KeyError):
        get_op("conv3d")


def test_workload_keys_are_op_scoped_and_gemm_legacy_exact():
    """GEMM keys keep the pre-registry spelling bit-for-bit; other ops
    lead with their op name, so keys can never collide across ops."""
    gk = workload_key_for("gemm", (512, 1024, 2048), "bfloat16", "be")
    assert gk == "gemm/m512k1024n2048/bfloat16/be" == workload_key(
        512, 1024, 2048, "bfloat16", "be"
    )
    fk = workload_key_for("flash", (4096, 4096, 128), "bfloat16", "be")
    assert fk == "flash/4096x4096x128/bfloat16/be"
    assert parse_workload_key_generic(gk) == (
        "gemm", (512, 1024, 2048), "bfloat16", "be"
    )
    assert parse_workload_key_generic(fk) == (
        "flash", (4096, 4096, 128), "bfloat16", "be"
    )


def test_gemm_workload_alias_is_generic_workload():
    wl = GemmWorkload(128, 64, 256, dtype="float32", label="x")
    assert isinstance(wl, Workload)
    assert (wl.op, wl.dims, wl.depths) == ("gemm", (128, 64, 256), (4, 2, 4))
    assert (wl.m, wl.k, wl.n) == (128, 64, 256)
    assert isinstance(wl.space(), GemmConfigSpace)


# -- flash cost model --------------------------------------------------------


def test_flash_analytical_model_has_structure():
    space = FlashAttnConfigSpace(4096, 4096, 128)
    cost = FlashAnalyticalCost(space)
    s0 = space.initial_state()
    c0 = cost.cost(s0)
    assert math.isfinite(c0) and c0 > 0
    best, bc = cost.optimum()
    assert bc < c0  # tuning beats the untiled schedule
    # the VMEM cliff is real: some enumerable state fails to build
    assert any(math.isinf(cost.cost(s)) for s in space.enumerate())
    # batch == scalar, per the CostBackend contract
    states = list(space.enumerate())[:12]
    assert cost.batch_cost(states) == [cost.cost(s) for s in states]


def test_flash_worker_spec_round_trip():
    space = FlashAttnConfigSpace(512, 512, 64, causal=False)
    cost = FlashAnalyticalCost(space, n_repeats=2, noise_sigma=0.1, seed=9)
    spec = cost.worker_spec()
    assert spec is not None
    rebuilt = backend_from_spec(spec)
    assert rebuilt.space.causal is False
    s = space.random_state(__import__("random").Random(3))
    assert rebuilt.cost(s) == cost.cost(s)
    # constraint closures refuse to ship (same policy as GEMM)
    guarded = FlashAttnConfigSpace(512, 512, 64, extra_constraint=lambda s: True)
    assert FlashAnalyticalCost(guarded).worker_spec() is None


def test_causal_flag_is_measurement_identity():
    """causal=True/False change every measured value, so journal
    fingerprints and executable-cache content keys must differ — while
    default-constructed GEMM spaces (empty spec_kwargs) keep their
    pre-registry fingerprints, so old journals stay valid."""
    from repro.core.cost.measured import ExecutableCache

    sc = FlashAttnConfigSpace(256, 256, 64, causal=True)
    sn = FlashAttnConfigSpace(256, 256, 64, causal=False)
    s = sc.initial_state()
    assert FlashAnalyticalCost(sc).cost(s) != FlashAnalyticalCost(sn).cost(s)
    assert (
        FlashAnalyticalCost(sc).measure_fingerprint()
        != FlashAnalyticalCost(sn).measure_fingerprint()
    )
    assert ExecutableCache.content_key(
        sc, "float32", s
    ) != ExecutableCache.content_key(sn, "float32", s)
    g = GemmConfigSpace(64, 64, 64)
    assert (
        AnalyticalTPUCost(g, n_repeats=2, noise_sigma=0.1, seed=3)
        .measure_fingerprint()
        == "r2|noise0.1|seed3|io2.2"
    )


def test_flash_tuner_beats_initial_state():
    space = FlashAttnConfigSpace(2048, 2048, 128)
    cost = FlashAnalyticalCost(space)
    res = GBFSTuner(space, cost, seed=0).tune(Budget(max_trials=40))
    assert res.best_state is not None
    assert res.best_cost < cost.cost(space.initial_state())


@pytest.mark.parametrize("tuner_name", ["random", "genetic", "sim-anneal",
                                        "xgboost-like", "grid"])
def test_baseline_tuners_run_on_flash_space(tuner_name):
    """Every non-RL tuner runs unmodified against the non-GEMM space —
    the point of the operator-agnostic protocol."""
    from repro.core.tuners import TUNERS

    space = FlashAttnConfigSpace(1024, 1024, 128)
    cost = FlashAnalyticalCost(space)
    res = TUNERS[tuner_name](space, cost, seed=0).tune(Budget(max_trials=25))
    assert res.n_trials <= 25
    assert res.best_state is not None and math.isfinite(res.best_cost)


# -- journal op isolation ----------------------------------------------------


def test_mixed_op_journal_never_serves_across_ops(tmp_path):
    """A journal holding rows for BOTH ops serves each engine only its
    own op's rows — a flash row is never handed to a GEMM lookup (and
    vice versa), even under handle reloads."""
    jpath = str(tmp_path / "mixed.jsonl")
    gspace = GemmConfigSpace(64, 64, 64)
    fspace = FlashAttnConfigSpace(64, 64, 32)
    gcost = AnalyticalTPUCost(gspace)
    fcost = FlashAnalyticalCost(fspace)

    with TrialJournal(jpath) as j:
        ge = MeasureEngine(gcost, journal=j, workload_key=GemmWorkload(64, 64, 64).key(gcost.name))
        fe = MeasureEngine(
            fcost, journal=j,
            workload_key=Workload("flash", (64, 64, 32)).key(fcost.name),
        )
        g_out = ge.measure_wave([gspace.initial_state()])
        f_out = fe.measure_wave([fspace.initial_state()])
        assert not g_out[0].cache_hit and not f_out[0].cache_hit
        # repeat lookups hit only within the op
        assert ge.measure_wave([gspace.initial_state()])[0].cache_hit
        assert fe.measure_wave([fspace.initial_state()])[0].cache_hit

    # rows persisted with the op schema field
    rows = [json.loads(l) for l in open(jpath)]
    assert {r["op"] for r in rows} == {"gemm", "flash"}

    # a fresh handle reconstructs op-typed states per workload
    j2 = TrialJournal(jpath)
    for wkey in j2.workloads():
        best = j2.best_state(wkey)
        assert best is not None
        expected = TilingState if j2.op_of(wkey) == "gemm" else FlashScheduleState
        assert isinstance(best[0], expected)
    # op-asserting lookups refuse foreign rows even for matching keys
    gkey = next(w for w in j2.workloads() if j2.op_of(w) == "gemm")
    state_key = next(iter(j2._costs[gkey]))
    assert j2.get(gkey, state_key, op="gemm") is not None
    assert j2.get(gkey, state_key, op="flash") is None


def test_legacy_journal_rows_load_as_gemm(tmp_path):
    """Rows written before the op schema field (no "op") load as GEMM."""
    jpath = str(tmp_path / "legacy.jsonl")
    wkey = workload_key(64, 64, 64)
    s = GemmConfigSpace(64, 64, 64).initial_state()
    with open(jpath, "w") as f:
        f.write(json.dumps({"w": wkey, "k": s.key(), "s": s.as_lists(),
                            "c": 1.5e-5}) + "\n")
    j = TrialJournal(jpath)
    assert j.op_of(wkey) == "gemm"
    assert j.get(wkey, s.key(), op="gemm") == 1.5e-5
    assert j.get(wkey, s.key(), op="flash") is None
    best = j.best_state(wkey)
    assert best is not None and isinstance(best[0], TilingState)


def test_warm_start_scoped_to_op(tmp_path):
    """A tuned GEMM can never seed a flash search of 'similar' dims, and
    flash workloads warm-start from their own op's nearest shape."""
    session = TuningSession(
        TuningRecords(str(tmp_path / "r.json")), seed=0, verbose=False,
        journal=TrialJournal(str(tmp_path / "j.jsonl")),
    )
    session.tune_workload(GemmWorkload(64, 64, 64), "g-bfs", Budget(max_trials=30))
    flash_twin = Workload("flash", (64, 64, 64))
    assert session.warm_start_state(
        flash_twin, flash_twin.space(), "analytical_tpu_v5e"
    ) is None
    # tune one flash shape; a nearby flash shape warm-starts from it
    session.tune_workload(Workload("flash", (128, 128, 64)), "g-bfs",
                          Budget(max_trials=30))
    near = Workload("flash", (256, 256, 64))
    s0 = session.warm_start_state(near, near.space(), "analytical_tpu_v5e")
    assert s0 is not None and near.space().is_legitimate(s0)
    # ...but never across head sizes: head_dim is workload identity, not
    # a factored row — the seq rows would transplant, so this pins the
    # fixed-tail donor guard (records AND journal scans)
    other_head = Workload("flash", (128, 128, 128))
    assert session.warm_start_state(
        other_head, other_head.space(), "analytical_tpu_v5e"
    ) is None


# -- session / CLI end-to-end ------------------------------------------------


def test_session_tunes_mixed_op_workloads_through_one_pool(tmp_path):
    """tune_arch fans GEMM and flash workloads through one shared
    budget pool and records both under op-scoped keys."""
    records = TuningRecords(str(tmp_path / "rec.json"))
    session = TuningSession(
        records, seed=0, verbose=False,
        journal=TrialJournal(str(tmp_path / "j.jsonl")),
    )
    wls = [
        GemmWorkload(64, 64, 64, label="g"),
        Workload("flash", (128, 128, 64), label="f"),
    ]
    report = session.tune_arch(workloads=wls, budget=Budget(max_trials=40))
    assert set(report.results) == {"g", "f"}
    assert report.total_trials <= 40
    keys = set(records.keys())
    assert any(k.startswith("gemm/") for k in keys)
    assert any(k.startswith("flash/") for k in keys)
    # records deserialize per op
    for k in keys:
        s = records.lookup_state(k)
        assert s is not None
        expected = TilingState if k.startswith("gemm/") else FlashScheduleState
        assert isinstance(s, expected)


def test_tune_cli_op_flash(tmp_path):
    """The acceptance command: `--op flash --tuner g-bfs --fraction
    0.001 --workers 2` completes on the analytical backend and journals
    trials under flash-scoped keys (sim executor here; process lanes are
    covered by the slow marker below)."""
    from repro.launch import tune as tune_mod

    argv = sys.argv
    sys.argv = [
        "tune", "--op", "flash", "--tuner", "g-bfs", "--fraction", "1.0",
        "--max-trials", "30", "--workers", "2",
        "--records", str(tmp_path / "r.json"),
    ]
    try:
        tune_mod.main()
    finally:
        sys.argv = argv
    rec = TuningRecords(str(tmp_path / "r.json"))
    assert len(rec) == 1
    (key,) = rec.keys()
    assert key.startswith("flash/")
    assert isinstance(rec.lookup_state(key), FlashScheduleState)
    journal = TrialJournal(str(tmp_path / "r.json") + ".journal.jsonl")
    assert len(journal) > 0
    assert all(journal.op_of(w) == "flash" for w in journal.workloads())


@pytest.mark.slow
def test_tune_cli_op_flash_process_lanes(tmp_path):
    """The exact acceptance-criteria invocation: flash + process
    executor, end-to-end on the analytical backend."""
    from repro.launch import tune as tune_mod

    argv = sys.argv
    sys.argv = [
        "tune", "--op", "flash", "--tuner", "g-bfs", "--fraction", "0.001",
        "--workers", "2", "--executor", "process",
        "--records", str(tmp_path / "r.json"),
    ]
    try:
        tune_mod.main()
    finally:
        sys.argv = argv
    rec = TuningRecords(str(tmp_path / "r.json"))
    assert len(rec) == 1 and next(iter(rec.keys())).startswith("flash/")


# -- measured backends across ops -------------------------------------------


@pytest.mark.slow
def test_xla_timed_flash_schedule(tmp_path):
    """XLATimedCost builds and times the flash op via the registry's
    per-op build, with op-distinct executable-cache keys."""
    from repro.core.cost.measured import ExecutableCache, XLATimedCost

    fspace = FlashAttnConfigSpace(64, 64, 16)
    gspace = GemmConfigSpace(64, 64, 16, d_m=2, d_k=2, d_n=2)
    s = fspace.state_from_rows([[4, 16], [4, 16]])
    cost = XLATimedCost(fspace, n_repeats=1, cache_dir=str(tmp_path / "xc"))
    c = cost.cost(s)
    assert math.isfinite(c) and c > 0
    assert cost.compile_stats()["compiles"] == 1
    # op field keeps one shared cache dir collision-free across ops
    k_flash = ExecutableCache.content_key(fspace, "float32", s)
    k_gemm = ExecutableCache.content_key(
        gspace, "float32", gspace.initial_state()
    )
    assert k_flash != k_gemm
    # worker spec round-trips through the registry
    spec = cost.worker_spec()
    assert spec is not None and spec[1]["op"] == "flash"
    rebuilt = backend_from_spec(spec)
    assert math.isfinite(rebuilt.cost(s))


@pytest.mark.slow
def test_pallas_interpret_worker_spec_round_trip():
    """PallasInterpretCost is process-shippable now (ROADMAP open item):
    worker_spec() rebuilds an equivalent backend for both ops."""
    from repro.core.cost.measured import PallasInterpretCost

    for space in (
        GemmConfigSpace(32, 32, 32),
        FlashAttnConfigSpace(64, 64, 16),
    ):
        cost = PallasInterpretCost(space, n_repeats=1, seed=2)
        spec = cost.worker_spec()
        assert spec is not None
        rebuilt = backend_from_spec(spec)
        assert rebuilt.op == space.op
        assert rebuilt.measure_fingerprint() == cost.measure_fingerprint()
        s = space.random_state(__import__("random").Random(0))
        c = rebuilt.cost(s)
        assert math.isfinite(c) and c > 0
    # constraint closures refuse to ship
    guarded = GemmConfigSpace(32, 32, 32, extra_constraint=lambda s: True)
    assert PallasInterpretCost(guarded, n_repeats=1).worker_spec() is None

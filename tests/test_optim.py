"""Optimizer tests: AdamW / Adafactor convergence, mixed precision,
clipping, schedules."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.optim import (
    Adafactor,
    AdamW,
    clip_by_global_norm,
    constant,
    global_norm,
    make_optimizer,
    warmup_cosine,
    warmup_linear,
)


def _quadratic_params(dtype=jnp.float32):
    return {
        "w": jnp.asarray([[2.0, -3.0], [1.5, 0.5]], dtype),
        "b": jnp.asarray([1.0, -1.0], dtype),
    }


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_optimizer_reduces_quadratic(opt_name):
    opt = make_optimizer(opt_name, 0.05)
    params = _quadratic_params()
    state = opt.init(params)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss_fn(params))
    for _ in range(60):
        grads = jax.grad(loss_fn)(params)
        params, state = opt.update(grads, state, params)
    assert float(loss_fn(params)) < 0.2 * l0


def test_adamw_mixed_precision_master_weights():
    params = jax.tree_util.tree_map(
        lambda a: a.astype(jnp.bfloat16), _quadratic_params()
    )
    opt = AdamW(lr=1e-3)
    state = opt.init(params)
    assert "master" in state
    assert all(
        m.dtype == jnp.float32 for m in jax.tree_util.tree_leaves(state["master"])
    )
    grads = jax.tree_util.tree_map(lambda a: jnp.ones_like(a), params)
    new_params, new_state = opt.update(grads, state, params)
    # bf16 params update, master tracks in f32
    assert all(p.dtype == jnp.bfloat16 for p in jax.tree_util.tree_leaves(new_params))
    # tiny lr accumulates in master even when bf16 can't represent the delta
    for _ in range(3):
        new_params, new_state = opt.update(grads, new_state, new_params)
    m = new_state["master"]["w"]
    assert float(jnp.max(jnp.abs(m - state["master"]["w"]))) > 0


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,))}
    opt = Adafactor(lr=1e-3)
    state = opt.init(params)
    assert state["factored"]["w"]["vr"].shape == (64,)
    assert state["factored"]["w"]["vc"].shape == (32,)
    assert state["factored"]["b"]["v"].shape == (64,)
    # factored memory << AdamW memory for matrices
    adam_bytes = 2 * 64 * 32 * 4
    fact_bytes = (64 + 32) * 4
    assert fact_bytes < adam_bytes / 10


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(norm), np.sqrt(10 * 9 + 10 * 16), rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    # below threshold: unchanged
    unclipped, _ = clip_by_global_norm(tree, 1e9)
    np.testing.assert_allclose(np.asarray(unclipped["a"]), np.asarray(tree["a"]))


def test_schedules():
    for sched in [
        warmup_cosine(1e-3, 10, 100),
        warmup_linear(1e-3, 10, 100),
        constant(1e-3),
    ]:
        vals = [float(sched(jnp.asarray(s))) for s in range(0, 101, 5)]
        assert all(v >= 0 for v in vals)
        assert max(vals) <= 1e-3 + 1e-9
    wc = warmup_cosine(1e-3, 10, 100)
    assert float(wc(jnp.asarray(5))) < 1e-3  # warming up
    assert float(wc(jnp.asarray(100))) < float(wc(jnp.asarray(20)))  # decaying

"""Cost-backend tests: analytical model physics, batched measurement
parity, and measured backends."""

import math

import pytest

from repro.core import AnalyticalTPUCost, CountingCost, GemmConfigSpace, TilingState
from repro.core.cost.measured import PallasInterpretCost, XLATimedCost


def test_vmem_cliff(small_space):
    """Configurations whose working set exceeds VMEM fail like a TVM
    measurement failure (inf)."""
    # block everything into one giant tile on a big space -> exceeds VMEM
    big = GemmConfigSpace(4096, 4096, 4096)
    cost_big = AnalyticalTPUCost(big)
    s = TilingState((1, 1, 1, 4096), (1, 4096), (1, 1, 1, 4096))
    assert math.isinf(cost_big.cost(s))
    # the no-tiling initial state is legitimate but slow, not inf
    c0 = cost_big.cost(big.initial_state())
    assert math.isfinite(c0)


def test_alignment_penalty(small_space):
    """Lane-misaligned (bn % 128 != 0) tiles cost more than aligned ones
    with the same traffic."""
    sp = GemmConfigSpace(1024, 1024, 1024)
    cost = AnalyticalTPUCost(sp)
    aligned = TilingState((8, 1, 1, 128), (2, 512), (8, 1, 1, 128))
    misaligned = TilingState((8, 1, 2, 64), (2, 512), (16, 1, 2, 32))
    assert cost.compute_time(aligned) <= cost.compute_time(misaligned)


def test_noise_determinism(paper_space):
    c1 = AnalyticalTPUCost(paper_space, noise_sigma=0.1, seed=7, n_repeats=3)
    c2 = AnalyticalTPUCost(paper_space, noise_sigma=0.1, seed=7, n_repeats=3)
    s = paper_space.initial_state()
    assert c1.cost(s) == c2.cost(s)
    c3 = AnalyticalTPUCost(paper_space, noise_sigma=0.1, seed=8, n_repeats=3)
    assert c1.cost(s) != c3.cost(s)


def test_noiseless_cost_reproducible_and_positive(small_space):
    cost = AnalyticalTPUCost(small_space)
    for s in list(small_space.enumerate())[:100]:
        c = cost.cost(s)
        assert c > 0


def test_counting_cost_tracks_trials(small_space):
    inner = AnalyticalTPUCost(small_space)
    cc = CountingCost(inner, simulated_overhead_s=0.5)
    s = small_space.initial_state()
    cc.cost(s)
    cc.cost(s)
    assert cc.n_measured == 2
    assert cc.simulated_clock_s > 1.0


def test_counting_cost_timeout_cap(small_space):
    """A pathological config charges at most timeout_s of simulated
    clock per trial — matching TuningContext.measure_timeout_s."""

    class SlowCost(AnalyticalTPUCost):
        def cost_once(self, s, repeat_idx):
            return 1e6  # "runs for minutes"

    cc = CountingCost(SlowCost(small_space), simulated_overhead_s=0.35, timeout_s=4.0)
    cc.cost(small_space.initial_state())
    assert cc.simulated_clock_s == pytest.approx(0.35 + 4.0)


def test_counting_cost_parallel_lanes(small_space):
    """batch_cost with n_workers lanes charges each wave's max lane
    time, so the counting clock agrees with the engine's wave model."""
    states = list(small_space.enumerate())[:8]
    serial = CountingCost(AnalyticalTPUCost(small_space), simulated_overhead_s=0.5)
    lanes = CountingCost(
        AnalyticalTPUCost(small_space), simulated_overhead_s=0.5, n_workers=4
    )
    cs = serial.batch_cost(states)
    cl = lanes.batch_cost(states)
    assert cs == cl  # values never change, only time accounting
    assert lanes.n_measured == serial.n_measured == 8
    # 8 serial charges vs 2 wave maxima
    assert lanes.simulated_clock_s < serial.simulated_clock_s
    assert lanes.simulated_clock_s >= 2 * 0.5


def test_analytical_batch_cost_matches_serial(small_space):
    """batch_cost must be value-identical to the scalar path, noise and
    repeats included (the engine's parity guarantee rests on this)."""
    cost = AnalyticalTPUCost(small_space, n_repeats=3, noise_sigma=0.1, seed=11)
    states = list(small_space.enumerate())[:64]
    assert cost.batch_cost(states) == [cost.cost(s) for s in states]
    # vmem failures and illegitimate states round-trip as inf
    big = GemmConfigSpace(4096, 4096, 4096)
    cost_big = AnalyticalTPUCost(big)
    bad = TilingState((1, 1, 1, 4096), (1, 4096), (1, 4096, 1, 1))
    out = cost_big.batch_cost([bad, big.initial_state()])
    assert math.isinf(out[0]) and math.isfinite(out[1])


def test_brute_force_optimum_is_minimum(small_space):
    cost = AnalyticalTPUCost(small_space)
    best_s, best_c = cost.optimum()
    for s in small_space.enumerate():
        assert cost.cost(s) >= best_c - 1e-18


@pytest.mark.slow
def test_xla_timed_cost_runs():
    sp = GemmConfigSpace(128, 128, 128)
    cost = XLATimedCost(sp, n_repeats=1)
    c = cost.cost(TilingState((2, 1, 1, 64), (2, 64), (2, 1, 1, 64)))
    assert 0 < c < 10


@pytest.mark.slow
def test_pallas_interpret_cost_runs():
    sp = GemmConfigSpace(128, 128, 128)
    cost = PallasInterpretCost(sp)
    c = cost.cost(TilingState((2, 1, 1, 64), (1, 128), (2, 1, 1, 64)))
    assert 0 < c < 60

"""Batched measurement engine: serial parity, parallel-lane budgets and
clock compression, the persistent trial journal, warm starts, and
arch-level fan-out."""

import heapq
import itertools
import math
import random

import pytest

from repro.core import (
    AnalyticalTPUCost,
    Budget,
    GBFSTuner,
    GemmConfigSpace,
    GemmWorkload,
    MeasureEngine,
    SimulatedExecutor,
    TrialJournal,
    TuningRecords,
    TuningSession,
    workload_key,
)
from repro.core.tuners.base import BudgetExhausted, TuningContext


def _make_cost(space, seed=3):
    return AnalyticalTPUCost(space, n_repeats=2, noise_sigma=0.1, seed=seed)


def _reference_serial_gbfs(space, cost, seed, budget, rho=5):
    """The pre-engine serial G-BFS loop, state-for-state: pops the
    cheapest frontier state and measures its ρ-sample one state at a
    time through ``ctx.measure``.  The parity oracle for the refactor."""
    ctx = TuningContext(space, cost, budget)
    rng = random.Random(seed)
    try:
        s0 = space.initial_state()
        c0 = ctx.measure(s0)
        tie = itertools.count()
        pq = [(c0, next(tie), s0)]
        while pq and not ctx.done():
            _, _, s = heapq.heappop(pq)
            neigh = [s2 for s2 in space.neighbors(s) if not ctx.seen(s2)]
            if not neigh:
                continue
            batch = rng.sample(neigh, min(rho, len(neigh)))
            for s2 in batch:
                c2 = ctx.measure(s2)
                heapq.heappush(pq, (c2, next(tie), s2))
    except BudgetExhausted:
        pass
    return ctx.result("serial-reference")


@pytest.fixture(scope="module")
def space():
    return GemmConfigSpace(256, 256, 256)


def test_gbfs_serial_parity(space):
    """With n_workers=1 the engine-backed GBFSTuner visits the same
    states, in the same order, at the same costs and clock, as the
    historical serial loop (acceptance: Fig. 7/8 runs must not shift)."""
    budget = Budget(max_trials=150)
    ref = _reference_serial_gbfs(space, _make_cost(space), 7, budget)
    new = GBFSTuner(space, _make_cost(space), seed=7).tune(budget)
    assert [t.state.key() for t in ref.trials] == [t.state.key() for t in new.trials]
    assert [t.cost for t in ref.trials] == [t.cost for t in new.trials]
    assert [t.clock_s for t in ref.trials] == [t.clock_s for t in new.trials]
    assert new.best_cost == ref.best_cost


def test_simulated_executor_is_bit_identical(space):
    """An explicitly-passed SimulatedExecutor reproduces the historical
    serial loop exactly — the executor layer must not perturb the
    ``n_workers=1`` parity guarantee."""
    budget = Budget(max_trials=150)
    ref = _reference_serial_gbfs(space, _make_cost(space), 7, budget)
    engine = MeasureEngine(_make_cost(space), executor=SimulatedExecutor())
    new = GBFSTuner(space, _make_cost(space), seed=7).tune(budget, engine=engine)
    assert [t.state.key() for t in ref.trials] == [t.state.key() for t in new.trials]
    assert [t.cost for t in ref.trials] == [t.cost for t in new.trials]
    assert [t.clock_s for t in ref.trials] == [t.clock_s for t in new.trials]
    assert new.best_cost == ref.best_cost
    assert new.executor == "sim"


def test_gbfs_parallel_same_sequence_never_exceeds_budget(space):
    """n_workers>1 compresses the clock but must not change the trial
    sequence (order-preserving waves) nor overshoot max_trials."""
    budget = Budget(max_trials=150)
    serial = GBFSTuner(space, _make_cost(space), seed=7).tune(budget)
    for workers in (4, 8):
        par = GBFSTuner(space, _make_cost(space), seed=7).tune(budget, n_workers=workers)
        assert par.n_trials <= 150
        assert [t.state.key() for t in par.trials] == [
            t.state.key() for t in serial.trials
        ]
        assert par.best_cost == serial.best_cost
        assert par.clock_s < serial.clock_s
    par8 = GBFSTuner(space, _make_cost(space), seed=7).tune(budget, n_workers=8)
    # ρ=5 batches measured as one wave each: ≥4x clock compression
    assert serial.clock_s / par8.clock_s >= 4.0


def test_measure_many_dedup_and_intra_batch_duplicates(space):
    cost = AnalyticalTPUCost(space)
    ctx = TuningContext(space, cost, Budget(max_trials=10), n_workers=4)
    s0 = space.initial_state()
    s1 = space.neighbors(s0)[0]
    out = ctx.measure_many([s0, s1, s0, s1])
    assert len(ctx.trials) == 2  # duplicates served, not re-charged
    assert out[0] == out[2] and out[1] == out[3]
    out2 = ctx.measure_many([s1])  # previously visited: free, no trial
    assert len(ctx.trials) == 2 and out2[0] == out[1]


def test_measure_many_raises_when_exhausted(space):
    cost = AnalyticalTPUCost(space)
    ctx = TuningContext(space, cost, Budget(max_trials=3), n_workers=2)
    states = [s for s in itertools.islice(space.enumerate(), 6)]
    with pytest.raises(BudgetExhausted):
        ctx.measure_many(states)
    assert len(ctx.trials) == 3  # the measured prefix is kept


def test_journal_serves_repeat_sessions(tmp_path, space):
    """A second session over the same workload is served from the
    persistent journal: same result, zero measurement clock."""
    jpath = str(tmp_path / "trials.jsonl")
    wkey = workload_key(space.m, space.k, space.n, "bfloat16", "analytical_tpu_v5e")
    cost = AnalyticalTPUCost(space)
    eng1 = MeasureEngine(cost, n_workers=4, journal=TrialJournal(jpath), workload_key=wkey)
    r1 = GBFSTuner(space, cost, seed=0).tune(Budget(max_trials=60), engine=eng1)
    assert r1.n_cache_hits == 0

    journal2 = TrialJournal(jpath)  # reload from disk: a "new session"
    assert len(journal2) == 60
    eng2 = MeasureEngine(cost, n_workers=4, journal=journal2, workload_key=wkey)
    r2 = GBFSTuner(space, cost, seed=0).tune(Budget(max_trials=60), engine=eng2)
    assert [t.state.key() for t in r2.trials] == [t.state.key() for t in r1.trials]
    assert r2.n_cache_hits == 60 and r2.cache_hit_rate == 1.0
    assert r2.clock_s == 0.0
    assert r2.best_cost == r1.best_cost


def test_journal_scoped_by_measurement_settings(tmp_path, space):
    """Entries journaled under one noise model / seed / repeat count must
    never be served to a backend with different settings."""
    jpath = str(tmp_path / "j.jsonl")
    wkey = workload_key(space.m, space.k, space.n, "bfloat16", "analytical_tpu_v5e")

    def run(noise, seed):
        cost = AnalyticalTPUCost(space, noise_sigma=noise, seed=seed)
        eng = MeasureEngine(
            cost, n_workers=4, journal=TrialJournal(jpath), workload_key=wkey
        )
        return GBFSTuner(space, cost, seed=0).tune(Budget(max_trials=40), engine=eng)

    r1 = run(0.05, 0)
    assert r1.n_cache_hits == 0
    assert run(0.3, 0).n_cache_hits == 0  # different noise: no sharing
    assert run(0.05, 1).n_cache_hits == 0  # different seed: no sharing
    assert run(0.05, 0).n_cache_hits == 40  # same settings: full cache


def test_engine_arg_conflicts_rejected(space):
    """Passing an engine plus conflicting overhead/worker arguments must
    raise instead of silently dropping the arguments."""
    cost = AnalyticalTPUCost(space)
    engine = MeasureEngine(cost, n_workers=2, overhead_s=0.5)
    with pytest.raises(ValueError):
        GBFSTuner(space, cost, seed=0).tune(
            Budget(max_trials=5), n_workers=8, engine=engine
        )
    with pytest.raises(ValueError):
        GBFSTuner(space, cost, seed=0).tune(
            Budget(max_trials=5), overhead_s=0.35, engine=engine
        )


def test_auto_reload_serves_sibling_rows_mid_search(tmp_path, space):
    """With reload_every=N, an engine periodically merges rows appended
    by a *sibling* engine/process sharing the journal file, and serves
    them as cache hits instead of re-measuring (the ROADMAP's
    multi-engine mid-search sharing)."""
    jpath = str(tmp_path / "shared.jsonl")
    wkey = workload_key(space.m, space.k, space.n, "bfloat16", "analytical_tpu_v5e")
    cost = AnalyticalTPUCost(space)
    jkey = f"{wkey}?{cost.measure_fingerprint()}"
    s0 = space.initial_state()
    s_sib = space.neighbors(s0)[0]

    journal_a = TrialJournal(jpath)
    journal_b = TrialJournal(jpath)  # the "sibling engine's" handle
    eng = MeasureEngine(cost, n_workers=2, journal=journal_a,
                        workload_key=wkey, reload_every=2)
    eng.measure_wave([s0])  # wave 1: miss, dispatched
    assert eng.stats.n_dispatched == 1
    # a sibling measures s_sib and appends it to the shared file
    journal_b.record(jkey, s_sib, cost.cost(s_sib))
    # wave 2 triggers the auto-reload: the sibling's row is a cache hit
    out = eng.measure_wave([s_sib])
    assert out[0].cache_hit and out[0].lane_s == 0.0
    assert eng.stats.n_dispatched == 1  # never re-measured
    assert eng.stats.n_journal_reloads == 1
    assert eng.stats.n_journal_rows_merged >= 1
    journal_a.close()
    journal_b.close()


def test_auto_reload_disabled_by_default(tmp_path, space):
    jpath = str(tmp_path / "j.jsonl")
    wkey = workload_key(space.m, space.k, space.n, "bfloat16", "analytical_tpu_v5e")
    eng = MeasureEngine(AnalyticalTPUCost(space), n_workers=2,
                        journal=TrialJournal(jpath), workload_key=wkey)
    for s in itertools.islice(space.enumerate(), 4):
        eng.measure_wave([s])
    assert eng.stats.n_journal_reloads == 0


class _FakeCompilingCost(AnalyticalTPUCost):
    """Analytical values plus a synthetic build-cache counter, so engine
    aggregation is testable without paying real XLA compiles."""

    def __init__(self, space):
        super().__init__(space)
        self._counters = {"compiles": 0, "mem_hits": 0, "disk_hits": 0,
                          "evictions": 0, "compile_s": 0.0, "n_timed": 0}
        self._seen: set[str] = set()

    def cost(self, s):
        key = s.key()
        if key in self._seen:
            self._counters["mem_hits"] += 1
        else:
            self._seen.add(key)
            self._counters["compiles"] += 1
            self._counters["compile_s"] += 0.25
        self._counters["n_timed"] += 1
        return super().cost(s)

    def batch_cost(self, states):
        return [self.cost(s) for s in states]

    def compile_stats(self):
        return dict(self._counters)


def test_engine_folds_compile_stats_into_measure_stats(space):
    cost = _FakeCompilingCost(space)
    eng = MeasureEngine(cost, n_workers=2)
    s0 = space.initial_state()
    s1 = space.neighbors(s0)[0]
    eng.measure_wave([s0, s1])
    eng.measure_wave([s0, s1])  # journal-less: dispatched again, but "cached"
    assert eng.stats.n_compiles == 2
    assert eng.stats.n_compile_mem_hits == 2
    assert eng.stats.compile_s == pytest.approx(0.5)
    assert eng.stats.compile_cache_hit_rate() == 0.5


def test_journal_caches_failed_builds(tmp_path):
    jpath = str(tmp_path / "inf.jsonl")
    j = TrialJournal(jpath)
    wkey = "gemm/m4096k4096n4096/bfloat16/analytical_tpu_v5e"
    from repro.core.config_space import TilingState

    bad = TilingState((1, 1, 1, 4096), (1, 4096), (1, 4096, 1, 1))
    j.record(wkey, bad, math.inf)
    j2 = TrialJournal(jpath)
    assert math.isinf(j2.get(wkey, bad.key()))


def test_warm_start_from_nearest_shape(tmp_path):
    records = TuningRecords(str(tmp_path / "rec.json"))
    session = TuningSession(
        records, seed=0, verbose=False, journal=TrialJournal(str(tmp_path / "j.jsonl"))
    )
    small = GemmWorkload(64, 64, 64)
    session.tune_workload(small, "g-bfs", Budget(max_trials=150))
    big = GemmWorkload(128, 128, 128)
    s0 = session.warm_start_state(big, big.space(), "analytical_tpu_v5e")
    assert s0 is not None and big.space().is_legitimate(s0)
    # warm-started search must start from the transplanted donor, not s0
    res = session.tune_workload(
        big, "g-bfs", Budget(max_trials=30), warm_start=True
    )
    assert res.trials[0].state.key() == s0.key()


def test_warm_start_scoped_to_dtype(tmp_path):
    """A bf16-tuned best must never seed a search for another dtype —
    neither via the records donor scan nor via the journal."""
    records = TuningRecords(str(tmp_path / "rec.json"))
    session = TuningSession(
        records, seed=0, verbose=False, journal=TrialJournal(str(tmp_path / "j.jsonl"))
    )
    session.tune_workload(GemmWorkload(64, 64, 64, dtype="bfloat16"), "g-bfs",
                          Budget(max_trials=150))
    bf16_twin = GemmWorkload(128, 128, 128, dtype="bfloat16")
    int8_twin = GemmWorkload(128, 128, 128, dtype="int8")
    assert session.warm_start_state(
        bf16_twin, bf16_twin.space(), "analytical_tpu_v5e"
    ) is not None
    assert session.warm_start_state(
        int8_twin, int8_twin.space(), "analytical_tpu_v5e"
    ) is None
    # the journal donor path is dtype-scoped too (fingerprint form)
    fp = AnalyticalTPUCost(bf16_twin.space(), n_repeats=1).measure_fingerprint()
    assert session.warm_start_state(
        bf16_twin, bf16_twin.space(), "analytical_tpu_v5e", fingerprint=fp
    ) is not None
    assert session.warm_start_state(
        int8_twin, int8_twin.space(), "analytical_tpu_v5e", fingerprint=fp
    ) is None


def test_tune_arch_trial_pool_is_hard_ceiling(tmp_path):
    """The shared trial pool can never be overspent, even with more
    workloads than trials and parallel lanes."""
    wls = [
        GemmWorkload(64, 64, 64, label="w0"),
        GemmWorkload(64, 64, 128, label="w1"),
        GemmWorkload(64, 128, 64, label="w2"),
        GemmWorkload(128, 64, 64, label="w3"),
        GemmWorkload(128, 128, 128, label="w4"),
    ]
    for max_trials, n_workers in [(2, 1), (3, 4), (7, 4), (50, 8)]:
        session = TuningSession(TuningRecords(), seed=0, verbose=False)
        report = session.tune_arch(
            workloads=wls, budget=Budget(max_trials=max_trials), n_workers=n_workers
        )
        assert report.total_trials <= max_trials, (
            f"pool overspent: {report.total_trials} > {max_trials} "
            f"(workers={n_workers})"
        )


def test_tune_cli_workers_and_warm_start(tmp_path):
    """The tune CLI writes records + a trial journal with --workers, and
    a --warm-start re-run is served from the journal cache."""
    import sys

    from repro.launch import tune as tune_mod

    argv = sys.argv
    base = [
        "tune", "--arch", "whisper-tiny", "--shape", "train_4k",
        "--tuner", "g-bfs", "--max-trials", "60", "--fraction", "1.0",
        "--records", str(tmp_path / "r.json"), "--workers", "4",
    ]
    try:
        sys.argv = base
        tune_mod.main()
        sys.argv = base + ["--warm-start", "--executor", "thread"]
        tune_mod.main()
    finally:
        sys.argv = argv
    rec = TuningRecords(str(tmp_path / "r.json"))
    assert len(rec) >= 3
    journal = TrialJournal(str(tmp_path / "r.json") + ".journal.jsonl")
    assert len(journal) > 0


def test_tune_arch_shares_budget_and_dedups_shapes(tmp_path):
    session = TuningSession(
        TuningRecords(str(tmp_path / "rec.json")),
        seed=0,
        verbose=False,
        journal=TrialJournal(str(tmp_path / "j.jsonl")),
    )
    wls = [
        GemmWorkload(128, 128, 128, label="a/qkv"),
        GemmWorkload(128, 128, 128, label="a/attn_out"),  # duplicate shape
        GemmWorkload(128, 128, 256, label="a/ffn_in"),
    ]
    report = session.tune_arch(
        workloads=wls, budget=Budget(max_trials=90), n_workers=4
    )
    assert set(report.results) == {"a/qkv", "a/attn_out", "a/ffn_in"}
    assert report.results["a/qkv"] is report.results["a/attn_out"]
    assert report.n_unique_shapes == 2
    assert report.total_trials <= 90
    # a re-run over the same shapes is served from the shared journal
    session2 = TuningSession(
        TuningRecords(str(tmp_path / "rec.json")),
        seed=0,
        verbose=False,
        journal=TrialJournal(str(tmp_path / "j.jsonl")),
    )
    report2 = session2.tune_arch(
        workloads=wls, budget=Budget(max_trials=90), n_workers=4
    )
    assert report2.stats.n_cache_hits > 0


def test_sharded_engines_split_one_stream_disjointly(tmp_path, space):
    """Two engines on shard 0/2 and 1/2 of one shared journal dispatch
    disjoint candidate sets whose union covers the whole stream: the
    non-owner defers (inf, zero lane time, nothing journaled) and is
    later served by the sibling's rows instead of re-measuring."""
    from repro.core import ShardSpec, shard_of

    jpath = str(tmp_path / "shared.jsonl")
    wkey = workload_key(space.m, space.k, space.n, "bfloat16", "analytical_tpu_v5e")
    cost = AnalyticalTPUCost(space)
    jkey = f"{wkey}?{cost.measure_fingerprint()}"
    stream = list(itertools.islice(space.enumerate(), 24))
    eng_a = MeasureEngine(cost, n_workers=4, journal=TrialJournal(jpath),
                          workload_key=wkey, shard=ShardSpec(0, 2))
    eng_b = MeasureEngine(cost, n_workers=4, journal=TrialJournal(jpath),
                          workload_key=wkey, shard=ShardSpec(1, 2))

    def drive(eng):
        dispatched, out = set(), []
        before = eng.stats.n_dispatched
        for i in range(0, len(stream), 4):
            wave = stream[i:i + 4]
            outs = eng.measure_wave(wave)
            out.extend(outs)
            for o in outs:
                if not o.cache_hit and not o.deferred and not math.isinf(o.cost):
                    dispatched.add(o.state.key())
        assert eng.stats.n_dispatched - before == len(dispatched)
        return dispatched, out

    measured_a, outs_a = drive(eng_a)
    # engine A owns exactly the shard-0 keys; the rest deferred
    assert measured_a == {
        s.key() for s in stream if shard_of(jkey, s.key(), 2) == 0
    }
    deferred_a = [o for o in outs_a if o.deferred]
    assert len(deferred_a) == len(stream) - len(measured_a)
    assert all(math.isinf(o.cost) and o.lane_s == 0.0 for o in deferred_a)
    assert eng_a.stats.n_deferred_to_sibling == len(deferred_a)

    # engine B (same stream, sibling journal handle) measures the
    # complement and serves A's rows from the shared file
    measured_b, outs_b = drive(eng_b)
    assert measured_a.isdisjoint(measured_b)
    assert measured_a | measured_b == {s.key() for s in stream}
    assert eng_b.stats.n_deferred_to_sibling == 0
    assert eng_b.stats.n_served_by_sibling == len(measured_a)
    # nothing deferred was journaled: every journal row belongs to its owner
    eng_a.journal.close()
    eng_b.journal.close()
    import json
    rows = [json.loads(l) for l in open(jpath)]
    assert len(rows) == len(stream)
    for row in rows:
        si, sn = row["shard"]
        assert shard_of(row["w"], row["k"], sn) == si

    # A's second pass over the stream: everything now serves from cache
    # (its own rows directly, B's via the shard-stage reload)
    before = eng_a.stats.n_dispatched
    hits, sib_before = 0, eng_a.stats.n_served_by_sibling
    for i in range(0, len(stream), 4):
        for o in eng_a.measure_wave(stream[i:i + 4]):
            assert o.cache_hit and math.isfinite(o.cost)
            hits += 1
    assert hits == len(stream)
    assert eng_a.stats.n_dispatched == before
    assert eng_a.stats.n_served_by_sibling - sib_before == len(measured_b)


def test_single_shard_spec_is_bit_identical_to_no_shard(tmp_path, space):
    """ShardSpec(0, 1) — the CLI default 0/1 — normalizes away: same
    outcomes, stats, and journal bytes as an engine built without one."""
    from repro.core import ShardSpec

    wkey = workload_key(space.m, space.k, space.n, "bfloat16", "analytical_tpu_v5e")
    stream = list(itertools.islice(space.enumerate(), 8))
    results = []
    for tag, shard in (("plain", None), ("01", ShardSpec(0, 1))):
        jpath = str(tmp_path / f"j_{tag}.jsonl")
        eng = MeasureEngine(AnalyticalTPUCost(space), n_workers=4,
                            journal=TrialJournal(jpath),
                            workload_key=wkey, shard=shard)
        assert eng.shard is None
        outs = []
        for i in range(0, len(stream), 4):
            outs.extend(eng.measure_wave(stream[i:i + 4]))
        eng.journal.close()
        results.append((
            [(o.state.key(), o.cost, o.cache_hit, o.lane_s) for o in outs],
            open(jpath).read(),
        ))
    assert results[0] == results[1]


def test_sharded_engine_requires_a_journal(space):
    from repro.core import ShardSpec

    with pytest.raises(ValueError, match="shared journal"):
        MeasureEngine(AnalyticalTPUCost(space), shard=ShardSpec(0, 2))

"""shard_map all-to-all MoE dispatch == pure-GSPMD dispatch, forward and
gradients, for both expert regimes (E >= model axis and E < model axis).
Subprocess-based: needs a multi-device mesh."""

import json
import os
import subprocess
import sys

import pytest

pytest.importorskip(
    "repro.dist", reason="repro.dist subsystem not present in this tree yet"
)

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(n_devices, mesh_shape, arch, n_experts, topk):
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"
import sys; sys.path.insert(0, {json.dumps(SRC)})
import dataclasses, numpy as np, jax, jax.numpy as jnp
from repro.configs.registry import get_arch
from repro.models.transformer import init_moe, moe_apply
from repro.dist.api import mesh_context, MeshRules

cfg = get_arch({json.dumps(arch)}).reduced(
    n_experts={n_experts}, experts_per_token={topk},
    moe_capacity_factor=8.0, d_ff=32)
p = init_moe(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(1)
x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)) * 0.3, jnp.float32)
mesh = jax.make_mesh({mesh_shape}, ("data", "model"))
with mesh_context(mesh, MeshRules()):
    out_g, aux_g = jax.jit(lambda x: moe_apply(cfg, p, x))(x)
    cfg2 = dataclasses.replace(cfg, moe_impl="a2a")
    out_a, aux_a = jax.jit(lambda x: moe_apply(cfg2, p, x))(x)
    g1 = jax.jit(jax.grad(lambda x: jnp.sum(moe_apply(cfg, p, x)[0] ** 2)))(x)
    g2 = jax.jit(jax.grad(lambda x: jnp.sum(moe_apply(cfg2, p, x)[0] ** 2)))(x)
assert float(jnp.max(jnp.abs(out_g - out_a))) < 1e-4
assert abs(float(aux_g) - float(aux_a)) < 1e-6
assert float(jnp.max(jnp.abs(g1 - g2))) < 1e-3
print("A2A_EQ_OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, timeout=480
    )
    assert "A2A_EQ_OK" in out.stdout, out.stdout[-1000:] + out.stderr[-2000:]


def test_a2a_many_experts():
    """E=8 experts on a 4-way model axis (e_loc=2)."""
    _run(8, "(2, 4)", "qwen3-moe-235b-a22b", 8, 2)


def test_a2a_few_experts_capacity_split():
    """E=4 experts on an 8-way model axis (r=2 replicas per expert)."""
    _run(16, "(2, 8)", "grok-1-314b", 4, 2)

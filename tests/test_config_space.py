"""Configuration-space tests: paper-exact sizes + MDP invariants
(property-based via hypothesis)."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import GemmConfigSpace, TilingState
from repro.core.config_space import compositions_pow2, count_compositions_pow2


def test_paper_space_sizes():
    # the paper reports these counts for d=(4,2,4) (Sec. 5 / Fig. 8)
    assert GemmConfigSpace(512, 512, 512).size() == 484_000
    assert GemmConfigSpace(1024, 1024, 1024).size() == 899_756
    assert GemmConfigSpace(2048, 2048, 2048).size() == 1_589_952


def test_enumeration_matches_count(small_space):
    states = list(small_space.enumerate())
    assert len(states) == small_space.size()
    assert len({s.key() for s in states}) == len(states)
    for s in states[:50]:
        assert small_space.is_legitimate(s)


def test_initial_state_is_paper_s0(paper_space):
    s0 = paper_space.initial_state()
    assert s0.as_lists() == [[1024, 1, 1, 1], [1024, 1], [1024, 1, 1, 1]]
    assert paper_space.is_legitimate(s0)


def test_action_space_size(paper_space):
    # d_m=4 -> 12 ordered pairs, d_k=2 -> 2, d_n=4 -> 12
    assert paper_space.n_actions == 26


def test_compositions_pow2_count():
    for value, parts in [(64, 4), (1024, 2), (96, 3)]:
        assert len(list(compositions_pow2(value, parts))) == count_compositions_pow2(
            value, parts
        )


@st.composite
def space_and_state(draw):
    em = draw(st.integers(2, 6))
    ek = draw(st.integers(2, 6))
    en = draw(st.integers(2, 6))
    space = GemmConfigSpace(2**em, 2**ek, 2**en)
    import random

    rng = random.Random(draw(st.integers(0, 10_000)))
    state = space.random_state(rng)
    return space, state


@given(space_and_state())
@settings(max_examples=60, deadline=None)
def test_actions_preserve_products(pair):
    """Eqn. 6 moves keep every dimension's product exact (the core
    legitimacy invariant)."""
    space, s = pair
    dims = s.dims()
    for a in space.actions:
        s2 = space.step(s, a)
        if s2 is not None:
            assert s2.dims() == dims
            assert space.is_legitimate(s2)


@given(space_and_state())
@settings(max_examples=60, deadline=None)
def test_neighbor_symmetry(pair):
    """Every move has an inverse: s' in g(s) implies s in g(s')."""
    space, s = pair
    for s2 in space.neighbors(s):
        back_keys = {b.key() for b in space.neighbors(s2)}
        assert s.key() in back_keys


@given(space_and_state())
@settings(max_examples=60, deadline=None)
def test_random_state_legitimate_and_features_finite(pair):
    space, s = pair
    assert space.is_legitimate(s)
    f = space.features(s)
    assert f.shape == (space.n_features,)
    assert all(map(math.isfinite, f.tolist()))


def test_reachability_closure(small_space):
    """BFS from s0 under the action set reaches exactly the enumerated
    space (paper: 'guaranteed to visit all configuration states')."""
    seen = {small_space.initial_state().key()}
    frontier = [small_space.initial_state()]
    while frontier:
        s = frontier.pop()
        for s2 in small_space.neighbors(s):
            if s2.key() not in seen:
                seen.add(s2.key())
                frontier.append(s2)
    assert len(seen) == small_space.size()


def test_state_key_roundtrip(paper_space):
    s = paper_space.initial_state()
    s2 = TilingState.from_lists(s.as_lists())
    assert s2 == s and s2.key() == s.key()


def test_tpu_mapping_views():
    s = TilingState((2, 4, 8, 16), (4, 256), (2, 8, 8, 8))
    assert s.grid == (2, 4, 2)
    assert s.block_m == 4 * 8 * 16
    assert s.block_k == 256
    assert s.block_n == 8 * 8 * 8
    assert s.sub_m == 8 * 16 and s.sub_n == 64
    assert s.reg_m == 16 and s.reg_n == 8

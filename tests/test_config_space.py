"""Configuration-space tests: paper-exact sizes + deterministic MDP
invariants.  Property-based (hypothesis) variants live in
``test_config_space_properties.py`` so this module collects without the
optional dependency."""


from repro.core import GemmConfigSpace, TilingState
from repro.core.config_space import compositions_pow2, count_compositions_pow2


def test_paper_space_sizes():
    # the paper reports these counts for d=(4,2,4) (Sec. 5 / Fig. 8)
    assert GemmConfigSpace(512, 512, 512).size() == 484_000
    assert GemmConfigSpace(1024, 1024, 1024).size() == 899_756
    assert GemmConfigSpace(2048, 2048, 2048).size() == 1_589_952


def test_enumeration_matches_count(small_space):
    states = list(small_space.enumerate())
    assert len(states) == small_space.size()
    assert len({s.key() for s in states}) == len(states)
    for s in states[:50]:
        assert small_space.is_legitimate(s)


def test_initial_state_is_paper_s0(paper_space):
    s0 = paper_space.initial_state()
    assert s0.as_lists() == [[1024, 1, 1, 1], [1024, 1], [1024, 1, 1, 1]]
    assert paper_space.is_legitimate(s0)


def test_action_space_size(paper_space):
    # d_m=4 -> 12 ordered pairs, d_k=2 -> 2, d_n=4 -> 12
    assert paper_space.n_actions == 26


def test_compositions_pow2_count():
    for value, parts in [(64, 4), (1024, 2), (96, 3)]:
        assert len(list(compositions_pow2(value, parts))) == count_compositions_pow2(
            value, parts
        )


def test_actions_preserve_products_deterministic(small_space):
    """Eqn. 6 moves keep every dimension's product exact (the core
    legitimacy invariant) — deterministic sweep over sampled states."""
    import random

    rng = random.Random(0)
    for _ in range(40):
        s = small_space.random_state(rng)
        dims = s.dims()
        for a in small_space.actions:
            s2 = small_space.step(s, a)
            if s2 is not None:
                assert s2.dims() == dims
                assert small_space.is_legitimate(s2)


def test_reachability_closure(small_space):
    """BFS from s0 under the action set reaches exactly the enumerated
    space (paper: 'guaranteed to visit all configuration states')."""
    seen = {small_space.initial_state().key()}
    frontier = [small_space.initial_state()]
    while frontier:
        s = frontier.pop()
        for s2 in small_space.neighbors(s):
            if s2.key() not in seen:
                seen.add(s2.key())
                frontier.append(s2)
    assert len(seen) == small_space.size()


def test_state_key_roundtrip(paper_space):
    s = paper_space.initial_state()
    s2 = TilingState.from_lists(s.as_lists())
    assert s2 == s and s2.key() == s.key()


def test_tpu_mapping_views():
    s = TilingState((2, 4, 8, 16), (4, 256), (2, 8, 8, 8))
    assert s.grid == (2, 4, 2)
    assert s.block_m == 4 * 8 * 16
    assert s.block_k == 256
    assert s.block_n == 8 * 8 * 8
    assert s.sub_m == 8 * 16 and s.sub_n == 64
    assert s.reg_m == 16 and s.reg_n == 8


def test_transplant_preserves_inner_tiling():
    """Warm-start translation: inner (block/sub/register) factors carry
    over when they divide the new dims; the grid factor absorbs the rest."""
    src_space = GemmConfigSpace(1024, 1024, 1024)
    s = TilingState((8, 1, 1, 128), (2, 512), (8, 1, 1, 128))
    dst = GemmConfigSpace(2048, 2048, 2048)
    s2 = dst.transplant(s)
    assert s2 is not None and dst.is_legitimate(s2)
    assert s2.as_lists() == [[16, 1, 1, 128], [4, 512], [16, 1, 1, 128]]
    # shrink path: donor block larger than the whole target dim
    tiny = GemmConfigSpace(64, 64, 64)
    s3 = tiny.transplant(s)
    assert s3 is not None and tiny.is_legitimate(s3)
    # identity transplant round-trips
    same = src_space.transplant(s)
    assert same is not None and same.key() == s.key()


def test_transplant_handles_odd_parts():
    # 96 = 2^5 * 3: the odd part must stay on the grid factor
    dst = GemmConfigSpace(96, 64, 96)
    s = TilingState((8, 1, 1, 128), (2, 512), (8, 1, 1, 128))
    s2 = dst.transplant(s)
    assert s2 is not None and dst.is_legitimate(s2)
    assert s2.m[0] % 3 == 0

"""Sharding-rule engine tests (no XLA compile — pure spec logic, but
exercised for EVERY full-size assigned architecture)."""

import json
import os
import subprocess
import sys

import pytest

import jax

pytest.importorskip(
    "repro.dist.api", reason="repro.dist.api not present in this tree yet"
)

from repro.dist.api import MeshRules, resolve_spec

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_resolve_spec_drops_indivisible():
    """Without a live mesh we can still check the drop logic via a tiny
    fake mesh namespace."""

    class FakeMesh:
        shape = {"data": 4, "model": 8}

    rules = MeshRules()
    spec = resolve_spec(("dp", "tp"), (8, 24), FakeMesh, rules)
    assert spec == jax.sharding.PartitionSpec(("data",), "model")
    # 25 % 8 != 0 -> tp dropped
    spec = resolve_spec(("dp", "tp"), (8, 25), FakeMesh, rules)
    assert spec == jax.sharding.PartitionSpec(("data",))


@pytest.mark.parametrize("multi", [False, True], ids=["single", "multi"])
def test_param_specs_divisible_all_archs(multi):
    """Every sharded dim of every param of every FULL-SIZE arch divides
    its mesh axes — run in a subprocess with 512 fake devices."""
    pytest.importorskip(
        "repro.dist.sharding",
        reason="full dist sharding subsystem not present in this tree yet",
    )
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
sys.path.insert(0, {json.dumps(SRC)})
import math
import jax
from repro.configs.registry import ARCHS
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh, rules_for_mesh
from repro.models.api import Model

mesh = make_production_mesh(multi_pod={multi})
rules = rules_for_mesh(mesh)
for name, cfg in ARCHS.items():
    model = Model(cfg)
    abs_params = model.abstract_params()
    specs = shd.param_specs(cfg, abs_params, mesh, rules)
    flat_p = jax.tree_util.tree_leaves(abs_params)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
    assert len(flat_p) == len(flat_s), name
    total, sharded_bytes = 0, 0
    for aval, spec in zip(flat_p, flat_s):
        shards = 1
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            k = math.prod(mesh.shape[a] for a in axes)
            assert aval.shape[dim] % k == 0, (name, aval.shape, spec)
            shards *= k
        total += aval.size * aval.dtype.itemsize
        sharded_bytes += aval.size * aval.dtype.itemsize // shards
    # production posture: params per device well under 8 GB for all archs
    assert sharded_bytes < 8e9, (name, sharded_bytes / 1e9)
    print(name, "OK", round(sharded_bytes / 1e9, 3), "GB/device")
print("ALL_SPECS_OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, timeout=600
    )
    assert "ALL_SPECS_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]


def test_opt_state_sharding_structure():
    """ZeRO-1 shards optimizer state without duplicating mesh axes."""
    pytest.importorskip(
        "repro.dist.sharding",
        reason="full dist sharding subsystem not present in this tree yet",
    )
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
sys.path.insert(0, {json.dumps(SRC)})
import jax
from repro.configs.registry import get_arch
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh, rules_for_mesh
from repro.models.api import Model
from repro.optim import make_optimizer

for arch in ["qwen2-72b", "grok-1-314b"]:
    cfg = get_arch(arch)
    mesh = make_production_mesh()
    rules = rules_for_mesh(mesh)
    model = Model(cfg)
    abs_params = model.abstract_params()
    pspecs = shd.param_specs(cfg, abs_params, mesh, rules)
    opt = make_optimizer(cfg.optimizer, 1e-4)
    abs_state = jax.eval_shape(opt.init, abs_params)
    osh = shd.opt_state_shardings(cfg.optimizer, abs_state, pspecs, mesh, rules)
    for s in jax.tree_util.tree_leaves(
        osh, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding)):
        seen = set()
        for entry in s.spec:
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                if a is None: continue
                assert a not in seen, (arch, s.spec)
                seen.add(a)
print("OPT_OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, timeout=600
    )
    assert "OPT_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]

"""The learned-cost-model subsystem (``repro.core.learn``): journal
corpora, the pairwise rank model, content-keyed persistence, and the
measurement proposal filter — plus its contracts with the journal row
taxonomy (pred rows are provenance, never cache), the engine
(``learned_filter=None`` stays bit-identical), and the launch CLIs."""

import itertools
import json
import math

import numpy as np
import pytest

from repro.core import (
    AnalyticalTPUCost,
    CountingCost,
    GemmConfigSpace,
    MeasureEngine,
    TrialJournal,
    workload_key,
)
from repro.core.learn import (
    ProposalFilter,
    RankingCostModel,
    build_dataset,
    learn_cache_dir_for,
    scan_corpus,
    spearman_rank_corr,
    top_k_recall,
)
from repro.core.learn.gbt import PairwiseRankGBT


# -- corpus plumbing ----------------------------------------------------------


def _fill_journal(jpath, shapes, n_states=48):
    """Measure the first legitimate enumerable states of each shape into
    one journal (deterministic, noise-free analytical costs)."""
    for m, k, n in shapes:
        space = GemmConfigSpace(m, k, n)
        cost = AnalyticalTPUCost(space)
        with TrialJournal(jpath) as j:
            eng = MeasureEngine(cost, n_workers=8, journal=j,
                                workload_key=workload_key(m, k, n))
            states = list(itertools.islice(
                (s for s in space.enumerate() if space.is_legitimate(s)),
                n_states,
            ))
            for i in range(0, len(states), 8):
                eng.measure_wave(states[i : i + 8])
    return cost.measure_fingerprint()


def test_build_dataset_triages_row_taxonomy(tmp_path):
    jpath = str(tmp_path / "j.jsonl")
    space = GemmConfigSpace(64, 64, 64)
    _fill_journal(jpath, [(64, 64, 64)], n_states=12)
    sts = list(itertools.islice(space.enumerate(), 40, 44))
    wkey = workload_key(64, 64, 64) + "?fp"
    with TrialJournal(jpath) as j:
        j.record_static(wkey, sts[0], "degenerate", op="gemm")
        j.record_predicted(wkey, sts[1], 0.25, op="gemm")
        j.record(wkey, sts[2], math.inf, op="gemm")  # failure row
        j.record(wkey, sts[3], 1.0, op="gemm")
    with open(jpath, "a") as f:  # raw duplicate (the writer dedups)
        f.write(json.dumps({"w": wkey, "k": sts[3].key(),
                            "s": sts[3].as_lists(), "op": "gemm",
                            "c": 2.0}) + "\n")
    ds = build_dataset([jpath], "gemm")
    c = ds.counts
    assert c.n_trainable == 13 == len(ds)  # 12 measured + 1 fresh
    assert c.n_static == 1 and c.n_predicted == 1
    assert c.n_fail == 1 and c.n_duplicate == 1
    assert ds.n_features == space.n_features
    assert ds.X.shape == (13, space.n_features)
    assert np.isfinite(ds.X).all() and np.isfinite(ds.y).all()
    # the census CLI path sees the same taxonomy (row-level: the
    # census reports what the log holds, without cross-row dedup)
    counts = scan_corpus([jpath])
    assert counts[("gemm", "bfloat16")].n_trainable == 14
    assert counts[("gemm", "bfloat16")].n_predicted == 1


def test_build_dataset_groups_cross_shape(tmp_path):
    jpath = str(tmp_path / "j.jsonl")
    _fill_journal(jpath, [(64, 64, 64), (32, 64, 32)], n_states=10)
    ds = build_dataset([jpath], "gemm")
    assert ds.n_groups == 2
    assert len(ds) == 20
    train, held = ds.split_group(0)
    assert len(train) == len(held) == 10
    assert set(np.unique(held.groups)) == {0}


def test_build_dataset_scopes_by_op_and_dtype(tmp_path):
    jpath = str(tmp_path / "j.jsonl")
    _fill_journal(jpath, [(64, 64, 64)], n_states=8)
    assert len(build_dataset([jpath], "flash_attn")) == 0
    assert len(build_dataset([jpath], "gemm", dtype="float32")) == 0
    assert len(build_dataset([jpath], "gemm", dtype="bfloat16")) == 8
    assert len(build_dataset([jpath], "gemm", fingerprint="nope")) == 0


# -- the pairwise rank model --------------------------------------------------


def test_pairwise_rank_gbt_orders_within_and_across_groups():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(240, 6)).astype(np.float32)
    latent = X[:, 0] * 2.0 + X[:, 1]
    # two "shapes" with wildly different cost scales — the pairwise loss
    # must not care (rank groups are per-shape)
    groups = np.repeat([0, 1], 120)
    y = np.where(groups == 0, latent, 1e4 * latent + 5e4)
    m = PairwiseRankGBT(n_trees=40)
    m.fit(X, y, groups)
    pred = m.predict(X)
    for g in (0, 1):
        corr = spearman_rank_corr(y[groups == g], pred[groups == g],
                                  np.zeros(120, dtype=np.intp))
        assert corr > 0.9
    # deterministic: refitting gives identical scores (no hidden RNG)
    m2 = PairwiseRankGBT(n_trees=40)
    m2.fit(X, y, groups)
    assert np.array_equal(pred, m2.predict(X))


def test_pairwise_rank_gbt_json_round_trip():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(60, 4)).astype(np.float32)
    y = X[:, 0] ** 2 + X[:, 1]
    m = PairwiseRankGBT(n_trees=10)
    m.fit(X, y, np.zeros(60, dtype=np.intp))
    m2 = PairwiseRankGBT.from_jsonable(json.loads(json.dumps(m.to_jsonable())))
    assert np.array_equal(m.predict(X), m2.predict(X))


def test_gbt_reexport_is_the_same_object():
    # satellite: tuners/gbt.py re-exports the lifted machinery (pinned
    # by the CI deprecation guard too)
    from repro.core.learn.gbt import GradientBoostedTrees as lifted
    from repro.core.tuners.gbt import GradientBoostedTrees as legacy

    assert legacy is lifted


def test_rank_metrics_sanity():
    y = np.array([1.0, 2.0, 3.0, 4.0])
    g = np.zeros(4, dtype=np.intp)
    assert spearman_rank_corr(y, y, g) == pytest.approx(1.0)
    assert spearman_rank_corr(y, -y, g) == pytest.approx(-1.0)
    assert top_k_recall(y, y, 2, g) == pytest.approx(1.0)
    assert top_k_recall(y, -y, 2, g) == pytest.approx(0.0)


# -- RankingCostModel: fit / transfer / persistence ---------------------------


def test_model_fits_and_transfers_to_held_out_shape(tmp_path):
    jpath = str(tmp_path / "j.jsonl")
    _fill_journal(jpath, [(64, 64, 64), (32, 64, 32), (64, 32, 64)],
                  n_states=48)
    ds = build_dataset([jpath], "gemm")
    train, held = ds.split_group(2)
    model = RankingCostModel.fit_dataset(train)
    assert model.is_fitted
    in_sample = model.evaluate(train)
    assert in_sample["rank_corr"] > 0.8
    # rank a shape the model never saw (the filter's deployment mode)
    held_corr = spearman_rank_corr(held.y, model.predict(held.X), held.groups)
    assert held_corr > 0.5


def test_model_persistence_round_trip_and_content_key(tmp_path):
    jpath = str(tmp_path / "j.jsonl")
    _fill_journal(jpath, [(64, 64, 64)], n_states=32)
    ds = build_dataset([jpath], "gemm")
    model = RankingCostModel.fit_dataset(ds)
    cache = str(tmp_path / "cache")
    path = model.save(cache)
    hit = RankingCostModel.load_for(cache, "gemm", ds.dtype, ds.fingerprint,
                                    ds.n_features)
    assert hit is not None and hit.is_fitted
    assert hit.n_rows_trained == model.n_rows_trained == len(ds)
    assert np.array_equal(hit.predict(ds.X), model.predict(ds.X))
    # a different scope/hyper hashes to a different key -> miss
    assert RankingCostModel.load_for(cache, "gemm", ds.dtype, ds.fingerprint,
                                     ds.n_features, n_trees=7) is None
    assert RankingCostModel.load_for(cache, "flash_attn", ds.dtype,
                                     ds.fingerprint, ds.n_features) is None
    # corrupted file -> clean miss, not a crash
    with open(path, "w") as f:
        f.write("{not json")
    assert RankingCostModel.load(path) is None


def test_model_rejects_wrong_feature_width(tmp_path):
    jpath = str(tmp_path / "j.jsonl")
    _fill_journal(jpath, [(64, 64, 64)], n_states=16)
    ds = build_dataset([jpath], "gemm")
    model = RankingCostModel.fit_dataset(ds)
    with pytest.raises(ValueError, match="feature"):
        model.predict(np.zeros((3, ds.n_features + 1), dtype=np.float32))


# -- ProposalFilter -----------------------------------------------------------


def test_filter_validates_keep_fraction(small_space):
    for bad in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError, match="keep"):
            ProposalFilter(small_space, None, keep=bad)


def test_filter_passes_through_until_trained(tmp_path, small_space):
    jpath = str(tmp_path / "j.jsonl")
    with TrialJournal(jpath) as j:
        flt = ProposalFilter(small_space, j, min_rows=10_000)
        assert not flt.active
        assert not flt.maybe_retrain()
        states = list(itertools.islice(small_space.enumerate(), 8))
        kept, skipped = flt.select(states)
        assert kept == list(range(8)) and skipped == []


def test_filter_selects_keep_fraction_in_dispatch_order(tmp_path, small_space):
    jpath = str(tmp_path / "j.jsonl")
    fp = _fill_journal(jpath, [(64, 64, 64)], n_states=48)
    with TrialJournal(jpath) as j:
        flt = ProposalFilter(small_space, j, fingerprint=fp, keep=0.5,
                             min_rows=16)
        assert flt.maybe_retrain() and flt.active and flt.n_retrains == 1
        states = list(itertools.islice(small_space.enumerate(), 100, 108))
        kept, skipped = flt.select(states)
        assert len(kept) == 4 and len(skipped) == 4
        assert kept == sorted(kept)  # deterministic dispatch order
        assert sorted(kept + [i for i, _ in skipped]) == list(range(8))
        assert all(math.isfinite(score) for _, score in skipped)
        # at least one candidate always reaches a lane
        kept1, skipped1 = flt.select(states[:2])
        assert len(kept1) == 1 and len(skipped1) == 1
        # retrain is a no-op until the corpus grows
        flt._waves_since_check = flt.retrain_every
        assert not flt.maybe_retrain()


def test_filter_prewarms_from_model_cache(tmp_path, small_space):
    jpath = str(tmp_path / "j.jsonl")
    fp = _fill_journal(jpath, [(64, 64, 64)], n_states=48)
    with TrialJournal(jpath) as j:
        flt = ProposalFilter(small_space, j, fingerprint=fp, min_rows=16)
        flt.maybe_retrain()
        assert flt.active
    # a later session's filter is fitted before its first wave
    with TrialJournal(jpath) as j2:
        flt2 = ProposalFilter(small_space, j2, fingerprint=fp, min_rows=16)
        assert flt2.active and flt2.n_retrains == 0
        assert flt2.cache_dir == learn_cache_dir_for(jpath)


# -- engine integration -------------------------------------------------------


def _filtered_engine(space, jpath, fingerprint, **kw):
    j = TrialJournal(jpath)
    flt = ProposalFilter(space, j, fingerprint=fingerprint, keep=0.5,
                         min_rows=16, **kw)
    cc = CountingCost(AnalyticalTPUCost(space))
    eng = MeasureEngine(cc, n_workers=8, journal=j,
                        workload_key=workload_key(64, 64, 64),
                        learned_filter=flt)
    return cc, eng, j


def test_engine_skips_predicted_slow_candidates(tmp_path, small_space):
    jpath = str(tmp_path / "j.jsonl")
    fp = _fill_journal(jpath, [(32, 64, 32)], n_states=48)  # sibling shape
    cc, eng, j = _filtered_engine(small_space, jpath, fp)
    try:
        states = list(itertools.islice(small_space.enumerate(), 200, 208))
        outs = eng.measure_wave(states)
    finally:
        j.close()
    assert cc.n_measured == 4
    assert eng.stats.n_dispatched == 4
    assert eng.stats.trials_avoided_learned == 4
    assert eng.stats.n_learned_retrains == 1
    assert eng.stats.learn_s > 0.0
    skipped = [o for o in outs if o.predicted is not None]
    assert len(skipped) == 4
    for o in skipped:
        assert o.cost == math.inf and not o.cache_hit
        assert math.isfinite(o.predicted)
    # skip provenance is journaled, deduped on re-encounter
    rows = [json.loads(line) for line in open(jpath)]
    pred_rows = [r for r in rows if "pred" in r]
    assert len(pred_rows) == 4
    for r in pred_rows:
        assert r["c"] is None and math.isfinite(r["pred"])
        assert r["op"] == "gemm"


def test_pred_rows_never_served_as_cache_hits(tmp_path, small_space):
    jpath = str(tmp_path / "j.jsonl")
    fp = _fill_journal(jpath, [(32, 64, 32)], n_states=48)
    cc, eng, j = _filtered_engine(small_space, jpath, fp)
    try:
        states = list(itertools.islice(small_space.enumerate(), 200, 208))
        outs = eng.measure_wave(states)
        skipped_keys = {o.state.key() for o in outs if o.predicted is not None}
    finally:
        j.close()
    # a fresh journal reload keeps pred rows out of the cost table...
    with TrialJournal(jpath) as j2:
        wkey = f"{workload_key(64, 64, 64)}?{fp}"
        for key in skipped_keys:
            assert j2.get(wkey, key) is None
        # ...so an UNFILTERED engine re-measures every skipped state
        cc2 = CountingCost(AnalyticalTPUCost(small_space))
        eng2 = MeasureEngine(cc2, n_workers=8, journal=j2,
                             workload_key=workload_key(64, 64, 64))
        outs2 = eng2.measure_wave(states)
    remeasured = [o for o in outs2 if o.state.key() in skipped_keys]
    assert len(remeasured) == len(skipped_keys)
    assert all(not o.cache_hit and math.isfinite(o.cost) for o in remeasured)
    # the 4 really-measured states DO cache-hit (legacy rows unaffected)
    assert eng2.stats.n_cache_hits == 4
    assert cc2.n_measured == 4


def test_engine_without_filter_is_bit_identical(small_space):
    states = list(itertools.islice(small_space.enumerate(), 300, 316))
    eng_none = MeasureEngine(AnalyticalTPUCost(small_space), n_workers=8,
                             learned_filter=None)
    eng_plain = MeasureEngine(AnalyticalTPUCost(small_space), n_workers=8)
    outs_a, outs_b = [], []
    for i in range(0, len(states), 8):
        outs_a.extend(eng_none.measure_wave(states[i : i + 8]))
        outs_b.extend(eng_plain.measure_wave(states[i : i + 8]))
    assert [(o.state.key(), o.cost) for o in outs_a] == [
        (o.state.key(), o.cost) for o in outs_b
    ]
    assert eng_none.stats.trials_avoided_learned == 0
    assert eng_none.stats.learn_s == 0.0


def test_inactive_filter_measures_everything(tmp_path, small_space):
    # journal too small to train: the filter is plugged in but inert
    jpath = str(tmp_path / "j.jsonl")
    fp = _fill_journal(jpath, [(32, 64, 32)], n_states=4)
    cc, eng, j = _filtered_engine(small_space, jpath, fp)
    try:
        states = list(itertools.islice(small_space.enumerate(), 200, 208))
        outs = eng.measure_wave(states)
    finally:
        j.close()
    assert cc.n_measured == 8
    assert eng.stats.trials_avoided_learned == 0
    assert all(o.predicted is None for o in outs)


# -- session/CLI plumbing -----------------------------------------------------


def test_session_rejects_bad_filter_mode(tmp_path):
    from repro.core import GemmWorkload, TuningSession

    from repro.core import Budget

    sess = TuningSession(verbose=False)
    with pytest.raises(ValueError, match="learned.filter"):
        sess.tune_workload(GemmWorkload(64, 64, 64), "random",
                           budget=Budget(max_trials=2),
                           learned_filter="sometimes")


def test_analyze_cli_flags_pred_row_posing_as_measurement(tmp_path, capsys):
    from repro.launch.analyze import main as analyze_main

    jpath = str(tmp_path / "j.jsonl")
    _fill_journal(jpath, [(64, 64, 64)], n_states=8)
    space = GemmConfigSpace(64, 64, 64)
    s = next(iter(space.enumerate()))
    wkey = workload_key(64, 64, 64) + "?fp"
    with TrialJournal(jpath) as j:
        j.record_predicted(wkey, s, 0.5, op="gemm")
    assert analyze_main(["--journal", jpath]) == 0
    out = capsys.readouterr().out
    assert "1 predicted rows" in out
    assert "learn-corpus: op=gemm dtype=bfloat16 trainable=8" in out
    # a pred row claiming a finite measured cost is an error
    with open(jpath, "a") as f:
        f.write(json.dumps({"w": wkey, "k": "bogus", "s": s.as_lists(),
                            "op": "gemm", "c": 1.0, "pred": 0.5}) + "\n")
    assert analyze_main(["--journal", jpath]) == 1
    assert "provenance-only" in capsys.readouterr().out


def test_learn_cli_train_then_eval(tmp_path, capsys):
    from repro.launch.learn import main as learn_main

    jpath = str(tmp_path / "j.jsonl")
    _fill_journal(jpath, [(64, 64, 64), (32, 64, 32), (64, 32, 64)],
                  n_states=32)
    assert learn_main(["train", "--journal", jpath]) == 0
    out = capsys.readouterr().out
    assert "saved model to" in out
    import glob
    assert glob.glob(learn_cache_dir_for(jpath) + "/rankmodel-*.json")
    assert learn_main(["eval", "--journal", jpath, "--min-corr", "0.0"]) == 0
    assert "held_out_rank_corr=" in capsys.readouterr().out
    # an unreachable gate fails the exit code (the CI contract)
    assert learn_main(["eval", "--journal", jpath, "--min-corr", "1.0"]) == 1

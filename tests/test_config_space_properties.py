"""Property-based MDP invariants (hypothesis).  Guarded with
``pytest.importorskip`` so environments without hypothesis skip cleanly
instead of erroring at collection (deterministic variants of the same
invariants live in ``test_config_space.py``)."""

import math

import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core import GemmConfigSpace


@st.composite
def space_and_state(draw):
    em = draw(st.integers(2, 6))
    ek = draw(st.integers(2, 6))
    en = draw(st.integers(2, 6))
    space = GemmConfigSpace(2**em, 2**ek, 2**en)
    import random

    rng = random.Random(draw(st.integers(0, 10_000)))
    state = space.random_state(rng)
    return space, state


@given(space_and_state())
@settings(max_examples=60, deadline=None)
def test_actions_preserve_products(pair):
    """Eqn. 6 moves keep every dimension's product exact (the core
    legitimacy invariant)."""
    space, s = pair
    dims = s.dims()
    for a in space.actions:
        s2 = space.step(s, a)
        if s2 is not None:
            assert s2.dims() == dims
            assert space.is_legitimate(s2)


@given(space_and_state())
@settings(max_examples=60, deadline=None)
def test_neighbor_symmetry(pair):
    """Every move has an inverse: s' in g(s) implies s in g(s')."""
    space, s = pair
    for s2 in space.neighbors(s):
        back_keys = {b.key() for b in space.neighbors(s2)}
        assert s.key() in back_keys


@given(space_and_state())
@settings(max_examples=60, deadline=None)
def test_random_state_legitimate_and_features_finite(pair):
    space, s = pair
    assert space.is_legitimate(s)
    f = space.features(s)
    assert f.shape == (space.n_features,)
    assert all(map(math.isfinite, f.tolist()))


@given(space_and_state())
@settings(max_examples=40, deadline=None)
def test_transplant_into_random_space(pair):
    """Any state transplants into any power-of-two space legitimately."""
    space, s = pair
    dst = GemmConfigSpace(128, 256, 512)
    s2 = dst.transplant(s)
    assert s2 is not None
    assert dst.is_legitimate(s2)

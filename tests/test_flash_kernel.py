"""Pallas flash-attention kernel vs the pure-jnp attention oracle:
shape/dtype sweep + property-based block configs (interpret mode)."""

import numpy as np
import pytest

import jax.numpy as jnp
pytest.importorskip("hypothesis")  # optional dev dep; skip, don't error
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention
from repro.models import common as cm


def _qkv(b, s, h, kv, hd, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, kv, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, kv, hd)), dtype)
    return q, k, v


@pytest.mark.parametrize("dtype,tol", [("float32", 2e-5), ("bfloat16", 0.05)])
@pytest.mark.parametrize(
    "shape",
    [(2, 128, 8, 2, 16), (1, 256, 4, 4, 32), (2, 64, 8, 8, 16), (1, 128, 16, 4, 8)],
    ids=str,
)
def test_flash_matches_oracle(shape, dtype, tol):
    b, s, h, kv, hd = shape
    q, k, v = _qkv(b, s, h, kv, hd, dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    ref = cm.causal_attention(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol * 4,
    )


@given(
    log_bq=st.integers(4, 6),
    log_bk=st.integers(4, 6),
    g=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 50),
)
@settings(max_examples=8, deadline=None)
def test_flash_block_config_sweep(log_bq, log_bk, g, seed):
    """Any (block_q, block_k) tiling computes identical attention — the
    tunability contract (same as the GEMM kernel's)."""
    b, s, kv, hd = 1, 128, 2, 16
    q, k, v = _qkv(b, s, kv * g, kv, hd, "float32", seed)
    out = flash_attention(
        q, k, v, block_q=1 << log_bq, block_k=1 << log_bk, interpret=True
    )
    ref = cm.causal_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=1e-4)


def test_flash_non_causal():
    q, k, v = _qkv(1, 64, 4, 2, 16, "float32")
    out = flash_attention(q, k, v, block_q=32, block_k=32, causal=False,
                          interpret=True)
    ref = cm.cross_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=1e-4)


def test_flash_rejects_indivisible_blocks():
    q, k, v = _qkv(1, 100, 4, 2, 16, "float32")
    with pytest.raises(ValueError):
        flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)

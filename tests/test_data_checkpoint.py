"""Data pipeline determinism/sharding + checkpointer atomicity, keep-N,
and elastic restore."""

import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer, latest_step
from repro.data.pipeline import DataPipeline, SyntheticLM


def test_pipeline_deterministic():
    ds = SyntheticLM(vocab_size=100, seq_len=16, seed=3)
    p1 = DataPipeline(ds, global_batch=8)
    p2 = DataPipeline(ds, global_batch=8)
    b1, b2 = p1.build_batch(5), p2.build_batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = p1.build_batch(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_pipeline_host_sharding_disjoint():
    ds = SyntheticLM(vocab_size=1000, seq_len=8, seed=0)
    full = DataPipeline(ds, global_batch=8).build_batch(2)
    halves = [
        DataPipeline(ds, global_batch=8, process_index=i, process_count=2).build_batch(2)
        for i in range(2)
    ]
    stacked = np.concatenate([h["tokens"] for h in halves])
    np.testing.assert_array_equal(full["tokens"], stacked)


def test_pipeline_labels_are_next_token():
    ds = SyntheticLM(vocab_size=50, seq_len=10, seed=1, noise=0.0)
    t, l = ds.sample(7)
    assert t.shape == (10,) and l.shape == (10,)
    # with zero noise, label[i] follows the same stride as t
    stride = (l[0] - t[0]) % 50
    assert all(((l[i] - t[i]) % 50) == stride for i in range(10))


def test_pipeline_prefetch_and_resume():
    ds = SyntheticLM(vocab_size=100, seq_len=4, seed=0)
    p = DataPipeline(ds, global_batch=4, prefetch=2)
    it = iter(p)
    for _ in range(3):
        next(it)
    p.stop()
    state = p.state_dict()
    p2 = DataPipeline(ds, global_batch=4, start_step=0)
    p2.load_state_dict(state)
    nxt = p2.build_batch(p2.step)
    expected = DataPipeline(ds, global_batch=4).build_batch(3)
    np.testing.assert_array_equal(nxt["tokens"], expected["tokens"])


# -----------------------------------------------------------------------------


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)},
        "opt": {"step": jnp.asarray(3, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    tree = _tree()
    ck.save(10, tree, metadata={"step": 10, "pipeline": {"step": 10, "seed": 0}})
    abstract = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree
    )
    restored, meta = ck.restore(abstract)
    assert meta["step"] == 10
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(tree["params"]["w"])
    )


def test_checkpoint_async_and_keep_n(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_n=2, async_save=True)
    for s in [1, 2, 3, 4]:
        ck.save(s, _tree(s), metadata={"step": s})
    ck.wait()
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("step_")
    )
    assert steps == [3, 4]
    assert latest_step(str(tmp_path)) == 4


def test_checkpoint_atomicity_marker(tmp_path):
    """A directory without COMMIT is ignored (crashed mid-write)."""
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(5, _tree(), metadata={"step": 5})
    # fake a torn write at step 6
    os.makedirs(tmp_path / "step_00000006")
    with open(tmp_path / "step_00000006" / "manifest.json", "w") as f:
        json.dump({}, f)
    assert latest_step(str(tmp_path)) == 5


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, _tree(), metadata={})
    bad = {
        "params": {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)},
        "opt": {"step": jax.ShapeDtypeStruct((), jnp.int32)},
    }
    with pytest.raises(ValueError):
        ck.restore(bad)


def test_checkpoint_elastic_reshard_subprocess(tmp_path):
    """Save on an 8-device (4,2) mesh, restore onto (2,4) — the elastic
    path (different shard layout, same logical arrays)."""
    import subprocess
    import sys

    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {json.dumps(os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src")))})
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.checkpointer import Checkpointer

d = {json.dumps(str(tmp_path))}
mesh1 = jax.make_mesh((4, 2), ("data", "model"))
w = jnp.arange(64.0, dtype=jnp.float32).reshape(8, 8)
w1 = jax.device_put(w, NamedSharding(mesh1, P("data", "model")))
ck = Checkpointer(d, async_save=False)
ck.save(1, {{"w": w1}}, metadata={{"step": 1}})

mesh2 = jax.make_mesh((2, 4), ("data", "model"))
sh2 = {{"w": NamedSharding(mesh2, P("model", "data"))}}
restored, _ = ck.restore({{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}}, shardings=sh2)
assert restored["w"].sharding.is_equivalent_to(sh2["w"], 2)
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
print("ELASTIC_OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, timeout=240
    )
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]

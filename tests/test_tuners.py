"""Tuner behaviour: budgets, dedup, determinism, and solution quality on
a brute-forced space."""

import math

import pytest

from repro.core import AnalyticalTPUCost, Budget, GemmConfigSpace
from repro.core.tuners import (
    TUNERS,
    AnnealingTuner,
    GBFSTuner,
    GBTTuner,
    GeneticTuner,
    GridTuner,
    NA2CTuner,
    RandomTuner,
    RNNControllerTuner,
)

FAST_TUNERS = [GBFSTuner, RandomTuner, AnnealingTuner, GeneticTuner, GBTTuner]
ALL_TUNERS = FAST_TUNERS + [NA2CTuner, RNNControllerTuner]


@pytest.fixture(scope="module")
def space_and_opt():
    space = GemmConfigSpace(256, 256, 256)  # size 120*9*120 = 97k... small-ish
    cost = AnalyticalTPUCost(space)
    # brute-force a TINY reference space for exact-optimum checks — the
    # learned tuners pay a policy-inference round trip per trial, so the
    # 25%-budget test must stay at a few hundred trials
    small = GemmConfigSpace(16, 16, 16)
    small_cost = AnalyticalTPUCost(small)
    best_s, best_c = small_cost.optimum()
    return space, cost, small, small_cost, best_s, best_c


@pytest.mark.parametrize("tuner_cls", FAST_TUNERS, ids=lambda c: c.name)
def test_budget_respected(space_and_opt, tuner_cls):
    space, cost, *_ = space_and_opt
    res = tuner_cls(space, cost, seed=0).tune(Budget(max_trials=100))
    assert res.n_trials <= 100
    assert res.best_state is not None
    assert math.isfinite(res.best_cost)


@pytest.mark.parametrize("tuner_cls", FAST_TUNERS, ids=lambda c: c.name)
def test_no_duplicate_measurements(space_and_opt, tuner_cls):
    space, cost, *_ = space_and_opt
    res = tuner_cls(space, cost, seed=1).tune(Budget(max_trials=150))
    keys = [t.state.key() for t in res.trials]
    assert len(keys) == len(set(keys)), "states must not be re-measured"


@pytest.mark.parametrize("tuner_cls", FAST_TUNERS, ids=lambda c: c.name)
def test_seed_determinism(space_and_opt, tuner_cls):
    space, cost, *_ = space_and_opt
    r1 = tuner_cls(space, cost, seed=3).tune(Budget(max_trials=80))
    r2 = tuner_cls(space, cost, seed=3).tune(Budget(max_trials=80))
    assert [t.state.key() for t in r1.trials] == [t.state.key() for t in r2.trials]


@pytest.mark.parametrize("tuner_cls", FAST_TUNERS, ids=lambda c: c.name)
def test_finds_optimum_on_small_space(space_and_opt, tuner_cls):
    """With 25% of a small space, every method should find the global
    optimum (the G-BFS guarantee; others in practice)."""
    *_, small, small_cost, best_s, best_c = space_and_opt
    budget = Budget(max_fraction=0.25)
    res = tuner_cls(small, small_cost, seed=0).tune(budget)
    assert res.best_cost <= best_c * 1.05


@pytest.mark.parametrize("tuner_cls", [NA2CTuner, RNNControllerTuner],
                         ids=lambda c: c.name)
def test_learned_tuners_near_optimum(space_and_opt, tuner_cls):
    """The RL tuners pay a policy-inference round trip per trial, so they
    get a small fixed budget and a near-optimality bar."""
    *_, small, small_cost, best_s, best_c = space_and_opt
    res = tuner_cls(small, small_cost, seed=0).tune(Budget(max_trials=150))
    assert res.best_cost <= best_c * 2.0


def test_gbfs_explores_everything_with_full_rho(space_and_opt):
    """rho = len(g(s)) + unlimited budget -> full reachable space
    (paper Sec. 4.2)."""
    *_, small, small_cost, _, _ = space_and_opt
    res = GBFSTuner(small, small_cost, seed=0, rho=10_000).tune(
        Budget(max_trials=small.size() + 10)
    )
    assert res.n_trials == small.size()


def test_grid_tuner_sequential(space_and_opt):
    *_, small, small_cost, _, _ = space_and_opt
    res = GridTuner(small, small_cost, seed=0).tune(Budget(max_trials=50))
    enumerated = [s.key() for s in list(small.enumerate())[:50]]
    assert [t.state.key() for t in res.trials] == enumerated


def test_curves_monotone(space_and_opt):
    space, cost, *_ = space_and_opt
    res = GBFSTuner(space, cost, seed=0).tune(Budget(max_trials=200))
    curve = res.best_curve()
    costs = [c for _, c in curve]
    assert all(b <= a + 1e-18 for a, b in zip(costs, costs[1:]))
    tcurve = res.best_time_curve()
    assert all(t2 >= t1 for (t1, _), (t2, _) in zip(tcurve, tcurve[1:]))


def test_tuner_registry_complete():
    assert set(TUNERS) == {
        "g-bfs", "n-a2c", "xgboost-like", "rnn-controller",
        "random", "grid", "sim-anneal", "genetic",
    }


def test_gbfs_beats_random_under_noise():
    """The paper's headline: neighborhood search finds better configs
    than unstructured baselines at equal (small) budget."""
    space = GemmConfigSpace(1024, 1024, 1024)
    wins = 0
    for seed in range(3):
        cost = AnalyticalTPUCost(space, noise_sigma=0.15, seed=seed, n_repeats=2)
        b = Budget(max_trials=400)
        g = GBFSTuner(space, cost, seed=seed).tune(b)
        r = RandomTuner(space, cost, seed=seed).tune(b)
        if g.best_cost <= r.best_cost:
            wins += 1
    assert wins >= 2

"""Fault tolerance (watchdog, injection, restart loop) and gradient
compression codecs."""

import json
import os

import numpy as np
import pytest

import jax.numpy as jnp

pytest.importorskip(
    "repro.dist.fault",
    reason="dist fault/compress subsystems not present in this tree yet",
)

from repro.dist.compress import (
    compress_tree_bf16,
    dequantize_int8,
    ef_compress_tree_int8,
    init_error_feedback,
    quantize_int8,
)
from repro.dist.fault import (
    ChipFailure,
    FailureInjector,
    StragglerWatchdog,
    run_with_restarts,
)


def test_watchdog_flags_straggler():
    wd = StragglerWatchdog(k_sigma=3.0, rel_factor=1.5, warmup_steps=3)
    for s in range(10):
        wd.observe(s, 0.10 + 0.001 * (s % 2))
    ev = wd.observe(11, 0.50)  # 5x the mean: must flag
    assert ev is not None and ev.duration_s == 0.50
    assert len(wd.events) == 1
    # normal step afterwards: no flag
    assert wd.observe(12, 0.10) is None


def test_watchdog_hard_timeout_raises():
    wd = StragglerWatchdog(hard_timeout_s=1.0, warmup_steps=1)
    wd.observe(0, 0.1)
    wd.observe(1, 0.1)
    with pytest.raises(ChipFailure):
        wd.observe(2, 2.0)


def test_failure_injector_once():
    inj = FailureInjector(fail_at_steps=(3,), max_failures=1)
    inj.maybe_fail(2)
    with pytest.raises(ChipFailure):
        inj.maybe_fail(3)
    inj.maybe_fail(3)  # second pass: already failed once


def test_run_with_restarts():
    calls = []

    def attempt(i):
        calls.append(i)
        if i < 2:
            raise ChipFailure("boom")
        return "done"

    assert run_with_restarts(attempt, max_restarts=3) == "done"
    assert calls == [0, 1, 2]

    with pytest.raises(RuntimeError):
        run_with_restarts(lambda i: (_ for _ in ()).throw(ChipFailure("x")), max_restarts=1)


# -----------------------------------------------------------------------------


def test_int8_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 64)) * 3.0, jnp.float32)
    q, scale = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, scale) - x)
    assert float(err.max()) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_preserves_signal():
    """EF: the sum of transmitted (dequantized) grads converges to the
    sum of true grads — no permanent signal loss."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.standard_normal((32,)) * 1e-4, jnp.float32)
    grads = {"w": g_true}
    residual = init_error_feedback(grads)
    sent_total = jnp.zeros_like(g_true)
    steps = 50
    for _ in range(steps):
        payload, residual = ef_compress_tree_int8(grads, residual)
        q, scale = payload["w"]
        sent_total = sent_total + dequantize_int8(q, scale)
    np.testing.assert_allclose(
        np.asarray(sent_total), np.asarray(g_true) * steps, rtol=0.05, atol=1e-5
    )


def test_bf16_tree_compression():
    tree = {"a": jnp.ones((4,), jnp.float32) * 1.00390625}
    out = compress_tree_bf16(tree)
    assert out["a"].dtype == jnp.bfloat16


def test_compressed_psum_subprocess():
    """Real shard_map psum over 8 devices with bf16 and int8 codecs."""
    import subprocess
    import sys

    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {json.dumps(src)})
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.dist.compress import compressed_psum

mesh = jax.make_mesh((8,), ("pod",))
x = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16) / 37.0
want = np.asarray(x).mean(axis=0)

for codec, tol in [("none", 1e-6), ("bf16", 2e-2), ("int8", 2e-2)]:
    fn = shard_map(
        lambda t: compressed_psum(t, "pod", codec=codec),
        mesh=mesh, in_specs=P("pod", None), out_specs=P("pod", None),
    )
    out = np.asarray(jax.jit(fn)(x))
    for row in out.reshape(8, -1, 16):
        np.testing.assert_allclose(row[0], want, rtol=tol, atol=tol)
print("PSUM_OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, timeout=300
    )
    assert "PSUM_OK" in out.stdout, out.stderr[-2000:]

"""Lane executors (thread/process) and the crash-safe shared journal:
real-concurrency waves, per-lane timeout/crash isolation, O_APPEND
multi-process journal appends, strict-JSON cost encoding, and sibling
reload merging."""

import itertools
import json
import math
import multiprocessing
import time

import pytest

from repro.core import (
    AnalyticalTPUCost,
    Budget,
    GBFSTuner,
    GemmConfigSpace,
    MeasureEngine,
    ProcessExecutor,
    SimulatedExecutor,
    SleepingCost,
    ThreadExecutor,
    TrialJournal,
    make_executor,
    workload_key,
)
from repro.core.config_space import TilingState
from repro.core.cost.base import backend_from_spec


def _strict_loads(line):
    """json.loads that rejects the non-standard Infinity/NaN literals."""
    def _reject(const):
        raise AssertionError(f"non-strict JSON constant in journal: {const}")

    return json.loads(line, parse_constant=_reject)


@pytest.fixture(scope="module")
def space():
    return GemmConfigSpace(256, 256, 256)


@pytest.fixture(scope="module")
def states(space):
    return [space.initial_state()] + space.neighbors(space.initial_state())[:3]


# -- worker-spec protocol ------------------------------------------------------


def test_backend_spec_round_trip(space):
    cost = AnalyticalTPUCost(space, n_repeats=2, noise_sigma=0.1, seed=5)
    rebuilt = backend_from_spec(cost.worker_spec())
    for s in itertools.islice(space.enumerate(), 20):
        assert rebuilt.cost(s) == cost.cost(s)
    assert rebuilt.measure_fingerprint() == cost.measure_fingerprint()


def test_sleeping_backend_spec_round_trip(space):
    sl = SleepingCost(AnalyticalTPUCost(space), delay_s=0.0)
    rebuilt = backend_from_spec(sl.worker_spec())
    s = space.initial_state()
    assert rebuilt.cost(s) == sl.cost(s)


def test_unshippable_backend_refused(space):
    guarded = GemmConfigSpace(256, 256, 256, extra_constraint=lambda s: True)
    cost = AnalyticalTPUCost(guarded)
    assert cost.worker_spec() is None
    ex = ProcessExecutor()
    try:
        with pytest.raises(ValueError, match="worker_spec"):
            ex.run_wave(cost, [guarded.initial_state()])
    finally:
        ex.close()


@pytest.mark.slow
def test_cold_start_excluded_from_lane_timeout(space, states):
    """Worker start-up (interpreter + imports, easily seconds) must not
    eat into the per-lane measurement timeout: a tight timeout with
    cold workers still measures fine because _ensure_workers blocks
    until workers are ready."""
    sl = SleepingCost(AnalyticalTPUCost(space), delay_s=0.05)
    with ProcessExecutor(timeout_s=1.0) as ex:  # no warm_up on purpose
        eng = MeasureEngine(sl, n_workers=4, executor=ex)
        out = eng.measure_wave(states)
    assert all(o.error is None for o in out), [o.error for o in out]
    assert all(o.lane_s < 1.0 for o in out)  # lane wall is the job, not spawn


def test_tune_workload_rejects_engine_executor_conflict(tmp_path):
    from repro.core import GemmWorkload, TuningSession

    space = GemmConfigSpace(64, 64, 64)
    session = TuningSession(verbose=False)
    cost = AnalyticalTPUCost(space, n_repeats=1)
    engine = MeasureEngine(cost)
    with pytest.raises(ValueError, match="conflicts"):
        session.tune_workload(
            GemmWorkload(64, 64, 64), "g-bfs", Budget(max_trials=3),
            engine=engine, executor=SimulatedExecutor(),
        )


def test_make_executor_names():
    for name, cls_name in [("sim", "SimulatedExecutor"), ("thread", "ThreadExecutor"),
                           ("process", "ProcessExecutor")]:
        ex = make_executor(name)
        assert type(ex).__name__ == cls_name and ex.name == name
        ex.close()
    with pytest.raises(ValueError):
        make_executor("rpc")


# -- thread lanes --------------------------------------------------------------


def test_thread_executor_value_parity_and_overlap(space, states):
    """Thread lanes return the exact costs the simulated path returns,
    and genuinely overlap sleeps."""
    cost = AnalyticalTPUCost(space, n_repeats=2, noise_sigma=0.1, seed=3)
    sim = MeasureEngine(cost, n_workers=4).measure_wave(states)
    sl = SleepingCost(AnalyticalTPUCost(space, n_repeats=2, noise_sigma=0.1, seed=3),
                      delay_s=0.15)
    with ThreadExecutor() as ex:
        eng = MeasureEngine(sl, n_workers=4, executor=ex)
        t0 = time.perf_counter()
        out = eng.measure_wave(states)
        wall = time.perf_counter() - t0
    assert [o.cost for o in out] == [o.cost for o in sim]
    assert wall < len(states) * 0.15  # overlapped, not serial
    assert all(o.lane_s >= 0.15 for o in out)  # measured wall, not modeled


def test_thread_executor_isolates_raises_and_timeouts(space, states):
    bad = SleepingCost(
        AnalyticalTPUCost(space), delay_s=0.02,
        raise_keys=[states[1].key()], hang_keys=[states[2].key()], hang_s=30.0,
    )
    with ThreadExecutor(timeout_s=0.5) as ex:  # executor owns the kill timeout
        eng = MeasureEngine(bad, n_workers=4, executor=ex)
        out = eng.measure_wave([states[0], states[1], states[2]])
    assert out[0].error is None and math.isfinite(out[0].cost)
    assert math.isinf(out[1].cost) and "RuntimeError" in out[1].error
    assert math.isinf(out[2].cost) and "timeout" in out[2].error
    assert eng.stats.n_failures == 2


# -- process lanes -------------------------------------------------------------


@pytest.mark.slow
def test_process_executor_value_parity(space, states):
    cost = AnalyticalTPUCost(space, n_repeats=2, noise_sigma=0.1, seed=3)
    ref = [cost.cost(s) for s in states]
    with ProcessExecutor() as ex:
        eng = MeasureEngine(cost, n_workers=4, executor=ex)
        out = eng.measure_wave(states)
    assert [o.cost for o in out] == ref


@pytest.mark.slow
def test_process_executor_crash_and_timeout_isolation(tmp_path, space, states):
    """A worker hard-death (os._exit) or hang costs one inf trial — the
    session survives and the next wave measures normally on respawned
    workers — and executor failures are never journaled as infeasible
    configs."""
    bad = SleepingCost(
        AnalyticalTPUCost(space), delay_s=0.02,
        exit_keys=[states[1].key()], hang_keys=[states[2].key()], hang_s=30.0,
    )
    jpath = str(tmp_path / "crash.jsonl")
    wkey = workload_key(space.m, space.k, space.n, "bfloat16", "crashy")
    with ProcessExecutor(timeout_s=1.0) as ex:  # executor owns the kill timeout
        ex.warm_up(3)
        journal = TrialJournal(jpath)
        eng = MeasureEngine(bad, n_workers=3, executor=ex,
                            journal=journal, workload_key=wkey)
        out = eng.measure_wave(states[:3])
        assert out[0].error is None and math.isfinite(out[0].cost)
        assert math.isinf(out[1].cost) and "crash" in out[1].error
        assert math.isinf(out[2].cost) and "timeout" in out[2].error
        assert eng.stats.n_failures == 2
        # the genuine measurement is journaled; the crash/timeout are not
        assert journal.get(eng.journal_key, states[0].key()) is not None
        assert journal.get(eng.journal_key, states[1].key()) is None
        assert journal.get(eng.journal_key, states[2].key()) is None
        # next wave measures normally on respawned workers
        again = eng.measure_wave([states[3]])
        assert again[0].error is None and math.isfinite(again[0].cost)
        journal.close()


@pytest.mark.slow
def test_process_wave_concurrency_with_shared_journal(tmp_path, space, states):
    """Acceptance: a ProcessExecutor wave shows real wall-clock
    concurrency (N-state wave < N x single-state wall) while two engines
    append to one journal file without corrupting it."""
    delay = 0.25
    sl = SleepingCost(AnalyticalTPUCost(space), delay_s=delay)
    jpath = str(tmp_path / "shared.jsonl")
    key_a = workload_key(space.m, space.k, space.n, "bfloat16", "wave-a")
    key_b = workload_key(space.m, space.k, space.n, "bfloat16", "wave-b")
    with ProcessExecutor() as ex:
        ex.warm_up(len(states))
        journal_a = TrialJournal(jpath)
        journal_b = TrialJournal(jpath)  # second handle on the same file
        eng_a = MeasureEngine(sl, n_workers=4, executor=ex,
                              journal=journal_a, workload_key=key_a)
        eng_b = MeasureEngine(sl, n_workers=4, executor=ex,
                              journal=journal_b, workload_key=key_b)
        # serial baseline: one single-state wave at a time, warmed lanes
        t0 = time.perf_counter()
        eng_a.measure_wave([states[0]])
        single_wall = time.perf_counter() - t0
        t0 = time.perf_counter()
        wave = eng_a.measure_wave(states[1:])  # 3 fresh states, one wave
        wave_wall = time.perf_counter() - t0
        n = len(states) - 1
        assert wave_wall < n * single_wall, (
            f"no real concurrency: {n}-state wave {wave_wall:.2f}s vs "
            f"{n} x {single_wall:.2f}s serial"
        )
        assert all(o.error is None for o in wave)
        eng_b.measure_wave(states)  # interleaved appends from engine B
        journal_a.close()
        journal_b.close()
    # the shared file holds every row from both engines, all strict JSON
    merged = TrialJournal(jpath)
    jkey_a = f"{key_a}?{sl.measure_fingerprint()}"
    jkey_b = f"{key_b}?{sl.measure_fingerprint()}"
    assert merged.n_trials(jkey_a) == len(states)
    assert merged.n_trials(jkey_b) == len(states)
    with open(jpath) as f:
        raw = f.read()
    assert raw.endswith("\n")
    rows = [_strict_loads(line) for line in raw.splitlines()]
    assert len(rows) == 2 * len(states)
    # sibling visibility without re-opening: reload() merges B's rows
    journal_a2 = TrialJournal(jpath)
    assert journal_a2.get(jkey_b, states[0].key()) is not None


@pytest.mark.slow
def test_gbfs_search_identical_through_process_lanes(tmp_path):
    """End-to-end: the same G-BFS search through process lanes visits the
    same states at the same costs as the simulated engine (values never
    depend on the executor), and journals them identically."""
    space = GemmConfigSpace(128, 128, 128)
    budget = Budget(max_trials=40)

    def run(executor, jpath):
        cost = AnalyticalTPUCost(space, n_repeats=2, noise_sigma=0.1, seed=3)
        journal = TrialJournal(jpath)
        eng = MeasureEngine(
            cost, n_workers=4, executor=executor, journal=journal,
            workload_key=workload_key(space.m, space.k, space.n, "bfloat16", cost.name),
        )
        res = GBFSTuner(space, cost, seed=7).tune(budget, engine=eng)
        journal.close()
        return res

    sim = run(None, str(tmp_path / "sim.jsonl"))
    with ProcessExecutor() as ex:
        ex.warm_up(4)
        proc = run(ex, str(tmp_path / "proc.jsonl"))
    assert [t.state.key() for t in proc.trials] == [t.state.key() for t in sim.trials]
    assert [t.cost for t in proc.trials] == [t.cost for t in sim.trials]
    assert proc.best_cost == sim.best_cost
    assert proc.executor == "process" and sim.executor == "sim"
    j_sim = TrialJournal(str(tmp_path / "sim.jsonl"))
    j_proc = TrialJournal(str(tmp_path / "proc.jsonl"))
    assert len(j_sim) == len(j_proc) == 40


# -- journal: strict JSON, O_APPEND concurrency, reload ------------------------


def _journal_writer(path, wid, n_rows):
    """Child-process body for the concurrent-append stress test."""
    from repro.core.config_space import GemmConfigSpace
    from repro.core.records import TrialJournal

    space = GemmConfigSpace(64, 64, 64)
    stream = itertools.islice(space.enumerate(), n_rows)
    with TrialJournal(path) as j:
        for i, s in enumerate(stream):
            # mix finite and failed costs so both encodings hit the file
            cost = math.inf if i % 7 == 0 else 1e-4 * (wid + 1) * (i + 1)
            j.record(f"gemm/m64k64n64/bfloat16/writer{wid}", s, cost)


@pytest.mark.slow
def test_concurrent_multiprocess_appends_no_torn_rows(tmp_path):
    """N processes hammering one journal path: every row survives,
    nothing interleaves, everything is strict JSON."""
    jpath = str(tmp_path / "stress.jsonl")
    n_procs, n_rows = 4, 120
    ctx = multiprocessing.get_context(
        "forkserver" if "forkserver" in multiprocessing.get_all_start_methods()
        else "spawn"
    )
    procs = [
        ctx.Process(target=_journal_writer, args=(jpath, wid, n_rows))
        for wid in range(n_procs)
    ]
    for p in procs:
        p.start()
    for p in procs:
        p.join(timeout=120)
        assert p.exitcode == 0
    with open(jpath) as f:
        raw = f.read()
    lines = raw.splitlines()
    assert len(lines) == n_procs * n_rows
    rows = [_strict_loads(line) for line in lines]
    by_writer = {}
    for row in rows:
        by_writer.setdefault(row["w"], set()).add(row["k"])
    assert all(len(keys) == n_rows for keys in by_writer.values())
    j = TrialJournal(jpath)
    assert len(j) == n_procs * n_rows
    assert len(list(j.workloads())) == n_procs
    assert all(j.n_trials(w) == n_rows for w in j.workloads())


def test_journal_inf_costs_are_strict_json(tmp_path):
    jpath = str(tmp_path / "inf.jsonl")
    space = GemmConfigSpace(4096, 4096, 4096)
    bad = TilingState((1, 1, 1, 4096), (1, 4096), (1, 4096, 1, 1))
    good = space.initial_state()
    wkey = "gemm/m4096k4096n4096/bfloat16/analytical_tpu_v5e"
    with TrialJournal(jpath) as j:
        j.record(wkey, bad, math.inf)
        j.record(wkey, good, 3.25e-3)
    with open(jpath) as f:
        rows = [_strict_loads(line) for line in f.read().splitlines()]
    assert rows[0]["c"] is None and rows[0]["fail"] is True
    assert rows[1]["c"] == 3.25e-3 and "fail" not in rows[1]
    j2 = TrialJournal(jpath)
    assert math.isinf(j2.get(wkey, bad.key()))
    assert j2.get(wkey, good.key()) == 3.25e-3
    # inf rows never become the warm-start best
    best = j2.best_state(wkey)
    assert best is not None and best[0].key() == good.key()


def test_journal_reads_legacy_infinity_rows(tmp_path):
    """Rows written by the pre-strict format (bare Infinity literal)
    still load."""
    jpath = str(tmp_path / "legacy.jsonl")
    with open(jpath, "w") as f:
        f.write('{"w": "wk", "k": "64,1,1,1|64,1|64,1,1,1", '
                '"s": [[64,1,1,1],[64,1],[64,1,1,1]], "c": Infinity}\n')
        f.write('{"w": "wk", "k": "32,2,1,1|64,1|64,1,1,1", '
                '"s": [[32,2,1,1],[64,1],[64,1,1,1]], "c": 0.5}\n')
    j = TrialJournal(jpath)
    assert math.isinf(j.get("wk", "64,1,1,1|64,1|64,1,1,1"))
    assert j.get("wk", "32,2,1,1|64,1|64,1,1,1") == 0.5


def test_journal_reload_merges_sibling_rows_and_skips_torn_tail(tmp_path):
    jpath = str(tmp_path / "j.jsonl")
    space = GemmConfigSpace(64, 64, 64)
    s0, s1 = list(itertools.islice(space.enumerate(), 2))
    j_writer = TrialJournal(jpath)
    j_reader = TrialJournal(jpath)
    j_writer.record("w", s0, 1.5)
    assert j_reader.get("w", s0.key()) is None  # not yet merged
    assert j_reader.reload() == 1
    assert j_reader.get("w", s0.key()) == 1.5
    assert j_writer.reload() == 0  # own rows dedup to nothing new
    # a torn tail (no newline) is not consumed ...
    with open(jpath, "a") as f:
        f.write('{"w":"w","k":"')
    assert j_reader.reload() == 0
    # ... until a surviving writer completes the line
    with open(jpath, "a") as f:
        f.write(f'{s1.key()}","s":{json.dumps(s1.as_lists())},"c":2.5}}\n')
    assert j_reader.reload() == 1
    assert j_reader.get("w", s1.key()) == 2.5
    j_writer.close()
    j_reader.close()


def test_journal_context_manager_closes_and_reopens(tmp_path):
    jpath = str(tmp_path / "cm.jsonl")
    space = GemmConfigSpace(64, 64, 64)
    s0, s1 = list(itertools.islice(space.enumerate(), 2))
    with TrialJournal(jpath) as j:
        j.record("w", s0, 1.0)
    assert j._fd is None  # handle released on exit
    j.record("w", s1, 2.0)  # lazily reopens
    j.close()
    assert len(TrialJournal(jpath)) == 2

"""XLATimedCost hot path: persistent executable cache (memory LRU +
on-disk layer), batch dedup, process-shippable worker spec, and the
compile-stat attribution the engine folds into MeasureStats."""

import math

import pytest

from repro.core import (
    GemmConfigSpace,
    MeasureEngine,
    ProcessExecutor,
)
from repro.core.cost.base import backend_from_spec
from repro.core.cost.measured import ExecutableCache, XLATimedCost


@pytest.fixture(scope="module")
def space():
    return GemmConfigSpace(64, 64, 64)


@pytest.fixture(scope="module")
def states(space):
    return [space.initial_state()] + space.neighbors(space.initial_state())[:2]


def test_worker_spec_refused_with_extra_constraint():
    guarded = GemmConfigSpace(64, 64, 64, extra_constraint=lambda s: True)
    assert XLATimedCost(guarded, n_repeats=1).worker_spec() is None


def test_content_key_covers_dims_dtype_state_and_version(space):
    s = space.initial_state()
    k1 = ExecutableCache.content_key(space, "float32", s)
    assert k1 == ExecutableCache.content_key(space, "float32", s)  # stable
    assert k1 != ExecutableCache.content_key(space, "float64", s)
    other = GemmConfigSpace(128, 128, 128)
    assert k1 != ExecutableCache.content_key(other, "float32", other.initial_state())


@pytest.mark.slow
def test_worker_spec_round_trip(tmp_path, space, states):
    cost = XLATimedCost(space, n_repeats=1, seed=4,
                        cache_dir=str(tmp_path / "xc"))
    spec = cost.worker_spec()
    assert spec is not None
    rebuilt = backend_from_spec(spec)
    assert rebuilt.measure_fingerprint() == cost.measure_fingerprint()
    assert rebuilt.cache.cache_dir == cost.cache.cache_dir
    # every worker rebuilt from the spec shares one timing-gate lock file
    assert rebuilt.timing_lock_path == cost.timing_lock_path
    c = rebuilt.cost(states[0])
    assert 0 < c < 10


@pytest.mark.slow
def test_batch_cost_times_each_unique_state_once(space, states):
    cost = XLATimedCost(space, n_repeats=1)
    s0, s1 = states[0], states[1]
    out = cost.batch_cost([s0, s1, s0, s0])
    stats = cost.compile_stats()
    assert stats["compiles"] == 2  # two unique states, two builds
    assert stats["n_timed"] == 2  # duplicates fanned out, never re-timed
    assert out[0] == out[2] == out[3]
    assert all(map(math.isfinite, out))


@pytest.mark.slow
def test_persistent_cache_warm_restart_zero_compiles(tmp_path, space, states):
    """A second 'session' (fresh backend, same cache dir) is served
    entirely by the on-disk layer — cold-start compilation is paid once
    ever, not once per session — and the engine attributes it."""
    cdir = str(tmp_path / "xc")
    eng1 = MeasureEngine(XLATimedCost(space, n_repeats=1, cache_dir=cdir),
                         n_workers=1)
    for s in states:
        eng1.measure_wave([s])
    assert eng1.stats.n_compiles == len(states)
    assert eng1.stats.compile_cache_hit_rate() == 0.0

    eng2 = MeasureEngine(XLATimedCost(space, n_repeats=1, cache_dir=cdir),
                         n_workers=1)
    out = [eng2.measure_wave([s])[0] for s in states]
    assert eng2.stats.n_compiles == 0
    assert eng2.stats.n_compile_disk_hits == len(states)
    assert eng2.stats.compile_cache_hit_rate() == 1.0
    assert all(math.isfinite(o.cost) and o.cost > 0 for o in out)


@pytest.mark.slow
def test_lru_cap_bounds_memory_and_counts_evictions(space, states):
    """capacity=1 with no disk layer: revisiting an evicted state pays a
    recompile, and the eviction counters expose it."""
    cost = XLATimedCost(space, n_repeats=1, cache_capacity=1)
    s0, s1 = states[0], states[1]
    for s in (s0, s1, s0):
        cost.cost(s)
    stats = cost.compile_stats()
    assert stats["evictions"] >= 2
    assert stats["compiles"] == 3  # s0 recompiled after eviction
    assert len(cost.cache) <= 1


@pytest.mark.slow
def test_lru_eviction_with_disk_layer_rehydrates_without_compile(
    tmp_path, space, states
):
    cost = XLATimedCost(space, n_repeats=1, cache_capacity=1,
                        cache_dir=str(tmp_path / "xc"))
    s0, s1 = states[0], states[1]
    for s in (s0, s1, s0):
        cost.cost(s)
    stats = cost.compile_stats()
    assert stats["compiles"] == 2  # evicted s0 came back from disk
    assert stats["disk_hits"] == 1


@pytest.mark.slow
def test_sim_vs_process_value_parity(tmp_path, space, states):
    """Process lanes time the same programs the in-process path times:
    finite costs for the same states, compile-cache attribution shipped
    back across the process boundary, and the shared disk cache means
    the workers never recompile what the parent already built."""
    cdir = str(tmp_path / "xc")
    sim_cost = XLATimedCost(space, n_repeats=1, cache_dir=cdir)
    sim_eng = MeasureEngine(sim_cost, n_workers=len(states))
    sim_out = sim_eng.measure_wave(states)
    assert all(math.isfinite(o.cost) and o.cost > 0 for o in sim_out)

    proc_cost = XLATimedCost(space, n_repeats=1, cache_dir=cdir)
    with ProcessExecutor() as ex:
        ex.warm_up(2)
        eng = MeasureEngine(proc_cost, n_workers=2, executor=ex)
        proc_out = []
        for i in range(0, len(states), 2):
            proc_out.extend(eng.measure_wave(states[i : i + 2]))
    assert [o.state.key() for o in proc_out] == [o.state.key() for o in sim_out]
    assert all(o.error is None for o in proc_out)
    assert all(math.isfinite(o.cost) and o.cost > 0 for o in proc_out)
    # worker-side compile deltas made it back: all disk hits, no compiles
    assert eng.stats.n_compiles == 0
    assert eng.stats.n_compile_disk_hits == len(states)
    assert eng.stats.compile_cache_hit_rate() == 1.0


def test_vmem_guard_is_inf_without_compiling():
    big = GemmConfigSpace(4096, 4096, 4096)
    cost = XLATimedCost(big, n_repeats=1)
    from repro.core.config_space import TilingState

    bad = TilingState((1, 1, 1, 4096), (1, 4096), (1, 4096, 1, 1))
    assert math.isinf(cost.cost(bad))
    assert cost.compile_stats()["compiles"] == 0

"""Property-based invariants of the operator-agnostic ``SearchSpace``
protocol (hypothesis), run against BOTH registered factored spaces —
the canonical GEMM instance and the flash-attention instance.  Guarded
with ``pytest.importorskip`` so environments without hypothesis skip
cleanly instead of erroring at collection (GEMM-only deterministic
variants live in ``test_config_space.py``)."""

import math
import random

import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core import FlashAttnConfigSpace, GemmConfigSpace
from repro.core.space import SearchSpace, State


@st.composite
def gemm_space(draw):
    em = draw(st.integers(2, 6))
    ek = draw(st.integers(2, 6))
    en = draw(st.integers(2, 6))
    return GemmConfigSpace(2**em, 2**ek, 2**en)


@st.composite
def flash_space(draw):
    eq = draw(st.integers(2, 8))
    ekv = draw(st.integers(2, 8))
    hd = 2 ** draw(st.integers(3, 7))
    causal = draw(st.booleans())
    return FlashAttnConfigSpace(2**eq, 2**ekv, hd, causal=causal)


@st.composite
def space_and_state(draw):
    space = draw(st.one_of(gemm_space(), flash_space()))
    rng = random.Random(draw(st.integers(0, 10_000)))
    return space, space.random_state(rng)


@given(space_and_state())
@settings(max_examples=80, deadline=None)
def test_protocol_surface(pair):
    """Every space speaks the full SearchSpace protocol and its states
    speak the State protocol (the operator-agnostic contract every
    tuner/backend/journal layer programs against)."""
    space, s = pair
    assert isinstance(space, SearchSpace)
    assert isinstance(s, State)
    assert isinstance(space.op, str) and space.op
    assert len(space.depths) == len(space.dim_specs())
    assert space.n_actions == len(space.actions) > 0
    assert space.size() > 0
    # serialization round trip (journal / process-lane format)
    s2 = space.state_from_lists(s.as_lists())
    assert s2 == s and s2.key() == s.key()
    assert space.working_set_bytes(s) > 0


@given(space_and_state())
@settings(max_examples=80, deadline=None)
def test_actions_preserve_dim_products(pair):
    """Eqn. 6 moves keep every dimension row's product exact (the core
    legitimacy invariant), for every op."""
    space, s = pair
    dims = s.dims()
    for a in space.actions:
        s2 = space.step(s, a)
        if s2 is not None:
            assert s2.dims() == dims
            assert space.is_legitimate(s2)


@given(space_and_state())
@settings(max_examples=80, deadline=None)
def test_neighbor_symmetry(pair):
    """Every move has an inverse: s' in g(s) implies s in g(s')."""
    space, s = pair
    for s2 in space.neighbors(s):
        back_keys = {b.key() for b in space.neighbors(s2)}
        assert s.key() in back_keys


@given(space_and_state())
@settings(max_examples=80, deadline=None)
def test_random_state_legitimate_and_features_consistent(pair):
    """random_state lands inside the space and features match
    n_features, finitely, for every op."""
    space, s = pair
    assert space.is_legitimate(s)
    f = space.features(s)
    assert f.shape == (space.n_features,)
    assert all(map(math.isfinite, f.tolist()))


@given(space_and_state(), st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_transplant_into_sibling_space_is_legitimate(pair, seed2):
    """Any state transplants legitimately into any other power-of-two
    space of the SAME op (the warm-start translation)."""
    space, s = pair
    rng = random.Random(seed2)
    if space.op == "gemm":
        dst = GemmConfigSpace(
            2 ** rng.randint(2, 7), 2 ** rng.randint(2, 7), 2 ** rng.randint(2, 7)
        )
    else:
        dst = FlashAttnConfigSpace(
            2 ** rng.randint(2, 9), 2 ** rng.randint(2, 9), 128
        )
    s2 = dst.transplant(s)
    assert s2 is not None
    assert dst.is_legitimate(s2)


@given(space_and_state())
@settings(max_examples=30, deadline=None)
def test_cross_op_transplant_refused(pair):
    """A donor state from another op can never transplant in (the
    warm-start layer's cross-op guard)."""
    space, s = pair
    other = (
        FlashAttnConfigSpace(256, 256, 64)
        if space.op == "gemm"
        else GemmConfigSpace(64, 64, 64)
    )
    assert other.transplant(s) is None


@given(space_and_state(), st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_features_pure_finite_consistent_across_moves(pair, seed2):
    """features() is the learned cost model's input contract
    (``repro.core.learn`` trains cross-shape on exactly these vectors):
    it must be a pure function of the state — identical vector on
    repeated calls, donor unchanged by deriving moves — and stay finite
    and ``n_features``-wide across neighbor moves and transplant into a
    sibling space, for BOTH ops."""
    space, s = pair
    f1, f2 = space.features(s), space.features(s)
    assert f1.shape == (space.n_features,)
    assert (f1 == f2).all()
    assert all(map(math.isfinite, f1.tolist()))
    for s2 in space.neighbors(s)[:4]:
        g = space.features(s2)
        assert g.shape == (space.n_features,)
        assert all(map(math.isfinite, g.tolist()))
        # deriving a neighbor's features must not perturb the donor's
        assert (space.features(s) == f1).all()
    rng = random.Random(seed2)
    if space.op == "gemm":
        dst = GemmConfigSpace(
            2 ** rng.randint(2, 7), 2 ** rng.randint(2, 7), 2 ** rng.randint(2, 7)
        )
    else:
        dst = FlashAttnConfigSpace(
            2 ** rng.randint(2, 9), 2 ** rng.randint(2, 9), 128
        )
    st_t = dst.transplant(s)
    assert st_t is not None
    ft = dst.features(st_t)
    # same op + same depths => same feature width: cross-shape corpora
    # (the whole point of the rank model) stay concatenable
    assert dst.n_features == space.n_features
    assert ft.shape == (dst.n_features,)
    assert all(map(math.isfinite, ft.tolist()))


@given(st.one_of(gemm_space(), flash_space()))
@settings(max_examples=20, deadline=None)
def test_enumerate_matches_size_on_small_spaces(space):
    """size() counts exactly what enumerate() yields (no constraint)."""
    if space.size() > 3000:
        return
    states = list(space.enumerate())
    assert len(states) == space.size()
    assert len({s.key() for s in states}) == len(states)

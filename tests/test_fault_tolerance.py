"""Fault-tolerant search: failure taxonomy, retry/backoff lanes, the
deterministic fault-injection harness, worker respawn budgets, and the
journal's failure-provenance rows."""

import json
import math
import time

import pytest

from repro.core import (
    PERMANENT_KINDS,
    TRANSIENT_KINDS,
    AnalyticalTPUCost,
    Budget,
    FaultInjectionCost,
    FaultPlan,
    GBFSTuner,
    GemmConfigSpace,
    MeasureEngine,
    ProcessExecutor,
    RetryPolicy,
    SimulatedExecutor,
    SleepingCost,
    ThreadExecutor,
    TrialJournal,
    classify_error,
    workload_key,
)


@pytest.fixture(scope="module")
def space():
    return GemmConfigSpace(256, 256, 256)


@pytest.fixture(scope="module")
def states(space):
    return [space.initial_state()] + space.neighbors(space.initial_state())[:5]


def _wkey(space):
    return workload_key(space.m, space.k, space.n, "bfloat16",
                        "analytical_tpu_v5e")


# -- taxonomy ------------------------------------------------------------------


def test_taxonomy_is_a_partition():
    assert not (TRANSIENT_KINDS & PERMANENT_KINDS)


def test_classify_legacy_error_strings():
    assert classify_error(None) is None
    assert classify_error("lane timeout after 2.0s") == "timeout"
    assert classify_error("worker died before dispatch") == "spawn"
    assert classify_error("worker crashed (exit 13)") == "crash"
    assert classify_error("ValueError: bad tile") == "raise"


def test_retry_policy_deterministic_backoff():
    p = RetryPolicy(max_attempts=3, backoff_s=0.1, jitter=0.5, seed=7)
    q = RetryPolicy(max_attempts=3, backoff_s=0.1, jitter=0.5, seed=7)
    for attempt in (1, 2, 3):
        d = p.delay_s("some-state", attempt)
        assert d == q.delay_s("some-state", attempt)  # pure function
        assert 0.1 * 2 ** (attempt - 1) <= d <= 0.1 * 2 ** (attempt - 1) * 1.5
    # different states draw different jitter but the same base
    assert p.delay_s("a", 1) != p.delay_s("b", 1)
    assert not RetryPolicy(max_attempts=1).enabled
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)


def test_fault_plan_is_seeded_and_stable(states):
    plan = FaultPlan(seed=3, p_crash=0.2, p_raise=0.2)
    fates = [plan.fault_for(s.key()) for s in states]
    assert fates == [plan.fault_for(s.key()) for s in states]
    # raising p_crash never reshuffles which states take the OTHER kinds
    more = FaultPlan(seed=3, p_crash=0.5, p_raise=0.2)
    for s in states:
        if plan.fault_for(s.key()) == "raise":
            assert more.fault_for(s.key()) in ("raise", "crash")
    assert FaultPlan(seed=3).fault_for(states[0].key()) is None  # all p=0


def test_fault_injection_fire_budget(space, states, tmp_path):
    inner = AnalyticalTPUCost(space)
    s = states[0]
    plan = FaultPlan(seed=0, p_corrupt=1.0, fires=1)
    cost = FaultInjectionCost(inner, plan, fault_dir=str(tmp_path / "f1"))
    assert cost.cost(s) == -1.0  # first attempt: the planned fault
    assert cost.cost(s) == inner.cost(s)  # budget spent: clean
    always = FaultInjectionCost(
        inner, FaultPlan(seed=0, p_corrupt=1.0, fires=-1),
        fault_dir=str(tmp_path / "f2"),
    )
    assert always.cost(s) == -1.0
    assert always.cost(s) == -1.0
    never = FaultInjectionCost(
        inner, FaultPlan(seed=0, p_corrupt=1.0, fires=0),
        fault_dir=str(tmp_path / "f3"),
    )
    assert never.cost(s) == inner.cost(s)


def test_fault_injection_permanent_raise_every_attempt(space, states, tmp_path):
    plan = FaultPlan(seed=0, p_raise=1.0, fires=1)
    cost = FaultInjectionCost(
        AnalyticalTPUCost(space), plan, fault_dir=str(tmp_path)
    )
    for _ in range(3):  # permanent: fires-budget does not apply
        with pytest.raises(RuntimeError, match="injected permanent"):
            cost.cost(states[0])


def test_fault_injection_spec_round_trip(space, tmp_path):
    from repro.core.cost.base import backend_from_spec

    cost = FaultInjectionCost(
        AnalyticalTPUCost(space),
        FaultPlan(seed=2, p_corrupt=1.0, fires=0),
        fault_dir=str(tmp_path),
        delay_s=0.0,
    )
    rebuilt = backend_from_spec(cost.worker_spec())
    s = space.initial_state()
    assert rebuilt.cost(s) == cost.cost(s)
    assert rebuilt.plan == cost.plan
    assert rebuilt.measure_fingerprint() == cost.measure_fingerprint()


# -- engine retry loop (simulated lanes, corrupt = the in-process-safe
# transient: crash would kill the test runner, hang would stall it) -----------


def test_retry_recovers_every_transient(space, states, tmp_path):
    inner = AnalyticalTPUCost(space)
    faulty = FaultInjectionCost(
        inner, FaultPlan(seed=1, p_corrupt=1.0, fires=1),
        fault_dir=str(tmp_path / "faults"),
    )
    jpath = str(tmp_path / "j.jsonl")
    eng = MeasureEngine(
        faulty, n_workers=3, journal=TrialJournal(jpath),
        workload_key=_wkey(space), retry=RetryPolicy(max_attempts=3, seed=1),
    )
    outs = []
    for i in range(0, len(states), 3):
        outs.extend(eng.measure_wave(states[i : i + 3]))
    # zero inf surfaced to the tuner: every transient was retried to success
    assert all(math.isfinite(o.cost) for o in outs)
    assert {o.state.key(): o.cost for o in outs} == {
        s.key(): inner.cost(s) for s in states
    }  # same costs as a fault-free run
    assert eng.stats.n_retries == len(states)
    assert eng.stats.n_transient_recovered == len(states)
    assert eng.stats.retry_backoff_s > 0
    assert eng.stats.n_failed_transient == 0
    recovered = [o for o in outs if o.attempts > 1]
    assert len(recovered) == len(states)
    # the backoff was charged to the lane occupancy (and so to the clock)
    assert all(o.lane_s > eng.lane_time(o.cost) for o in recovered)
    # journal: only clean costs, zero transient rows in the cost table
    j2 = TrialJournal(jpath)
    for s in states:
        assert j2.get(f"{_wkey(space)}?{faulty.measure_fingerprint()}",
                      s.key(), op="gemm") == pytest.approx(inner.cost(s))


def test_retry_exhaustion_reports_failed_transient(space, states, tmp_path):
    faulty = FaultInjectionCost(
        AnalyticalTPUCost(space),
        FaultPlan(seed=1, p_corrupt=1.0, fires=-1),  # every attempt faults
        fault_dir=str(tmp_path / "faults"),
    )
    jpath = str(tmp_path / "j.jsonl")
    jkey = f"{_wkey(space)}?{faulty.measure_fingerprint()}"
    eng = MeasureEngine(
        faulty, n_workers=2, journal=TrialJournal(jpath),
        workload_key=_wkey(space), retry=RetryPolicy(max_attempts=2, seed=0),
    )
    outs = eng.measure_wave(states[:2])
    assert all(math.isinf(o.cost) for o in outs)
    # exhausted transients are REPORTED as such, distinct from infeasible
    assert all(o.failed_transient for o in outs)
    assert all(o.kind == "corrupt" for o in outs)
    assert all(o.attempts == 2 for o in outs)
    assert eng.stats.n_failed_transient == 2
    assert eng.stats.n_failures == 2
    # provenance rows exist on disk but must NEVER serve as cache hits
    rows = [json.loads(l) for l in open(jpath)]
    assert [r["kind"] for r in rows] == ["corrupt", "corrupt"]
    assert all(r["c"] is None and r["fail"] for r in rows)
    assert all(r["attempts"] == 2 for r in rows)
    j2 = TrialJournal(jpath)
    assert j2.get(jkey, states[0].key(), op="gemm") is None
    eng2 = MeasureEngine(
        faulty, n_workers=2, journal=j2, workload_key=_wkey(space),
        retry=RetryPolicy(max_attempts=2, seed=0),
    )
    eng2.measure_wave(states[:2])
    assert eng2.stats.n_cache_hits == 0  # re-dispatched, not served


def test_corrupt_value_is_never_journaled_without_retry(space, states, tmp_path):
    """Historical contract: without a RetryPolicy, executor-level failures
    are counted but never journaled — and a corrupt (negative) cost must
    not crash the strict-JSON journal or poison the cost table."""
    faulty = FaultInjectionCost(
        AnalyticalTPUCost(space),
        FaultPlan(seed=1, p_corrupt=1.0, fires=-1),
        fault_dir=str(tmp_path / "faults"),
    )
    jpath = str(tmp_path / "j.jsonl")
    eng = MeasureEngine(
        faulty, n_workers=1, journal=TrialJournal(jpath),
        workload_key=_wkey(space),
    )
    (o,) = eng.measure_wave(states[:1])
    assert math.isinf(o.cost) and o.kind == "corrupt"
    assert o.attempts == 1 and o.failed_transient
    assert eng.stats.n_failures == 1
    import os

    assert not os.path.exists(jpath) or open(jpath).read() == ""


def test_permanent_raise_is_cached_not_retried(space, states, tmp_path):
    """A deterministic raise is a property of the schedule: one attempt,
    journaled as a cacheable inf row with kind='raise'."""
    faulty = FaultInjectionCost(
        AnalyticalTPUCost(space),
        FaultPlan(seed=1, p_raise=1.0),
        fault_dir=str(tmp_path / "faults"),
    )
    jpath = str(tmp_path / "j.jsonl")
    jkey = f"{_wkey(space)}?{faulty.measure_fingerprint()}"
    eng = MeasureEngine(
        faulty, n_workers=1, journal=TrialJournal(jpath),
        workload_key=_wkey(space), retry=RetryPolicy(max_attempts=3, seed=0),
    )
    (o,) = eng.measure_wave(states[:1])
    assert math.isinf(o.cost)
    assert o.kind == "raise" and o.attempts == 1 and not o.failed_transient
    assert eng.stats.n_retries == 0
    (row,) = [json.loads(l) for l in open(jpath)]
    assert row["kind"] == "raise" and row["c"] is None
    # permanent failures ARE cache hits for future sessions
    j2 = TrialJournal(jpath)
    assert math.isinf(j2.get(jkey, states[0].key(), op="gemm"))


def test_legacy_fail_rows_load_as_build_kind(space, states, tmp_path):
    """Pre-taxonomy fail rows (no 'kind' field) must keep serving as
    cacheable failed builds."""
    jpath = str(tmp_path / "j.jsonl")
    jkey = _wkey(space)
    with open(jpath, "w") as f:
        f.write(json.dumps({
            "w": jkey, "k": states[0].key(), "s": states[0].as_lists(),
            "op": "gemm", "c": None, "fail": True,
        }) + "\n")
    j = TrialJournal(jpath)
    assert math.isinf(j.get(jkey, states[0].key(), op="gemm"))


def test_retried_run_matches_fault_free_journal(space, states, tmp_path):
    """Same seed, faults on vs off: with retry enabled the surviving
    journal cost tables are identical — fault recovery is invisible to
    the search."""
    inner = AnalyticalTPUCost(space)
    wkey = _wkey(space)

    def run(faulted: bool, tag: str) -> dict:
        backend = (
            FaultInjectionCost(
                inner, FaultPlan(seed=5, p_corrupt=0.4, fires=1),
                fault_dir=str(tmp_path / f"faults-{tag}"),
            )
            if faulted
            else inner
        )
        jpath = str(tmp_path / f"j-{tag}.jsonl")
        eng = MeasureEngine(
            backend, n_workers=2, journal=TrialJournal(jpath),
            workload_key=wkey, retry=RetryPolicy(max_attempts=3, seed=0),
        )
        tuner = GBFSTuner(space, backend, seed=4)
        res = tuner.tune(Budget(max_trials=24), engine=eng)
        rows = [json.loads(l) for l in open(jpath)]
        return {
            "best_key": res.best_state.key(),
            "best_cost": res.best_cost,
            "trial_keys": [t.state.key() for t in res.trials],
            "costs": {r["k"]: r["c"] for r in rows if r.get("c") is not None},
        }

    clean = run(False, "clean")
    faulted = run(True, "faulted")
    assert faulted["best_key"] == clean["best_key"]
    assert faulted["best_cost"] == clean["best_cost"]
    assert faulted["trial_keys"] == clean["trial_keys"]
    # fingerprints differ (faulty(...) wrapper name) but the measured
    # cost tables are identical state-for-state
    assert faulted["costs"] == clean["costs"]


def test_retry_determinism_same_plan_same_journal(space, tmp_path):
    """Satellite: two runs with the same seeded FaultPlan and seed produce
    the same journal contents and the same best state."""
    inner = AnalyticalTPUCost(space)
    wkey = _wkey(space)

    def run(tag: str):
        backend = FaultInjectionCost(
            inner, FaultPlan(seed=9, p_corrupt=0.3, fires=1),
            fault_dir=str(tmp_path / f"faults-{tag}"),  # fresh fire counters
        )
        jpath = str(tmp_path / f"j-{tag}.jsonl")
        eng = MeasureEngine(
            backend, n_workers=3, journal=TrialJournal(jpath),
            workload_key=wkey, retry=RetryPolicy(max_attempts=3, seed=2),
        )
        res = GBFSTuner(space, backend, seed=11).tune(
            Budget(max_trials=20), engine=eng
        )
        rows = [json.loads(l) for l in open(jpath)]
        return res, rows, eng.stats

    r1, rows1, st1 = run("one")
    r2, rows2, st2 = run("two")
    assert r1.best_state.key() == r2.best_state.key()
    assert r1.best_cost == r2.best_cost
    assert r1.clock_s == r2.clock_s  # deterministic backoff charges
    assert rows1 == rows2  # byte-identical journal contents
    assert st1.n_retries == st2.n_retries


# -- straggler detection -------------------------------------------------------


@pytest.mark.slow
def test_straggler_detection(space, tmp_path):
    plan = FaultPlan(seed=13, p_outlier=0.2, outlier_s=0.6, fires=1)
    pool, outlier = [], None
    for s in [space.initial_state()] + space.neighbors(space.initial_state()):
        fate = plan.fault_for(s.key())
        if fate == "outlier" and outlier is None:
            outlier = s
        elif fate is None and len(pool) < 2:
            pool.append(s)
    if outlier is None:
        pytest.skip("no outlier state in the sampled neighborhood")
    backend = FaultInjectionCost(
        SleepingCost(AnalyticalTPUCost(space), delay_s=0.01), plan,
        fault_dir=str(tmp_path), delay_s=0.0,
    )
    with ThreadExecutor(timeout_s=30.0) as ex:
        eng = MeasureEngine(backend, n_workers=3, executor=ex)
        outs = eng.measure_wave(pool + [outlier])
    assert all(math.isfinite(o.cost) for o in outs)
    assert eng.stats.n_stragglers >= 1


# -- process lanes: crash recovery, respawn budget, degradation ---------------


@pytest.mark.slow
def test_process_retry_recovers_worker_crash(space, states, tmp_path):
    """A seeded crash kills the worker process; the respawned lane's
    retry measures the same state cleanly — zero inf surfaced."""
    inner = AnalyticalTPUCost(space)
    faulty = FaultInjectionCost(
        inner, FaultPlan(seed=1, p_crash=1.0, fires=1),
        fault_dir=str(tmp_path / "faults"),
    )
    with ProcessExecutor(timeout_s=30.0) as ex:
        eng = MeasureEngine(
            faulty, n_workers=2, executor=ex,
            journal=TrialJournal(str(tmp_path / "j.jsonl")),
            workload_key=_wkey(space),
            retry=RetryPolicy(max_attempts=3, backoff_s=0.01, seed=0),
        )
        outs = eng.measure_wave(states[:2])
        assert all(math.isfinite(o.cost) for o in outs)
        assert {o.state.key(): o.cost for o in outs} == {
            s.key(): inner.cost(s) for s in states[:2]
        }
        assert eng.stats.n_transient_recovered == 2
        assert eng.stats.n_respawns >= 1


@pytest.mark.slow
def test_process_respawn_budget_degrades_to_thread(space, tmp_path):
    """A lane whose worker keeps dying exhausts its respawn budget and
    degrades to in-thread measurement for the rest of the run."""
    crash_states = [space.initial_state()] + space.neighbors(
        space.initial_state()
    )[:1]
    clean_state = space.neighbors(space.initial_state())[2]
    backend = SleepingCost(
        AnalyticalTPUCost(space), delay_s=0.0,
        exit_keys=[s.key() for s in crash_states],
    )
    with ProcessExecutor(timeout_s=30.0, max_respawns=1,
                         respawn_backoff_s=0.01) as ex:
        eng = MeasureEngine(backend, n_workers=1, executor=ex)
        for s in crash_states:  # two deaths on lane 0: budget (1) exhausted
            (o,) = eng.measure_wave([s])
            assert math.isinf(o.cost) and o.kind == "crash"
        # degraded lane still measures — in-thread, same values
        (o,) = eng.measure_wave([clean_state])
        assert o.cost == AnalyticalTPUCost(space).cost(clean_state)
        fs = ex.fault_stats()
        assert fs["n_degraded_lanes"] == 1
        assert fs["n_respawns"] >= 1
        assert eng.stats.n_degraded_lanes == 1


@pytest.mark.slow
def test_process_hot_spare_adoption(space, tmp_path):
    """``warm_up(n + spares, backend=...)`` parks pre-built spare
    workers; a lane whose worker dies adopts one instead of paying a
    cold interpreter start-up (and the adoption is counted)."""
    crash_state = space.initial_state()
    clean_state = space.neighbors(space.initial_state())[0]
    backend = SleepingCost(
        AnalyticalTPUCost(space), delay_s=0.0,
        exit_keys=[crash_state.key()],
    )
    with ProcessExecutor(timeout_s=30.0) as ex:
        ex.warm_up(2, backend=backend)  # one lane wide + one hot spare
        eng = MeasureEngine(backend, n_workers=1, executor=ex)
        (o,) = eng.measure_wave([crash_state])
        assert math.isinf(o.cost) and o.kind == "crash"
        t0 = time.perf_counter()
        (o,) = eng.measure_wave([clean_state])
        adoption_wall = time.perf_counter() - t0
        assert o.cost == AnalyticalTPUCost(space).cost(clean_state)
        fs = ex.fault_stats()
        assert fs["n_spare_adoptions"] == 1
        assert fs["n_respawns"] == 1  # the death is still charged
        assert fs["n_degraded_lanes"] == 0
        assert eng.stats.n_spare_adoptions == 1
        # the adopted worker was prewarmed: no interpreter start-up or
        # backend build inside the wave (a cold spawn takes seconds)
        assert adoption_wall < 2.0


@pytest.mark.slow
def test_process_retry_determinism(space, states, tmp_path):
    """Satellite: the same seeded FaultPlan over process lanes yields the
    same journal cost table and best state across two runs."""
    inner = AnalyticalTPUCost(space)
    wkey = _wkey(space)

    def run(tag: str):
        backend = FaultInjectionCost(
            inner, FaultPlan(seed=21, p_crash=0.3, fires=1),
            fault_dir=str(tmp_path / f"faults-{tag}"),
        )
        jpath = str(tmp_path / f"j-{tag}.jsonl")
        with ProcessExecutor(timeout_s=30.0) as ex:
            eng = MeasureEngine(
                backend, n_workers=2, executor=ex,
                journal=TrialJournal(jpath), workload_key=wkey,
                retry=RetryPolicy(max_attempts=3, backoff_s=0.01, seed=0),
            )
            outs = []
            for i in range(0, len(states), 2):
                outs.extend(eng.measure_wave(states[i : i + 2]))
        rows = [json.loads(l) for l in open(jpath)]
        costs = {r["k"]: r["c"] for r in rows if r.get("c") is not None}
        return {o.state.key(): o.cost for o in outs}, costs

    outs1, costs1 = run("one")
    outs2, costs2 = run("two")
    assert outs1 == outs2
    assert costs1 == costs2
    assert all(math.isfinite(c) for c in outs1.values())

"""Per-arch smoke tests (reduced configs, CPU) + decode/forward
consistency + SSD correctness.  Covers all 10 assigned architectures."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
pytest.importorskip("hypothesis")  # optional dev dep; skip, don't error
from hypothesis import given, settings, strategies as st

from repro.configs.registry import ARCHS, get_arch
from repro.models.api import Model
from repro.models.mamba2 import ssd_chunked, ssd_reference

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.frontend != "none":
        batch["frontend_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_frontend_tokens, cfg.d_model)) * 0.05,
            jnp.float32,
        )
    if cfg.family == "encdec":
        batch["enc_frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_len, cfg.d_model)) * 0.05,
            jnp.float32,
        )
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_loss(arch):
    """Reduced same-family config: one forward + loss, shape and
    finiteness checks (assignment: per-arch smoke test)."""
    cfg = get_arch(arch).reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = model.logits(params, batch)
    n_front = cfg.n_frontend_tokens if cfg.frontend != "none" else 0
    assert logits.shape == (2, 32 + n_front, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    assert float(metrics["tokens"]) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    """One real optimizer step on the reduced config: loss finite, params
    change, no NaNs anywhere."""
    from repro.optim import make_optimizer
    from repro.train.step import make_train_step

    cfg = get_arch(arch).reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = make_optimizer(cfg.optimizer, 1e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    new_params, new_state, metrics = step(params, opt_state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    # params must actually move
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        params, new_params,
    )
    assert max(jax.tree_util.tree_leaves(diffs)) > 0
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert not bool(jnp.isnan(leaf).any())


DECODE_ARCHS = ["yi-6b", "qwen2-72b", "qwen3-moe-235b-a22b", "mamba2-130m",
                "zamba2-1.2b", "whisper-tiny", "grok-1-314b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_shapes(arch):
    cfg = get_arch(arch).reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = {"tokens": jnp.arange(b * s, dtype=jnp.int32).reshape(b, s) % 50}
    if cfg.family == "encdec":
        batch["enc_frames"] = jnp.ones((b, cfg.encoder_len, cfg.d_model), jnp.float32) * 0.01
    logits, cache = model.prefill(params, batch, max_len=s + 4)
    assert logits.shape == (b, 1, cfg.padded_vocab)
    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1).astype(jnp.int32)[:, None]
    logits2, cache2 = model.decode_step(params, cache, tok)
    assert logits2.shape == (b, 1, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits2).any())
    assert int(cache2["len"]) == int(cache["len"]) + 1


@pytest.mark.parametrize("arch", ["yi-6b", "mamba2-130m", "zamba2-1.2b"])
def test_decode_matches_teacher_forcing(arch):
    """Token-by-token decode must reproduce the full-sequence forward
    logits (the KV-cache / recurrent-state correctness invariant)."""
    cfg = get_arch(arch).reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    b, s = 1, 8
    toks = (jnp.arange(b * s).reshape(b, s) % 50).astype(jnp.int32)
    full_logits, _ = model.logits(params, {"tokens": toks})
    pre_logits, cache = model.prefill(params, {"tokens": toks[:, :4]}, max_len=s)
    errs = [float(jnp.max(jnp.abs(pre_logits[:, 0] - full_logits[:, 3])))]
    for i in range(4, s):
        lg, cache = model.decode_step(params, cache, toks[:, i : i + 1])
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full_logits[:, i]))))
    assert max(errs) < 5e-3, errs


# -----------------------------------------------------------------------------
# SSD core
# -----------------------------------------------------------------------------


@given(
    b=st.integers(1, 2),
    nchunks=st.integers(1, 4),
    h=st.integers(1, 4),
    p=st.sampled_from([4, 8]),
    n=st.sampled_from([4, 16]),
    seed=st.integers(0, 1000),
)
@settings(max_examples=15, deadline=None)
def test_ssd_chunked_equals_recurrence(b, nchunks, h, p, n, seed):
    """Property: the chunked SSD algorithm == naive recurrence for any
    shape (state-space duality, Mamba2 paper Sec. 5)."""
    rng = np.random.default_rng(seed)
    l = 8 * nchunks
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.2, (b, l, h)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.2, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, l, h, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, l, h, n)), jnp.float32)
    y_ref = ssd_reference(x, dt, A, B, C)
    y = ssd_chunked(x, dt, A, B, C, chunk=8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)


def test_ssd_final_state_continues_correctly():
    """Prefill state handoff: running chunked on [0:L] then stepping the
    recurrence one token must equal running the recurrence on [0:L+1]."""
    rng = np.random.default_rng(3)
    b, l, h, p, n = 1, 16, 2, 4, 8
    mk = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    x, B, C = mk(b, l + 1, h, p), mk(b, l + 1, h, n), mk(b, l + 1, h, n)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, l + 1, h)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.2, 1.0, (h,)), jnp.float32)
    _, state = ssd_chunked(x[:, :l], dt[:, :l], A, B[:, :l], C[:, :l], 8, return_state=True)
    dA = jnp.exp(dt[:, l] * A)
    state2 = state * dA[..., None, None] + jnp.einsum(
        "bhn,bhp,bh->bhpn", B[:, l], x[:, l], dt[:, l]
    )
    y_step = jnp.einsum("bhpn,bhn->bhp", state2, C[:, l])
    y_full = ssd_reference(x, dt, A, B, C)[:, l]
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full), rtol=1e-4, atol=1e-4)


# -----------------------------------------------------------------------------
# MoE routing
# -----------------------------------------------------------------------------


def test_moe_aux_and_dispatch():
    from repro.models.transformer import init_moe, moe_apply

    cfg = get_arch("qwen3-moe-235b-a22b").reduced()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16, cfg.d_model)) * 0.1, jnp.float32)
    out, aux = moe_apply(cfg, p, x)
    assert out.shape == x.shape
    assert np.isfinite(float(aux)) and float(aux) >= 0
    assert not bool(jnp.isnan(out).any())


def test_moe_matches_dense_expert_sum_with_ample_capacity():
    """With capacity >= tokens, sorted dispatch == explicit per-token
    expert evaluation."""

    from repro.models import common as cm
    from repro.models.transformer import init_moe, moe_apply

    cfg = get_arch("qwen3-moe-235b-a22b").reduced(moe_capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 8, cfg.d_model)) * 0.3, jnp.float32)
    out, _ = moe_apply(cfg, p, x)

    # explicit reference
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    top_w, top_e = jax.lax.top_k(probs, cfg.experts_per_token)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    ref = np.zeros_like(np.asarray(xf))
    for t in range(xf.shape[0]):
        for j in range(cfg.experts_per_token):
            e = int(top_e[t, j])
            hid = cm.mlp_act(
                cfg.mlp_kind, np.asarray(xf[t] @ p["wi"][e]), np.asarray(xf[t] @ p["wg"][e])
            )
            ref[t] += float(top_w[t, j]) * np.asarray(hid @ p["wo"][e])
    np.testing.assert_allclose(np.asarray(out).reshape(-1, cfg.d_model), ref, rtol=2e-3, atol=2e-3)


def test_param_counts_full_configs():
    """n_params() sanity vs the published sizes (loose bands)."""
    expect = {
        "qwen2-72b": (65e9, 85e9),
        "yi-6b": (5e9, 7e9),
        "deepseek-67b": (60e9, 72e9),
        "grok-1-314b": (280e9, 340e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "zamba2-1.2b": (0.9e9, 1.6e9),
        "nemotron-4-15b": (12e9, 18e9),
        "llava-next-34b": (30e9, 40e9),
        "whisper-tiny": (2e7, 9e7),
    }
    for name, (lo, hi) in expect.items():
        n = ARCHS[name].n_params()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]B"

"""Pallas GEMM kernel vs the pure-jnp oracle: shape/dtype sweep +
property-based block configs + differentiability (interpret mode)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
pytest.importorskip("hypothesis")  # optional dev dep; skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core.config_space import GemmConfigSpace, TilingState
from repro.kernels import ops
from repro.kernels.gemm import KernelConfig, default_config, gemm_pallas, kernel_config_from_state
from repro.kernels.ref import ref_gemm, ref_gemm_vjp

SHAPES = [
    (64, 64, 64),
    (128, 256, 64),
    (256, 128, 512),
    (8, 1024, 8),
]
CONFIGS = [
    KernelConfig(32, 64, 32),
    KernelConfig(64, 128, 64, sub_m=32, sub_n=32),
    KernelConfig(8, 128, 8),
]


def _rand(shape, dtype, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


@pytest.mark.parametrize("dtype,tol", [("float32", 1e-4), ("bfloat16", 0.05)])
@pytest.mark.parametrize("shape", SHAPES, ids=str)
def test_gemm_matches_ref(shape, dtype, tol):
    m, k, n = shape
    cfg = default_config(m, k, n)
    a = _rand((m, k), dtype)
    b = _rand((k, n), dtype, seed=1)
    out = gemm_pallas(a, b, cfg, interpret=True)
    ref = ref_gemm(a, b)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol * 8,
    )


@pytest.mark.parametrize("cfg", CONFIGS, ids=str)
def test_gemm_explicit_configs(cfg):
    m, k, n = 128, 256, 128
    if m % cfg.block_m or k % cfg.block_k or n % cfg.block_n:
        pytest.skip("not divisible")
    a, b = _rand((m, k), "float32"), _rand((k, n), "float32", 1)
    out = gemm_pallas(a, b, cfg, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_gemm(a, b)), rtol=1e-4, atol=1e-3)


@given(
    em=st.integers(0, 2), ek=st.integers(0, 2), en=st.integers(0, 2),
    seed=st.integers(0, 100),
)
@settings(max_examples=12, deadline=None)
def test_gemm_tuner_state_configs(em, ek, en, seed):
    """Any legitimate tuner state maps to a kernel config that computes
    the right product (the tuner<->kernel contract)."""
    import random

    m, k, n = 64 << em, 64 << ek, 64 << en
    space = GemmConfigSpace(m, k, n)
    s = space.random_state(random.Random(seed))
    try:
        cfg = kernel_config_from_state(s)
    except ValueError:
        return  # config not realizable (e.g. sub-tile doesn't divide)
    # keep interpret-mode runtime sane
    if s.grid[0] * s.grid[1] * s.grid[2] > 64:
        return
    a, b = _rand((m, k), "float32"), _rand((k, n), "float32", 1)
    out = gemm_pallas(a, b, cfg, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_gemm(a, b)), rtol=1e-4, atol=1e-3)


def test_gemm_grad_matches_ref():
    ops.set_kernel_policy(ops.KernelPolicy(use_pallas=True, interpret=True))
    try:
        a, b = _rand((64, 128), "float32"), _rand((128, 64), "float32", 1)
        g = _rand((64, 64), "float32", 2)

        def f(a, b):
            return jnp.sum(ops.gemm(a, b) * g)

        da, db = jax.grad(f, argnums=(0, 1))(a, b)
        da_ref, db_ref = ref_gemm_vjp(a, b, g)
        np.testing.assert_allclose(np.asarray(da), np.asarray(da_ref), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(db), np.asarray(db_ref), rtol=1e-4, atol=1e-4)
    finally:
        ops.set_kernel_policy(ops.KernelPolicy())


def test_gemm_dispatch_fallback():
    """Indivisible shapes fall back to XLA silently."""
    ops.set_kernel_policy(ops.KernelPolicy(use_pallas=True, interpret=True))
    try:
        a, b = _rand((63, 127), "float32"), _rand((127, 65), "float32", 1)
        out = ops.gemm(a, b)
        np.testing.assert_allclose(np.asarray(out), np.asarray(a) @ np.asarray(b), rtol=1e-4, atol=1e-4)
    finally:
        ops.set_kernel_policy(ops.KernelPolicy())


def test_gemm_higher_rank_lhs():
    a, b = _rand((4, 8, 32), "float32"), _rand((32, 16), "float32", 1)
    out = ops.gemm(a, b)
    assert out.shape == (4, 8, 16)
    np.testing.assert_allclose(
        np.asarray(out), np.einsum("abk,kn->abn", np.asarray(a), np.asarray(b)),
        rtol=1e-4, atol=1e-4,
    )


def test_records_dispatch(tmp_path):
    """A tuning record changes which config gemm() picks."""
    from repro.core.records import TuningRecords, set_global_records, workload_key, global_records

    old = global_records()
    try:
        rec = TuningRecords(str(tmp_path / "records.json"))
        s = TilingState((2, 1, 2, 16), (1, 64), (2, 1, 2, 16))
        rec.update(workload_key(64, 64, 64, "float32"), s, 1e-6, "g-bfs", 10)
        set_global_records(rec)
        ops.set_kernel_policy(ops.KernelPolicy(use_pallas=True, interpret=True))
        a, b = _rand((64, 64), "float32"), _rand((64, 64), "float32", 1)
        out = ops.gemm(a, b)
        np.testing.assert_allclose(np.asarray(out), np.asarray(a) @ np.asarray(b), rtol=1e-4, atol=1e-3)
    finally:
        set_global_records(old)
        ops.set_kernel_policy(ops.KernelPolicy())

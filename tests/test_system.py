"""End-to-end behaviour tests for the paper's system: the full
tune -> record -> dispatch -> execute flow, exactly as a user drives it."""

import numpy as np

import jax.numpy as jnp

from repro.core import (
    Budget,
    GemmConfigSpace,
    GemmWorkload,
    TuningRecords,
    TuningSession,
    set_global_records,
    global_records,
    workload_key,
)
from repro.kernels import ops
from repro.kernels.ref import ref_gemm


def test_end_to_end_tune_record_dispatch(tmp_path):
    """TuningSession finds a config, persists it, ops.gemm picks it up,
    the Pallas kernel computes the right answer with it."""
    old = global_records()
    try:
        records = TuningRecords(str(tmp_path / "r.json"))
        session = TuningSession(records, verbose=False)
        wl = GemmWorkload(128, 128, 128, dtype="float32")
        res = session.tune_workload(wl, "g-bfs", Budget(max_fraction=0.05))
        assert res.best_state is not None
        key = workload_key(128, 128, 128, "float32")
        assert records.lookup_state(key) is not None

        # a fresh process would reload the same records file
        records2 = TuningRecords(str(tmp_path / "r.json"))
        assert records2.lookup_state(key).key() == records.lookup_state(key).key()

        set_global_records(records2)
        ops.set_kernel_policy(ops.KernelPolicy(use_pallas=True, interpret=True))
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
        out = ops.gemm(a, b)
        err = float(jnp.max(jnp.abs(out - ref_gemm(a, b))))
        assert err < 1e-3
    finally:
        set_global_records(old)
        ops.set_kernel_policy(ops.KernelPolicy())


def test_session_compare_protocol():
    """Paper-style head-to-head comparison under one budget."""
    session = TuningSession(verbose=False)
    wl = GemmWorkload(64, 64, 64)
    out = session.compare(wl, ["g-bfs", "random"], Budget(max_trials=60), n_seeds=2)
    assert set(out) == {"g-bfs", "random"}
    for results in out.values():
        assert len(results) == 2
        for r in results:
            assert r.n_trials <= 60


def test_records_keep_best(tmp_path):
    records = TuningRecords(str(tmp_path / "r.json"))
    space = GemmConfigSpace(64, 64, 64)
    s1, s2 = space.initial_state(), space.random_state(__import__("random").Random(0))
    key = workload_key(64, 64, 64)
    assert records.update(key, s1, 2.0, "a", 1)
    assert not records.update(key, s2, 3.0, "b", 1)  # worse: rejected
    assert records.update(key, s2, 1.0, "b", 1)  # better: accepted
    assert records.best_cost(key) == 1.0

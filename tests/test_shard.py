"""Deterministic candidate sharding (repro.core.shard): the partition
rule, the CLI spec, done markers, and the elect-and-merge step — plus
the session-level guarantee that a sharded search merges to the same
best as an unsharded one under a cost-independent proposal stream."""

import itertools
import math

import pytest

from repro.core import (
    AnalyticalTPUCost,
    Budget,
    GemmConfigSpace,
    GemmWorkload,
    ShardSpec,
    TrialJournal,
    TuningRecords,
    TuningSession,
    await_markers,
    elect_best,
    parse_shard,
    read_done_markers,
    shard_dir_for,
    shard_of,
    write_done_marker,
)


@pytest.fixture(scope="module")
def space():
    return GemmConfigSpace(256, 256, 256)


# -- the partition rule --------------------------------------------------------

def test_shard_of_is_a_stable_total_partition(space):
    """Every candidate has exactly one owner in [0, n), and the owner is
    a pure function of (workload key, state key, n) — two hosts compute
    it identically with no coordination."""
    keys = [s.key() for s in itertools.islice(space.enumerate(), 64)]
    for n in (2, 3, 5):
        owners = [shard_of("wl-a", k, n) for k in keys]
        assert all(0 <= o < n for o in owners)
        assert owners == [shard_of("wl-a", k, n) for k in keys]  # stable
        assert len(set(owners)) > 1  # not degenerate on a real key set


def test_shard_of_is_seeded_per_workload(space):
    """The workload key is hashed into the digest, so the same state
    keys partition differently for different workloads — no shard is
    systematically starved across an arch."""
    keys = [s.key() for s in itertools.islice(space.enumerate(), 64)]
    pa = [shard_of("wl-a", k, 2) for k in keys]
    pb = [shard_of("wl-b", k, 2) for k in keys]
    assert pa != pb


def test_shard_of_single_shard_owns_all():
    assert shard_of("w", "k", 1) == 0
    assert shard_of("w", "k", 0) == 0


def test_shardspec_validation_and_ownership():
    assert not ShardSpec(0, 1).enabled
    assert ShardSpec(0, 1).owns("w", "anything")
    s = ShardSpec(1, 2)
    assert s.enabled and str(s) == "1/2"
    assert s.owns("w", "k") == (shard_of("w", "k", 2) == 1)
    with pytest.raises(ValueError):
        ShardSpec(2, 2)
    with pytest.raises(ValueError):
        ShardSpec(-1, 2)
    with pytest.raises(ValueError):
        ShardSpec(0, 0)


def test_parse_shard():
    assert parse_shard("0/2") == ShardSpec(0, 2)
    assert parse_shard(" 1/4 ") == ShardSpec(1, 4)
    for bad in ("", "1", "1/", "/2", "a/b", "1:2", "0/2/3"):
        with pytest.raises(ValueError):
            parse_shard(bad)
    with pytest.raises(ValueError):
        parse_shard("2/2")  # range error surfaces from the dataclass


# -- done markers / election ---------------------------------------------------

def test_done_marker_roundtrip(tmp_path):
    root = shard_dir_for(str(tmp_path / "j.jsonl"))
    wkey = "gemm:m256k256n256:bf16:analytical?fp"
    write_done_marker(root, wkey, ShardSpec(0, 2), [[1, 2]], 0.5, 10)
    write_done_marker(root, wkey, ShardSpec(1, 2), None, math.inf, 7)
    markers = read_done_markers(root, wkey, 2)
    assert set(markers) == {0, 1}
    assert markers[0]["best"] == [[1, 2]]
    assert markers[0]["best_cost"] == 0.5
    assert markers[0]["n_measured"] == 10
    # inf encodes as null: the shard finished but found nothing finite
    assert markers[1]["best_cost"] is None and markers[1]["best"] is None
    # a different workload's directory is empty
    assert read_done_markers(root, "other-wl", 2) == {}


def test_await_markers_returns_partial_set_on_timeout(tmp_path):
    root = shard_dir_for(str(tmp_path / "j.jsonl"))
    write_done_marker(root, "w", ShardSpec(0, 2), [[1]], 1.0, 1)
    got = await_markers(root, "w", ShardSpec(0, 2), timeout_s=0.3, poll_s=0.05)
    assert set(got) == {0}  # shard 1 never reported; don't wedge forever


def test_elect_best_lowest_cost_then_lowest_index():
    assert elect_best({}) is None
    assert elect_best({0: {"best": None, "best_cost": None}}) is None
    won = elect_best({
        0: {"best": [[0]], "best_cost": 2.0},
        1: {"best": [[1]], "best_cost": 1.0},
        2: {"best": None, "best_cost": None},
    })
    assert won == (1, [[1]], 1.0)
    # exact tie -> the lower shard index wins, deterministically
    won = elect_best({
        1: {"best": [[1]], "best_cost": 1.0},
        0: {"best": [[0]], "best_cost": 1.0},
    })
    assert won == (0, [[0]], 1.0)


# -- session-level elect-and-merge ---------------------------------------------

def _run_session(tmp_path, wl, shard, budget, seed=11):
    """One shard's worth of a sharded search (or an unsharded reference
    when shard is None) over the shared journal in tmp_path."""
    journal = TrialJournal(str(tmp_path / "shared.journal.jsonl"))
    records = TuningRecords(str(tmp_path / f"records_{shard or 'ref'}.json"))
    session = TuningSession(records, seed=seed, verbose=False, journal=journal)
    try:
        result = session.tune_workload(
            wl, "random", budget, n_workers=4,
            shard=None if shard is None else parse_shard(shard),
            shard_wait_s=0.5,
        )
    finally:
        journal.close()
    return result, records


def test_sharded_session_merges_to_the_single_engine_best(tmp_path):
    """Two sequential shard sessions (0/2 then 1/2) sharing one journal
    split the random tuner's identical proposal stream; after the
    elect-and-merge both records tables carry the same best as an
    unsharded run at the same seed and budget."""
    wl = GemmWorkload(256, 256, 256)
    budget = Budget(max_trials=40)
    ref_dir = tmp_path / "ref"
    ref_dir.mkdir()
    ref, _ = _run_session(ref_dir, wl, None, budget)

    sh_dir = tmp_path / "sharded"
    sh_dir.mkdir()
    # shard 0 runs to completion first: its own marker is written, the
    # sibling's is absent, so it elects over the partial set (warning
    # path); shard 1 then sees both markers and elects the true winner
    _res0, rec0 = _run_session(sh_dir, wl, "0/2", budget)
    _res1, rec1 = _run_session(sh_dir, wl, "1/2", budget)

    wkey = wl.key("analytical_tpu_v5e")
    best1 = rec1.lookup(wkey)
    assert best1 is not None
    assert best1["cost"] == pytest.approx(ref.best_cost)
    assert best1.get("n_shards") == 2
    # the election is deterministic from the markers: rerunning the
    # merge (read + elect) reproduces the recorded winner
    root = shard_dir_for(str(sh_dir / "shared.journal.jsonl"))
    cost = AnalyticalTPUCost(wl.space(), n_repeats=1)
    jkey = f"{wkey}?{cost.measure_fingerprint()}"
    markers = read_done_markers(root, jkey, 2)
    assert set(markers) == {0, 1}
    won = elect_best(markers)
    assert won is not None and won[2] == pytest.approx(best1["cost"])


def test_unsharded_spec_requires_no_journal(tmp_path):
    """shard 0/1 normalizes away entirely — it must work without a
    journal, exactly like today's engine."""
    wl = GemmWorkload(256, 256, 256)
    records = TuningRecords(str(tmp_path / "r.json"))
    session = TuningSession(records, seed=3, verbose=False)
    res = session.tune_workload(
        wl, "random", Budget(max_trials=10), shard=parse_shard("0/1")
    )
    assert res.n_trials == 10


def test_sharded_session_without_journal_is_an_error():
    session = TuningSession(TuningRecords(), verbose=False)
    with pytest.raises(ValueError, match="shared journal"):
        session.tune_workload(
            GemmWorkload(256, 256, 256), "random", Budget(max_trials=4),
            shard=ShardSpec(0, 2),
        )

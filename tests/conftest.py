"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here —
smoke tests and benches must see 1 CPU device; only the dry-run (and the
subprocess-based multi-device tests, which set the env var on their own
child processes) uses 512/8 placeholder devices."""

import os
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(SRC))


@pytest.fixture(scope="session")
def small_space():
    from repro.core import GemmConfigSpace

    # 64^3 with d=(4,2,4): small enough to brute-force (size = C(9,3)*7*C(9,3))
    return GemmConfigSpace(64, 64, 64)


@pytest.fixture(scope="session")
def paper_space():
    from repro.core import GemmConfigSpace

    return GemmConfigSpace(1024, 1024, 1024)

"""Property suite over the static schedule analyzer (ISSUE PR 7,
satellite 3): verdicts are pure functions of ``(state, spec)``,
enumerated legitimate states are never ILLEGAL on in-budget workloads,
and verdicts agree with Pallas interpret-mode compile success on a
sampled grid of both ops.

Hypothesis is a dev-only dependency (CI installs it; the container may
not), so the whole module skips when it is absent."""

import itertools
import random

import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from repro.core import FlashAttnConfigSpace, GemmConfigSpace  # noqa: E402
from repro.core.analysis import ILLEGAL, ScheduleAnalyzer  # noqa: E402

# in-budget workloads: every enumerable state fits the 16 MiB budget
# (flash seq 32768 @ hd 128 would make ALL states vmem_overflow — K/V
# residency alone exceeds the budget — so such workloads are out of
# scope for the never-ILLEGAL property, not a counterexample to it)
_GEMM_DIMS = st.sampled_from([16, 32, 64, 128, 256, 512, 1024])
_FLASH_SEQ = st.sampled_from([64, 128, 256, 512, 1024, 2048, 4096, 8192])
_FLASH_HD = st.sampled_from([32, 64, 128])

_COMMON = dict(
    deadline=None, suppress_health_check=[HealthCheck.too_slow], max_examples=25
)


def _sample_states(space, seed, n=20):
    rng = random.Random(seed)
    return [space.random_state(rng) for _ in range(n)]


@settings(**_COMMON)
@given(m=_GEMM_DIMS, k=_GEMM_DIMS, n=_GEMM_DIMS, seed=st.integers(0, 2**16))
def test_gemm_legitimate_states_never_illegal(m, k, n, seed):
    space = GemmConfigSpace(m, k, n)
    an = ScheduleAnalyzer(space)
    for s in _sample_states(space, seed):
        assert space.is_legitimate(s)
        res = an.analyze(s)
        assert res.verdict != ILLEGAL, (s, res)


@settings(**_COMMON)
@given(seq=_FLASH_SEQ, hd=_FLASH_HD, seed=st.integers(0, 2**16))
def test_flash_legitimate_states_never_illegal(seq, hd, seed):
    space = FlashAttnConfigSpace(seq, seq, hd)
    an = ScheduleAnalyzer(space)
    for s in _sample_states(space, seed):
        assert space.is_legitimate(s)
        res = an.analyze(s)
        assert res.verdict != ILLEGAL, (s, res)


@settings(**_COMMON)
@given(
    m=_GEMM_DIMS, k=_GEMM_DIMS, n=_GEMM_DIMS,
    seed=st.integers(0, 2**16),
    in_bytes=st.sampled_from([1, 2, 4]),
    ratio=st.sampled_from([8.0, 16.0, 64.0]),
)
def test_verdicts_are_pure_functions_of_state_and_spec(m, k, n, seed,
                                                       in_bytes, ratio):
    space = GemmConfigSpace(m, k, n)
    an1 = ScheduleAnalyzer(space, in_bytes=in_bytes, wasteful_padding_ratio=ratio)
    an2 = ScheduleAnalyzer(space, in_bytes=in_bytes, wasteful_padding_ratio=ratio)
    for s in _sample_states(space, seed, n=10):
        r1 = an1.analyze(s)
        # repeated analysis is stable, and an equal-parameter analyzer
        # (fresh cache) derives the identical verdict
        assert an1.analyze(s) == r1
        assert an2.analyze(s) == r1


@settings(**_COMMON)
@given(seq=_FLASH_SEQ, hd=_FLASH_HD)
def test_flash_vmem_component_bound(seq, hd):
    """Every flash schedule's working set is at least its resident K/V
    bytes — the term that makes huge-seq workloads wholly infeasible."""
    space = FlashAttnConfigSpace(seq, seq, hd)
    an = ScheduleAnalyzer(space)
    floor = 2 * seq * hd * an.in_bytes
    for s in itertools.islice(space.enumerate(), 10):
        assert an.vmem_bytes(s) >= floor
        if floor > an.vmem_budget_bytes:
            assert an.analyze(s).reason == "vmem_overflow"

"""End-to-end trainer integration: loss goes down, checkpoint/restart
resumes exactly, failure injection + restart loop works, straggler
watchdog observes steps."""


import numpy as np
import pytest


pytest.importorskip(
    "repro.dist.fault",
    reason="dist fault subsystem (trainer dependency) not present in this tree yet",
)

from repro.configs.registry import get_arch
from repro.data.pipeline import DataPipeline, SyntheticLM
from repro.dist.fault import FailureInjector, StragglerWatchdog, run_with_restarts
from repro.train.trainer import Trainer


def _mk_trainer(tmp_path, arch="yi-6b", injector=None, watchdog=None, seed=0,
                ckpt_every=5):
    cfg = get_arch(arch).reduced()
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, seed=1)
    pipe = DataPipeline(ds, global_batch=8)
    return Trainer(
        cfg,
        pipe,
        ckpt_dir=str(tmp_path / "ckpt"),
        lr=3e-3,
        warmup_steps=5,
        total_steps=100,
        ckpt_every=ckpt_every,
        injector=injector,
        watchdog=watchdog,
        seed=seed,
    )


def test_loss_decreases(tmp_path):
    tr = _mk_trainer(tmp_path)
    log = tr.train(30, resume=False)
    first = np.mean([r["loss"] for r in log[:5]])
    last = np.mean([r["loss"] for r in log[-5:]])
    assert last < first - 0.5, f"no learning: {first:.3f} -> {last:.3f}"


def test_checkpoint_resume_exact(tmp_path):
    """Train 10; train 20-with-restart-at-10 == train 20 straight."""
    tr1 = _mk_trainer(tmp_path, seed=0, ckpt_every=5)
    tr1.train(10, resume=False)
    tr1.ckpt.wait()
    # new trainer object resumes from step 10 and continues to 20
    tr2 = _mk_trainer(tmp_path, seed=0, ckpt_every=5)
    log2 = tr2.train(20, resume=True)
    assert log2[0]["step"] == 11
    # straight run to 20 in a different dir
    tr3 = _mk_trainer(tmp_path / "b", seed=0, ckpt_every=50)
    log3 = tr3.train(20, resume=False)
    l2 = {r["step"]: r["loss"] for r in log2}
    l3 = {r["step"]: r["loss"] for r in log3}
    for s in range(12, 21):
        np.testing.assert_allclose(l2[s], l3[s], rtol=2e-3, atol=2e-3)


def test_failure_injection_and_restart(tmp_path):
    """A ChipFailure at step 12 restarts from the step-10 checkpoint and
    completes — the coordinator-loop contract."""
    attempts = []

    def make_and_run(attempt):
        attempts.append(attempt)
        inj = FailureInjector(fail_at_steps=(12,), max_failures=1) if attempt == 0 else None
        tr = _mk_trainer(tmp_path, injector=inj)
        return tr.train(18, resume=True)

    log = run_with_restarts(make_and_run, max_restarts=2)
    assert attempts == [0, 1]
    assert log[-1]["step"] == 18


def test_watchdog_observes_training(tmp_path):
    wd = StragglerWatchdog(warmup_steps=2)
    tr = _mk_trainer(tmp_path, watchdog=wd)
    tr.train(8, resume=False)
    assert wd.n == 8


def test_metrics_logged_jsonl(tmp_path):
    tr = _mk_trainer(tmp_path)
    tr.log_path = str(tmp_path / "log.jsonl")
    tr.train(5, resume=False)
    lines = open(tr.log_path).read().strip().splitlines()
    assert len(lines) == 5
    import json

    rec = json.loads(lines[-1])
    assert {"step", "loss", "grad_norm", "step_time_s"} <= set(rec)

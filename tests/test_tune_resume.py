"""Crash-safe tune resume: the pytree<->JSON codec, the atomic snapshot
store, per-tuner state_dict round trips, interrupt-and-resume equivalence
(in-process and through the CLI with a real SIGTERM), and done-snapshot
serving."""

import json
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core import (
    AnalyticalTPUCost,
    Budget,
    CountingCost,
    GemmConfigSpace,
    TrialJournal,
    TuneCheckpointer,
    TuneInterrupted,
    TuningRecords,
    TuningSession,
    Workload,
)
from repro.core.snapshot import tree_from_jsonable, tree_to_jsonable
from repro.core.tuners import (
    GBFSTuner,
    GBTTuner,
    GeneticTuner,
    GridTuner,
    NA2CTuner,
    RandomTuner,
    RNNControllerTuner,
)

RESUMABLE_FAST = [GBFSTuner, RandomTuner, GridTuner, GeneticTuner, GBTTuner]
# keep proposal batches small so a 24-trial budget spans several rounds
# (the interrupt must land at a round boundary before exhaustion)
TUNER_KW = {
    GeneticTuner: {"pop": 8, "elite": 4},
    GBTTuner: {"warmup": 6, "batch_size": 4},
}


@pytest.fixture(scope="module")
def space():
    return GemmConfigSpace(256, 256, 256)


# -- pytree <-> JSON codec ----------------------------------------------------


def test_tree_codec_round_trip_exact():
    tree = {
        "params": [
            np.arange(6, dtype=np.float32).reshape(2, 3) / 7.0,
            (np.int32(3), np.bool_(True)),
        ],
        "scalar": np.float32(0.1),
        "empty": [],
    }
    data = json.loads(json.dumps(tree_to_jsonable(tree)))  # survives JSON
    back = tree_from_jsonable(data)
    assert isinstance(back["params"], list)
    assert isinstance(back["params"][1], tuple)  # tuples stay tuples
    np.testing.assert_array_equal(back["params"][0], tree["params"][0])
    assert back["params"][0].dtype == np.float32
    # float32 values survive the float repr round trip bit-identically
    assert back["scalar"] == np.float32(0.1)
    assert back["params"][1][0] == 3 and back["params"][1][1]


def test_tree_codec_leaf_hook():
    got = tree_from_jsonable(
        tree_to_jsonable([np.float32(2.0)]), leaf=lambda a: a * 2
    )
    assert got == [np.float32(4.0)]


# -- the snapshot store -------------------------------------------------------


def test_checkpointer_save_load_gc_clear(tmp_path):
    ck = TuneCheckpointer(str(tmp_path / "state"), keep_n=2)
    assert ck.load("w", "g-bfs") is None
    for step in (1, 2, 3):
        ck.save("w", "g-bfs", {"round": step}, step=step)
    assert ck.latest_step("w", "g-bfs") == 3
    assert ck.load("w", "g-bfs") == {"round": 3}
    wdir = ck._wdir("w", "g-bfs")
    kept = sorted(n for n in os.listdir(wdir) if n.startswith("step_"))
    assert len(kept) == 2  # GC keeps keep_n committed snapshots
    # other (workload, tuner) identities are independent
    ck.save("w", "random", {"round": 9}, step=9)
    assert ck.load("w", "g-bfs") == {"round": 3}
    ck.clear("w", "g-bfs")
    assert ck.load("w", "g-bfs") is None
    assert ck.load("w", "random") == {"round": 9}


def test_checkpointer_uncommitted_snapshot_is_invisible(tmp_path):
    ck = TuneCheckpointer(str(tmp_path / "state"))
    final = ck.save("w", "g-bfs", {"round": 1}, step=1)
    ck.save("w", "g-bfs", {"round": 2}, step=2)
    os.remove(os.path.join(ck._wdir("w", "g-bfs"), "step_00000002", "COMMIT"))
    assert ck.load("w", "g-bfs") == {"round": 1}  # torn publish ignored
    assert os.path.exists(final)


def test_interrupt_flag_is_cooperative(tmp_path):
    ck = TuneCheckpointer(str(tmp_path / "state"))
    assert not ck.interrupted
    ck.request_interrupt()
    assert ck.interrupted


# -- tuner state_dict round trips --------------------------------------------


@pytest.mark.parametrize("tuner_cls", RESUMABLE_FAST, ids=lambda c: c.name)
def test_state_dict_json_round_trip(space, tuner_cls):
    cost = AnalyticalTPUCost(space)
    t = tuner_cls(space, cost, seed=3)
    t.tune(Budget(max_trials=8))
    payload = json.loads(json.dumps(t.state_dict()))
    t2 = tuner_cls(space, cost, seed=3)
    t2.load_state_dict(payload)
    assert t2.rng.getstate() == t.rng.getstate()
    assert t2.state_dict() == json.loads(json.dumps(payload))


def test_state_dict_rejects_foreign_tuner(space):
    cost = AnalyticalTPUCost(space)
    snap = GBFSTuner(space, cost).state_dict()
    with pytest.raises(ValueError, match="belongs to tuner"):
        RandomTuner(space, cost).load_state_dict(snap)


# -- interrupt-and-resume equivalence (in-process) ----------------------------


def _reference(tuner_cls, space, cost, n_trials, **kw):
    res = tuner_cls(space, cost, seed=7, **kw).tune(Budget(max_trials=n_trials))
    return res


def _interrupt_then_resume(tuner_cls, space, cost, n_trials, stop_round, **kw):
    """Run until round ``stop_round``, snapshot there, resume a FRESH
    tuner from the JSON-round-tripped snapshot."""
    box = {}

    def checkpoint_fn(t, ctx):
        box["payload"] = {"tuner_state": t.state_dict(), "ctx": ctx.snapshot()}
        if ctx.round_idx >= stop_round:
            raise TuneInterrupted("test")

    t1 = tuner_cls(space, cost, seed=7, **kw)
    with pytest.raises(TuneInterrupted):
        t1.tune(Budget(max_trials=n_trials), checkpoint_fn=checkpoint_fn)
    payload = json.loads(json.dumps(box["payload"]))
    t2 = tuner_cls(space, cost, seed=7, **kw)
    return t2.tune(Budget(max_trials=n_trials), restore=payload)


def _assert_equivalent(ref, res):
    assert [t.state.key() for t in res.trials] == [
        t.state.key() for t in ref.trials
    ]
    assert [t.cost for t in res.trials] == [t.cost for t in ref.trials]
    assert res.best_state.key() == ref.best_state.key()
    assert res.best_cost == ref.best_cost
    assert res.clock_s == ref.clock_s


@pytest.mark.parametrize("tuner_cls", RESUMABLE_FAST, ids=lambda c: c.name)
def test_interrupted_resume_is_bit_identical(space, tuner_cls):
    cost = AnalyticalTPUCost(space)
    kw = TUNER_KW.get(tuner_cls, {})
    ref = _reference(tuner_cls, space, cost, 24, **kw)
    res = _interrupt_then_resume(tuner_cls, space, cost, 24, stop_round=2, **kw)
    _assert_equivalent(ref, res)


@pytest.mark.parametrize("stop_round", [1, 2, 3])
def test_resume_equivalence_at_any_cut(space, stop_round):
    cost = AnalyticalTPUCost(space)
    ref = _reference(GBFSTuner, space, cost, 40)
    res = _interrupt_then_resume(
        GBFSTuner, space, cost, 40, stop_round=stop_round
    )
    _assert_equivalent(ref, res)


@pytest.mark.slow
@pytest.mark.parametrize(
    "tuner_cls", [NA2CTuner, RNNControllerTuner], ids=lambda c: c.name
)
def test_learned_tuner_resume_is_bit_identical(space, tuner_cls):
    """The learned tuners carry network weights + optimizer state through
    the snapshot (tree codec) — resume must continue the same trajectory."""
    cost = AnalyticalTPUCost(space)
    kw = {"batch_size": 4}  # several rounds inside the trial budget
    ref = _reference(tuner_cls, space, cost, 16, **kw)
    res = _interrupt_then_resume(tuner_cls, space, cost, 16, stop_round=2, **kw)
    _assert_equivalent(ref, res)


# -- session-level: done snapshots, fresh-run clearing ------------------------


def _session(tmp_path, cost):
    return TuningSession(
        TuningRecords(str(tmp_path / "records.json")),
        cost_factory=lambda space: cost,
        verbose=False,
        journal=TrialJournal(str(tmp_path / "journal.jsonl")),
    )


def test_done_snapshot_serves_finished_workload(space, tmp_path):
    wl = Workload("gemm", (256, 256, 256))
    cost = CountingCost(AnalyticalTPUCost(space))
    ck = TuneCheckpointer(str(tmp_path / "state"))
    sess = _session(tmp_path, cost)
    res = sess.tune_workload(wl, "g-bfs", Budget(max_trials=10),
                             checkpointer=ck)
    n_after_run = cost.n_measured
    assert n_after_run > 0
    # resume of a finished workload: served from the done marker — the
    # backend is never touched again
    res2 = sess.tune_workload(wl, "g-bfs", Budget(max_trials=10),
                              checkpointer=ck, resume=True)
    assert cost.n_measured == n_after_run
    assert res2.best_state.key() == res.best_state.key()
    assert res2.best_cost == res.best_cost
    assert res2.n_trials == res.n_trials
    assert [t.state.key() for t in res2.trials] == [
        t.state.key() for t in res.trials
    ]


def test_fresh_run_clears_stale_done_marker(space, tmp_path):
    wl = Workload("gemm", (256, 256, 256))
    cost = CountingCost(AnalyticalTPUCost(space))
    ck = TuneCheckpointer(str(tmp_path / "state"))
    sess = _session(tmp_path, cost)
    sess.tune_workload(wl, "g-bfs", Budget(max_trials=6), checkpointer=ck)
    # a NON-resume run must re-tune and drop the old done marker...
    n0 = cost.n_measured
    sess.tune_workload(wl, "g-bfs", Budget(max_trials=6), checkpointer=ck)
    assert cost.n_measured == n0  # (journal serves the repeats: no new calls)
    wkey = wl.key(cost.name)
    payload = ck.load(wkey, "g-bfs")
    assert payload is not None and payload.get("done")  # the NEW marker


# -- CLI kill-and-resume (the satellite: SIGTERM mid-search, --resume,
# identical visited sequence and best) ---------------------------------------


def _tune_cmd(tmp, extra):
    return [
        sys.executable, "-m", "repro.launch.tune",
        "--op", "flash", "--fraction", "0.5", "--max-trials", "30",
        "--workers", "1", "--seed", "3", "--measure-delay", "0.08",
        "--records", str(tmp / "records.json"),
        *extra,
    ]


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ["src", env.get("PYTHONPATH", "")] if p
    )
    return env


def _journal_keys(path):
    return [json.loads(l)["k"] for l in open(path)]


@pytest.mark.slow
@pytest.mark.parametrize("tuner", ["g-bfs", "random", "genetic"])
def test_cli_sigterm_resume_matches_uninterrupted(tuner, tmp_path):
    env = _env()
    # reference: uninterrupted run in its own directory
    ref_dir = tmp_path / "ref"
    ref_dir.mkdir()
    r = subprocess.run(_tune_cmd(ref_dir, ["--tuner", tuner]),
                       env=env, capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr
    ref_keys = _journal_keys(str(ref_dir / "records.json.journal.jsonl"))
    ref_recs = json.load(open(ref_dir / "records.json"))

    # interrupted run: SIGTERM lands mid-search (the --measure-delay
    # window), the process flushes a snapshot and exits 130
    run_dir = tmp_path / "run"
    run_dir.mkdir()
    p = subprocess.Popen(_tune_cmd(run_dir, ["--tuner", tuner]), env=env,
                         stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                         text=True)
    jpath = str(run_dir / "records.json.journal.jsonl")
    deadline = time.monotonic() + 120
    # wait until some measurements landed so the kill interrupts a search
    # in progress rather than start-up
    while time.monotonic() < deadline:
        if os.path.exists(jpath) and len(_journal_keys(jpath)) >= 3:
            break
        if p.poll() is not None:
            pytest.fail(f"tune exited early: {p.communicate()[1]}")
        time.sleep(0.05)
    p.send_signal(signal.SIGTERM)
    out, err = p.communicate(timeout=120)
    assert p.returncode == 130, (out, err)
    assert "rerun with --resume" in out
    interrupted_keys = _journal_keys(jpath)
    assert 0 < len(interrupted_keys) < len(ref_keys)

    # resume: finishes the search; the combined journal replays the
    # reference's visited sequence exactly and the record matches
    r2 = subprocess.run(_tune_cmd(run_dir, ["--tuner", tuner, "--resume"]),
                        env=env, capture_output=True, text=True, timeout=300)
    assert r2.returncode == 0, r2.stderr
    assert _journal_keys(jpath) == ref_keys
    recs = json.load(open(run_dir / "records.json"))
    assert sorted(recs) == sorted(ref_recs)
    for key in recs:
        assert recs[key]["cost"] == ref_recs[key]["cost"]
        assert recs[key]["state"] == ref_recs[key]["state"]

    # resuming the finished run is a no-op served from the done marker
    r3 = subprocess.run(_tune_cmd(run_dir, ["--tuner", tuner, "--resume"]),
                        env=env, capture_output=True, text=True, timeout=300)
    assert r3.returncode == 0, r3.stderr
    assert "already complete" in r3.stdout
    assert _journal_keys(jpath) == ref_keys  # no new measurements


@pytest.mark.slow
def test_cli_resume_without_snapshot_is_a_fresh_run(tmp_path):
    env = _env()
    d = tmp_path / "fresh"
    d.mkdir()
    r = subprocess.run(
        _tune_cmd(d, ["--tuner", "random", "--resume"]),
        env=env, capture_output=True, text=True, timeout=300,
    )
    assert r.returncode == 0, r.stderr
    assert json.load(open(d / "records.json"))


# -- learned-filter resume parity (the satellite bugfix: ProposalFilter
# cadence + model provenance now live in the snapshot) ------------------------


def _seed_filter_corpus(space, cost, jpath, n=16):
    """Journal enough *cost-diverse* measured rows (same workload and
    fingerprint scope) that a ProposalFilter trains on its first cadence
    check — the rank model needs unequal costs to form training pairs,
    so the states are random rather than the first of the enumeration."""
    import random

    from repro.core import MeasureEngine, workload_key

    rng = random.Random(123)
    wkey = workload_key(space.m, space.k, space.n, "bfloat16", cost.name)
    journal = TrialJournal(jpath)
    eng = MeasureEngine(cost, n_workers=4, journal=journal, workload_key=wkey)
    stream, keys = [], set()
    while len(stream) < n:
        s = space.random_state(rng)
        if s.key() not in keys:
            keys.add(s.key())
            stream.append(s)
    for i in range(0, n, 4):
        eng.measure_wave(stream[i:i + 4])
    journal.close()
    return wkey


def _filtered_engine(space, cost, jpath):
    """Engine with an aggressive ProposalFilter (short cadence, tiny
    corpus floor) over the journal at ``jpath``; n_workers=4 so waves
    carry >= 2 misses and the filter can actually skip."""
    from repro.core import MeasureEngine, ProposalFilter, workload_key

    wkey = workload_key(space.m, space.k, space.n, "bfloat16", cost.name)
    journal = TrialJournal(jpath)
    flt = ProposalFilter(
        space, journal, dtype="bfloat16",
        fingerprint=cost.measure_fingerprint(),
        keep=0.5, retrain_every=2, min_rows=8,
    )
    return MeasureEngine(cost, n_workers=4, journal=journal,
                         workload_key=wkey, learned_filter=flt)


def test_filter_state_dict_round_trip(space, tmp_path):
    cost = AnalyticalTPUCost(space)
    jpath = str(tmp_path / "j.jsonl")
    _seed_filter_corpus(space, cost, jpath)
    eng = _filtered_engine(space, cost, jpath)
    flt = eng.learned_filter
    flt.maybe_retrain()
    assert flt.active  # trained from the seeded corpus
    snap = json.loads(json.dumps(flt.state_dict()))
    assert snap["model_key"] == flt.model.content_key()
    assert snap["waves_since_check"] == 0
    # a fresh filter restored from the snapshot resumes the exact cadence
    # and reloads the exact persisted model
    eng2 = _filtered_engine(space, cost, jpath)
    flt2 = eng2.learned_filter
    flt2.load_state_dict(snap)
    assert flt2._waves_since_check == flt._waves_since_check
    assert flt2._rows_at_fit == flt._rows_at_fit
    assert flt2.n_retrains == flt.n_retrains
    assert flt2.model is not None
    assert flt2.model.content_key() == flt.model.content_key()
    # model_key None -> filtering off, exactly as snapshotted
    flt2.load_state_dict({"waves_since_check": None, "rows_at_fit": 0,
                          "n_retrains": 0, "model_key": None})
    assert flt2.model is None and flt2._waves_since_check is None


@pytest.mark.parametrize("stop_round", [2, 4])
def test_filtered_resume_is_bit_identical(space, tmp_path, stop_round):
    """Interrupt-and-resume with an ACTIVE ProposalFilter replays the
    identical trial/skip sequence: the snapshot carries the filter's
    retrain cadence and model provenance (without them the resumed run
    re-checks the cadence immediately and skips different candidates)."""
    import shutil

    cost = AnalyticalTPUCost(space)
    ref_j = str(tmp_path / "ref.jsonl")
    _seed_filter_corpus(space, cost, ref_j)
    run_j = str(tmp_path / "run.jsonl")
    shutil.copy(ref_j, run_j)  # identical corpus, independent journals

    def tune(jpath, checkpoint_fn=None, restore=None):
        eng = _filtered_engine(space, cost, jpath)
        t = GBFSTuner(space, cost, seed=7)
        try:
            return t.tune(Budget(max_trials=32), engine=eng,
                          checkpoint_fn=checkpoint_fn, restore=restore)
        finally:
            eng.journal.close()

    ref = tune(ref_j)
    assert any(t.cost == float("inf") for t in ref.trials), \
        "filter never skipped — the parity test is vacuous"

    box = {}

    def checkpoint_fn(t, ctx):
        box["payload"] = {"tuner_state": t.state_dict(),
                          "ctx": ctx.snapshot()}
        if ctx.round_idx >= stop_round:
            raise TuneInterrupted("test")

    with pytest.raises(TuneInterrupted):
        tune(run_j, checkpoint_fn=checkpoint_fn)
    payload = json.loads(json.dumps(box["payload"]))
    assert "filter" in payload["ctx"]  # the filter half of the snapshot
    res = tune(run_j, restore=payload)
    _assert_equivalent(ref, res)
    # the journals agree row for row — including the {"c": null, "pred"}
    # skip provenance, i.e. the filter skipped the same candidates
    assert _journal_keys(run_j) == _journal_keys(ref_j)


@pytest.mark.slow
def test_cli_sigterm_resume_with_learned_filter_matches(tmp_path):
    """End-to-end satellite acceptance: tune --learned-filter on, SIGTERM
    mid-search, --resume replays the reference's journal sequence (trials
    AND learned skips) and lands the same records."""
    env = _env()
    flags = ["--tuner", "g-bfs", "--workers", "4", "--learned-filter", "on",
             "--filter-min-rows", "8", "--filter-retrain-every", "2"]
    ref_dir = tmp_path / "ref"
    ref_dir.mkdir()
    r = subprocess.run(_tune_cmd(ref_dir, flags), env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr
    ref_jpath = str(ref_dir / "records.json.journal.jsonl")
    ref_keys = _journal_keys(ref_jpath)
    # the filter actually skipped something, else this test proves nothing
    assert any(
        "pred" in json.loads(l) for l in open(ref_jpath)
    ), "no learned skips in the reference run"
    ref_recs = json.load(open(ref_dir / "records.json"))

    run_dir = tmp_path / "run"
    run_dir.mkdir()
    p = subprocess.Popen(_tune_cmd(run_dir, flags), env=env,
                         stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                         text=True)
    jpath = str(run_dir / "records.json.journal.jsonl")
    deadline = time.monotonic() + 120
    while time.monotonic() < deadline:
        if os.path.exists(jpath) and len(_journal_keys(jpath)) >= 3:
            break
        if p.poll() is not None:
            pytest.fail(f"tune exited early: {p.communicate()[1]}")
        time.sleep(0.05)
    p.send_signal(signal.SIGTERM)
    out, err = p.communicate(timeout=120)
    assert p.returncode == 130, (out, err)
    assert 0 < len(_journal_keys(jpath)) < len(ref_keys)

    r2 = subprocess.run(_tune_cmd(run_dir, flags + ["--resume"]), env=env,
                        capture_output=True, text=True, timeout=300)
    assert r2.returncode == 0, r2.stderr
    assert _journal_keys(jpath) == ref_keys
    recs = json.load(open(run_dir / "records.json"))
    assert sorted(recs) == sorted(ref_recs)
    for key in recs:
        assert recs[key]["cost"] == ref_recs[key]["cost"]
        assert recs[key]["state"] == ref_recs[key]["state"]

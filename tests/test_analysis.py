"""Static schedule analyzer (repro.core.analysis): verdict lattice,
single-source VMEM budget, engine pre-filter, dispatch guard, and the
audit CLI.  Deterministic variants; the hypothesis property suite lives
in ``test_analysis_properties.py``."""

import itertools
import json
import math
import os
import sys

import pytest

from repro.core import (
    AnalyticalTPUCost,
    CountingCost,
    FlashAttnConfigSpace,
    GemmConfigSpace,
    MeasureEngine,
    MeasureStats,
    TilingState,
    TrialJournal,
    TuningRecords,
    workload_key,
    workload_key_for,
)
from repro.core.analysis import (
    ILLEGAL,
    OK,
    WASTEFUL,
    AnalysisResult,
    ScheduleAnalyzer,
    analyzer_for_backend,
    dtype_in_bytes,
    flash_working_set_bytes,
    gemm_working_set_bytes,
    should_prune,
)
from repro.core.cost.flash_analytical import FlashAnalyticalCost
from repro.core.flash_space import FlashScheduleState

REPO = os.path.join(os.path.dirname(__file__), "..")


# -- verdict lattice ----------------------------------------------------------


def test_enumerated_states_never_illegal_gemm(small_space):
    an = ScheduleAnalyzer(small_space)
    for s in itertools.islice(small_space.enumerate(), 300):
        res = an.analyze(s)
        assert not res.illegal, (s, res)


def test_enumerated_states_never_illegal_flash():
    space = FlashAttnConfigSpace(256, 256, 64)
    an = ScheduleAnalyzer(space)
    for s in space.enumerate():
        res = an.analyze(s)
        assert not res.illegal, (s, res)


def test_structural_illegal_reasons(small_space):
    an = ScheduleAnalyzer(small_space)
    cases = [
        (TilingState((64, 1, 1, 1), (64, 1), (64, 1, 1)), "row_depth"),
        (TilingState((64, 1, 1, 1), (64, 0), (64, 1, 1, 1)), "factor_nonpositive"),
        (TilingState((64, 1, 1, 1), (64, 2), (64, 1, 1, 1)), "product_mismatch"),
        # block larger than the dim is a product mismatch too
        (TilingState((1, 128, 1, 1), (64, 1), (64, 1, 1, 1)), "product_mismatch"),
    ]
    for st, reason in cases:
        res = an.analyze(st)
        assert res.verdict == ILLEGAL and res.reason == reason, (st, res)
    # wrong row count arrives via a foreign state type
    res = an.analyze(FlashScheduleState((64, 1), (64, 1)))
    assert res.illegal and res.reason == "row_count"
    # garbage factors are malformed, never an uncaught exception
    res = an.analyze(TilingState(("a", "b", "c", "d"), (64, 1), (64, 1, 1, 1)))
    assert res.illegal and res.reason == "malformed"


def test_vmem_overflow_gemm():
    space = GemmConfigSpace(4096, 4096, 4096)
    an = ScheduleAnalyzer(space)
    huge = TilingState((1, 4096, 1, 1), (1, 4096), (1, 4096, 1, 1))
    res = an.analyze(huge)
    assert res.verdict == ILLEGAL and res.reason == "vmem_overflow"
    assert an.exceeds_vmem(huge)
    # the oracle's cliff is the same function
    assert AnalyticalTPUCost(space).cost(huge) == math.inf


def test_vmem_overflow_flash_huge_seq():
    # K/V residency means every schedule of this workload is over budget
    space = FlashAttnConfigSpace(32768, 32768, 128)
    an = ScheduleAnalyzer(space)
    cost = FlashAnalyticalCost(space)
    for s in itertools.islice(space.enumerate(), 20):
        res = an.analyze(s)
        assert res.verdict == ILLEGAL and res.reason == "vmem_overflow", (s, res)
        assert cost.cost(s) == math.inf


def test_degenerate_and_padding_verdicts(paper_space):
    an = ScheduleAnalyzer(paper_space)
    s0 = paper_space.initial_state()  # untiled: sub_m == block_k == sub_n == 1
    res = an.analyze(s0)
    assert res.verdict == WASTEFUL and res.reason == "degenerate"
    assert should_prune(res)
    # lane-aligned sub_n but no k/m tiling: heavy padding, not degenerate
    s = TilingState((1024, 1, 1, 1), (1024, 1), (8, 1, 8, 16))
    res = an.analyze(s)
    assert res.verdict == WASTEFUL and res.reason == "padding"
    assert not should_prune(res)
    # a well-tiled state is OK
    good = TilingState((8, 8, 4, 4), (8, 128), (8, 8, 4, 4))
    assert an.analyze(good).verdict == OK


def test_under_buffer_verdict(paper_space):
    # disable the padding checks to expose the floor (gemm states under
    # the floor otherwise classify as padding first)
    an = ScheduleAnalyzer(paper_space, wasteful_padding_ratio=math.inf)
    s = TilingState((512, 1, 2, 1), (1024, 1), (1024, 1, 1, 1))
    res = an.analyze(s)
    assert res.verdict == WASTEFUL and res.reason == "under_buffer"
    assert an.vmem_bytes(s) < an.buffer_floor_bytes


def test_should_prune_policy():
    assert should_prune(AnalysisResult(ILLEGAL, "vmem_overflow"))
    assert should_prune(AnalysisResult(WASTEFUL, "degenerate"))
    assert not should_prune(AnalysisResult(WASTEFUL, "padding"))
    assert not should_prune(AnalysisResult(WASTEFUL, "under_buffer"))
    assert not should_prune(AnalysisResult(OK))


# -- single-source VMEM budget ------------------------------------------------


def test_budget_single_source_gemm(small_space):
    cost = AnalyticalTPUCost(small_space)
    for s in itertools.islice(small_space.enumerate(), 50):
        ws = gemm_working_set_bytes(s.block_m, s.block_k, s.block_n, 2)
        assert small_space.working_set_bytes(s, 2) == ws
        assert cost.vmem_bytes(s) == ws
        assert cost.analyzer.vmem_bytes(s) == ws


def test_budget_single_source_flash():
    space = FlashAttnConfigSpace(256, 256, 64)
    cost = FlashAnalyticalCost(space)
    for s in itertools.islice(space.enumerate(), 50):
        ws = flash_working_set_bytes(s.block_q, s.block_kv, 256, 64, 2)
        assert space.working_set_bytes(s, 2) == ws
        assert cost.vmem_bytes(s) == ws
        assert cost.analyzer.vmem_bytes(s) == ws


def test_batch_cost_matches_scalar_with_shared_budget(paper_space):
    """The vectorized gemm batch path uses the same budget function —
    bit-identical to the scalar path, including the inf cliff."""
    cost = AnalyticalTPUCost(paper_space, noise_sigma=0.1, seed=3)
    states = list(itertools.islice(paper_space.enumerate(), 64))
    batch = cost.batch_cost(states)
    for s, b in zip(states, batch):
        assert cost.cost(s) == b


def test_analyzer_for_backend_reads_measurement_settings(small_space):
    cost = AnalyticalTPUCost(small_space, in_bytes=4)
    an = analyzer_for_backend(cost)
    assert an.in_bytes == 4
    assert an.spec is cost.spec
    assert dtype_in_bytes("float32") == 4
    assert dtype_in_bytes("bfloat16") == 2
    assert dtype_in_bytes(None) == 2
    assert dtype_in_bytes("who_knows") == 2


# -- measurement-engine pre-filter --------------------------------------------


def _engine(space, analyze, **kw):
    cc = CountingCost(AnalyticalTPUCost(space))
    return cc, MeasureEngine(cc, n_workers=8, analyze=analyze, **kw)


def test_engine_rejects_bad_analyze_mode(small_space):
    with pytest.raises(ValueError, match="analyze"):
        _engine(small_space, "aggressive")


def test_engine_prune_avoids_trials(small_space):
    cc, eng = _engine(small_space, "prune")
    s0 = small_space.initial_state()  # degenerate -> prunable
    states = [s0] + list(itertools.islice(small_space.enumerate(), 3))
    outs = eng.measure_wave(states)
    assert len(outs) == len(states)
    by_key = {o.state.key(): o for o in outs}
    pruned = by_key[s0.key()]
    assert pruned.cost == math.inf and pruned.static == "degenerate"
    assert pruned.lane_s == 0.0 and not pruned.cache_hit
    assert eng.stats.trials_avoided == 1
    assert eng.stats.n_cache_hits == 0
    assert eng.stats.n_dispatched == len(states) - 1
    assert cc.n_measured == len(states) - 1  # never reached the backend
    assert eng.stats.static_s > 0.0


def test_engine_warn_measures_everything(small_space):
    cc, eng = _engine(small_space, "warn")
    s0 = small_space.initial_state()
    states = [s0] + list(itertools.islice(small_space.enumerate(), 3))
    outs = eng.measure_wave(states)
    assert all(o.static is None for o in outs)
    assert eng.stats.trials_avoided == 0
    assert eng.stats.n_static_flags >= 1  # s0 flagged, still measured
    assert cc.n_measured == len(states)


def test_engine_off_never_touches_analyzer(small_space):
    cc, eng = _engine(small_space, "off")
    states = [small_space.initial_state()]
    eng.measure_wave(states)
    assert eng.stats.trials_avoided == 0 and eng.stats.static_s == 0.0
    assert eng._analyzer is None  # lazily built only when consulted
    assert cc.n_measured == 1


def test_engine_prune_journals_static_rows(small_space, tmp_path):
    jpath = str(tmp_path / "j.jsonl")
    wkey = workload_key(64, 64, 64)
    s0 = small_space.initial_state()
    states = [s0] + list(itertools.islice(small_space.enumerate(), 2))
    with TrialJournal(jpath) as j:
        cc = CountingCost(AnalyticalTPUCost(small_space))
        eng = MeasureEngine(cc, n_workers=8, journal=j, workload_key=wkey,
                            analyze="prune")
        eng.measure_wave(states)
        eng.measure_wave(states)  # dedup: no duplicate static row
    rows = [json.loads(line) for line in open(jpath)]
    static_rows = [r for r in rows if "static" in r]
    assert len(static_rows) == 1
    assert static_rows[0]["k"] == s0.key()
    assert static_rows[0]["c"] is None
    assert static_rows[0]["static"] == "degenerate"
    # a fresh journal skips static rows from its cost table...
    with TrialJournal(jpath) as j2:
        assert len(j2) == len(states) - 1
        fp = f"{wkey}?{cc.measure_fingerprint()}"
        assert j2.get(fp, s0.key()) is None
        # ...so an analyze=off engine re-measures the pruned state
        cc2 = CountingCost(AnalyticalTPUCost(small_space))
        eng2 = MeasureEngine(cc2, n_workers=8, journal=j2, workload_key=wkey)
        outs = [o for o in eng2.measure_wave(states) if o.state.key() == s0.key()]
        assert not outs[0].cache_hit and math.isfinite(outs[0].cost)


def test_verdicts_memoized_and_repeatable(small_space):
    an = ScheduleAnalyzer(small_space)
    an2 = ScheduleAnalyzer(small_space)
    for s in itertools.islice(small_space.enumerate(), 40):
        r1 = an.analyze(s)
        assert an.analyze(s) is r1  # memoized per key
        assert an2.analyze(s) == r1  # equal analyzers agree


# -- trace-time dispatch guard ------------------------------------------------


@pytest.fixture
def clean_dispatch():
    from repro.core.records import set_global_records
    from repro.kernels import ops as kops

    kops.reset_dispatch_stats()
    kops.invalidate_dispatch_cache()
    yield kops
    set_global_records(TuningRecords())
    kops.reset_dispatch_stats()


def test_dispatch_refuses_illegal_record(clean_dispatch, tmp_path):
    from repro.core.records import set_global_records

    kops = clean_dispatch
    recs = TuningRecords(str(tmp_path / "r.json"))
    key = workload_key_for("gemm", (64, 64, 64), "bfloat16",
                           kops.kernel_policy().cost_backend)
    # a stale record: factor products say 128, the workload says 64
    stale = TilingState((1, 128, 1, 1), (1, 128), (1, 128, 1, 1))
    recs.update(key, stale, 1e-6, "g-bfs", 10)
    set_global_records(recs)
    assert kops.lookup_tuned_state("gemm", (64, 64, 64), "bfloat16") is None
    assert kops.dispatch_stats()["gemm"]["static_reject"] == 1
    # the refusal is memoized: a second lookup is a memo hit, not a re-audit
    assert kops.lookup_tuned_state("gemm", (64, 64, 64), "bfloat16") is None
    assert kops.dispatch_stats()["gemm"]["static_reject"] == 1


def test_dispatch_serves_legal_record(clean_dispatch, tmp_path):
    from repro.core.records import set_global_records

    kops = clean_dispatch
    recs = TuningRecords(str(tmp_path / "r.json"))
    key = workload_key_for("gemm", (64, 64, 64), "bfloat16",
                           kops.kernel_policy().cost_backend)
    good = TilingState((4, 2, 2, 4), (1, 64), (4, 2, 2, 4))
    recs.update(key, good, 1e-6, "g-bfs", 10)
    set_global_records(recs)
    st = kops.lookup_tuned_state("gemm", (64, 64, 64), "bfloat16")
    assert st == good
    assert kops.dispatch_stats()["gemm"].get("static_reject", 0) == 0


# -- audit CLI ----------------------------------------------------------------


def _write_records(path, entries):
    with open(path, "w") as f:
        json.dump(entries, f)


def test_analyze_cli_passes_good_store(tmp_path):
    from repro.launch.analyze import main

    path = str(tmp_path / "good.json")
    key = workload_key(1024, 1024, 1024)
    _write_records(path, {
        key: {"op": "gemm",
              "state": [[8, 8, 4, 4], [8, 128], [8, 8, 4, 4]],
              "cost": 1e-4},
    })
    assert main(["--records", path]) == 0


def test_analyze_cli_fails_on_over_vmem_record(tmp_path):
    from repro.launch.analyze import main

    path = str(tmp_path / "bad.json")
    key = workload_key(8192, 8192, 8192)
    # hand-corrupted: legitimate factorization whose working set is ~1 GiB
    _write_records(path, {
        key: {"op": "gemm",
              "state": [[1, 8192, 1, 1], [1, 8192], [1, 8192, 1, 1]],
              "cost": 1e-4},
    })
    assert main(["--records", path]) == 1


def test_analyze_cli_fails_on_stale_and_cross_op_records(tmp_path):
    from repro.launch.analyze import main

    stale = str(tmp_path / "stale.json")
    _write_records(stale, {
        workload_key(1024, 1024, 1024): {
            "op": "gemm",
            "state": [[1, 2048, 1, 1], [1, 2048], [1, 2048, 1, 1]],
            "cost": 1e-4},
    })
    assert main(["--records", stale]) == 1
    crossed = str(tmp_path / "crossed.json")
    _write_records(crossed, {
        workload_key(1024, 1024, 1024): {
            "op": "flash",
            "state": [[8, 128], [8, 128]],
            "cost": 1e-4},
    })
    assert main(["--records", crossed]) == 1


def test_analyze_cli_journal_finite_cost_for_illegal(tmp_path):
    from repro.launch.analyze import main

    jpath = str(tmp_path / "j.jsonl")
    key = workload_key(8192, 8192, 8192)
    lists = [[1, 8192, 1, 1], [1, 8192], [1, 8192, 1, 1]]
    skey = "1,8192,1,1|1,8192|1,8192,1,1"
    with open(jpath, "w") as f:
        # an inf row for an illegal schedule is consistent (fine)...
        f.write(json.dumps({"w": key + "?r1", "k": skey, "s": lists,
                            "op": "gemm", "c": None, "fail": True}) + "\n")
    assert main(["--journal", jpath]) == 0
    with open(jpath, "a") as f:
        # ...a finite one contradicts every backend's VMEM guard
        f.write(json.dumps({"w": key + "?r1", "k": skey, "s": lists,
                            "op": "gemm", "c": 0.001}) + "\n")
    assert main(["--journal", jpath]) == 1


def test_analyze_cli_counts_static_rows_as_clean(tmp_path, small_space):
    from repro.launch.analyze import main

    jpath = str(tmp_path / "j.jsonl")
    wkey = workload_key(64, 64, 64)
    with TrialJournal(jpath) as j:
        eng = MeasureEngine(
            CountingCost(AnalyticalTPUCost(small_space)), n_workers=4,
            journal=j, workload_key=wkey, analyze="prune",
        )
        eng.measure_wave(
            [small_space.initial_state()]
            + list(itertools.islice(small_space.enumerate(), 2))
        )
    assert main(["--journal", jpath]) == 0


def test_analyze_cli_nothing_to_audit(tmp_path, monkeypatch):
    from repro.launch.analyze import main

    monkeypatch.chdir(tmp_path)
    assert main([]) == 0


def test_tune_cli_unknown_op_errors(monkeypatch, capsys):
    from repro.launch import tune

    monkeypatch.setattr(sys, "argv", ["tune", "--op", "conv9000"])
    with pytest.raises(SystemExit) as exc:
        tune.main()
    assert exc.value.code == 2
    err = capsys.readouterr().err
    assert "operator registry" in err and "gemm" in err


# -- interpret-mode agreement -------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("op,dims", [("gemm", (64, 64, 64)),
                                     ("flash", (128, 128, 64))])
def test_verdicts_agree_with_pallas_interpret_compile(op, dims):
    """Non-ILLEGAL enumerated states compile and run under Pallas
    interpret mode; a structurally broken state does not."""
    jax = pytest.importorskip("jax")
    from repro.core import get_op

    spec = get_op(op)
    space = spec.make_space(dims)
    an = ScheduleAnalyzer(space)
    operands = spec.timed_operands(space, "float32", seed=0)
    states = list(itertools.islice(space.enumerate(), 3))
    for s in states:
        assert not an.analyze(s).illegal
        out = spec.pallas_run(space, s, operands, interpret=True)
        assert all(
            bool(jax.numpy.isfinite(x).all()) for x in jax.tree.leaves(out)
        )
    # corrupt a block factor: product mismatch -> ILLEGAL, and Pallas
    # agrees (the 0.75x block no longer divides the real operands; a
    # *doubled* block would be silently clamped by the flash kernel)
    rows = states[0].as_lists()
    rows[0][-1] = rows[0][-1] // 4 * 3
    bad = space.state_from_rows(rows)
    assert an.analyze(bad).illegal
    with pytest.raises(Exception):
        spec.pallas_run(space, bad, operands, interpret=True)


# -- search neutrality (the fig7 protocol in miniature) -----------------------


def test_gbfs_prune_reaches_equal_best(paper_space):
    """``--analyze prune`` on the paper's 1024^3 G-BFS protocol: same
    final best as unfiltered, with trials actually avoided."""
    sys.path.insert(0, os.path.abspath(REPO))
    from benchmarks.common import run_tuner
    from repro.core import Budget

    budget = Budget(max_fraction=0.0002)
    res_off, final_off = run_tuner(paper_space, "g-bfs", budget, seed=0)
    stats = MeasureStats()
    res_pr, final_pr = run_tuner(paper_space, "g-bfs", budget, seed=0,
                                 analyze="prune", stats=stats)
    assert final_pr == final_off
    assert res_pr.n_trials == res_off.n_trials  # pruned trials still charged
    assert stats.trials_avoided > 0

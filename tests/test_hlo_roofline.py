"""HLO collective parser + roofline math unit tests."""

import numpy as np

from repro.utils.hlo import collective_stats, parse_shape_bytes
from repro.utils.roofline import model_flops, roofline_from_costs


def test_parse_shape_bytes():
    assert parse_shape_bytes("f32[4,8]") == 128
    assert parse_shape_bytes("bf16[1024]") == 2048
    assert parse_shape_bytes("(f32[2,2], bf16[4])") == 16 + 8
    assert parse_shape_bytes("pred[]") == 1
    assert parse_shape_bytes("f32[16,256,4096]{2,0,1}") == 16 * 256 * 4096 * 4


SYNTHETIC_HLO = """
HloModule test
ENTRY %main {
  %p0 = f32[128,64]{1,0} parameter(0)
  %p1 = f32[64,64]{1,0} parameter(1)
  %ag = f32[512,64]{1,0} all-gather(%p0), replica_groups=[4,4]<=[16], dimensions={0}
  %ar = f32[64,64]{1,0} all-reduce(%p1), replica_groups=[2,8]<=[16], to_apply=%add
  %rs = f32[32,64]{1,0} reduce-scatter(%p1), replica_groups=[2,8]<=[16], dimensions={0}
  %cp = f32[128,64]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  ROOT %out = f32[128,64]{1,0} add(%cp, %cp)
}
"""


def test_collective_stats_synthetic():
    st = collective_stats(SYNTHETIC_HLO, tpu_equivalence=False)
    assert st["all-gather"]["count"] == 1
    assert st["all-gather"]["operand_bytes"] == 128 * 64 * 4
    assert st["all-gather"]["result_bytes"] == 512 * 64 * 4
    assert st["all-reduce"]["operand_bytes"] == 64 * 64 * 4
    assert st["reduce-scatter"]["count"] == 1
    assert st["collective-permute"]["operand_bytes"] == 128 * 64 * 4
    assert st["total_operand_bytes"] == (128 * 64 + 64 * 64 + 64 * 64 + 128 * 64) * 4


PROMOTED_HLO = """
HloModule test2
ENTRY %main {
  %p1 = f32[64,64]{1,0} parameter(0)
  %ar = f32[64,64]{1,0} all-reduce(%p1), replica_groups=[2,8]<=[16], to_apply=%add.clone_promoted
  %ds = f32[8,64]{1,0} dynamic-slice(%ar, %c0, %c1), dynamic_slice_sizes={8,64}
  ROOT %out = f32[8,64]{1,0} add(%ds, %ds)
}
"""


def test_tpu_equivalence_corrections():
    raw = collective_stats(PROMOTED_HLO, tpu_equivalence=False)
    assert raw["all-reduce"]["operand_bytes"] == 64 * 64 * 4
    fixed = collective_stats(PROMOTED_HLO, tpu_equivalence=True)
    # promoted f32 payload halved back to bf16 AND AR+slice -> RS (/8)
    assert "all-reduce" not in fixed
    assert fixed["reduce-scatter"]["operand_bytes"] == 64 * 64 * 4 // 2 // 8


def test_roofline_terms_and_dominance():
    coll = {"all-reduce": {"operand_bytes": 1e9, "count": 1, "result_bytes": 1e9}}
    t = roofline_from_costs(
        flops_per_device=197e12,  # exactly 1 second of compute
        bytes_per_device=819e9 * 2,  # 2 seconds of HBM
        collective=coll,
        chips=256,
        mflops=197e12 * 256 * 0.5,
    )
    np.testing.assert_allclose(t.compute_s, 1.0)
    np.testing.assert_allclose(t.memory_s, 2.0)
    np.testing.assert_allclose(t.collective_s, 2e9 / 50e9)  # ring factor 2
    assert t.dominant == "memory"
    np.testing.assert_allclose(t.useful_ratio, 0.5)


def test_model_flops_kinds():
    from repro.configs.registry import get_arch
    from repro.configs.base import SHAPES

    cfg = get_arch("yi-6b")
    n = cfg.n_active_params()
    t_train = model_flops(cfg, SHAPES["train_4k"])
    assert t_train == 6.0 * n * 4096 * 256
    t_pre = model_flops(cfg, SHAPES["prefill_32k"])
    assert t_pre == 2.0 * n * 32768 * 32
    t_dec = model_flops(cfg, SHAPES["decode_32k"])
    assert t_dec == 2.0 * n * 128


def test_moe_active_params_less_than_total():
    from repro.configs.registry import get_arch

    cfg = get_arch("qwen3-moe-235b-a22b")
    assert cfg.n_active_params() < 0.2 * cfg.n_params()
    dense = get_arch("yi-6b")
    assert dense.n_active_params() == dense.n_params()

"""Serving engine + tune launcher smoke tests."""

import numpy as np

import jax

import pytest

pytest.importorskip(
    "repro.dist", reason="repro.dist subsystem not present in this tree yet"
)

from repro.configs.registry import get_arch
from repro.core.flash_space import FlashScheduleState
from repro.core.records import (
    TuningRecords,
    set_global_records,
    workload_key_for,
)
from repro.kernels.ops import (
    KernelPolicy,
    dispatch_stats,
    flash_schedule,
    kernel_policy,
    reset_dispatch_stats,
    set_kernel_policy,
)
from repro.launch.serve import ServeEngine
from repro.launch.tune import workloads_for_arch
from repro.models.api import Model


@pytest.fixture
def clean_dispatch():
    """Isolate the process-global kernel policy + records the dispatch
    layer consults."""
    saved = kernel_policy()
    yield
    set_kernel_policy(saved)
    set_global_records(TuningRecords())
    reset_dispatch_stats()


def _reduced_model(arch="yi-6b", seed=0):
    cfg = get_arch(arch).reduced()
    model = Model(cfg)
    return cfg, model.init_params(jax.random.PRNGKey(seed))


def test_serve_engine_generates():
    cfg, params = _reduced_model()
    engine = ServeEngine(cfg, params, max_batch=2, max_len=24)
    prompts = np.arange(2 * 8, dtype=np.int32).reshape(2, 8) % cfg.vocab_size
    out = engine.generate(prompts, gen_tokens=4)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
    # greedy decoding is deterministic
    out2 = engine.generate(prompts, gen_tokens=4)
    np.testing.assert_array_equal(out, out2)


def test_tuned_record_drives_flash_dispatch(clean_dispatch):
    """A flash schedule tuned into records changes the blocks the traced
    attention actually uses — the tune→serve loop, observed via the
    trace-time dispatch counters."""
    cfg, params = _reduced_model()
    seq, hd = 128, cfg.resolved_head_dim  # > reduced attn_chunk_threshold
    pol = KernelPolicy(use_pallas=True, interpret=True, pallas_ops=("flash",))

    # no record: the built-in gate (256/512 divisibility) fails at 128,
    # so dispatch falls back to XLA
    set_global_records(TuningRecords())
    set_kernel_policy(pol)
    assert flash_schedule(seq, seq, hd, "float32") is None
    reset_dispatch_stats()
    eng = ServeEngine(cfg, params, max_batch=1, max_len=seq + 4,
                      prompt_buckets=[seq], gen_buckets=[4])
    assert dispatch_stats()["flash"]["xla"] >= 1
    prompts = (np.arange(seq, dtype=np.int32)[None] * 3) % cfg.vocab_size
    base = eng.generate(prompts, gen_tokens=4)

    # tune a record for this workload: the trace now picks up its blocks
    rec = TuningRecords()
    state = FlashScheduleState(q=(4, 32), kv=(2, 64))  # blocks (32, 64)
    rec.update(
        workload_key_for("flash", (seq, seq, hd), "float32",
                         pol.cost_backend),
        state, cost=1.0, tuner="test", n_trials=1,
    )
    set_global_records(rec)
    assert flash_schedule(seq, seq, hd, "float32") == (32, 64)
    reset_dispatch_stats()
    tuned = ServeEngine(cfg, params, max_batch=1, max_len=seq + 4,
                        prompt_buckets=[seq], gen_buckets=[4])
    stats = dispatch_stats()["flash"]
    assert stats["records"] >= 1 and stats["xla"] == 0
    # the tuned kernel is a numerics-equivalent schedule change
    np.testing.assert_array_equal(tuned.generate(prompts, 4), base)


def test_serve_prewarm_zero_compiles_on_restart(tmp_path, clean_dispatch):
    """A restarted ServeEngine over the same persistent cache directory
    rehydrates every bucket executable from disk: zero fresh compiles."""
    cfg, params = _reduced_model()
    mk = lambda: ServeEngine(
        cfg, params, max_batch=2, max_len=40,
        prompt_buckets=[8, 16], gen_buckets=[4],
        cache_dir=str(tmp_path / "aot"),
    )
    cold = mk()
    r = cold.cache_report()
    assert r["compiles"] == 3 and r["disk_hits"] == 0  # 2 prefill + 1 decode
    prompts = np.arange(2 * 8, dtype=np.int32).reshape(2, 8) % cfg.vocab_size
    out_cold = cold.generate(prompts, gen_tokens=4)

    warm = mk()
    r = warm.cache_report()
    assert r["compiles"] == 0 and r["disk_hits"] == 3
    np.testing.assert_array_equal(warm.generate(prompts, 4), out_cold)
    assert warm.cache_report()["compiles"] == 0  # serving stayed warm


def test_bucket_padding_avoids_recompiles(clean_dispatch):
    """Prompt-length jitter inside a bucket never compiles a new
    executable, and padded generation matches the exact-shape run."""
    cfg, params = _reduced_model()
    eng = ServeEngine(cfg, params, max_batch=2, max_len=40,
                      prompt_buckets=[16], gen_buckets=[4])
    assert eng.cache_report()["compiles"] == 2
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32)
    outs = {}
    for n in (5, 9, 13, 16):
        outs[n] = eng.generate(prompts[:, :n], gen_tokens=4)
    assert eng.cache_report()["compiles"] == 2  # no new executables
    assert eng.stats["prefill_buckets"] == {16: 4}

    # padded rows decode bit-identically to their exact-shape runs:
    # per-sequence last_idx logits, pad K/V masking, per-sequence
    # decode positions (see launch/serve.py module doc)
    exact = ServeEngine(cfg, params, max_batch=2, max_len=40)
    for n in (5, 9, 13):
        np.testing.assert_array_equal(
            outs[n], exact.generate(prompts[:, :n], gen_tokens=4)
        )

    # ragged rows ride in one batch via prompt_lens
    rag = np.zeros((2, 16), np.int32)
    rag[0, :5] = prompts[0, :5]
    rag[1, :13] = prompts[1, :13]
    br = eng.generate(rag, gen_tokens=4, prompt_lens=np.array([5, 13]))
    np.testing.assert_array_equal(br[0], outs[5][0])
    np.testing.assert_array_equal(br[1], outs[13][1])
    assert eng.cache_report()["compiles"] == 2


def test_workloads_for_arch_cover_block_gemms():
    wls = workloads_for_arch("qwen2-72b", "train_4k")
    labels = {w.label.split("/")[-1] for w in wls}
    assert {"qkv", "attn_out", "ffn_in", "ffn_out", "lm_head"} <= labels
    for w in wls:
        assert w.m > 0 and w.k > 0 and w.n > 0

    moe_wls = workloads_for_arch("qwen3-moe-235b-a22b", "train_4k")
    moe_labels = {w.label.split("/")[-1] for w in moe_wls}
    assert {"expert_in", "expert_out", "router"} <= moe_labels

    ssm_wls = workloads_for_arch("mamba2-130m", "train_4k")
    ssm_labels = {w.label.split("/")[-1] for w in ssm_wls}
    assert {"ssm_in", "ssm_out"} <= ssm_labels


def test_tune_cli_writes_records(tmp_path):
    import sys

    from repro.launch import tune as tune_mod

    argv = sys.argv
    sys.argv = [
        "tune", "--arch", "whisper-tiny", "--shape", "train_4k",
        "--tuner", "g-bfs", "--max-trials", "40", "--fraction", "1.0",
        "--records", str(tmp_path / "r.json"),
    ]
    try:
        tune_mod.main()
    finally:
        sys.argv = argv
    from repro.core.records import TuningRecords

    rec = TuningRecords(str(tmp_path / "r.json"))
    assert len(rec) >= 3

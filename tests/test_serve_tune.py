"""Serving engine + tune launcher smoke tests."""

import numpy as np

import jax

import pytest

pytest.importorskip(
    "repro.dist", reason="repro.dist subsystem not present in this tree yet"
)

from repro.configs.registry import get_arch
from repro.launch.serve import ServeEngine
from repro.launch.tune import workloads_for_arch
from repro.models.api import Model


def test_serve_engine_generates():
    cfg = get_arch("yi-6b").reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_batch=2, max_len=24)
    prompts = np.arange(2 * 8, dtype=np.int32).reshape(2, 8) % cfg.vocab_size
    out = engine.generate(prompts, gen_tokens=4)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
    # greedy decoding is deterministic
    out2 = engine.generate(prompts, gen_tokens=4)
    np.testing.assert_array_equal(out, out2)


def test_workloads_for_arch_cover_block_gemms():
    wls = workloads_for_arch("qwen2-72b", "train_4k")
    labels = {w.label.split("/")[-1] for w in wls}
    assert {"qkv", "attn_out", "ffn_in", "ffn_out", "lm_head"} <= labels
    for w in wls:
        assert w.m > 0 and w.k > 0 and w.n > 0

    moe_wls = workloads_for_arch("qwen3-moe-235b-a22b", "train_4k")
    moe_labels = {w.label.split("/")[-1] for w in moe_wls}
    assert {"expert_in", "expert_out", "router"} <= moe_labels

    ssm_wls = workloads_for_arch("mamba2-130m", "train_4k")
    ssm_labels = {w.label.split("/")[-1] for w in ssm_wls}
    assert {"ssm_in", "ssm_out"} <= ssm_labels


def test_tune_cli_writes_records(tmp_path):
    import sys

    from repro.launch import tune as tune_mod

    argv = sys.argv
    sys.argv = [
        "tune", "--arch", "whisper-tiny", "--shape", "train_4k",
        "--tuner", "g-bfs", "--max-trials", "40", "--fraction", "1.0",
        "--records", str(tmp_path / "r.json"),
    ]
    try:
        tune_mod.main()
    finally:
        sys.argv = argv
    from repro.core.records import TuningRecords

    rec = TuningRecords(str(tmp_path / "r.json"))
    assert len(rec) >= 3
